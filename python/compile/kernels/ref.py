"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Every public op in :mod:`compile.kernels.matmul` has an oracle here with
the same signature and dtype contract (f32 accumulation, output dtype
matching the kernel). ``python/tests/test_kernel.py`` sweeps shapes and
dtypes with hypothesis and asserts allclose between the two.
"""

import jax
import jax.numpy as jnp


def matmul_nn(a, b):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def matmul_nt(a, b):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32).T,
                   preferred_element_type=jnp.float32)


def matmul_tn(a, b):
    return jnp.dot(a.astype(jnp.float32).T, b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def _act(pre, act):
    if act is None:
        return pre
    if act == "relu6":
        return jnp.clip(pre, 0.0, 6.0)
    if act == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(pre.dtype)
        inner = c * (pre + 0.044715 * pre * pre * pre)
        return 0.5 * pre * (1.0 + jnp.tanh(inner))
    raise ValueError(act)


def _linear(x, w, b=None, r=None, act=None):
    pre = matmul_nn(x, w)
    if b is not None:
        pre = pre + b.astype(jnp.float32)[None, :]
    if r is not None:
        pre = pre + r.astype(jnp.float32)
    return _act(pre, act).astype(x.dtype)


def matmul(x, w):
    return matmul_nn(x, w)


def linear(x, w, b):
    return _linear(x, w, b)


def linear_relu6(x, w, b):
    return _linear(x, w, b, act="relu6")


def linear_gelu(x, w, b):
    return _linear(x, w, b, act="gelu")


def linear_residual(x, w, b, r):
    return _linear(x, w, b, r=r)
