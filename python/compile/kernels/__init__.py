"""Layer-1 Pallas kernels (and their pure-jnp oracle in :mod:`ref`)."""

from .matmul import (  # noqa: F401
    linear,
    linear_gelu,
    linear_relu6,
    linear_residual,
    matmul,
    matmul_nn,
    matmul_nt,
    matmul_tn,
)
