"""Layer-1 Pallas kernels: tiled matmuls with fused epilogues.

The compute hot-spot of FTPipeHD's workload (MobileNetV2-style inverted
residual blocks, adapted to MXU-friendly matmuls — see DESIGN.md
`Hardware adaptation`) is expressed as three raw tiled-matmul kernels:

  * ``matmul_nn`` —  A @ B        (forward GEMM)
  * ``matmul_nt`` —  A @ B.T      (dX = dPre @ W.T, no materialized transpose)
  * ``matmul_tn`` —  A.T @ B      (dW = X.T @ dPre, no materialized transpose)

plus fused ``linear_*`` epilogues (bias add, residual add, ReLU6 / GELU)
applied in VMEM on the final K step, so the activation never round-trips
through HBM. Accumulation is always f32 regardless of the input dtype.

Each public op carries a ``jax.custom_vjp`` whose backward pass is built
from the same Pallas kernels, so both the forward *and* backward HLO that
`aot.py` ships to the Rust runtime run through Layer 1.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin used
by the Rust runtime cannot execute Mosaic custom-calls, and interpret
mode traces the kernel into plain HLO (grid -> fori_loop) with identical
numerics. Block shapes are still chosen as if for a TPU (128-lane
alignment when the problem allows it); see DESIGN.md §7 for the VMEM /
MXU estimates.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile targets. 128 matches the MXU systolic-array edge; the
# helpers below shrink tiles to the largest divisor when a dimension is
# smaller or not a multiple (interpret mode has no hardware constraint,
# but keeping the divisibility invariant keeps the BlockSpecs exact).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _divisor_tile(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target`` (>= 1)."""
    t = min(dim, target)
    while dim % t:
        t -= 1
    return t


def _tiles(m, n, k, bm, bn, bk):
    bm = _divisor_tile(m, bm)
    bn = _divisor_tile(n, bn)
    bk = _divisor_tile(k, bk)
    return bm, bn, bk, m // bm, n // bn, k // bk


def _apply_act(pre, act):
    if act is None:
        return pre
    if act == "relu6":
        return jnp.clip(pre, 0.0, 6.0)
    if act == "gelu":
        # tanh-approximate GELU: the exact erf form lowers to an `erf`
        # opcode the pinned XLA 0.5.1 HLO parser does not know; the tanh
        # approximation (GPT-2 convention) uses only portable opcodes.
        c = jnp.sqrt(2.0 / jnp.pi).astype(pre.dtype)
        inner = c * (pre + 0.044715 * pre * pre * pre)
        return 0.5 * pre * (1.0 + jnp.tanh(inner))
    raise ValueError(f"unknown activation {act!r}")


def _act_grad(pre, act):
    """d act(pre) / d pre, elementwise, in f32."""
    if act is None:
        return jnp.ones_like(pre)
    if act == "relu6":
        return ((pre > 0.0) & (pre < 6.0)).astype(pre.dtype)
    if act == "gelu":
        # derivative of the tanh-approximate GELU
        c = jnp.sqrt(2.0 / jnp.pi).astype(pre.dtype)
        inner = c * (pre + 0.044715 * pre * pre * pre)
        t = jnp.tanh(inner)
        dinner = c * (1.0 + 3.0 * 0.044715 * pre * pre)
        return 0.5 * (1.0 + t) + 0.5 * pre * (1.0 - t * t) * dinner
    raise ValueError(f"unknown activation {act!r}")


# ---------------------------------------------------------------------------
# Raw tiled matmul kernels (no autodiff) — the MXU schedule.
# ---------------------------------------------------------------------------


def _mm_kernel(a_ref, b_ref, o_ref, *, nk, mode):
    """Grid = (nm, nn, nk); o block is revisited across the K dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    if mode == "nn":
        prod = jnp.dot(a, b, preferred_element_type=o_ref.dtype)
    elif mode == "nt":
        prod = jnp.dot(a, b.T, preferred_element_type=o_ref.dtype)
    elif mode == "tn":
        prod = jnp.dot(a.T, b, preferred_element_type=o_ref.dtype)
    else:  # pragma: no cover - internal
        raise ValueError(mode)
    o_ref[...] += prod


def matmul_nn(a, b, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """``a @ b`` with a (M,K), b (K,N); f32 accumulate, result f32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk, nm, nn, nk = _tiles(m, n, k, bm, bn, bk)
    return pl.pallas_call(
        partial(_mm_kernel, nk=nk, mode="nn"),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def matmul_nt(a, b, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """``a @ b.T`` with a (M,K), b (N,K) — no materialized transpose."""
    m, k = a.shape
    n, k2 = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk, nm, nn, nk = _tiles(m, n, k, bm, bn, bk)
    return pl.pallas_call(
        partial(_mm_kernel, nk=nk, mode="nt"),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def matmul_tn(a, b, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """``a.T @ b`` with a (S,M), b (S,N) — no materialized transpose."""
    s, m = a.shape
    s2, n = b.shape
    assert s == s2, (a.shape, b.shape)
    bm, bn, bk, nm, nn, nk = _tiles(m, n, s, bm, bn, bk)
    return pl.pallas_call(
        partial(_mm_kernel, nk=nk, mode="tn"),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# Fused linear kernel: pre = x @ w (+ b) (+ r); y = act(pre).
# Bias/residual/activation are applied in VMEM on the last K step.
# ---------------------------------------------------------------------------


def _linear_kernel(*refs, nk, act, has_bias, has_res):
    it = iter(refs)
    x_ref = next(it)
    w_ref = next(it)
    b_ref = next(it) if has_bias else None
    r_ref = next(it) if has_res else None
    pre_ref = next(it)
    y_ref = next(it)

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        pre_ref[...] = jnp.zeros_like(pre_ref)

    pre_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=pre_ref.dtype)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        p = pre_ref[...]
        if has_bias:
            p = p + b_ref[...][None, :].astype(p.dtype)
        if has_res:
            p = p + r_ref[...].astype(p.dtype)
        pre_ref[...] = p
        y_ref[...] = _apply_act(p, act).astype(y_ref.dtype)


def _linear_raw(x, w, b=None, r=None, *, act=None, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Returns (pre, y); pre is the f32 pre-activation (saved for the VJP)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk, nm, nn, nk = _tiles(m, n, k, bm, bn, bk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        args.append(b)
    if r is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        args.append(r)
    out_specs = [
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), x.dtype),
    ]
    pre, y = pl.pallas_call(
        partial(_linear_kernel, nk=nk, act=act, has_bias=b is not None, has_res=r is not None),
        grid=(nm, nn, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )(*args)
    return pre, y


# ---------------------------------------------------------------------------
# Public differentiable ops. Backward passes are built from the raw
# kernels (nt/tn) so the whole fwd+bwd HLO flows through Layer 1.
# ---------------------------------------------------------------------------


def _linear_bwd_core(x, w, pre, gy, act, has_res):
    gy32 = gy.astype(jnp.float32)
    dpre = gy32 * _act_grad(pre, act)
    dx = matmul_nt(dpre, w.astype(jnp.float32)).astype(x.dtype)
    dw = matmul_tn(x.astype(jnp.float32), dpre).astype(w.dtype)
    db = jnp.sum(dpre, axis=0)
    dr = gy32.astype(x.dtype) if has_res else None
    return dx, dw, db, dr


@jax.custom_vjp
def matmul(x, w):
    """Differentiable tiled matmul: x (M,K) @ w (K,N) -> f32 (M,N)."""
    return matmul_nn(x, w)


def _matmul_fwd(x, w):
    return matmul_nn(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    g32 = g.astype(jnp.float32)
    dx = matmul_nt(g32, w.astype(jnp.float32)).astype(x.dtype)
    dw = matmul_tn(x.astype(jnp.float32), g32).astype(w.dtype)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def _make_linear(act, has_res, name):
    if has_res:

        @jax.custom_vjp
        def op(x, w, b, r):
            _, y = _linear_raw(x, w, b, r, act=act)
            return y

        def fwd(x, w, b, r):
            pre, y = _linear_raw(x, w, b, r, act=act)
            return y, (x, w, pre)

        def bwd(res, gy):
            x, w, pre = res
            dx, dw, db, dr = _linear_bwd_core(x, w, pre, gy, act, True)
            return dx, dw, db.astype(jnp.float32), dr

    else:

        @jax.custom_vjp
        def op(x, w, b):
            _, y = _linear_raw(x, w, b, act=act)
            return y

        def fwd(x, w, b):
            pre, y = _linear_raw(x, w, b, act=act)
            return y, (x, w, pre)

        def bwd(res, gy):
            x, w, pre = res
            dx, dw, db, _ = _linear_bwd_core(x, w, pre, gy, act, False)
            return dx, dw, db.astype(jnp.float32)

    op.defvjp(fwd, bwd)
    op.__name__ = name
    op.__qualname__ = name
    return op


#: y = x @ w + b
linear = _make_linear(None, False, "linear")
#: y = relu6(x @ w + b)              (inverted-residual expansion)
linear_relu6 = _make_linear("relu6", False, "linear_relu6")
#: y = gelu(x @ w + b)               (transformer MLP)
linear_gelu = _make_linear("gelu", False, "linear_gelu")
#: y = x @ w + b + r                 (inverted-residual projection)
linear_residual = _make_linear(None, True, "linear_residual")
