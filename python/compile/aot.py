"""AOT compiler: lower every model block to HLO text + emit the manifest.

This is the *entire* Python footprint at deployment time: it runs once
(``make artifacts``), and the Rust coordinator then loads the HLO text
through PJRT (`HloModuleProto::from_text_file`) with Python never on the
training path.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Per model the output tree is::

    <out>/<model>/manifest.json
    <out>/<model>/block{i}_fwd.hlo.txt     (p..., x) -> (y,)
    <out>/<model>/block{i}_bwd.hlo.txt     (p..., x, gy) -> (gp..., [gx])
    <out>/<model>/head_step.hlo.txt        (p..., x, labels) -> (gp..., gx, loss, ncorrect)
    <out>/<model>/head_eval.hlo.txt        (p..., x, labels) -> (loss, ncorrect)
    <out>/<model>/init/b{i}_p{k}.bin       f32 little-endian initial weights
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MODELS, ModelDef, param_count

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), _DTYPES[dtype])


def _param_specs(params):
    return [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]


def _lower_block_fwd(blk, params):
    n = len(params)

    def f(*args):
        return blk.fwd(list(args[:n]), args[n])

    specs = _param_specs(params) + [_spec(blk.in_shape, blk.in_dtype)]
    return to_hlo_text(jax.jit(f, keep_unused=True).lower(*specs))


def _lower_block_bwd(blk, params):
    n = len(params)

    def f(*args):
        p, x, gy = list(args[:n]), args[n], args[n + 1]
        _, vjp = jax.vjp(lambda pp, xx: blk.fwd(pp, xx), p, x)
        gp, gx = vjp(gy)
        if blk.has_gx:
            return tuple(gp) + (gx,)
        return tuple(gp)

    specs = _param_specs(params) + [
        _spec(blk.in_shape, blk.in_dtype),
        jax.ShapeDtypeStruct(tuple(blk.out_shape), jnp.float32),
    ]
    return to_hlo_text(jax.jit(f, keep_unused=True).lower(*specs))


def _lower_head_step(head, params):
    n = len(params)

    def f(*args):
        p, x, labels = list(args[:n]), args[n], args[n + 1]
        (loss, nc), grads = jax.value_and_grad(
            lambda pp, xx: head.loss(pp, xx, labels), argnums=(0, 1), has_aux=True
        )(p, x)
        gp, gx = grads
        return tuple(gp) + (gx, loss, nc)

    specs = _param_specs(params) + [
        _spec(head.in_shape, "f32"),
        _spec(head.label_shape, head.label_dtype),
    ]
    return to_hlo_text(jax.jit(f, keep_unused=True).lower(*specs))


def _lower_head_eval(head, params):
    n = len(params)

    def f(*args):
        p, x, labels = list(args[:n]), args[n], args[n + 1]
        loss, nc = head.loss(p, x, labels)
        return loss, nc

    specs = _param_specs(params) + [
        _spec(head.in_shape, "f32"),
        _spec(head.label_shape, head.label_dtype),
    ]
    return to_hlo_text(jax.jit(f, keep_unused=True).lower(*specs))


def _write(path, text):
    with open(path, "w") as f:
        f.write(text)


def _param_entry(i, k, p, init_dir_rel):
    return {
        "shape": list(p.shape),
        "size": int(p.size),
        "init": f"{init_dir_rel}/b{i}_p{k}.bin",
    }


def compile_model(model: ModelDef, out_root: str, seed: int = 0,
                  verbose: bool = True) -> dict:
    """Lower all artifacts for ``model`` under ``out_root/<model.name>``."""
    mdir = os.path.join(out_root, model.name)
    idir = os.path.join(mdir, "init")
    os.makedirs(idir, exist_ok=True)

    all_params = model.init_all(seed)
    blocks_json = []
    nb = len(model.blocks)

    for i, (blk, params) in enumerate(zip(model.blocks, all_params[:nb])):
        if verbose:
            print(f"[aot] {model.name}: lowering block {i} ({blk.name})", flush=True)
        _write(os.path.join(mdir, f"block{i}_fwd.hlo.txt"),
               _lower_block_fwd(blk, params))
        _write(os.path.join(mdir, f"block{i}_bwd.hlo.txt"),
               _lower_block_bwd(blk, params))
        for k, p in enumerate(params):
            with open(os.path.join(idir, f"b{i}_p{k}.bin"), "wb") as f:
                f.write(jax.device_get(p).astype("<f4").tobytes())
        out_elems = 1
        for d in blk.out_shape:
            out_elems *= d
        blocks_json.append({
            "index": i,
            "name": blk.name,
            "kind": "block",
            "fwd": f"block{i}_fwd.hlo.txt",
            "bwd": f"block{i}_bwd.hlo.txt",
            "params": [_param_entry(i, k, p, "init") for k, p in enumerate(params)],
            "in_shape": list(blk.in_shape),
            "in_dtype": blk.in_dtype,
            "out_shape": list(blk.out_shape),
            "flops_fwd": int(blk.flops_fwd),
            # backward is ~2x forward (two GEMMs per forward GEMM)
            "flops_bwd": int(2 * blk.flops_fwd),
            "out_bytes": out_elems * 4,
            "param_bytes": int(sum(p.size for p in params)) * 4,
            "has_gx": bool(blk.has_gx),
        })

    head, hparams = model.head, all_params[nb]
    i = nb
    if verbose:
        print(f"[aot] {model.name}: lowering head ({head.name})", flush=True)
    _write(os.path.join(mdir, "head_step.hlo.txt"), _lower_head_step(head, hparams))
    _write(os.path.join(mdir, "head_eval.hlo.txt"), _lower_head_eval(head, hparams))
    for k, p in enumerate(hparams):
        with open(os.path.join(idir, f"b{i}_p{k}.bin"), "wb") as f:
            f.write(jax.device_get(p).astype("<f4").tobytes())
    blocks_json.append({
        "index": i,
        "name": head.name,
        "kind": "head",
        "step": "head_step.hlo.txt",
        "eval": "head_eval.hlo.txt",
        "params": [_param_entry(i, k, p, "init") for k, p in enumerate(hparams)],
        "in_shape": list(head.in_shape),
        "in_dtype": "f32",
        "out_shape": [],
        "flops_fwd": int(head.flops_fwd),
        "flops_bwd": int(2 * head.flops_fwd),
        "out_bytes": 8,  # loss + ncorrect scalars
        "param_bytes": int(sum(p.size for p in hparams)) * 4,
        "has_gx": True,
    })

    manifest = {
        "model": model.name,
        "batch_size": model.batch_size,
        "input": {"shape": list(model.input_shape), "dtype": model.input_dtype},
        "labels": {"shape": list(model.label_shape), "dtype": model.label_dtype},
        "acc_denom": model.head.acc_denom,
        "param_count": param_count(model),
        "meta": model.meta,
        "blocks": blocks_json,
    }
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] {model.name}: {len(blocks_json)} blocks, "
              f"{manifest['param_count']:,} params -> {mdir}", flush=True)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", nargs="+", default=["edgenet"],
                    choices=sorted(MODELS), help="model configs to compile")
    ap.add_argument("--out", default="../artifacts", help="output root")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    for name in args.models:
        compile_model(MODELS[name](), args.out, seed=args.seed)


if __name__ == "__main__":
    main()
