"""L1 performance model: VMEM footprint + MXU utilization estimates.

Interpret-mode Pallas gives CPU-numpy timings that say nothing about TPU
performance, so (per DESIGN.md §7) the optimization target for Layer 1 is
*structural*: tiles sized for VMEM, lane/sublane alignment for the MXU
systolic array, and enough arithmetic intensity to beat the HBM roofline.

This script prints, for every matmul call site of a model family, the
chosen tile sizes and:

  * VMEM bytes = (bm*bk + bk*bn) * 4   (operand tiles)
               + 2 * bm*bn * 4         (pre + y accumulator tiles)
    — must stay well under ~16 MiB/core.
  * MXU utilization estimate = how full the 128x128 systolic array is for
    the tile shape: min(bm,128)/128 * min(bn,128)/128 (the K dimension
    streams, so it does not gate utilization).
  * Arithmetic intensity (flops/byte) of one grid step — above ~100
    flops/byte the kernel is MXU-bound on all TPU generations.

Usage: python -m compile.perf_estimate [--models edgenet pipeformer-e2e]
"""

import argparse

from .kernels.matmul import DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, _tiles
from .model import MODELS


def matmul_sites(model):
    """Yield (name, M, K, N) for every forward matmul call site."""
    meta = model.meta
    b = model.batch_size
    if meta.get("family") == "edgenet":
        d, ex, ind = meta["d"], meta["expand"], meta["in_dim"]
        yield ("stem", b, ind, d)
        yield ("ir.expand", b, d, d * ex)
        yield ("ir.project", b, d * ex, d)
        yield ("head", b, d, meta["n_classes"])
    else:
        d, s, v = meta["d"], meta["seq"], meta["vocab"]
        t = b * s
        yield ("qkv", t, d, 3 * d)
        yield ("attn_out", t, d, d)
        yield ("mlp.in", t, d, 4 * d)
        yield ("mlp.out", t, 4 * d, d)
        yield ("lm_head", t, d, v)


def analyze(name, m, k, n):
    bm, bn, bk, nm, nn, nk = _tiles(m, n, k, DEFAULT_BM, DEFAULT_BN, DEFAULT_BK)
    vmem = (bm * bk + bk * bn + 2 * bm * bn) * 4
    mxu = min(bm, 128) / 128 * min(bn, 128) / 128
    flops = 2 * bm * bn * bk
    bytes_moved = (bm * bk + bk * bn) * 4  # per grid step (acc stays in VMEM)
    ai = flops / bytes_moved
    return {
        "site": name,
        "mkn": f"{m}x{k}x{n}",
        "tile": f"{bm}x{bk}x{bn}",
        "grid": f"{nm}x{nn}x{nk}",
        "vmem_kib": vmem / 1024,
        "mxu_util": mxu,
        "flops_per_byte": ai,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="+",
                    default=["edgenet", "pipeformer-small", "pipeformer-e2e"])
    args = ap.parse_args()
    for mname in args.models:
        model = MODELS[mname]()
        print(f"\n== {mname} (batch {model.batch_size}) ==")
        print(f"{'site':<10} {'M*K*N':<16} {'tile':<14} {'grid':<10} "
              f"{'VMEM KiB':>9} {'MXU util':>9} {'fl/B':>7}")
        for site in matmul_sites(model):
            a = analyze(*site)
            flag = ""
            if a["vmem_kib"] > 8 * 1024:
                flag += " !VMEM"
            if a["mxu_util"] < 0.25:
                flag += " !MXU(batch-bound)"
            print(f"{a['site']:<10} {a['mkn']:<16} {a['tile']:<14} {a['grid']:<10} "
                  f"{a['vmem_kib']:>9.1f} {a['mxu_util']:>9.2f} {a['flops_per_byte']:>7.1f}{flag}")


if __name__ == "__main__":
    main()
