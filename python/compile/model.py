"""Layer-2: the DNN model families, written in JAX, calling Layer-1 kernels.

A model is a chain of partitionable **blocks** (the "layers" the paper's
dynamic-programming partitioner operates over) plus a **head** that fuses
forward + loss + backward for the last pipeline stage (under 1F1B the last
stage always runs backward immediately with the same weights, so a fused
artifact is both correct and faster — PipeDream invariant).

Two families (see DESIGN.md §2 and §4):

* ``edgenet`` — the MobileNetV2 adaptation: a stem projection, N
  inverted-residual MLP blocks (expand ``t``×, ReLU6, project, residual),
  and a classifier head. This is the paper's §IV workload re-expressed as
  MXU-friendly matmuls.
* ``pipeformer`` — a decoder-only transformer (pre-LN, causal MHA, GELU
  MLP) for the end-to-end training demo.

Everything here runs at *build* time only: ``aot.py`` lowers each block's
forward/backward to HLO text, which the Rust runtime loads via PJRT.
"""

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from .kernels import linear, linear_gelu, linear_relu6, linear_residual


# ---------------------------------------------------------------------------
# Definitions
# ---------------------------------------------------------------------------


@dataclass
class BlockDef:
    """One partitionable unit of the model chain."""

    name: str
    init: Callable  # key -> [params...]
    fwd: Callable  # (params, x) -> y
    in_shape: tuple
    out_shape: tuple
    in_dtype: str = "f32"  # activation dtype entering this block
    flops_fwd: int = 0
    has_gx: bool = True  # False for the first block (int input / no upstream)


@dataclass
class HeadDef:
    """The final block: forward + loss (+ fused backward at AOT time)."""

    name: str
    init: Callable
    loss: Callable  # (params, x, labels) -> (loss_scalar, ncorrect_scalar)
    in_shape: tuple
    label_shape: tuple
    label_dtype: str
    flops_fwd: int
    acc_denom: int  # predictions per batch (batch or batch*seq)


@dataclass
class ModelDef:
    name: str
    batch_size: int
    blocks: List[BlockDef]
    head: HeadDef
    input_shape: tuple
    input_dtype: str
    label_shape: tuple
    label_dtype: str
    meta: dict = field(default_factory=dict)

    def init_all(self, seed: int = 0):
        """[[params per block], ..., head params] with a fixed seed."""
        keys = jax.random.split(jax.random.PRNGKey(seed), len(self.blocks) + 1)
        out = [b.init(k) for b, k in zip(self.blocks, keys[:-1])]
        out.append(self.head.init(keys[-1]))
        return out

    def forward_all(self, all_params, x):
        """Reference whole-model forward (used by tests)."""
        for blk, p in zip(self.blocks, all_params[: len(self.blocks)]):
            x = blk.fwd(p, x)
        return x


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def cross_entropy(logits, labels):
    """Mean CE over leading axes; logits (..., C), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    loss = jnp.mean(logz - ll)
    ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, ncorrect


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


# ---------------------------------------------------------------------------
# edgenet — MobileNetV2 adapted to matmul blocks (DESIGN.md §2)
# ---------------------------------------------------------------------------


def _stem_block(batch, in_dim, d):
    """Projection stem. LayerNorm replaces MobileNetV2's BatchNorm (BN is
    impractical when the pipeline sees one micro-batch at a time; LN is the
    standard substitution — see DESIGN.md §Hardware-Adaptation)."""

    def init(key):
        kw, = jax.random.split(key, 1)
        return [
            _he(kw, (in_dim, d), in_dim),
            jnp.zeros((d,), jnp.float32),
            jnp.ones((d,), jnp.float32),   # ln gamma
            jnp.zeros((d,), jnp.float32),  # ln beta
        ]

    def fwd(params, x):
        w, b, g, bb = params
        return layer_norm(linear_relu6(x, w, b), g, bb)

    return BlockDef(
        name="stem",
        init=init,
        fwd=fwd,
        in_shape=(batch, in_dim),
        out_shape=(batch, d),
        flops_fwd=2 * batch * in_dim * d,
        has_gx=False,
    )


def _ir_block(batch, d, expand, idx):
    """Inverted residual: expand (ReLU6) -> project (+residual) -> LN.
    The LN substitutes MobileNetV2's per-conv BatchNorm (DESIGN.md §2)."""
    h = d * expand

    def init(key):
        k1, k2 = jax.random.split(key)
        return [
            _he(k1, (d, h), d),
            jnp.zeros((h,), jnp.float32),
            _he(k2, (h, d), h) * 0.5,
            jnp.zeros((d,), jnp.float32),
            jnp.ones((d,), jnp.float32),   # ln gamma
            jnp.zeros((d,), jnp.float32),  # ln beta
        ]

    def fwd(params, x):
        w1, b1, w2, b2, g, bb = params
        hidden = linear_relu6(x, w1, b1)
        return layer_norm(linear_residual(hidden, w2, b2, x), g, bb)

    return BlockDef(
        name=f"ir{idx}",
        init=init,
        fwd=fwd,
        in_shape=(batch, d),
        out_shape=(batch, d),
        flops_fwd=2 * batch * d * h * 2,
    )


def _cls_head(batch, d, n_classes):
    def init(key):
        return [_he(key, (d, n_classes), d), jnp.zeros((n_classes,), jnp.float32)]

    def loss(params, x, labels):
        w, b = params
        logits = linear(x, w, b)
        return cross_entropy(logits, labels)

    return HeadDef(
        name="cls_head",
        init=init,
        loss=loss,
        in_shape=(batch, d),
        label_shape=(batch,),
        label_dtype="i32",
        flops_fwd=2 * batch * d * n_classes,
        acc_denom=batch,
    )


def edgenet(batch=32, in_dim=3072, d=128, n_blocks=10, expand=4, n_classes=10,
            name="edgenet"):
    blocks = [_stem_block(batch, in_dim, d)]
    blocks += [_ir_block(batch, d, expand, i) for i in range(n_blocks)]
    return ModelDef(
        name=name,
        batch_size=batch,
        blocks=blocks,
        head=_cls_head(batch, d, n_classes),
        input_shape=(batch, in_dim),
        input_dtype="f32",
        label_shape=(batch,),
        label_dtype="i32",
        meta={"family": "edgenet", "d": d, "expand": expand,
              "n_classes": n_classes, "in_dim": in_dim},
    )


# ---------------------------------------------------------------------------
# pipeformer — decoder-only transformer for the e2e demo
# ---------------------------------------------------------------------------


def _embed_block(batch, seq, vocab, d):
    def init(key):
        k1, k2 = jax.random.split(key)
        return [
            jax.random.normal(k1, (vocab, d), jnp.float32) * 0.02,
            jax.random.normal(k2, (seq, d), jnp.float32) * 0.02,
        ]

    def fwd(params, tokens):
        tok_emb, pos_emb = params
        return tok_emb[tokens] + pos_emb[None, :, :]

    return BlockDef(
        name="embed",
        init=init,
        fwd=fwd,
        in_shape=(batch, seq),
        in_dtype="i32",
        out_shape=(batch, seq, d),
        flops_fwd=batch * seq * d,  # gather + add, negligible
        has_gx=False,
    )


def _tf_block(batch, seq, d, heads, idx):
    hd = d // heads
    assert hd * heads == d
    mlp_h = 4 * d

    def init(key):
        ks = jax.random.split(key, 4)
        return [
            jnp.ones((d,), jnp.float32),  # ln1 gamma
            jnp.zeros((d,), jnp.float32),  # ln1 beta
            _he(ks[0], (d, 3 * d), d) * 0.5,  # qkv
            jnp.zeros((3 * d,), jnp.float32),
            _he(ks[1], (d, d), d) * 0.5,  # out proj
            jnp.zeros((d,), jnp.float32),
            jnp.ones((d,), jnp.float32),  # ln2 gamma
            jnp.zeros((d,), jnp.float32),  # ln2 beta
            _he(ks[2], (d, mlp_h), d),  # mlp in
            jnp.zeros((mlp_h,), jnp.float32),
            _he(ks[3], (mlp_h, d), mlp_h),  # mlp out
            jnp.zeros((d,), jnp.float32),
        ]

    def fwd(params, x):
        (g1, b1, wqkv, bqkv, wo, bo, g2, b2, w1, bb1, w2, bb2) = params
        B, S, D = x.shape
        # --- causal MHA (pre-LN) ---
        h = layer_norm(x, g1, b1)
        qkv = linear(h.reshape(B * S, D), wqkv, bqkv).reshape(B, S, 3, heads, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        # (B, heads, S, hd)
        q = q.transpose(0, 2, 1, 3)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        mask = jnp.tril(jnp.ones((S, S), jnp.bool_))
        scores = jnp.where(mask[None, None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(B * S, D)
        x = x + linear(ctx, wo, bo).reshape(B, S, D).astype(x.dtype)
        # --- MLP (pre-LN) ---
        h = layer_norm(x, g2, b2).reshape(B * S, D)
        h = linear_gelu(h, w1, bb1)
        x = x + linear(h, w2, bb2).reshape(B, S, D).astype(x.dtype)
        return x

    fl = 2 * batch * seq * d * 3 * d  # qkv
    fl += 2 * batch * heads * seq * seq * hd * 2  # scores + ctx
    fl += 2 * batch * seq * d * d  # out proj
    fl += 2 * batch * seq * d * mlp_h * 2  # mlp
    return BlockDef(
        name=f"tf{idx}",
        init=init,
        fwd=fwd,
        in_shape=(batch, seq, d),
        out_shape=(batch, seq, d),
        flops_fwd=fl,
    )


def _lm_head(batch, seq, d, vocab):
    def init(key):
        return [
            jnp.ones((d,), jnp.float32),
            jnp.zeros((d,), jnp.float32),
            _he(key, (d, vocab), d) * 0.5,
            jnp.zeros((vocab,), jnp.float32),
        ]

    def loss(params, x, labels):
        g, b, w, bb = params
        B, S, D = x.shape
        h = layer_norm(x, g, b).reshape(B * S, D)
        logits = linear(h, w, bb).reshape(B, S, vocab)
        return cross_entropy(logits, labels)

    return HeadDef(
        name="lm_head",
        init=init,
        loss=loss,
        in_shape=(batch, seq, d),
        label_shape=(batch, seq),
        label_dtype="i32",
        flops_fwd=2 * batch * seq * d * vocab,
        acc_denom=batch * seq,
    )


def pipeformer(batch=8, seq=64, vocab=512, d=128, n_layers=4, heads=4,
               name="pipeformer"):
    blocks = [_embed_block(batch, seq, vocab, d)]
    blocks += [_tf_block(batch, seq, d, heads, i) for i in range(n_layers)]
    return ModelDef(
        name=name,
        batch_size=batch,
        blocks=blocks,
        head=_lm_head(batch, seq, d, vocab),
        input_shape=(batch, seq),
        input_dtype="i32",
        label_shape=(batch, seq),
        label_dtype="i32",
        meta={"family": "pipeformer", "d": d, "n_layers": n_layers,
              "heads": heads, "vocab": vocab, "seq": seq},
    )


# ---------------------------------------------------------------------------
# Registry — the configs aot.py knows how to build.
# ---------------------------------------------------------------------------

MODELS = {
    # Paper §IV-C/D workload (MobileNetV2-on-CIFAR10 analogue), batch 32.
    "edgenet": lambda: edgenet(batch=32, name="edgenet"),
    # Paper §IV-F continuous-learning config on Raspberry Pis, batch 8.
    "edgenet-pi": lambda: edgenet(batch=8, name="edgenet-pi"),
    # Fast config for tests.
    "edgenet-tiny": lambda: edgenet(batch=8, in_dim=192, d=32, n_blocks=4,
                                    name="edgenet-tiny"),
    # Transformer demo configs (DESIGN.md §4).
    "pipeformer-small": lambda: pipeformer(batch=8, seq=64, vocab=512, d=128,
                                           n_layers=4, name="pipeformer-small"),
    "pipeformer-e2e": lambda: pipeformer(batch=8, seq=128, vocab=4096, d=512,
                                         n_layers=8, heads=8,
                                         name="pipeformer-e2e"),
    "pipeformer-100m": lambda: pipeformer(batch=4, seq=128, vocab=8192, d=768,
                                          n_layers=12, heads=12,
                                          name="pipeformer-100m"),
}


def param_count(model: ModelDef) -> int:
    tot = 0
    for ps in model.init_all(0):
        tot += sum(int(p.size) for p in ps)
    return tot
