"""L1/L2 performance *structure* checks (DESIGN.md §7).

Interpret-mode wallclock is not a TPU proxy, so these tests pin the
structural properties the perf pass optimizes instead:

* every kernel tile fits VMEM with a healthy margin;
* large (>=128) dims get full 128-lane tiles (MXU-aligned);
* the lowered backward HLO does not re-compute the forward matmul
  (activation checkpointing is explicit: `pre` is saved by the VJP), which
  we verify by counting `dot` ops in the HLO text;
* the fused epilogue really is in the same kernel (no separate clamp pass
  between HBM round-trips) — one pallas_call per linear.
"""

import re

import jax
import jax.numpy as jnp
import pytest

from compile.kernels.matmul import DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, _tiles
from compile.perf_estimate import analyze, matmul_sites
from compile.model import MODELS


@pytest.mark.parametrize("mname", ["edgenet", "pipeformer-small", "pipeformer-e2e"])
def test_vmem_budget(mname):
    model = MODELS[mname]()
    for site in matmul_sites(model):
        a = analyze(*site)
        assert a["vmem_kib"] < 8 * 1024, f"{mname}/{a['site']}: {a['vmem_kib']} KiB"


@pytest.mark.parametrize("mname", ["edgenet", "pipeformer-e2e"])
def test_mxu_alignment_on_large_dims(mname):
    model = MODELS[mname]()
    for (name, m, k, n) in matmul_sites(model):
        bm, bn, bk, *_ = _tiles(m, n, k, DEFAULT_BM, DEFAULT_BN, DEFAULT_BK)
        if k >= 128:
            assert bk == 128, f"{name}: K tile {bk} not MXU-aligned (K={k})"
        if n >= 128 and n % 128 == 0:
            assert bn == 128, f"{name}: N tile {bn} not MXU-aligned (N={n})"


def _count_dots(hlo_text):
    return len(re.findall(r" dot\(", hlo_text))


def _hlo_for(fn, *specs):
    from compile.aot import to_hlo_text

    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))


def test_backward_gemm_count_is_rematerialization():
    """The standalone bwd artifact takes (params, x, gy), so jax.vjp
    re-runs the forward to rebuild the VJP residuals: 2 recompute GEMMs +
    4 gradient GEMMs = 6. This is the GPipe rematerialization tradeoff —
    deliberate (saves shipping per-linear activations between fwd and bwd
    across the network; see EXPERIMENTS.md §Perf L2). This test pins the
    count so an accidental second recompute (8+) is caught."""
    model = MODELS["edgenet-tiny"]()
    blk = model.blocks[1]  # first ir block
    params = blk.init(jax.random.PRNGKey(0))

    def bwd(*args):
        p, x, gy = list(args[: len(params)]), args[len(params)], args[len(params) + 1]
        _, vjp = jax.vjp(lambda pp, xx: blk.fwd(pp, xx), p, x)
        gp, gx = vjp(gy)
        return tuple(gp) + (gx,)

    specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    specs += [
        jax.ShapeDtypeStruct(tuple(blk.in_shape), jnp.float32),
        jax.ShapeDtypeStruct(tuple(blk.out_shape), jnp.float32),
    ]
    hlo = _hlo_for(bwd, *specs)
    dots = _count_dots(hlo)
    assert dots == 6, f"expected 2 recompute + 4 gradient GEMMs, found {dots}"


def test_forward_has_one_gemm_per_linear():
    model = MODELS["edgenet-tiny"]()
    blk = model.blocks[1]
    params = blk.init(jax.random.PRNGKey(0))

    def fwd(*args):
        return blk.fwd(list(args[:-1]), args[-1])

    specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    specs += [jax.ShapeDtypeStruct(tuple(blk.in_shape), jnp.float32)]
    hlo = _hlo_for(fwd, *specs)
    dots = _count_dots(hlo)
    assert dots == 2, f"ir fwd should be exactly 2 GEMMs (expand+project), found {dots}"
    # the ReLU6 epilogue is fused inside the kernel (clip lowers to
    # minimum/maximum inside the grid loop body)
    assert "minimum" in hlo and "maximum" in hlo, "fused ReLU6 epilogue missing"
