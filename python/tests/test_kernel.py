"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including primes, 1-sized dims, and non-tile
multiples) and dtypes (f32, bf16); forward outputs and custom-VJP
gradients are both checked against `kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    linear,
    linear_gelu,
    linear_relu6,
    linear_residual,
    matmul,
    matmul_nn,
    matmul_nt,
    matmul_tn,
)
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=70)
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _close(a, b, dtype):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), **_tol(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
def test_matmul_nn_matches_ref(m, k, n, dtype, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (m, k), dtype), _rand(k2, (k, n), dtype)
    _close(matmul_nn(a, b), ref.matmul_nn(a, b), dtype)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
def test_matmul_nt_matches_ref(m, k, n, dtype, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (m, k), dtype), _rand(k2, (n, k), dtype)
    _close(matmul_nt(a, b), ref.matmul_nt(a, b), dtype)


@settings(max_examples=25, deadline=None)
@given(s=DIMS, m=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
def test_matmul_tn_matches_ref(s, m, n, dtype, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (s, m), dtype), _rand(k2, (s, n), dtype)
    _close(matmul_tn(a, b), ref.matmul_tn(a, b), dtype)


@pytest.mark.parametrize(
    "op,refop",
    [
        (linear, ref.linear),
        (linear_relu6, ref.linear_relu6),
        (linear_gelu, ref.linear_gelu),
    ],
)
@settings(max_examples=15, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_linear_fused_matches_ref(op, refop, m, k, n, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (m, k), jnp.float32)
    w = _rand(k2, (k, n), jnp.float32)
    b = _rand(k3, (n,), jnp.float32)
    _close(op(x, w, b), refop(x, w, b), jnp.float32)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_linear_residual_matches_ref(m, k, n, seed):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(k1, (m, k), jnp.float32)
    w = _rand(k2, (k, n), jnp.float32)
    b = _rand(k3, (n,), jnp.float32)
    r = _rand(k4, (m, n), jnp.float32)
    _close(linear_residual(x, w, b, r), ref.linear_residual(x, w, b, r), jnp.float32)


@pytest.mark.parametrize(
    "op,refop",
    [
        (linear, ref.linear),
        (linear_relu6, ref.linear_relu6),
        (linear_gelu, ref.linear_gelu),
    ],
)
@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 40), n=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1))
def test_linear_grads_match_autodiff_of_ref(op, refop, m, k, n, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (m, k), jnp.float32)
    w = _rand(k2, (k, n), jnp.float32)
    b = _rand(k3, (n,), jnp.float32)

    def f(x, w, b):
        return jnp.sum(jnp.sin(op(x, w, b)))

    def g(x, w, b):
        return jnp.sum(jnp.sin(refop(x, w, b)))

    got = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(g, argnums=(0, 1, 2))(x, w, b)
    for a, bb in zip(got, want):
        np.testing.assert_allclose(a, bb, rtol=5e-3, atol=5e-3)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 40), n=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1))
def test_residual_grads_match_autodiff_of_ref(m, k, n, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (m, k), jnp.float32)
    w = _rand(ks[1], (k, n), jnp.float32)
    b = _rand(ks[2], (n,), jnp.float32)
    r = _rand(ks[3], (m, n), jnp.float32)

    def f(*a):
        return jnp.sum(jnp.cos(linear_residual(*a)))

    def g(*a):
        return jnp.sum(jnp.cos(ref.linear_residual(*a)))

    got = jax.grad(f, argnums=(0, 1, 2, 3))(x, w, b, r)
    want = jax.grad(g, argnums=(0, 1, 2, 3))(x, w, b, r)
    for a, bb in zip(got, want):
        np.testing.assert_allclose(a, bb, rtol=5e-3, atol=5e-3)


def test_matmul_custom_vjp_grad():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (16, 24))
    w = jax.random.normal(k2, (24, 8))

    def f(x, w):
        return jnp.sum(matmul(x, w) ** 2)

    def g(x, w):
        return jnp.sum(ref.matmul(x, w) ** 2)

    got = jax.grad(f, argnums=(0, 1))(x, w)
    want = jax.grad(g, argnums=(0, 1))(x, w)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_relu6_clamps_both_sides():
    x = jnp.array([[-10.0, 0.0, 3.0, 100.0]])
    w = jnp.eye(4, dtype=jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    y = linear_relu6(x, w, b)
    np.testing.assert_allclose(y, [[0.0, 0.0, 3.0, 6.0]])


def test_relu6_grad_zero_in_saturation():
    # gradient must be 0 where pre <= 0 or pre >= 6
    x = jnp.array([[-1.0, 2.0, 7.0]])
    w = jnp.eye(3, dtype=jnp.float32)
    b = jnp.zeros((3,), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(linear_relu6(x, w, b)))(x)
    np.testing.assert_allclose(g, [[0.0, 1.0, 0.0]])


def test_big_mxu_aligned_shape():
    # A shape that actually exercises multi-step grids (128-tiles).
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(k1, (256, 384), jnp.float32)
    b = jax.random.normal(k2, (384, 256), jnp.float32)
    _close(matmul_nn(a, b), ref.matmul_nn(a, b), jnp.float32)
