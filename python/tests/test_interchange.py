"""Cross-language interchange: Rust checkpoints are plain npy + JSON that
numpy/python load directly (and the reverse direction parses too).

The Rust side's writer is exercised in its own unit tests; here we verify
the format contract from the Python side with files produced by both
languages' writers.
"""

import json
import os
import subprocess

import numpy as np
import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
FTPIPEHD = os.path.join(REPO, "target", "release", "ftpipehd")


def test_numpy_reads_rust_style_npy(tmp_path):
    """Re-create the Rust writer's byte layout by hand and np.load it."""
    header = "{'descr': '<f4', 'fortran_order': False, 'shape': (3, 4), }"
    unpadded = 10 + len(header) + 1
    pad = (64 - unpadded % 64) % 64
    header = header + " " * pad + "\n"
    data = np.arange(12, dtype="<f4")
    p = tmp_path / "rust_style.npy"
    with open(p, "wb") as f:
        f.write(b"\x93NUMPY")
        f.write(bytes([1, 0]))
        f.write(len(header).to_bytes(2, "little"))
        f.write(header.encode())
        f.write(data.tobytes())
    arr = np.load(p)
    assert arr.shape == (3, 4)
    np.testing.assert_array_equal(arr.ravel(), data)


def test_manifest_json_round_trips_with_python():
    """The Rust JSON writer mirrors python json.dumps; the manifest on disk
    parses identically from both sides (python side checked here)."""
    mpath = os.path.join(REPO, "artifacts", "edgenet-tiny", "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("run `make artifacts` first")
    with open(mpath) as f:
        m = json.load(f)
    assert m["model"] == "edgenet-tiny"
    assert m["blocks"][0]["index"] == 0
    total = sum(sum(p["size"] for p in b["params"]) for b in m["blocks"])
    assert total == m["param_count"]


def test_init_weights_files_match_python_reference():
    """init/*.bin are the exact bytes of the seeded jax init — re-derive
    them and compare (guards against seed or layout drift)."""
    mdir = os.path.join(REPO, "artifacts", "edgenet-tiny")
    if not os.path.exists(os.path.join(mdir, "manifest.json")):
        pytest.skip("run `make artifacts` first")
    from compile.model import MODELS

    model = MODELS["edgenet-tiny"]()
    params = model.init_all(0)
    # spot-check block 1 tensor 0
    import jax

    want = jax.device_get(params[1][0]).astype("<f4").tobytes()
    with open(os.path.join(mdir, "init", "b1_p0.bin"), "rb") as f:
        got = f.read()
    assert got == want
