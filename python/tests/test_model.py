"""L2 correctness: block-chained forward/backward equals whole-model autodiff.

The pipeline executes the model block by block (that is the whole point);
these tests prove that chaining block fwd/bwd artifacts reproduces the
gradients of differentiating the monolithic model end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import MODELS, cross_entropy


def tiny_edgenet():
    return MODELS["edgenet-tiny"]()


def tiny_pipeformer():
    # even smaller than pipeformer-small for test speed
    from compile.model import pipeformer

    return pipeformer(batch=2, seq=8, vocab=32, d=16, n_layers=2, heads=2,
                      name="pipeformer-test")


def _fake_batch(model, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    if model.input_dtype == "f32":
        x = jax.random.normal(k1, model.input_shape, jnp.float32)
    else:
        vocab = model.meta["vocab"]
        x = jax.random.randint(k1, model.input_shape, 0, vocab, jnp.int32)
    nlab = model.meta.get("n_classes") or model.meta.get("vocab")
    labels = jax.random.randint(k2, model.label_shape, 0, nlab, jnp.int32)
    return x, labels


def _whole_model_loss(model, all_params, x, labels):
    h = x
    nb = len(model.blocks)
    for blk, p in zip(model.blocks, all_params[:nb]):
        h = blk.fwd(p, h)
    loss, nc = model.head.loss(all_params[nb], h, labels)
    return loss, nc


@pytest.mark.parametrize("builder", [tiny_edgenet, tiny_pipeformer])
def test_blockwise_forward_matches_whole_model(builder):
    model = builder()
    params = model.init_all(0)
    x, labels = _fake_batch(model)
    whole, _ = _whole_model_loss(model, params, x, labels)

    # block-by-block (what the rust pipeline does)
    h = x
    for blk, p in zip(model.blocks, params[:-1]):
        h = blk.fwd(p, h)
    loss, _ = model.head.loss(params[-1], h, labels)
    np.testing.assert_allclose(loss, whole, rtol=1e-6)


@pytest.mark.parametrize("builder", [tiny_edgenet, tiny_pipeformer])
def test_blockwise_backward_matches_autodiff(builder):
    model = builder()
    params = model.init_all(0)
    x, labels = _fake_batch(model)
    nb = len(model.blocks)

    # reference: grad of the whole model w.r.t. every block's params
    ref_grads = jax.grad(
        lambda ps: _whole_model_loss(model, ps, x, labels)[0]
    )(params)

    # pipeline-style: fwd chain saving activations, then head step, then
    # per-block vjp with the incoming grad — exactly what the artifacts do.
    acts = [x]
    for blk, p in zip(model.blocks, params[:nb]):
        acts.append(blk.fwd(p, acts[-1]))

    (loss, nc), grads = jax.value_and_grad(
        lambda hp, h: model.head.loss(hp, h, labels), argnums=(0, 1), has_aux=True
    )(params[nb], acts[nb])
    ghead, gy = grads
    for a, b in zip(ghead, ref_grads[nb]):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    for i in reversed(range(nb)):
        blk = model.blocks[i]
        _, vjp = jax.vjp(lambda p, xx: blk.fwd(p, xx), params[i], acts[i])
        gp, gx = vjp(gy)
        for a, b in zip(gp, ref_grads[i]):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
        gy = gx


def test_cross_entropy_matches_manual():
    logits = jnp.array([[2.0, 0.5, -1.0], [0.0, 0.0, 0.0]])
    labels = jnp.array([0, 2], jnp.int32)
    loss, nc = cross_entropy(logits, labels)
    p0 = np.exp(2.0) / (np.exp(2.0) + np.exp(0.5) + np.exp(-1.0))
    manual = -(np.log(p0) + np.log(1.0 / 3.0)) / 2.0
    np.testing.assert_allclose(loss, manual, rtol=1e-6)
    # sample 0 predicted class 0 (correct); sample 1 is a tie -> argmax 0 (wrong)
    assert float(nc) == 1.0


def test_cross_entropy_perfect_prediction():
    logits = jnp.array([[100.0, 0.0], [0.0, 100.0]])
    labels = jnp.array([0, 1], jnp.int32)
    loss, nc = cross_entropy(logits, labels)
    assert float(loss) < 1e-3
    assert float(nc) == 2.0


@pytest.mark.parametrize("name", ["edgenet", "edgenet-pi", "pipeformer-small"])
def test_registry_models_build(name):
    model = MODELS[name]()
    assert len(model.blocks) >= 2
    # shapes chain up
    for a, b in zip(model.blocks[:-1], model.blocks[1:]):
        assert tuple(a.out_shape) == tuple(b.in_shape), (a.name, b.name)
    assert tuple(model.blocks[-1].out_shape) == tuple(model.head.in_shape)


def test_param_count_scale():
    from compile.model import param_count

    small = param_count(MODELS["pipeformer-small"]())
    assert 500_000 < small < 5_000_000
    e2e = param_count(MODELS["pipeformer-e2e"]())
    assert 20_000_000 < e2e < 60_000_000


def test_causal_masking():
    """Future tokens must not influence earlier positions."""
    model = tiny_pipeformer()
    params = model.init_all(0)
    x, _ = _fake_batch(model)
    h = model.blocks[0].fwd(params[0], x)
    out1 = model.blocks[1].fwd(params[1], h)
    # perturb the last position's embedding; outputs at earlier positions
    # must be unchanged
    h2 = h.at[:, -1, :].add(1.0)
    out2 = model.blocks[1].fwd(params[1], h2)
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(out1[:, -1], out2[:, -1])
