"""AOT pipeline: manifest consistency + artifact well-formedness."""

import json
import os

import pytest

from compile.aot import compile_model
from compile.model import pipeformer, edgenet


@pytest.fixture(scope="module")
def compiled(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    model = edgenet(batch=4, in_dim=48, d=16, n_blocks=2, n_classes=4,
                    name="edgenet-aot-test")
    manifest = compile_model(model, out, verbose=False)
    return out, model, manifest


def test_manifest_written(compiled):
    out, model, manifest = compiled
    path = os.path.join(out, model.name, "manifest.json")
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["model"] == model.name
    assert len(on_disk["blocks"]) == len(model.blocks) + 1


def test_all_artifacts_exist_and_parse_as_hlo(compiled):
    out, model, manifest = compiled
    mdir = os.path.join(out, model.name)
    for b in manifest["blocks"]:
        files = [b[k] for k in ("fwd", "bwd", "step", "eval") if k in b]
        assert files, b
        for f in files:
            p = os.path.join(mdir, f)
            assert os.path.exists(p), p
            text = open(p).read()
            assert text.startswith("HloModule"), p
            assert "ENTRY" in text


def test_init_files_match_declared_sizes(compiled):
    out, model, manifest = compiled
    mdir = os.path.join(out, model.name)
    for b in manifest["blocks"]:
        for p in b["params"]:
            path = os.path.join(mdir, p["init"])
            assert os.path.getsize(path) == p["size"] * 4


def test_flops_and_bytes_positive(compiled):
    _, _, manifest = compiled
    for b in manifest["blocks"]:
        assert b["flops_fwd"] > 0
        assert b["flops_bwd"] >= b["flops_fwd"]
        assert b["out_bytes"] > 0
        assert b["param_bytes"] > 0


def test_first_block_has_no_gx(compiled):
    _, _, manifest = compiled
    assert manifest["blocks"][0]["has_gx"] is False
    for b in manifest["blocks"][1:]:
        assert b["has_gx"] is True


def test_shapes_chain(compiled):
    _, _, manifest = compiled
    blocks = manifest["blocks"]
    for a, b in zip(blocks[:-1], blocks[1:]):
        if a["kind"] == "block" and b["kind"] == "block":
            assert a["out_shape"] == b["in_shape"]


def test_pipeformer_embed_block_is_int_input(tmp_path):
    model = pipeformer(batch=2, seq=4, vocab=16, d=8, n_layers=1, heads=2,
                       name="pf-aot-test")
    manifest = compile_model(model, str(tmp_path), verbose=False)
    assert manifest["blocks"][0]["in_dtype"] == "i32"
    assert manifest["labels"]["dtype"] == "i32"
    assert manifest["acc_denom"] == 2 * 4
