//! Property tests: the eq-(5) dynamic program is optimal (vs brute force)
//! and produces valid partitions across random cost models.

use ftpipehd::partition::{
    bruteforce_partition, bruteforce_replica_chains, chain_cost, homogeneous_partition,
    optimal_partition, replica_plan, split_chains, validate_partition, validate_replica_plan,
    CostModel,
};
use ftpipehd::util::prop::{check, G};

fn random_cost_model(g: &mut G<'_>) -> CostModel {
    let n_blocks = g.usize_in(3, 12);
    let n_dev = g.usize_in(1, n_blocks.min(4));
    CostModel {
        t0_ms: (0..n_blocks).map(|_| g.f64_in(0.5, 50.0)).collect(),
        out_bytes: (0..n_blocks)
            .map(|_| g.f64_in(1e3, 5e6) as u64)
            .collect(),
        capacities: (0..n_dev).map(|i| if i == 0 { 1.0 } else { g.f64_in(0.25, 12.0) }).collect(),
        bandwidth_bps: (0..n_dev.saturating_sub(1)).map(|_| g.f64_in(1e5, 1e9)).collect(),
    }
}

#[test]
fn prop_dp_output_is_valid_partition() {
    check("dp-valid", 400, |g| {
        let cm = random_cost_model(g);
        let (p, cost) = optimal_partition(&cm);
        validate_partition(&p, cm.n_blocks()).map_err(|e| e.to_string())?;
        if p.len() != cm.n_devices() {
            return Err(format!("{} stages != {} devices", p.len(), cm.n_devices()));
        }
        if !cost.is_finite() || cost <= 0.0 {
            return Err(format!("bad cost {cost}"));
        }
        // reported cost must equal the objective evaluated on the partition
        let eval = cm.cost(&p);
        if (eval - cost).abs() > 1e-6 * cost.max(1.0) {
            return Err(format!("cost mismatch: dp={cost} eval={eval}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dp_matches_bruteforce_optimum() {
    check("dp-optimal", 250, |g| {
        let cm = random_cost_model(g);
        let (_, dp_cost) = optimal_partition(&cm);
        let (_, bf_cost) = bruteforce_partition(&cm);
        if (dp_cost - bf_cost).abs() > 1e-6 * bf_cost.max(1.0) {
            return Err(format!("dp {dp_cost} != brute force {bf_cost} for {cm:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_capacity_aware_never_worse_than_blind() {
    check("aware-beats-blind", 250, |g| {
        let cm = random_cost_model(g);
        let (_, aware) = optimal_partition(&cm);
        let (_, blind) = homogeneous_partition(&cm);
        // blind cost is evaluated under the true capacities; the aware DP
        // optimizes that objective exactly, so it can never lose
        if aware > blind + 1e-9 {
            return Err(format!("aware {aware} worse than blind {blind}"));
        }
        Ok(())
    });
}

#[test]
fn heterogeneity_speedup_grows_with_skew() {
    // the paper's §IV-D setting: uniform blocks, one device k-times slower.
    let mk = |skew: f64| CostModel {
        t0_ms: vec![10.0; 12],
        out_bytes: vec![100_000; 12],
        capacities: vec![1.0, 1.0, skew],
        bandwidth_bps: vec![12.5e6, 12.5e6],
    };
    let ratio = |skew: f64| {
        let cm = mk(skew);
        let (_, aware) = optimal_partition(&cm);
        let (_, blind) = homogeneous_partition(&cm);
        blind / aware
    };
    let r2 = ratio(2.0);
    let r10 = ratio(10.0);
    assert!(r10 > r2, "speedup should grow with skew: r2={r2:.2} r10={r10:.2}");
    // at 10x skew the blind partition leaves the slow device with 1/3 of
    // the blocks -> ~>2.5x bottleneck gap
    assert!(r10 > 2.0, "r10={r10:.2}");
}

/// Satellite (ISSUE 10): the replica-axis chain DP is optimal against
/// brute-force cut enumeration and its plans are always structurally
/// valid — every device in exactly one chain (fleet order), shards
/// disjoint and complete under the `b % R` round-robin rule.
#[test]
fn prop_replica_chain_split_is_optimal_and_valid() {
    check("replica-chains", 300, |g| {
        let n = g.usize_in(3, 9);
        let replicas = g.usize_in(1, n.min(4));
        let caps: Vec<f64> = (0..n)
            .map(|i| if i == 0 { 1.0 } else { g.f64_in(0.25, 12.0) })
            .collect();
        let batches = g.usize_in(0, 40) as u64;
        let plan = replica_plan(&caps, replicas, batches);
        validate_replica_plan(&plan, n, batches).map_err(|e| e.to_string())?;
        if plan.chains.len() != replicas {
            return Err(format!("{} chains != {replicas} replicas", plan.chains.len()));
        }
        // DP worst-chain cost must equal the brute-force optimum
        let dp_worst = plan
            .chains
            .iter()
            .map(|devs| chain_cost(&devs.iter().map(|&d| caps[d]).collect::<Vec<_>>()))
            .fold(0.0f64, f64::max);
        let (_, bf_worst) = bruteforce_replica_chains(&caps, replicas);
        if (dp_worst - bf_worst).abs() > 1e-9 * bf_worst.max(1.0) {
            return Err(format!("dp worst {dp_worst} != brute force {bf_worst} for {caps:?}"));
        }
        // split_chains and replica_plan must agree (same DP underneath)
        if split_chains(&caps, replicas) != plan.chains {
            return Err("split_chains disagrees with replica_plan".into());
        }
        Ok(())
    });
}
