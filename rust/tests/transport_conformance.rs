//! Transport conformance: one generic test suite run against BOTH
//! [`Transport`] implementations — the in-process [`SimNet`] and the real
//! [`TcpEndpoint`] sockets. Any behavior the pipeline relies on
//! (identity, FIFO per link, payload integrity across every message
//! family, fire-and-forget to unreachable peers, bidirectional traffic,
//! and the lifecycle surface: `flush`, `peer_health`, `shutdown`) must
//! hold identically on both, or the sim results stop predicting the
//! real deployment.

use std::time::{Duration, Instant};

use ftpipehd::net::message::{ExecReport, Message, Payload, ReplicaKind, TrainInit, WireTensor};
use ftpipehd::net::quant::{Bits, ChannelHint, Tier};
use ftpipehd::net::{Compression, QTensor, SimNet, TcpEndpoint, Transport};

/// Messages spanning every wire family: small control, tensor payloads,
/// nested wire blocks, state structs.
fn probe_messages() -> Vec<Message> {
    vec![
        Message::Probe,
        Message::ProbeAck { id: 2, fresh: true },
        Message::Forward {
            batch: 11,
            version0: 3,
            is_eval: false,
            data: Payload::F32(vec![0.5; 513].into()),
        },
        Message::Forward {
            batch: 12,
            version0: 0,
            is_eval: true,
            data: Payload::I32(vec![-7, 0, 9]),
        },
        Message::Labels { batch: 11, is_eval: false, data: vec![1, 2, 3, 4] },
        Message::Backward {
            batch: 11,
            grad: vec![-0.25; 127].into(),
            loss: 1.5,
            ncorrect: 7.0,
            reports: vec![ExecReport { device: 1, avg_ms: 12.5, batches: 8 }],
        },
        // quantized data plane: the INT8 arms must survive both
        // transports bit-exactly, like their f32 siblings
        Message::Forward {
            batch: 13,
            version0: 3,
            is_eval: false,
            data: Payload::Quant(QTensor::quantize(&[0.0, -1.5, 2.25, 0.125])),
        },
        Message::Backward {
            batch: 13,
            grad: WireTensor::Quant(QTensor::quantize(&[-0.5, 0.5, 0.0625])),
            loss: 0.25,
            ncorrect: 3.0,
            reports: vec![],
        },
        Message::EvalResult { batch: 4, loss: 0.75, ncorrect: 30.0 },
        Message::InitState(TrainInit {
            committed_forward: -1,
            committed_backward: -1,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 4e-5,
            epochs: 2,
            batches_per_epoch: 50,
            ranges: vec![(0, 2), (3, 5)],
            worker_list: vec![0, 1],
            agg_k: 4,
            chain_every: 50,
            global_every: 100,
            status: 0,
            compression: Compression::Activations,
            bw_probe_every: 4,
            bw_probe_bytes: 0,
            tier_floor: Tier::Off,
            tier_ceiling: Tier::FullQ4,
            replica_epoch: 1,
            worker_quota: 4,
            replicas: 2,
            sync_every: 10,
        }),
        Message::Repartition {
            ranges: vec![(0, 3), (4, 5)],
            worker_list: vec![0, 1],
            failed: vec![2],
        },
        Message::FetchWeights { blocks: vec![3, 4, 5] },
        Message::Weights {
            blocks: vec![(3, vec![vec![1.0; 65].into(), vec![2.0; 3].into()])],
        },
        Message::ReplicaPush {
            kind: ReplicaKind::Chain,
            owner_stage: 1,
            owner_device: 1,
            version: 9,
            blocks: vec![(
                4,
                vec![vec![-1.0; 33].into(), WireTensor::Quant(QTensor::quantize(&[1.0, 2.0]))],
            )],
        },
        Message::FetchDone { id: 1 },
        Message::Commit,
        Message::Reset { committed: 10 },
        Message::BwTest { payload_bytes: 64, data: vec![0xAB; 64] },
        Message::BwAck { payload_bytes: 64 },
        Message::BwReport { stage: 1, bps: 12.5e6, to: 2 },
        Message::SetLr { lr: 0.005 },
        Message::CentralRestart { committed: 29 },
        Message::WorkerState { id: 1, committed_fwd: 34, committed_bwd: 33, fresh: false },
        Message::SetCompression { tier: Tier::FullQ4, links: vec![(2, Tier::Full)] },
        // v4 quant arms: per-channel scales and packed 4-bit codes must
        // survive both transports bit-exactly, odd lengths included
        Message::Weights {
            blocks: vec![(7, vec![WireTensor::Quant(QTensor::quantize_weights(
                &(0..64).map(|i| i as f32 * 0.3 - 9.0).collect::<Vec<_>>(),
                ChannelHint::Rows(2),
                Bits::B8,
            ))])],
        },
        Message::ReplicaPush {
            kind: ReplicaKind::Global,
            owner_stage: 2,
            owner_device: 2,
            version: 11,
            blocks: vec![(5, vec![
                WireTensor::Quant(QTensor::quantize_weights(
                    &(0..48).map(|i| (i as f32).cos()).collect::<Vec<_>>(),
                    ChannelHint::Cols(4),
                    Bits::B4,
                )),
                WireTensor::Quant(QTensor::quantize_bits(&[0.1, -0.2, 0.3], Bits::B4)),
            ])],
        },
        // v8 replica-sync arms: f32 and quantized weight partials must
        // survive both transports bit-exactly
        Message::ReplicaSync {
            round: 3,
            block_id: 2,
            tensors: vec![vec![0.5; 17].into(), vec![-2.0; 3].into()],
        },
        Message::ReplicaSync {
            round: 4,
            block_id: 0,
            tensors: vec![
                WireTensor::Quant(QTensor::quantize_weights(
                    &(0..32).map(|i| (i as f32).sin()).collect::<Vec<_>>(),
                    ChannelHint::Rows(4),
                    Bits::B8,
                )),
                WireTensor::Quant(QTensor::quantize_bits(&[0.25, -0.75], Bits::B4)),
            ],
        },
        Message::Shutdown,
    ]
}

/// The generic conformance suite. `e0`/`e1` are live endpoints with ids
/// 0 and 1 in a 3-device deployment; device `dead_to` is unreachable
/// (killed on the sim, never bound on TCP).
fn conformance(e0: &dyn Transport, e1: &dyn Transport, dead_to: usize) {
    // --- identity ---
    assert_eq!(e0.my_id(), 0);
    assert_eq!(e1.my_id(), 1);
    assert_eq!(e0.n_devices(), 3);
    assert_eq!(e1.n_devices(), 3);

    // --- payload integrity across every message family, 0 -> 1 ---
    for msg in probe_messages() {
        e0.send(1, msg.clone()).unwrap();
        let (from, got) = e1
            .recv_timeout(Duration::from_secs(5))
            .unwrap_or_else(|| panic!("no delivery of {}", msg.tag()));
        assert_eq!(from, 0, "{}", msg.tag());
        assert_eq!(got, msg, "payload corrupted for {}", msg.tag());
    }

    // --- FIFO per directed link ---
    for b in 0..32u64 {
        e0.send(1, Message::Labels { batch: b, is_eval: false, data: vec![] }).unwrap();
    }
    for b in 0..32u64 {
        match e1.recv_timeout(Duration::from_secs(2)) {
            Some((0, Message::Labels { batch, .. })) => assert_eq!(batch, b, "FIFO violated"),
            other => panic!("unexpected {other:?}"),
        }
    }

    // --- bidirectional traffic on one pair ---
    e1.send(0, Message::FetchDone { id: 1 }).unwrap();
    e0.send(1, Message::Commit).unwrap();
    assert!(matches!(
        e0.recv_timeout(Duration::from_secs(2)),
        Some((1, Message::FetchDone { id: 1 }))
    ));
    assert!(matches!(e1.recv_timeout(Duration::from_secs(2)), Some((0, Message::Commit))));

    // --- fire-and-forget to an unreachable peer: Ok, no delivery ---
    e0.send(dead_to, Message::Probe).expect("send to unreachable peer must not error");
    // and the live link still works afterwards
    e0.send(1, Message::Probe).unwrap();
    assert!(matches!(e1.recv_timeout(Duration::from_secs(2)), Some((0, Message::Probe))));
}

/// Poll `cond` until it holds or `secs` elapse. The health surface is
/// updated by background machinery (the TCP driver thread, the sim wire
/// thread), so observations need a deadline, not a single probe.
fn eventually(secs: u64, what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Lifecycle surface: `flush` drains a burst, probe traffic feeds
/// `peer_health`, sends to a dead peer raise `consecutive_failures`,
/// and `shutdown` silences an endpoint without breaking its peers.
/// Runs after [`conformance`] on the same endpoints; kills `e1` at the
/// end, so it must be the last thing a test does with these endpoints.
fn lifecycle(e0: &dyn Transport, e1: &dyn Transport, dead_to: usize) {
    // --- flush: after it returns, the burst has left this endpoint ---
    for b in 100..108u64 {
        e0.send(1, Message::Labels { batch: b, is_eval: false, data: vec![1, 2] }).unwrap();
    }
    e0.flush(Duration::from_secs(5)).expect("flush of a small burst must drain");
    for b in 100..108u64 {
        match e1.recv_timeout(Duration::from_secs(2)) {
            Some((0, Message::Labels { batch, .. })) => assert_eq!(batch, b),
            other => panic!("lost flushed message: {other:?}"),
        }
    }

    // --- peer_health: a probe/ack round-trip yields last_seen + rtt ---
    e0.send(1, Message::Probe).unwrap();
    assert!(matches!(e1.recv_timeout(Duration::from_secs(2)), Some((0, Message::Probe))));
    e1.send(0, Message::ProbeAck { id: 1, fresh: false }).unwrap();
    assert!(matches!(e0.recv_timeout(Duration::from_secs(2)), Some((1, Message::ProbeAck { .. }))));
    eventually(5, "probe round-trip to show up in peer_health", || {
        let h = e0.peer_health(1);
        h.last_seen.is_some() && h.rtt.is_some() && h.consecutive_failures == 0
    });

    // --- dead peer: failures accumulate, health reports them ---
    e0.send(dead_to, Message::Labels { batch: 0, is_eval: false, data: vec![] }).unwrap();
    e0.flush(Duration::from_secs(5)).unwrap();
    eventually(5, "consecutive_failures on the dead peer", || {
        e0.peer_health(dead_to).consecutive_failures >= 1
    });

    // --- shutdown: e1 goes quiet, e0 keeps working (fire-and-forget) ---
    e1.shutdown();
    e0.send(1, Message::Commit).expect("send to a shut-down peer must not error");
    assert!(
        e1.recv_timeout(Duration::from_millis(200)).is_none(),
        "a shut-down endpoint must hear nothing"
    );
}

#[test]
fn simnet_conforms() {
    let (net, eps) = SimNet::new(3, vec![1e9], Duration::ZERO);
    net.kill(2);
    conformance(&eps[0], &eps[1], 2);
    assert!(
        eps[2].recv_timeout(Duration::from_millis(50)).is_none(),
        "killed device must hear nothing"
    );
    lifecycle(&eps[0], &eps[1], 2);
}

#[test]
fn tcp_conforms() {
    // device 2's address is allocated but never bound: the unreachable peer
    let addrs: Vec<String> = (0..3).map(|i| format!("127.0.0.1:{}", 46300 + i)).collect();
    let e0 = TcpEndpoint::bind(0, addrs.clone()).unwrap();
    let e1 = TcpEndpoint::bind(1, addrs).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // listeners up
    conformance(&e0, &e1, 2);
    lifecycle(&e0, &e1, 2);
}
