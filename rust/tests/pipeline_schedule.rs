//! Integration: the 1F1B / weight-stashing / aggregation schedule the
//! paper's Fig. 2 illustrates, asserted on a real 3-stage training run
//! over the compiled edgenet-tiny artifacts.
//!
//! Requires `make artifacts` (skips gracefully if missing).

use std::collections::HashMap;

use ftpipehd::config::{DeviceConfig, RunConfig};
use ftpipehd::coordinator::{run_sim_full, RunOpts};
use ftpipehd::pipeline::trace::{new_sink, TraceKind};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/edgenet-tiny/manifest.json").exists()
}

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model_dir = "artifacts/edgenet-tiny".into();
    cfg.devices = vec![DeviceConfig::default(); 3];
    cfg.epochs = 1;
    cfg.batches_per_epoch = 24;
    cfg.eval_batches = 0;
    cfg.repartition_first = None; // keep stages fixed so the trace is clean
    cfg.repartition_every = None;
    cfg.chain_every = None;
    cfg.global_every = None;
    cfg.agg_interval_k = Some(2);
    cfg.bandwidth_bps = vec![1e9];
    cfg.link_latency_s = 0.0;
    cfg
}

#[test]
fn schedule_obeys_1f1b_stashing_and_aggregation() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let (sink, events) = new_sink();
    let cfg = base_cfg();
    let out = run_sim_full(
        &cfg,
        RunOpts { trace: sink, ..Default::default() },
    )
    .expect("run");
    assert_eq!(out.record.batches.len(), 24);

    let ev = events.lock().unwrap().clone();
    assert!(!ev.is_empty());

    // --- every batch is forwarded and backwarded exactly once per stage ---
    let mut fwd_count: HashMap<(usize, u64), usize> = HashMap::new();
    let mut bwd_count: HashMap<(usize, u64), usize> = HashMap::new();
    for e in &ev {
        match e.kind {
            TraceKind::Forward => *fwd_count.entry((e.stage, e.batch)).or_default() += 1,
            TraceKind::Backward => *bwd_count.entry((e.stage, e.batch)).or_default() += 1,
            TraceKind::Aggregate => {}
        }
    }
    for stage in 0..3usize {
        for b in 0..24u64 {
            assert_eq!(fwd_count.get(&(stage, b)), Some(&1), "fwd s{stage} b{b}");
            assert_eq!(bwd_count.get(&(stage, b)), Some(&1), "bwd s{stage} b{b}");
        }
    }

    // --- per-stage event order: F(b) precedes B(b); batches complete in order ---
    for stage in 0..3usize {
        let stage_ev: Vec<_> = ev.iter().filter(|e| e.stage == stage).collect();
        let mut fwd_seen: Vec<u64> = vec![];
        let mut bwd_seen: Vec<u64> = vec![];
        for e in &stage_ev {
            match e.kind {
                TraceKind::Forward => fwd_seen.push(e.batch),
                TraceKind::Backward => {
                    assert!(
                        fwd_seen.contains(&e.batch),
                        "stage {stage}: backward of {} before forward",
                        e.batch
                    );
                    bwd_seen.push(e.batch);
                }
                TraceKind::Aggregate => {}
            }
        }
        // forwards and backwards are FIFO within a stage (pipeline order)
        let mut sorted_f = fwd_seen.clone();
        sorted_f.sort_unstable();
        assert_eq!(fwd_seen, sorted_f, "stage {stage} forward order");
        let mut sorted_b = bwd_seen.clone();
        sorted_b.sort_unstable();
        assert_eq!(bwd_seen, sorted_b, "stage {stage} backward order");
    }

    // --- asynchrony: stage 0 forwards several batches before its first
    //     backward (warmup = pipeline depth; PipeDream 1F1B signature) ---
    let s0: Vec<_> = ev.iter().filter(|e| e.stage == 0).collect();
    let first_bwd_pos = s0.iter().position(|e| e.kind == TraceKind::Backward).unwrap();
    assert!(
        first_bwd_pos >= 2,
        "stage 0 should forward >=2 batches before its first backward (got {first_bwd_pos})"
    );

    // --- weight versions advance once per backward at each stage ---
    for stage in 0..3usize {
        let bwd_versions: Vec<u64> = ev
            .iter()
            .filter(|e| e.stage == stage && e.kind == TraceKind::Backward)
            .map(|e| e.version)
            .collect();
        for w in bwd_versions.windows(2) {
            assert!(w[1] > w[0], "stage {stage}: version must strictly increase");
        }
    }

    // --- aggregation fires on stages with >= 2 live versions, not the last ---
    let agg_stages: std::collections::BTreeSet<usize> = ev
        .iter()
        .filter(|e| e.kind == TraceKind::Aggregate)
        .map(|e| e.stage)
        .collect();
    assert!(agg_stages.contains(&0), "stage 0 must aggregate (agg_k=2)");
    assert!(agg_stages.contains(&1), "stage 1 must aggregate");
    assert!(!agg_stages.contains(&2), "last stage has one live version");
}

#[test]
fn aggregation_disabled_produces_no_aggregate_events() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let (sink, events) = new_sink();
    let mut cfg = base_cfg();
    cfg.agg_interval_k = None;
    run_sim_full(&cfg, RunOpts { trace: sink, ..Default::default() }).expect("run");
    let ev = events.lock().unwrap();
    assert!(ev.iter().all(|e| e.kind != TraceKind::Aggregate));
}
