//! Property tests for the Algorithm-1 weight-redistribution planner and
//! the worker-list renumbering rules (paper §III-D/F).

use std::collections::BTreeSet;

use ftpipehd::fault::{
    plan_redistribution, renumber, renumber_worker_list, source_of_block, Source,
};
use ftpipehd::partition::{uniform_partition, validate_partition, Partition};
use ftpipehd::util::prop::{check, G};

fn random_partition(g: &mut G<'_>, n_blocks: usize, n_stages: usize) -> Partition {
    let cuts = g.cuts(n_blocks, n_stages - 1);
    let mut parts = Vec::with_capacity(n_stages);
    let mut lo = 0;
    for c in cuts {
        parts.push((lo, c - 1));
        lo = c;
    }
    parts.push((lo, n_blocks - 1));
    parts
}

#[test]
fn prop_random_partitions_are_valid() {
    check("random-partition-valid", 300, |g| {
        let n_blocks = g.usize_in(3, 24);
        let n_stages = g.usize_in(1, n_blocks.min(6));
        let p = random_partition(g, n_blocks, n_stages);
        validate_partition(&p, n_blocks).map_err(|e| e.to_string())
    });
}

/// Every block of the new partition is either held locally or has a
/// source; sources never point at dead stages; the plan covers exactly
/// the device's new range.
#[test]
fn prop_plan_covers_new_range_exactly() {
    check("plan-covers-range", 500, |g| {
        let n_blocks = g.usize_in(4, 20);
        let n_old = g.usize_in(2, n_blocks.min(5));
        let p_cur = random_partition(g, n_blocks, n_old);
        // pick failures (keep central alive; at least one survivor worker)
        let n_fail = g.usize_in(0, n_old - 2);
        let mut failed: Vec<usize> = Vec::new();
        while failed.len() < n_fail {
            let f = g.usize_in(1, n_old - 1);
            if !failed.contains(&f) {
                failed.push(f);
            }
        }
        failed.sort_unstable();
        let n_new = n_old - failed.len();
        let p_new = random_partition(g, n_blocks, n_new);

        // check the plan of every alive device
        for old_stage in 0..n_old {
            if failed.contains(&old_stage) {
                continue;
            }
            let i_new = renumber(old_stage, &failed).unwrap();
            let (lo, hi) = p_cur[old_stage];
            let held: Vec<usize> = (lo..=hi).collect();
            let plan = plan_redistribution(&p_new, &p_cur, &failed, &held, i_new, Some(old_stage));

            let (nlo, nhi) = p_new[i_new];
            let covered: BTreeSet<usize> = plan
                .local
                .iter()
                .copied()
                .chain(plan.need.values().flatten().copied())
                .collect();
            let expected: BTreeSet<usize> = (nlo..=nhi).collect();
            if covered != expected {
                return Err(format!(
                    "coverage mismatch: {covered:?} != {expected:?} (plan {plan:?})"
                ));
            }
            // locals must be held
            for l in &plan.local {
                if !held.contains(l) {
                    return Err(format!("local block {l} not actually held"));
                }
            }
            // stage sources must be alive new-list stages, never myself
            for (src, blocks) in &plan.need {
                if let Source::Stage(s) = src {
                    if *s >= n_new {
                        return Err(format!("source stage {s} out of range"));
                    }
                    if *s == i_new {
                        return Err(format!(
                            "plan asks to network-fetch {blocks:?} from itself"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Single-failure plans must follow the paper's Algorithm-1 index rules.
#[test]
fn prop_single_failure_index_correction_matches_paper() {
    check("alg1-index-rules", 300, |g| {
        let n_blocks = g.usize_in(6, 18);
        let n_old = g.usize_in(3, n_blocks.min(6));
        let p_cur = random_partition(g, n_blocks, n_old);
        let i_fail = g.usize_in(1, n_old - 1);
        for l in 0..n_blocks {
            let owner = p_cur.iter().position(|&(lo, hi)| (lo..=hi).contains(&l)).unwrap();
            let src = source_of_block(l, &p_cur, &[i_fail]);
            let expect = if owner > i_fail {
                Source::Stage(owner - 1) // paper: I_target > I_fail
            } else if owner == i_fail {
                if i_fail == n_old - 1 {
                    Source::Stage(0) // paper: last stage -> central
                } else {
                    Source::Stage(i_fail) // paper: index unchanged (replica holder)
                }
            } else {
                Source::Stage(owner)
            };
            if src != expect {
                return Err(format!(
                    "block {l} owner {owner} fail {i_fail}: got {src:?}, want {expect:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_renumbered_list_preserves_alive_order() {
    check("renumber-order", 300, |g| {
        let n = g.usize_in(2, 8);
        let list: Vec<usize> = (100..100 + n).collect();
        let n_fail = g.usize_in(0, n - 1);
        let mut failed = Vec::new();
        while failed.len() < n_fail {
            let f = g.usize_in(0, n - 1);
            if !failed.contains(&f) {
                failed.push(f);
            }
        }
        failed.sort_unstable();
        let new = renumber_worker_list(&list, &failed);
        if new.len() != n - failed.len() {
            return Err(format!("length {} wrong", new.len()));
        }
        // order preserved and devices are exactly the alive ones
        let alive: Vec<usize> = (0..n).filter(|s| !failed.contains(s)).map(|s| list[s]).collect();
        if new != alive {
            return Err(format!("{new:?} != {alive:?}"));
        }
        // renumber() agrees with the list positions
        for (old_stage, &dev) in list.iter().enumerate() {
            match renumber(old_stage, &failed) {
                Some(ni) => {
                    if new[ni] != dev {
                        return Err(format!("renumber({old_stage}) -> {ni} mismatches"));
                    }
                }
                None => {
                    if !failed.contains(&old_stage) {
                        return Err("renumber returned None for alive stage".into());
                    }
                }
            }
        }
        Ok(())
    });
}

/// A restarted device (empty state) never plans a fetch from itself and
/// always covers its whole range from peers/backups.
#[test]
fn prop_restarted_device_plan_is_serviceable() {
    check("restart-plan", 300, |g| {
        let n_blocks = g.usize_in(4, 16);
        let n = g.usize_in(2, n_blocks.min(5));
        let p = uniform_partition(n_blocks, n);
        let stage = g.usize_in(1, n - 1);
        let plan = plan_redistribution(&p, &p, &[], &[], stage, Some(stage));
        if !plan.local.is_empty() {
            return Err("restarted device cannot hold anything".into());
        }
        let total: usize = plan.need.values().map(|v| v.len()).sum();
        let (lo, hi) = p[stage];
        if total != hi - lo + 1 {
            return Err(format!("plan covers {total}, want {}", hi - lo + 1));
        }
        for src in plan.need.keys() {
            match src {
                Source::Stage(s) if *s == stage => {
                    return Err("fetch from itself".into());
                }
                Source::LocalBackup => {
                    return Err("restarted device has no local backups".into());
                }
                _ => {}
            }
        }
        Ok(())
    });
}
