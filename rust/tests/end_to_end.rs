//! Integration: full simulated training runs across engines, fault
//! scenarios, re-partitioning, and warm-started continuous training.
//! All tests require `make artifacts` (they skip gracefully otherwise).

use ftpipehd::config::{DeviceConfig, Engine, FaultPlan, RunConfig};
use ftpipehd::coordinator::{run_sim, run_sim_full, RunOpts};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/edgenet-tiny/manifest.json").exists()
}

fn tiny_cfg(n_devices: usize, batches: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model_dir = "artifacts/edgenet-tiny".into();
    cfg.devices = vec![DeviceConfig::default(); n_devices];
    cfg.epochs = 1;
    cfg.batches_per_epoch = batches;
    cfg.eval_batches = 3;
    cfg.bandwidth_bps = vec![1e8];
    cfg.link_latency_s = 0.0005;
    cfg.fault_timeout_ms = 3000;
    cfg
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn three_device_training_learns() {
    require_artifacts!();
    let record = run_sim(&tiny_cfg(3, 50)).expect("run");
    assert_eq!(record.batches.len(), 50);
    let e = record.epochs.last().expect("epoch record");
    assert!(e.val_acc > 0.5, "val_acc {} too low", e.val_acc);
    // losses trend down
    let first: f32 = record.batches[..5].iter().map(|b| b.loss).sum::<f32>() / 5.0;
    let last: f32 = record.batches[45..].iter().map(|b| b.loss).sum::<f32>() / 5.0;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn single_device_equals_pipeline_semantics() {
    require_artifacts!();
    let mut cfg = tiny_cfg(1, 40);
    cfg.engine = Engine::SingleDevice;
    let record = run_sim(&cfg).expect("run");
    assert_eq!(record.batches.len(), 40);
    assert!(record.epochs.last().unwrap().val_acc > 0.5);
}

#[test]
fn sync_pipeline_engine_runs() {
    require_artifacts!();
    let mut cfg = tiny_cfg(3, 30);
    cfg.engine = Engine::SyncPipeline;
    let record = run_sim(&cfg).expect("run");
    assert_eq!(record.batches.len(), 30);
}

#[test]
fn pipedream_engine_never_repartitions() {
    require_artifacts!();
    let mut cfg = tiny_cfg(3, 40);
    cfg.engine = Engine::PipeDream;
    cfg.devices[2].capacity = 5.0;
    let record = run_sim(&cfg).expect("run");
    assert!(record.partitions.is_empty(), "pipedream must stay static");
}

#[test]
fn ftpipehd_repartitions_under_heterogeneity() {
    require_artifacts!();
    let mut cfg = tiny_cfg(3, 60);
    cfg.devices[2].capacity = 6.0;
    cfg.repartition_first = Some(10);
    cfg.repartition_every = Some(30);
    let record = run_sim(&cfg).expect("run");
    assert!(
        !record.partitions.is_empty(),
        "expected at least one re-partition under 6x skew"
    );
    // the slow device (last stage) must end with fewer blocks than uniform
    let (lo, hi) = *record.partitions.last().unwrap().1.last().unwrap();
    assert!(hi - lo + 1 <= 2, "slow stage kept {} blocks", hi - lo + 1);
}

#[test]
fn fault_recovery_dead_worker_completes_training() {
    require_artifacts!();
    let mut cfg = tiny_cfg(4, 60);
    cfg.fault = Some(FaultPlan { kill_device: 2, at_batch: 30, restarts: false });
    cfg.chain_every = Some(10);
    cfg.global_every = Some(20);
    let record = run_sim(&cfg).expect("run");
    assert_eq!(record.batches.len(), 60, "all batches must complete despite the fault");
    assert!(record.recovery_overhead_s.is_some());
    assert!(record.epochs.last().unwrap().val_acc > 0.5);
    // a re-partition to 3 stages must have happened
    let p = &record.partitions.last().expect("recovery partition").1;
    assert_eq!(p.len(), 3);
}

#[test]
fn fault_recovery_restarted_worker_case2() {
    require_artifacts!();
    let mut cfg = tiny_cfg(3, 60);
    cfg.fault = Some(FaultPlan { kill_device: 1, at_batch: 30, restarts: true });
    cfg.chain_every = Some(10);
    let record = run_sim(&cfg).expect("run");
    assert_eq!(record.batches.len(), 60);
    // case 2 keeps all 3 stages (no stage removal)
    let case2 = record.events.iter().any(|e| e.kind.contains("case 2"));
    if case2 {
        assert!(
            record.partitions.iter().all(|(_, p)| p.len() == 3),
            "case 2 must not shrink the pipeline"
        );
    } else {
        // timing may classify it as case 3 (still dead at probe time);
        // either way training must finish — but we log it
        eprintln!("note: restart raced the probe; classified as case 3");
    }
}

#[test]
fn respipe_recovery_merges_instead_of_repartitioning() {
    require_artifacts!();
    let mut cfg = tiny_cfg(4, 60);
    cfg.engine = Engine::ResPipe;
    cfg.fault = Some(FaultPlan { kill_device: 2, at_batch: 30, restarts: false });
    cfg.chain_every = Some(10);
    let record = run_sim(&cfg).expect("run");
    assert_eq!(record.batches.len(), 60);
    let p = &record.partitions.last().expect("recovery partition").1;
    assert_eq!(p.len(), 3);
    // merged: some stage covers the union of two old uniform ranges
    let widths: Vec<usize> = p.iter().map(|&(lo, hi)| hi - lo + 1).collect();
    assert!(
        widths.iter().any(|&w| w >= 2),
        "respipe merge should create an oversized stage: {p:?}"
    );
}

#[test]
fn oom_on_memory_capped_single_device() {
    require_artifacts!();
    let mut cfg = tiny_cfg(1, 10);
    cfg.engine = Engine::SingleDevice;
    cfg.devices[0].mem_cap_bytes = Some(1000); // way below model size
    let record = run_sim(&cfg).expect("run returns with OOM event");
    assert!(record.batches.is_empty());
    assert!(record.events.iter().any(|e| e.kind.contains("OOM")));
}

#[test]
fn continuous_training_warm_start_resumes_better() {
    require_artifacts!();
    // phase 1: pretrain and collect weights
    let mut cfg = tiny_cfg(3, 40);
    cfg.eval_batches = 5;
    let out = run_sim_full(
        &cfg,
        RunOpts { collect_final_weights: true, ..Default::default() },
    )
    .expect("pretrain");
    assert_eq!(out.final_weights.len(), 6, "one entry per block");
    let pretrain_acc = out.record.epochs.last().unwrap().val_acc;

    // phase 2: warm-start on the same data — accuracy from batch 0 must be
    // far above chance and the first-epoch val_acc at least as good
    let mut cfg2 = tiny_cfg(3, 10);
    cfg2.eval_batches = 5;
    let out2 = run_sim_full(
        &cfg2,
        RunOpts {
            initial_weights: Some(out.final_weights),
            ..Default::default()
        },
    )
    .expect("continue");
    let early_acc: f32 = out2.record.batches[..5]
        .iter()
        .map(|b| b.train_acc)
        .sum::<f32>()
        / 5.0;
    assert!(
        early_acc > 0.5,
        "warm start should begin near the pretrained accuracy, \
         got {early_acc} (pretrain {pretrain_acc})"
    );
}

#[test]
fn network_bytes_accounted() {
    require_artifacts!();
    let record = run_sim(&tiny_cfg(3, 20)).expect("run");
    // activations + gradients + labels must dominate: at least
    // batches * (act one way + grad back) bytes
    assert!(record.net_bytes > 100_000, "net bytes {}", record.net_bytes);
}
