//! Reactor-level integration tests for the event-driven TCP transport:
//! hostile-input hardening, the down-peer fast-fail/recovery cycle,
//! burst integrity under coalesced writes, the flush/shutdown contract,
//! and a loopback throughput smoke test wired to the same metric names
//! the `micro_runtime` bench gates in `BENCH_BASELINE.json`.
//!
//! Ports 46400-46449 (see the repo-wide test port map in
//! `rust/src/net/tcp.rs`).

use std::io::{Read, Write};
use std::time::{Duration, Instant};

use ftpipehd::net::message::{Message, Payload};
use ftpipehd::net::{TcpConfig, TcpEndpoint, Transport};
use ftpipehd::sim::real_clock;

fn eventually(secs: u64, what: &str, cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    let mut cond = cond;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// A connection that announces an absurd frame length is cut off — and
/// only that connection: the endpoint keeps serving its real peers.
#[test]
fn oversized_frame_kills_connection_but_not_endpoint() {
    let eps = ftpipehd::net::loopback_cluster(2, 46400).unwrap();

    // hostile raw connection: 4-byte header claiming a ~4 GiB frame
    let mut raw = std::net::TcpStream::connect("127.0.0.1:46400").unwrap();
    raw.write_all(&[0xFF, 0xFF, 0xFF, 0xFF]).unwrap();
    raw.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let mut buf = [0u8; 64];
        match raw.read(&mut buf) {
            Ok(0) => break, // driver dropped the connection (FIN)
            Ok(_) => panic!("driver should never write to an inbound connection"),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                assert!(Instant::now() < deadline, "hostile connection never dropped");
            }
            Err(_) => break, // reset also proves the drop
        }
    }

    // the endpoint itself is unharmed: a legitimate peer still gets through
    eps[1].send(0, Message::Labels { batch: 9, is_eval: false, data: vec![3] }).unwrap();
    match eps[0].recv_timeout(Duration::from_secs(5)) {
        Some((1, Message::Labels { batch: 9, .. })) => {}
        other => panic!("endpoint broken after hostile frame: {other:?}"),
    }
}

/// Once a dial fails, non-probe sends to that peer drop instantly for
/// `down_ttl` (no connect timeout on the training path). `Probe` bypasses
/// the TTL, and a successful dial clears the down state entirely.
#[test]
fn down_peer_fast_fail_and_recovery() {
    let addrs = vec!["127.0.0.1:46410".to_string(), "127.0.0.1:46411".to_string()];
    let cfg = TcpConfig::builder()
        .connect_attempts(1)
        .down_ttl(Duration::from_secs(10))
        .build();
    let e0 = TcpEndpoint::bind_with(0, addrs.clone(), cfg.clone(), real_clock()).unwrap();

    // peer 1 is not bound yet: the dial fails and marks it down
    e0.send(1, Message::Labels { batch: 0, is_eval: false, data: vec![] }).unwrap();
    eventually(5, "failed dial to mark the peer down", || {
        e0.peer_health(1).consecutive_failures >= 1
    });

    // fast-fail path: a send to a known-down peer never touches a socket,
    // so flush drains immediately even though the peer is unreachable
    let t0 = Instant::now();
    e0.send(1, Message::Labels { batch: 1, is_eval: false, data: vec![] }).unwrap();
    e0.flush(Duration::from_secs(5)).unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "down-peer send should drop at enqueue, not wait out a connect timeout"
    );

    // peer comes up; Probe bypasses the down TTL and the successful dial
    // clears the down state for normal traffic
    let e1 = TcpEndpoint::bind_with(1, addrs, cfg, real_clock()).unwrap();
    let mut probed = false;
    eventually(10, "probe to punch through the down TTL", || {
        e0.send(1, Message::Probe).unwrap();
        probed = probed
            || matches!(e1.recv_timeout(Duration::from_millis(250)), Some((0, Message::Probe)));
        probed
    });
    e0.send(1, Message::Labels { batch: 2, is_eval: false, data: vec![7] }).unwrap();
    eventually(5, "normal traffic to resume after recovery", || {
        matches!(
            e1.recv_timeout(Duration::from_millis(250)),
            Some((0, Message::Labels { batch: 2, .. }))
        )
    });
    assert_eq!(e0.peer_health(1).consecutive_failures, 0, "recovery must clear failures");
}

/// A large bidirectional burst with mixed frame sizes: per-link FIFO and
/// bit-exact payloads must survive write coalescing and partial writes.
#[test]
fn burst_bidirectional_integrity() {
    const N: u64 = 300;
    fn msg_for(sender: usize, b: u64) -> Message {
        if b % 10 == 0 {
            // big frame: forces multi-pass vectored writes mid-burst
            Message::Forward {
                batch: b,
                version0: 1,
                is_eval: false,
                data: Payload::F32(vec![sender as f32 + b as f32 * 0.5; 50_000].into()),
            }
        } else {
            Message::Labels {
                batch: b,
                is_eval: false,
                data: vec![(sender * 1000) as i32 + b as i32],
            }
        }
    }
    fn pump(me: &TcpEndpoint, peer: usize) {
        for b in 0..N {
            me.send(peer, msg_for(me.my_id(), b)).unwrap();
        }
        for b in 0..N {
            let (from, got) = me
                .recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|| panic!("device {} lost message {b}", me.my_id()));
            assert_eq!(from, peer);
            assert_eq!(got, msg_for(peer, b), "corrupt or out-of-order at {b}");
        }
    }

    let mut eps = ftpipehd::net::loopback_cluster(2, 46420).unwrap();
    let e1 = eps.pop().unwrap();
    let e0 = eps.pop().unwrap();
    let h = std::thread::spawn(move || {
        pump(&e1, 0);
        e1
    });
    pump(&e0, 1);
    h.join().unwrap();
}

/// `flush` then `shutdown` is a clean goodbye: everything enqueued before
/// the flush reaches the peer even though the sender is torn down
/// immediately after.
#[test]
fn flush_then_shutdown_loses_nothing() {
    let mut eps = ftpipehd::net::loopback_cluster(2, 46430).unwrap();
    let e1 = eps.pop().unwrap();
    let e0 = eps.pop().unwrap();

    const N: u64 = 200;
    for b in 0..N {
        e0.send(1, Message::Labels { batch: b, is_eval: false, data: vec![b as i32] }).unwrap();
    }
    e0.flush(Duration::from_secs(10)).expect("burst must drain");
    e0.shutdown();

    for b in 0..N {
        match e1.recv_timeout(Duration::from_secs(10)) {
            Some((0, Message::Labels { batch, .. })) => assert_eq!(batch, b),
            other => panic!("message {b} lost across flush+shutdown: {other:?}"),
        }
    }
}

/// Loopback throughput smoke test. Numbers on shared CI runners are too
/// noisy to assert against directly here — the release-build gate lives in
/// the `micro_runtime` bench vs `BENCH_BASELINE.json`. This test (a) keeps
/// the path exercised under `cargo test`, (b) fails if the two TCP metric
/// names ever fall out of the gated baseline, and (c) optionally writes
/// the measured numbers to `$FTPIPEHD_TCP_BENCH_JSON` as a CI artifact.
#[test]
fn loopback_throughput_smoke_and_baseline_names() {
    // the baseline must gate both TCP metrics, or the bench-regression job
    // silently stops covering the transport
    let baseline = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_BASELINE.json"
    ))
    .expect("BENCH_BASELINE.json readable");
    let v = ftpipehd::util::json::parse(&baseline).expect("BENCH_BASELINE.json parses");
    let names: Vec<&str> = v
        .get("metrics")
        .and_then(|m| m.as_arr())
        .expect("metrics array")
        .iter()
        .filter_map(|m| m.get("name").and_then(|n| n.as_str()))
        .collect();
    for required in ["tcp_msgs_per_sec", "tcp_bytes_per_sec"] {
        assert!(names.contains(&required), "{required} missing from BENCH_BASELINE.json");
    }

    let mut eps = ftpipehd::net::loopback_cluster(2, 46440).unwrap();
    let e1 = eps.pop().unwrap();
    let e0 = eps.pop().unwrap();

    // small-message rate: enqueue a batch, then drain
    const SMALL: u64 = 2000;
    let t0 = Instant::now();
    for b in 0..SMALL {
        e0.send(1, Message::Labels { batch: b, is_eval: false, data: vec![1] }).unwrap();
    }
    for _ in 0..SMALL {
        assert!(e1.recv_timeout(Duration::from_secs(10)).is_some(), "small burst lost");
    }
    let msgs_per_sec = SMALL as f64 / t0.elapsed().as_secs_f64();

    // bulk rate: 16 x 256 KiB forwards
    const BULK: usize = 16;
    const ELEMS: usize = 65_536;
    let t0 = Instant::now();
    for b in 0..BULK {
        e0.send(
            1,
            Message::Forward {
                batch: b as u64,
                version0: 0,
                is_eval: false,
                data: Payload::F32(vec![0.25; ELEMS].into()),
            },
        )
        .unwrap();
    }
    for _ in 0..BULK {
        assert!(e1.recv_timeout(Duration::from_secs(30)).is_some(), "bulk burst lost");
    }
    let bytes_per_sec = (BULK * ELEMS * 4) as f64 / t0.elapsed().as_secs_f64();

    assert!(msgs_per_sec > 0.0 && bytes_per_sec > 0.0);
    eprintln!("loopback tcp: {msgs_per_sec:.0} msgs/s small, {bytes_per_sec:.3e} B/s bulk");
    if let Ok(path) = std::env::var("FTPIPEHD_TCP_BENCH_JSON") {
        let body = format!(
            "{{\n  \"tcp_msgs_per_sec\": {msgs_per_sec:.1},\n  \"tcp_bytes_per_sec\": {bytes_per_sec:.1}\n}}\n"
        );
        std::fs::write(&path, body).expect("write FTPIPEHD_TCP_BENCH_JSON artifact");
    }
}
