//! Family: hybrid pipeline + data parallelism (DESIGN.md §14).
//!
//! R replica chains train disjoint `b % R` shards and average weights
//! through the central node every `sync_every` per-chain batches. The
//! family pins three contracts:
//!
//! * **healthy** — an R=2 run is run-twice byte-identical, and every
//!   resolved sync round's installed weights are bit-identical to the
//!   analytic average (ascending-chain fold, one reciprocal multiply)
//!   of the per-chain weights the central node saw;
//! * **replica death** — killing a whole replica mid-epoch makes the
//!   survivors absorb its untrained shard remainder; the run stays
//!   deterministic and every batch still gets a finite loss;
//! * **R=1 regression** — an explicit `with_replicas(1, 0)` keeps every
//!   trace byte-identical to the pre-replica single-chain runner.

use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};
use ftpipehd::sim::ScenarioOutcome;

use crate::common;

/// Replica scenarios must switch off the single-chain subsystems the
/// fused-chain runner does not model (`Scenario::validate` enforces it).
fn replicated(name: &str, n: usize, batches: u64, r: usize, sync_every: u64) -> Scenario {
    let mut sc = Scenario::exact_recovery(name, n, batches);
    sc.chain_every = 0;
    sc.global_every = 0;
    sc.with_replicas(r, sync_every)
}

/// Recompute every sync round's average from the recorded per-chain
/// pre-sync weights with EXACTLY the runner's fold (ascending chain
/// order, one reciprocal multiply at the end) and demand bit-identity
/// with what the runner installed.
fn assert_sync_averages_bit_exact(tag: &str, out: &ScenarioOutcome) {
    assert!(!out.sync_records.is_empty(), "{tag}: no sync rounds resolved");
    for rec in &out.sync_records {
        let inv = 1.0f32 / rec.pre.len() as f32;
        for (b, post) in &rec.post {
            for (k, tensor) in post.0.iter().enumerate() {
                for (j, got) in tensor.iter().enumerate() {
                    let mut sum = 0.0f32;
                    for blocks in rec.pre.values() {
                        sum += blocks
                            .get(b)
                            .unwrap_or_else(|| panic!("{tag}: round {} pre missing block {b}", rec.round))
                            .0[k][j];
                    }
                    let want = sum * inv;
                    assert_eq!(
                        want.to_bits(),
                        got.to_bits(),
                        "{tag}: round {} block {b} tensor {k}[{j}]: average {want} != installed {got}",
                        rec.round
                    );
                }
            }
        }
    }
}

const TOTAL: u64 = 16;

fn healthy_r2() -> Scenario {
    let mut sc = replicated("replica-healthy", 4, TOTAL, 2, 4);
    // heterogeneous chains: the DP puts the fast pair on one chain
    sc.capacities = vec![1.0, 1.5, 1.0, 1.5];
    sc
}

#[test]
fn replica_r2_healthy_is_deterministic_and_averages_bit_exact() {
    let out = common::run_twice_deterministic("replica-healthy-det", &healthy_r2());
    common::assert_loss_continuity("replica-healthy", &out, TOTAL);
    assert_eq!(out.recoveries, 0);
    // 8 shard batches per chain, synced every 4 -> exactly 2 rounds
    assert_eq!(out.sync_records.len(), 2, "expected 2 sync rounds");
    assert_sync_averages_bit_exact("replica-healthy", &out);
    // the final weights ARE the last round's average: the run finishes
    // at the resolving barrier, nothing trains afterwards
    let last = out.sync_records.last().unwrap();
    for (b, bp) in &out.final_weights {
        let post = &last.post[b];
        for (k, t) in bp.0.iter().enumerate() {
            for (j, v) in t.iter().enumerate() {
                assert_eq!(v.to_bits(), post.0[k][j].to_bits(), "block {b} tensor {k}[{j}]");
            }
        }
    }
    // the shared phase machine walked Training -> Syncing -> Training
    // exactly once per round (the coordinator_core family hand-drives
    // the same sequence and compares byte-for-byte)
    let phase_log: Vec<&str> = out.phase_log.iter().map(String::as_str).collect();
    assert_eq!(
        phase_log,
        vec![
            "training-started: idle->training",
            "sync-due: training->syncing [begin-sync]",
            "poll: syncing->training [resolve-sync]",
            "sync-due: training->syncing [begin-sync]",
            "poll: syncing->training [resolve-sync]",
        ],
        "phase machine walked an unexpected sync sequence"
    );
}

const KILL_TOTAL: u64 = 24;

fn replica_kill() -> Scenario {
    // 12 shard batches per chain, synced every 4; replica 1 dies when
    // round 2 opens (8 trained), orphaning 4 untrained batches
    replicated("replica-kill", 4, KILL_TOTAL, 2, 4).with_events(vec![ScriptEvent {
        at: Trigger::SyncRound(2),
        action: Action::KillReplica { replica: 1 },
    }])
}

#[test]
fn replica_kill_survivors_absorb_shard_deterministically() {
    let out = common::run_twice_deterministic("replica-kill-det", &replica_kill());
    assert_eq!(out.recoveries, 1, "exactly one replica death expected");
    common::assert_trace_contains("replica-kill", &out, "script: kill replica 1 orphans=4");
    // the survivor's shard grew from 12 to 16
    common::assert_trace_contains("replica-kill", &out, "absorb: chain=0 shard_len=16");
    // every batch — including the victim's orphaned remainder — still
    // trained to a finite loss somewhere
    common::assert_loss_continuity("replica-kill", &out, KILL_TOTAL);
    // rounds keep resolving after the death (chain 0 alone), and every
    // resolved round still averages bit-exactly over its contributors
    assert_sync_averages_bit_exact("replica-kill", &out);
    let last = out.sync_records.last().unwrap();
    assert_eq!(last.pre.len(), 1, "post-kill rounds have a single contributor");
    // rounds 2+ never hear from the dead chain again
    for rec in &out.sync_records {
        if rec.round >= 2 {
            assert!(!rec.pre.contains_key(&1), "round {} heard from the dead replica", rec.round);
        }
    }
}

#[test]
fn replica_r1_explicit_is_byte_identical_to_default_runner() {
    // R=1 must not route into the replica runner: an explicit
    // `with_replicas(1, 0)` is the documented default and every trace
    // byte must match the plain single-chain scenario — including under
    // a mid-run fault, so the whole recovery path is covered
    let faulted = |name: &str| {
        Scenario::exact_recovery(name, 3, 20).with_events(vec![ScriptEvent {
            at: Trigger::BatchDone(9),
            action: Action::Kill { device: 1, revive_after: None },
        }])
    };
    let base = common::run_once("replica-r1-base", &faulted("replica-r1"));
    let explicit =
        common::run_once("replica-r1-explicit", &faulted("replica-r1").with_replicas(1, 0));
    assert_eq!(base.trace, explicit.trace, "R=1 explicit config changed the trace");
    assert_eq!(base.weights_bits(), explicit.weights_bits());
    assert_eq!(base.net_bytes, explicit.net_bytes);
    assert!(base.sync_records.is_empty() && explicit.sync_records.is_empty());
}
