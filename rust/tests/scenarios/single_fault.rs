//! Family: one worker dies mid-training and stays dead (paper case 3).
//!
//! Configuration is the *exact-recovery* regime: serialized pipeline
//! (inflight 1), chain+global replication every batch, momentum 0. Under
//! it, the fault hits a quiesced pipeline whose newest chain replica is
//! exactly the committed weights, so recovery is mathematically lossless
//! — the faulted run's per-batch losses and final weights are
//! *bit-identical* to a run where the fault never happened.

use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};

use crate::common;

const TOTAL: u64 = 60;
const KILL_AT: u64 = 29;

fn faulted() -> Scenario {
    Scenario::exact_recovery("single-fault", 3, TOTAL).with_events(vec![ScriptEvent {
        at: Trigger::BatchDone(KILL_AT),
        action: Action::Kill { device: 1, revive_after: None },
    }])
}

#[test]
fn single_fault_is_deterministic_across_runs() {
    let out = common::run_twice_deterministic("single-fault-det", &faulted());
    assert_eq!(out.recoveries, 1, "exactly one fault round expected");
    common::assert_trace_contains("single-fault-det", &out, "fault case 3");
    common::assert_trace_contains("single-fault-det", &out, "dead stages [1]");
}

#[test]
fn single_fault_recovery_is_bit_exact_vs_no_fault_run() {
    let faulted_out = common::run_once("single-fault-exact-a", &faulted());
    let baseline = Scenario::exact_recovery("single-fault-baseline", 3, TOTAL);
    let baseline_out = common::run_once("single-fault-exact-b", &baseline);

    common::assert_loss_continuity("single-fault", &faulted_out, TOTAL);
    // a replayed batch reproduces the no-fault loss, bit for bit
    common::assert_losses_bit_equal("single-fault", &faulted_out, &baseline_out);
    // and the surviving pipeline trains to the very same weights
    assert_eq!(
        faulted_out.weights_bits(),
        baseline_out.weights_bits(),
        "recovered run must converge to the no-fault weights"
    );
    assert_eq!(baseline_out.recoveries, 0);
    assert_eq!(faulted_out.recoveries, 1);
}

#[test]
fn single_fault_fetches_match_algorithm_1_plan() {
    let out = common::run_once("single-fault-plan", &faulted());
    assert_eq!(out.redists.len(), 1, "one redistribution expected");
    let r = &out.redists[0];
    assert_eq!(r.failed, vec![1]);
    assert_eq!(r.new_list, vec![0, 2]);
    assert_eq!(r.committed_at_start, KILL_AT as i64);
    common::assert_fetches_match_plan("single-fault", r);
}

#[test]
fn single_fault_of_last_stage_falls_back_to_central_backup() {
    // the last worker's chain replica lives at the central node; killing
    // it exercises the Stage(0) source of Algorithm 1
    let sc = Scenario::exact_recovery("single-fault-last", 3, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(KILL_AT),
            action: Action::Kill { device: 2, revive_after: None },
        },
    ]);
    let out = common::run_twice_deterministic("single-fault-last", &sc);
    common::assert_loss_continuity("single-fault-last", &out, TOTAL);
    assert_eq!(out.recoveries, 1);
    let r = &out.redists[0];
    assert_eq!(r.failed, vec![2]);
    assert_eq!(r.new_list, vec![0, 1]);
    common::assert_fetches_match_plan("single-fault-last", r);
    // exactness holds here too: the chain replica at central is the
    // committed version
    let baseline = Scenario::exact_recovery("single-fault-last-base", 3, TOTAL);
    let baseline_out = common::run_once("single-fault-last-base", &baseline);
    common::assert_losses_bit_equal("single-fault-last", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn single_fault_under_async_pipeline_recovers_and_is_deterministic() {
    // pipelined regime (inflight = stages, momentum, aggregation): exact
    // equality no longer holds — assert continuity + determinism instead
    let sc = Scenario::pipelined("single-fault-async", 3, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(KILL_AT),
            action: Action::Kill { device: 1, revive_after: None },
        },
    ]);
    let out = common::run_twice_deterministic("single-fault-async", &sc);
    common::assert_loss_continuity("single-fault-async", &out, TOTAL);
    assert!(out.recoveries >= 1);
    common::assert_trace_contains("single-fault-async", &out, "fault case 3");
    // fault timeout is virtual: the whole run spans well under a minute
    // of virtual time and executes in milliseconds of wall time
    assert!(out.virtual_ms < 60_000.0, "virtual time ran away: {}ms", out.virtual_ms);
}
