//! Family: chaos — randomized-but-seeded kill/slowdown schedules
//! (ROADMAP open item). The schedule generator (`sim::script::chaos_events`)
//! derives the whole timeline from a seed: kills always revive inside the
//! gradient timeout (paper case 2) and slowdowns stay within the modeled
//! capacity range, so every generated schedule is recoverable by
//! construction. The point of the family is breadth + determinism: a
//! randomized failure storm must still produce byte-identical traces and
//! bit-identical weights across two runs of the same seed.

use ftpipehd::sim::script::{chaos_events, Action, Scenario};

use crate::common;

const TOTAL: u64 = 60;
const DEVICES: usize = 4;

fn chaos_scenario(seed: u64) -> Scenario {
    let mut sc = Scenario::exact_recovery(&format!("chaos-{seed}"), DEVICES, TOTAL);
    sc.events = chaos_events(DEVICES, TOTAL, 5, seed);
    sc
}

fn kills(sc: &Scenario) -> usize {
    sc.events.iter().filter(|e| matches!(e.action, Action::Kill { .. })).count()
}

#[test]
fn chaos_seed_7_storm_is_deterministic_and_survivable() {
    let sc = chaos_scenario(7);
    assert!(kills(&sc) >= 1, "generator must schedule at least one kill");
    // run twice: byte-identical traces + bit-identical weights
    let out = common::run_twice_deterministic("chaos-7", &sc);
    common::assert_loss_continuity("chaos-7", &out, TOTAL);
    assert!(out.recoveries >= 1, "a chaos kill must trip the fault handler");
    common::assert_trace_contains("chaos-7", &out, "fault case 2");
}

#[test]
fn chaos_seed_21_storm_is_deterministic_and_survivable() {
    let sc = chaos_scenario(21);
    assert!(kills(&sc) >= 1);
    let out = common::run_twice_deterministic("chaos-21", &sc);
    common::assert_loss_continuity("chaos-21", &out, TOTAL);
    assert!(out.recoveries >= 1);
}

#[test]
fn chaos_different_seeds_take_different_paths() {
    // the storms must actually differ (otherwise the generator is not
    // exploring the failure space), while each remains self-consistent
    let a = common::run_once("chaos-path-7", &chaos_scenario(7));
    let b = common::run_once("chaos-path-21", &chaos_scenario(21));
    assert_ne!(a.trace, b.trace, "two seeds replayed the identical storm");
    common::assert_loss_continuity("chaos-path-7", &a, TOTAL);
    common::assert_loss_continuity("chaos-path-21", &b, TOTAL);
}

#[test]
fn chaos_fast_revives_keep_the_full_worker_list() {
    // every chaos kill revives within the fault timeout, so recovery is
    // always case 2: the pipeline never shrinks below all 4 devices
    let out = common::run_once("chaos-list", &chaos_scenario(7));
    for r in &out.redists {
        assert_eq!(
            r.new_list.len(),
            DEVICES,
            "case-2 recovery must keep all devices: {:?}",
            r.new_list
        );
        assert!(r.failed.is_empty(), "case 2 has no failed stages");
    }
}
