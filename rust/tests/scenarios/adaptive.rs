//! Family: adaptive — the bandwidth-driven compression policy
//! (`Compression::Adaptive`, DESIGN.md §10). The coordinator watches the
//! measured per-link bandwidth (periodic `bw_probe_every` re-probes) and
//! walks a tier ladder off → activations → full → full+q4 *per
//! destination link*, broadcasting the per-link table via
//! `SetCompression`, with hysteresis so jitter cannot flip a tier back.
//!
//! Everything here is deterministic: scripted `SetBandwidth` drops, a
//! virtual clock, and probe echoes priced by the same
//! `latency + bytes/bandwidth` model as the data plane — so tier
//! transitions land at asserted trace points and every scenario is
//! run-twice byte-identical.

use std::time::Duration;

use ftpipehd::net::quant::AdaptiveThresholds;
use ftpipehd::net::Compression;
use ftpipehd::sim::fixture::FixtureSpec;
use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};

use crate::common;

/// Thresholds sized for the scripted rates below, with wide (>2x) gaps
/// so queueing skew in a measured echo can never land in the wrong band.
fn thresholds() -> AdaptiveThresholds {
    AdaptiveThresholds {
        activations_below: 3e6,
        full_below: 4e5,
        q4_below: 1.5e5,
        relax_factor: 1.5,
        ..AdaptiveThresholds::default()
    }
}

/// Serialized (inflight 1) 3-stage base on a fast link: the pipeline
/// quiesces between batches, so a 2 KiB probe echo times the bare link
/// and the measured bandwidth sits predictably inside its band.
fn esc_base(name: &str, batches: u64) -> Scenario {
    let mut sc = Scenario::exact_recovery(name, 3, batches);
    sc.bandwidth_bps = 5e7;
    sc.ns_per_flop = 0.01;
    // no faults are scripted; on the degraded rungs an f32 round trip
    // can exceed the default 200 ms gradient timeout — slowness is not
    // a fault (same reasoning as the bandwidth family)
    sc.fault_timeout = Duration::from_secs(30);
    sc.compression = Compression::Adaptive;
    sc.adaptive = thresholds();
    sc.bw_probe_every = 2;
    // fixed probe size: at this family's 100 us link latency a 2 KiB
    // echo measures every scripted rate accurately, and a fixed size
    // keeps the per-band margin analysis simple (auto-sizing is for
    // high-latency deployments)
    sc.bw_probe_bytes = 2048;
    sc
}

fn esc_spec() -> FixtureSpec {
    FixtureSpec { dim: 64, batch: 16, ..FixtureSpec::default() }
}

fn drop_at(batch: u64, bps: f64) -> ScriptEvent {
    ScriptEvent { at: Trigger::BatchDone(batch), action: Action::SetBandwidth { bps } }
}

/// Acceptance criterion: scripted bandwidth drops trigger the expected
/// tier escalations — off → activations → full → full+q4 — at the
/// scripted points, and the whole run is byte-identical across two
/// invocations.
#[test]
fn adaptive_escalates_at_scripted_bandwidth_drops() {
    let sc = esc_base("adaptive-esc", 40).with_events(vec![
        drop_at(9, 1e6),    // below activations_below (3e6)
        drop_at(19, 2.5e5), // below full_below (4e5)
        drop_at(29, 8e4),   // below q4_below (1.5e5)
    ]);
    let out = common::run_twice_deterministic_spec("adaptive-esc", &sc, &esc_spec());
    common::assert_loss_continuity("adaptive-esc", &out, 40);
    assert_eq!(out.recoveries, 0, "bandwidth drops are not faults");
    common::assert_trace_contains("adaptive-esc", &out, "tier off -> activations");
    common::assert_trace_contains("adaptive-esc", &out, "tier activations -> full");
    common::assert_trace_contains("adaptive-esc", &out, "tier full -> full+q4");
    // escalation only — the link never recovers in this script
    assert!(
        !out.trace.iter().any(|l| l.contains("-> off")),
        "no relaxation events expected:\n{}",
        out.trace.join("\n")
    );
}

/// Hysteresis: a drop straight into Full (skipping a rung), then a
/// partial recovery that clears the threshold but NOT the relax band
/// (4e5 * 1.5 = 6e5) — the tier must hold — then a full recovery that
/// relaxes directly to off. `SetBandwidth` reprices both pipeline links,
/// so each of the two per-link ladders (->1 and ->2) makes exactly the
/// two scripted transitions: four lines total, deterministic.
#[test]
fn adaptive_hysteresis_holds_tier_through_jitter() {
    let sc = esc_base("adaptive-hys", 40).with_events(vec![
        drop_at(9, 2.5e5), // off -> full in one observation
        drop_at(19, 5e5),  // inside the hysteresis band: hold full
        drop_at(29, 5e7),  // clears it: relax straight to off
    ]);
    let out = common::run_twice_deterministic_spec("adaptive-hys", &sc, &esc_spec());
    common::assert_loss_continuity("adaptive-hys", &out, 40);
    common::assert_trace_contains("adaptive-hys", &out, "tier off -> full");
    common::assert_trace_contains("adaptive-hys", &out, "tier full -> off");
    let transitions = out.trace.iter().filter(|l| l.contains("adaptive:")).count();
    assert_eq!(
        transitions,
        4,
        "hysteresis must allow exactly the scripted transitions on each link:\n{}",
        out.trace.join("\n")
    );
    for link in ["->1", "->2"] {
        assert!(
            out.trace.iter().any(|l| l.contains("adaptive: link") && l.contains(link)),
            "both per-link ladders must move ({link}):\n{}",
            out.trace.join("\n")
        );
    }
    assert!(
        !out.trace.iter().any(|l| l.contains("-> activations")),
        "the 5e5 B/s jitter must not relax full -> activations"
    );
}

/// Per-link independence: two scripted `SetLinkBandwidth` degradations
/// drive the two pipeline links into *different* bands — ->1 lands in
/// Full, ->2 in FullQ4 — and each ladder moves alone: no line ever
/// escalates ->1 past full, and the whole run is byte-identical across
/// two invocations.
#[test]
fn adaptive_walks_two_links_to_different_tiers() {
    let link_drop = |batch, from, to, bps| ScriptEvent {
        at: Trigger::BatchDone(batch),
        action: Action::SetLinkBandwidth { from, to, bps },
    };
    let sc = esc_base("adaptive-two-links", 40).with_events(vec![
        link_drop(9, 0, 1, 2.5e5), // ->1: Full band (4e5 > 2.5e5 > 1.5e5)
        link_drop(9, 1, 2, 8e4),   // ->2: FullQ4 band (< 1.5e5)
    ]);
    let out = common::run_twice_deterministic_spec("adaptive-two-links", &sc, &esc_spec());
    common::assert_loss_continuity("adaptive-two-links", &out, 40);
    assert_eq!(out.recoveries, 0, "degradations are not faults");
    assert!(
        out.trace.iter().any(|l| l.contains("adaptive: link ->1") && l.contains("-> full")
            && !l.contains("full+q4")),
        "->1 must settle in full:\n{}",
        out.trace.join("\n")
    );
    assert!(
        out.trace.iter().any(|l| l.contains("adaptive: link ->2") && l.contains("-> full+q4")),
        "->2 must settle in full+q4:\n{}",
        out.trace.join("\n")
    );
    assert!(
        !out.trace.iter().any(|l| l.contains("adaptive: link ->1") && l.contains("full+q4")),
        "->2's degradation must never move ->1's ladder:\n{}",
        out.trace.join("\n")
    );
}

/// Replica-heavy pipelined base for the byte/wall-clock comparisons:
/// small batches keep weight replication a first-class share of the
/// traffic (replication is the paper's dominant background cost).
fn cmp_base(name: &str, compression: Compression) -> Scenario {
    let mut sc = Scenario::pipelined(name, 3, 60);
    sc.bandwidth_bps = 8e6;
    sc.ns_per_flop = 0.01;
    sc.fault_timeout = Duration::from_secs(30);
    sc.chain_every = 1;
    sc.global_every = 2;
    sc.compression = compression;
    sc.adaptive = thresholds();
    sc.bw_probe_every = 4; // identical probe load in every compared run
    sc.bw_probe_bytes = 2048;
    sc
}

fn cmp_spec() -> FixtureSpec {
    FixtureSpec { dim: 64, batch: 4, ..FixtureSpec::default() }
}

/// Mean loss over the last `n` batches — small-batch per-step losses are
/// noisy, so convergence is compared on a trailing window.
fn tail_loss(out: &ftpipehd::sim::runner::ScenarioOutcome, total: u64, n: u64) -> f32 {
    let sum: f32 = (total - n..total).map(|b| out.losses[&b]).sum();
    sum / n as f32
}

/// Acceptance criterion: on a link degraded to 100 KB/s, Adaptive
/// escalates to full+q4 and beats *static Full* on virtual wall-clock
/// (the Q4 replica stream is the margin), while the final loss stays
/// within 2% of the f32 run.
#[test]
fn adaptive_beats_static_full_on_a_degraded_link() {
    let degrade = |name: &str, c| cmp_base(name, c).with_events(vec![drop_at(7, 1e5)]);
    let off = common::run_once_spec(
        "adaptive-deg-off",
        &degrade("adaptive-deg-off", Compression::Off),
        &cmp_spec(),
    );
    let full = common::run_once_spec(
        "adaptive-deg-full",
        &degrade("adaptive-deg-full", Compression::Full),
        &cmp_spec(),
    );
    let adaptive = common::run_twice_deterministic_spec(
        "adaptive-deg-adaptive",
        &degrade("adaptive-deg-adaptive", Compression::Adaptive),
        &cmp_spec(),
    );
    for (tag, out) in [("off", &off), ("full", &full), ("adaptive", &adaptive)] {
        common::assert_loss_continuity(tag, out, 60);
        assert_eq!(out.recoveries, 0, "{tag}: degradation is not a fault");
    }
    common::assert_trace_contains("adaptive-deg", &adaptive, "-> full+q4");
    assert!(
        adaptive.net_bytes < full.net_bytes,
        "q4 replicas must shave bytes off static full: {} vs {}",
        adaptive.net_bytes,
        full.net_bytes
    );
    let ratio = full.virtual_ms / adaptive.virtual_ms;
    assert!(
        ratio >= 1.05,
        "adaptive must beat static full on the degraded link: {:.1}ms vs {:.1}ms ({ratio:.3}x)",
        full.virtual_ms,
        adaptive.virtual_ms
    );
    let (loss_a, loss_f32) = (tail_loss(&adaptive, 60, 8), tail_loss(&off, 60, 8));
    assert!(
        (loss_a - loss_f32).abs() <= 0.02 * loss_f32.abs(),
        "adaptive training must converge within 2% of f32: {loss_a} vs {loss_f32}"
    );
}

/// Static-policy byte ladder at scenario scale: on one fixed slow link,
/// total wire bytes order full+q4 < full < off (the message-level ~8x
/// ladder is pinned in `replication` unit tests), and the q4 run is
/// deterministic with f32-comparable convergence.
#[test]
fn adaptive_static_q4_orders_bytes_and_converges() {
    let run = |name: &str, c| {
        let mut sc = cmp_base(name, c);
        sc.bandwidth_bps = 2.5e5;
        sc.bw_probe_every = 0; // static tiers: no probes needed
        sc
    };
    let off = common::run_once_spec(
        "adaptive-q4-off",
        &run("adaptive-q4-off", Compression::Off),
        &cmp_spec(),
    );
    let full = common::run_once_spec(
        "adaptive-q4-full",
        &run("adaptive-q4-full", Compression::Full),
        &cmp_spec(),
    );
    let q4 = common::run_twice_deterministic_spec(
        "adaptive-q4-fullq4",
        &run("adaptive-q4-fullq4", Compression::FullQ4),
        &cmp_spec(),
    );
    assert!(
        q4.net_bytes < full.net_bytes && full.net_bytes < off.net_bytes,
        "byte ladder: full+q4 {} < full {} < off {}",
        q4.net_bytes,
        full.net_bytes,
        off.net_bytes
    );
    common::assert_loss_continuity("adaptive-q4-fullq4", &q4, 60);
    let (loss_q4, loss_f32) = (tail_loss(&q4, 60, 8), tail_loss(&off, 60, 8));
    assert!(
        (loss_q4 - loss_f32).abs() <= 0.02 * loss_f32.abs(),
        "full+q4 must converge within 2% of f32 (replica coding never touches the \
         data plane): {loss_q4} vs {loss_f32}"
    );
}

/// On a healthy link the adaptive policy never leaves tier off, and an
/// Adaptive run is *byte-identical* to a plain Off run — trace, per-batch
/// losses, and byte accounting. (The no-regression identity: turning the
/// feature on costs nothing until a link actually degrades.)
#[test]
fn adaptive_on_a_healthy_link_is_byte_identical_to_off() {
    let mk = |name: &str, c| {
        let mut sc = esc_base(name, 30);
        sc.compression = c;
        sc.bw_probe_every = 0; // only the init measurement feeds the policy
        sc
    };
    let off = common::run_once_spec(
        "adaptive-id-off",
        &mk("adaptive-id", Compression::Off),
        &esc_spec(),
    );
    let ada = common::run_once_spec(
        "adaptive-id-ada",
        &mk("adaptive-id", Compression::Adaptive),
        &esc_spec(),
    );
    assert_eq!(ada.trace, off.trace, "healthy-link adaptive must be the Off trace, byte for byte");
    assert_eq!(ada.net_bytes, off.net_bytes);
    let bits = |o: &ftpipehd::sim::runner::ScenarioOutcome| -> Vec<(u64, u32)> {
        o.losses.iter().map(|(&k, v)| (k, v.to_bits())).collect()
    };
    assert_eq!(bits(&ada), bits(&off), "losses bit-equal: tier off is the f32 math");
}
