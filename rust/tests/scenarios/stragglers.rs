//! Family: p99.9 stragglers — a worker goes 20–60x slower for a few
//! batches (GC pause, thermal throttle), then recovers.
//!
//! Nothing dies: the contract under test is that *slow is not dead*.
//! With a fault timeout sized above the spiked stage time the detector
//! must never fire, and the only systemic response is the scheduled
//! dynamic re-partitioner shifting blocks off the spiked device (reason
//! "dynamic", fetch traffic per Algorithm 1) — and shifting them back
//! once the spike clears.

use std::time::Duration;

use ftpipehd::sim::fixture::FixtureSpec;
use ftpipehd::sim::hetero_capacities;
use ftpipehd::sim::script::{straggler_events, Action, Scenario, ScriptEvent, Trigger};

use crate::common;

const TOTAL: u64 = 40;

fn fixture() -> FixtureSpec {
    FixtureSpec { n_blocks: 16, dim: 8, classes: 4, batch: 4, seed: 11 }
}

#[test]
fn spike_triggers_dynamic_repartition_not_fault() {
    let mut sc = Scenario::exact_recovery("straggler-repart", 4, TOTAL);
    // slow is not dead: the timeout must outlast the 30x spike
    sc.fault_timeout = Duration::from_secs(5);
    sc.repartition = Some((10, 10));
    let sc = sc.with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(6),
            action: Action::SetCapacity { device: 2, capacity: 30.0 },
        },
        ScriptEvent {
            at: Trigger::BatchDone(14),
            action: Action::SetCapacity { device: 2, capacity: 1.0 },
        },
    ]);
    let out = common::run_twice_deterministic_spec("straggler-repart", &sc, &fixture());
    assert_eq!(out.recoveries, 0, "a straggler must never trip the fault detector");
    common::assert_trace_contains("straggler-repart", &out, "repartition check");
    assert!(
        !out.redists.is_empty(),
        "a 30x spike across a repartition mark must move blocks"
    );
    for r in &out.redists {
        assert_eq!(r.reason, "dynamic");
        assert!(r.failed.is_empty());
        common::assert_fetches_match_plan("straggler-repart", r);
    }
    common::assert_loss_continuity("straggler-repart", &out, TOTAL);
}

#[test]
fn generated_tail_spikes_are_survivable_and_deterministic() {
    // a heterogeneous fleet with generated p99.9 spikes and no scheduled
    // re-partition: the run just rides the tail out, deterministically
    let caps = hetero_capacities(6, 4.0, 3);
    let events = straggler_events(&caps, TOTAL, 3, 3);
    assert!(!events.is_empty());
    let mut sc = Scenario::exact_recovery("straggler-tail", 6, TOTAL);
    sc.capacities = caps;
    sc.fault_timeout = Duration::from_secs(10);
    let sc = sc.with_events(events);
    let out = common::run_twice_deterministic_spec("straggler-tail", &sc, &fixture());
    assert_eq!(out.recoveries, 0, "tail latency is not failure");
    assert!(out.redists.is_empty(), "no schedule, no redistribution");
    common::assert_loss_continuity("straggler-tail", &out, TOTAL);
}
