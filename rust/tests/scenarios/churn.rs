//! Family: worker churn — a device dies and comes back.
//!
//! A fast restart (back before the gradient timeout fires) is the
//! paper's case 2: the probe finds the worker alive but stateless
//! (`fresh`), the coordinator re-sends the training-init state and the
//! worker re-fetches its own range from its chain-replica holder, same
//! partition. A slow restart (back after recovery already re-partitioned
//! around it) is a late rejoin: the run must simply keep working on the
//! shrunken pipeline, deterministically.

use std::time::Duration;

use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};

use crate::common;

const TOTAL: u64 = 50;
const KILL_AT: u64 = 14;

#[test]
fn churn_fast_restart_takes_case_2_and_is_bit_exact() {
    // revived 20ms (virtual) after the kill — well inside the 200ms
    // gradient timeout, so the probe finds it alive-but-fresh
    let sc = Scenario::exact_recovery("churn-restart", 3, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(KILL_AT),
            action: Action::Kill { device: 1, revive_after: Some(Duration::from_millis(20)) },
        },
    ]);
    let out = common::run_twice_deterministic("churn-restart", &sc);
    assert_eq!(out.recoveries, 1);
    common::assert_trace_contains("churn-restart", &out, "fault case 2");
    common::assert_loss_continuity("churn-restart", &out, TOTAL);
    // the restarted worker restores the committed weights from its chain
    // replica: the run is lossless vs a never-faulted baseline
    let baseline = Scenario::exact_recovery("churn-restart-base", 3, TOTAL);
    let baseline_out = common::run_once("churn-restart-base", &baseline);
    common::assert_losses_bit_equal("churn-restart", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
    // case 2 keeps the worker list: the final commit retains device 1
    common::assert_trace_contains("churn-restart", &out, "commit: list [0, 1, 2]");
}

#[test]
fn churn_slow_restart_is_a_late_rejoin_after_case_3() {
    // revived after 2s (virtual) — the timeout (200ms) fires first and
    // case 3 removes the worker; when it comes back nobody is waiting
    // for it, and training continues on the survivors undisturbed
    let sc = Scenario::exact_recovery("churn-late", 3, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(KILL_AT),
            action: Action::Kill { device: 1, revive_after: Some(Duration::from_secs(2)) },
        },
    ]);
    let out = common::run_twice_deterministic("churn-late", &sc);
    assert_eq!(out.recoveries, 1);
    common::assert_trace_contains("churn-late", &out, "fault case 3");
    common::assert_trace_contains("churn-late", &out, "script: revive device 1");
    common::assert_loss_continuity("churn-late", &out, TOTAL);
    assert_eq!(out.redists.len(), 1);
    assert_eq!(out.redists[0].new_list, vec![0, 2]);
    // lossless, as in the single-fault family
    let baseline = Scenario::exact_recovery("churn-late-base", 3, TOTAL);
    let baseline_out = common::run_once("churn-late-base", &baseline);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn churn_repeated_faults_in_one_run_are_survivable() {
    // two separate fault rounds: worker 1 restarts fast (case 2), then
    // worker 2 dies for good (case 3) — 4 devices so a pipeline remains
    let sc = Scenario::exact_recovery("churn-repeat", 4, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(9),
            action: Action::Kill { device: 1, revive_after: Some(Duration::from_millis(20)) },
        },
        ScriptEvent {
            at: Trigger::BatchDone(29),
            action: Action::Kill { device: 2, revive_after: None },
        },
    ]);
    let out = common::run_twice_deterministic("churn-repeat", &sc);
    assert_eq!(out.recoveries, 2);
    common::assert_trace_contains("churn-repeat", &out, "fault case 2");
    common::assert_trace_contains("churn-repeat", &out, "fault case 3");
    common::assert_loss_continuity("churn-repeat", &out, TOTAL);
}
