//! Family: a second failure lands while a redistribution is in flight.
//!
//! Worker 1 dies; the coordinator probes and starts redistribution #1;
//! the moment the Repartition broadcast and FetchWeights requests are in
//! flight, worker 2 dies too. FetchDones stop arriving, the
//! redistribution stalls past `redist_window`, and the coordinator
//! re-probes — finding both workers dead — and replans against the
//! *original* (uncommitted) partition with the enlarged failure set.

use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};

use crate::common;

const TOTAL: u64 = 50;
const KILL_AT: u64 = 19;

fn scenario() -> Scenario {
    Scenario::exact_recovery("mid-redistribution", 4, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(KILL_AT),
            action: Action::Kill { device: 1, revive_after: None },
        },
        ScriptEvent {
            at: Trigger::RedistributionStart(1),
            action: Action::Kill { device: 2, revive_after: None },
        },
    ])
}

#[test]
fn mid_redistribution_failure_is_recovered_deterministically() {
    let out = common::run_twice_deterministic("mid-redist", &scenario());
    common::assert_loss_continuity("mid-redist", &out, TOTAL);
    assert_eq!(out.recoveries, 2, "stall must trigger a second probe round");
    assert_eq!(out.redists.len(), 2, "first redistribution abandoned, second commits");
    common::assert_trace_contains("mid-redist", &out, "redistribution stalled; re-probing");
    common::assert_trace_contains("mid-redist", &out, "dead stages [1, 2]");
}

#[test]
fn mid_redistribution_replan_uses_the_uncommitted_partition() {
    let out = common::run_once("mid-redist-replan", &scenario());
    let first = &out.redists[0];
    let second = &out.redists[1];
    assert_eq!(first.failed, vec![1]);
    // no commit happened in between: the second plan starts from the
    // same old partition and worker list, with both stages failed
    assert_eq!(second.old_ranges, first.old_ranges);
    assert_eq!(second.old_list, first.old_list);
    assert_eq!(second.failed, vec![1, 2]);
    assert_eq!(second.new_list, vec![0, 3]);
    // worker 1's chain replica died with worker 2: the survivors must
    // reach into the central node's global backups for those blocks
    let (lo1, hi1) = first.old_ranges[1];
    let expect = common::expected_fetches(second);
    let fetched_from_central = expect.iter().any(|((_, target), blocks)| {
        *target == 0 && blocks.iter().any(|b| (lo1..=hi1).contains(b))
    });
    let central_kept_them = second.new_ranges[0].0 <= lo1 && second.new_ranges[0].1 >= hi1;
    let central_served = fetched_from_central || central_kept_them;
    assert!(central_served, "stage-1 blocks must be served from the global backup");
    common::assert_fetches_match_plan("mid-redist", second);
}

#[test]
fn mid_redistribution_completes_training_on_the_survivors() {
    let out = common::run_once("mid-redist-complete", &scenario());
    common::assert_loss_continuity("mid-redist-complete", &out, TOTAL);
    // the final committed pipeline is central + the one survivor
    common::assert_trace_contains("mid-redist-complete", &out, "commit: list [0, 3]");
}
