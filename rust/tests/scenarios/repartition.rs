//! Family: a worker slows down and the scheduled dynamic re-partition
//! (paper §III-D) rebalances the pipeline — no failure involved.
//!
//! Compute is modeled (flops × ns_per_flop × capacity), so the slowed
//! worker's piggybacked execution reports yield an *exact* capacity
//! estimate and the DP's decision is deterministic.

use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};

use crate::common;

const TOTAL: u64 = 90;

fn scenario() -> Scenario {
    let mut sc = Scenario::pipelined("repartition", 3, TOTAL);
    // check at batch 10 (no-op: capacities equal), then at 50 and 90
    sc.repartition = Some((10, 40));
    sc.events = vec![ScriptEvent {
        at: Trigger::BatchDone(20),
        action: Action::SetCapacity { device: 2, capacity: 6.0 },
    }];
    sc
}

#[test]
fn repartition_slowdown_shifts_blocks_off_the_slow_worker() {
    let out = common::run_twice_deterministic("repartition", &scenario());
    common::assert_loss_continuity("repartition", &out, TOTAL);
    assert_eq!(out.recoveries, 0, "a slowdown is not a fault");
    let dynamic: Vec<_> = out
        .redists
        .iter()
        .filter(|r| r.reason == "dynamic" && r.committed_at_start >= 40)
        .collect();
    assert!(!dynamic.is_empty(), "the batch-50 check must trigger a re-partition");
    let r = dynamic[0];
    let blocks = |range: (usize, usize)| range.1 - range.0 + 1;
    let old_slow = blocks(r.old_ranges[2]);
    let new_slow = blocks(r.new_ranges[2]);
    assert!(
        new_slow < old_slow,
        "slow worker must shed blocks: {old_slow} -> {new_slow} ({:?} -> {:?})",
        r.old_ranges,
        r.new_ranges
    );
    // the first check (batch 10, equal capacities) must NOT repartition
    common::assert_trace_contains("repartition", &out, "repartition check");
    assert!(
        r.committed_at_start >= 49,
        "rebalance must come from the batch-50 check, got batch {}",
        r.committed_at_start
    );
}

#[test]
fn repartition_fetches_match_algorithm_1_plan() {
    let out = common::run_once("repartition-plan", &scenario());
    let dynamic: Vec<_> =
        out.redists.iter().filter(|r| r.reason == "dynamic").collect();
    assert!(!dynamic.is_empty());
    for r in dynamic {
        assert!(r.failed.is_empty(), "dynamic re-partition has no failed stages");
        common::assert_fetches_match_plan("repartition", r);
    }
}

#[test]
fn repartition_capacity_estimates_are_exact_under_the_model() {
    let out = common::run_once("repartition-caps", &scenario());
    // the trace logs the capacities the DP saw; the slowed device's
    // estimate must be 6.0 (modeled compute makes eq (1) exact)
    let line = out
        .trace
        .iter()
        .rev()
        .find(|l| l.contains("repartition check"))
        .expect("no repartition check in trace");
    assert!(
        line.contains("6.0") || line.contains("5.99") || line.contains("6.00"),
        "expected an exact 6x capacity estimate in: {line}"
    );
}
