//! Family: rolling-wave churn — continuous join/leave across a wide
//! fleet (the xaynet-style round-lifecycle stress the ROADMAP names).
//!
//! Waves of simultaneous worker kills, each reviving within 10–60
//! virtual ms — far inside the fault timeout — so every wave is observed
//! as one probe round full of alive-but-fresh workers (paper case 2):
//! the worker list never shrinks, the fresh workers restore their ranges
//! from replicas, and under the exact-recovery base the run is lossless
//! against a never-faulted baseline.

use std::time::Duration;

use ftpipehd::sim::fixture::FixtureSpec;
use ftpipehd::sim::hetero_link_topology;
use ftpipehd::sim::script::{rolling_churn_events, Scenario};

use crate::common;

const N: usize = 12;
const TOTAL: u64 = 30;

fn fixture() -> FixtureSpec {
    // every device owns at least two blocks
    FixtureSpec { n_blocks: 24, dim: 8, classes: 4, batch: 4, seed: 11 }
}

fn base(name: &str) -> Scenario {
    let mut sc = Scenario::exact_recovery(name, N, TOTAL);
    // churn revives (<= 60ms) must land well inside the timeout so a
    // wave is case 2, and the probe round must start after every member
    // of the wave is back
    sc.fault_timeout = Duration::from_secs(1);
    sc.ns_per_flop = 0.2;
    sc
}

#[test]
fn rolling_waves_are_case2_and_lossless() {
    let events = rolling_churn_events(N, TOTAL, 3, 3, 5);
    assert!(!events.is_empty());
    let sc = base("rolling-churn").with_events(events);
    let out = common::run_twice_deterministic_spec("rolling-churn", &sc, &fixture());
    assert!(out.recoveries >= 3, "one probe round per wave, got {}", out.recoveries);
    common::assert_trace_contains("rolling-churn", &out, "fault case 2");
    common::assert_loss_continuity("rolling-churn", &out, TOTAL);
    // every wave is case 2: no redistribution ever loses a stage and the
    // worker list never shrinks
    for r in &out.redists {
        assert!(r.failed.is_empty(), "wave escalated to case 3: {r:?}");
        assert_eq!(r.new_list.len(), N, "worker list shrank: {:?}", r.new_list);
    }
    // lossless against a never-faulted baseline (exact-recovery base)
    let baseline = base("rolling-churn-base");
    let baseline_out = common::run_once_spec("rolling-churn-base", &baseline, &fixture());
    common::assert_losses_bit_equal("rolling-churn", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn rolling_waves_over_asymmetric_links_are_deterministic() {
    // same churn over a heterogeneous directed topology: link pricing
    // changes every arrival time, determinism must not care
    let sc = base("rolling-churn-links")
        .with_link_bw(hetero_link_topology(N, 5e7, 2e8, 9))
        .with_events(rolling_churn_events(N, TOTAL, 2, 4, 7));
    let out = common::run_twice_deterministic_spec("rolling-churn-links", &sc, &fixture());
    assert!(out.recoveries >= 2);
    common::assert_trace_contains("rolling-churn-links", &out, "fault case 2");
    common::assert_loss_continuity("rolling-churn-links", &out, TOTAL);
    for r in &out.redists {
        assert!(r.failed.is_empty(), "wave escalated to case 3: {r:?}");
    }
}
