//! Family: bandwidth — scripted link degradation (`SetBandwidth`) and the
//! INT8 wire-compression payoff. The virtual network prices every message
//! as `latency + bytes/bandwidth`, so the compressed pipeline's speedup
//! is a deterministic, asserted number rather than a benchmark anecdote:
//! on a 1 MB/s link the `Compression::Full` run must finish the same
//! script in <= 0.6x the f32 run's virtual wall-clock while converging to
//! a final loss within 2%, and on a degraded 100 KB/s link the per-script
//! speedup must reach >= 1.8x.
//!
//! A larger fixture (dim 64, batch 16 -> 4 KiB f32 activations) keeps the
//! data plane dominant over the fixed-size init traffic (64 KiB bandwidth
//! probes), as in the paper's setting where activation transfer rivals
//! compute on the critical path.

use std::time::Duration;

use ftpipehd::net::Compression;
use ftpipehd::sim::fixture::FixtureSpec;
use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};

use crate::common;

const TOTAL: u64 = 60;

fn spec() -> FixtureSpec {
    FixtureSpec { dim: 64, batch: 16, ..FixtureSpec::default() }
}

/// Pipelined 3-stage base on a slow edge link.
fn slow_link(name: &str, bps: f64, compression: Compression) -> Scenario {
    let mut sc = Scenario::pipelined(name, 3, TOTAL);
    sc.bandwidth_bps = bps;
    // modeled compute fast relative to the link: communication-bound,
    // the regime AccEPT targets
    sc.ns_per_flop = 0.01;
    // no faults are scripted here, and on a ~100 KB/s link an f32 batch
    // round-trip alone can exceed the default 200 ms gradient timeout —
    // keep the detector out of the way so slowness is never "a fault"
    sc.fault_timeout = Duration::from_secs(30);
    sc.compression = compression;
    sc
}

/// Acceptance criterion: on a 1 MB/s link, Compression::Full completes
/// the same script in <= 0.6x the f32 virtual wall-clock, bit-identically
/// across two invocations, with a final loss within 2% of the f32 run.
#[test]
fn bandwidth_full_compression_hits_0_6x_on_1mbps_and_converges() {
    let off =
        common::run_once_spec("bw-1m-off", &slow_link("bw-1m-off", 1e6, Compression::Off), &spec());
    let full = common::run_twice_deterministic_spec(
        "bw-1m-full",
        &slow_link("bw-1m-full", 1e6, Compression::Full),
        &spec(),
    );
    common::assert_loss_continuity("bw-1m-off", &off, TOTAL);
    common::assert_loss_continuity("bw-1m-full", &full, TOTAL);
    assert_eq!((off.recoveries, full.recoveries), (0, 0), "slow links are not faults");
    assert!(
        full.virtual_ms <= 0.6 * off.virtual_ms,
        "compressed run must finish in <=0.6x of f32: {:.1}ms vs {:.1}ms (ratio {:.2})",
        full.virtual_ms,
        off.virtual_ms,
        full.virtual_ms / off.virtual_ms
    );
    let last = TOTAL - 1;
    let loss_off = off.losses[&last];
    let loss_full = full.losses[&last];
    assert!(
        (loss_full - loss_off).abs() <= 0.02 * loss_off.abs(),
        "quantized training must converge within 2% of f32: {loss_full} vs {loss_off}"
    );
    // byte accounting reflects the compressed wire (activations dominate)
    assert!(
        full.net_bytes < off.net_bytes / 2,
        "compressed bytes {} vs f32 bytes {}",
        full.net_bytes,
        off.net_bytes
    );
}

/// Scripted link degradation: the link drops from 8 MB/s to 100 KB/s at
/// batch 9. On the degraded link the compressed pipeline's virtual-time
/// batch latency must beat f32 by >= 1.8x over the whole script.
#[test]
fn bandwidth_degraded_link_speedup_is_at_least_1_8x() {
    let degrade = |name: &str, compression| {
        slow_link(name, 8e6, compression).with_events(vec![ScriptEvent {
            at: Trigger::BatchDone(9),
            action: Action::SetBandwidth { bps: 1e5 },
        }])
    };
    let off =
        common::run_once_spec("bw-deg-off", &degrade("bw-deg-off", Compression::Off), &spec());
    let full = common::run_twice_deterministic_spec(
        "bw-deg-full",
        &degrade("bw-deg-full", Compression::Full),
        &spec(),
    );
    common::assert_trace_contains("bw-deg-off", &off, "bandwidth -> 100000");
    common::assert_loss_continuity("bw-deg-full", &full, TOTAL);
    assert_eq!((off.recoveries, full.recoveries), (0, 0), "degradation is not a fault");
    let speedup = off.virtual_ms / full.virtual_ms;
    assert!(
        speedup >= 1.8,
        "degraded-link speedup {speedup:.2}x < 1.8x ({:.1}ms vs {:.1}ms)",
        off.virtual_ms,
        full.virtual_ms
    );
}

/// Activations-only compresses the data plane but leaves weight traffic
/// f32; Full compresses replica pushes too, so its replica bytes shrink
/// while both beat Off. (Also pins the policy granularity: the knob is
/// per message class, not all-or-nothing.)
#[test]
fn bandwidth_policy_granularity_orders_total_bytes() {
    let off = common::run_once_spec(
        "bw-pol-off",
        &slow_link("bw-pol-off", 1e6, Compression::Off),
        &spec(),
    );
    let acts = common::run_once_spec(
        "bw-pol-acts",
        &slow_link("bw-pol-acts", 1e6, Compression::Activations),
        &spec(),
    );
    let full = common::run_once_spec(
        "bw-pol-full",
        &slow_link("bw-pol-full", 1e6, Compression::Full),
        &spec(),
    );
    assert!(
        full.net_bytes < acts.net_bytes && acts.net_bytes < off.net_bytes,
        "byte ordering must follow the policy: full {} < activations {} < off {}",
        full.net_bytes,
        acts.net_bytes,
        off.net_bytes
    );
}

/// Compression::Off is the identity: the same script without compression
/// twice produces byte-identical traces (the existing families all run
/// Off, so their goldens are untouched — this pins the invariant inside
/// the bandwidth family too).
#[test]
fn bandwidth_off_is_deterministic_identity() {
    let mut sc = slow_link("bw-off-id", 1e6, Compression::Off);
    sc.events = vec![ScriptEvent {
        at: Trigger::BatchDone(20),
        action: Action::SetBandwidth { bps: 5e5 },
    }];
    // kill/slowdown-free run: only the link changes mid-flight
    let out = common::run_twice_deterministic_spec("bw-off-id", &sc, &spec());
    common::assert_loss_continuity("bw-off-id", &out, TOTAL);
    assert_eq!(out.recoveries, 0, "a slow link is not a fault");
}
