//! Family: big-cluster scale — the harness at 64 and 500 virtual
//! devices.
//!
//! The 500-device storm is the tentpole scenario of the O(log n) event
//! engine: rolling churn waves over a heterogeneous directed link
//! topology, hundreds of thousands of events, run twice byte-identical,
//! finishing in seconds of wall time as a normal `cargo test`. CI also
//! runs it under `timeout` in the scale-smoke job (release build) so a
//! complexity regression in the queue or the hot path fails loudly.

use std::time::Duration;

use ftpipehd::net::quant::AdaptiveThresholds;
use ftpipehd::net::Compression;
use ftpipehd::sim::fixture::FixtureSpec;
use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};
use ftpipehd::sim::{big_cluster_storm, hetero_link_topology};

use crate::common;

#[test]
fn asymmetric_links_64_devices_are_deterministic() {
    const N: usize = 64;
    const TOTAL: u64 = 8;
    let mut sc = Scenario::exact_recovery("scale-64-links", N, TOTAL);
    sc.ns_per_flop = 0.05;
    sc.latency = Duration::from_micros(20);
    let sc = sc
        .with_link_bw(hetero_link_topology(N, 2e7, 2e8, 13))
        .with_events(vec![ScriptEvent {
            // mid-run retarget of one directed link: pricing changes from
            // that instant on, byte-identity across runs must hold
            at: Trigger::At(Duration::from_millis(40)),
            action: Action::SetLinkBandwidth { from: 3, to: 4, bps: 1e6 },
        }]);
    let spec = FixtureSpec { n_blocks: N + 12, dim: 8, classes: 4, batch: 4, seed: 11 };
    let out = common::run_twice_deterministic_spec("scale-64-links", &sc, &spec);
    assert_eq!(out.recoveries, 0);
    common::assert_trace_contains(
        "scale-64-links",
        &out,
        "script: link 3->4 bandwidth -> 1000000 B/s",
    );
    common::assert_loss_continuity("scale-64-links", &out, TOTAL);
}

/// The one-bad-link blast radius, at fleet width: in an 8-stage
/// pipeline one directed link (3->4) is scripted down to 100 KB/s.
/// Only that destination's ladder may escalate — every other link keeps
/// tier off (the one-bad-link fleet-wide down-tier regression) — and
/// when the degraded worker is later killed (case 3), the committed
/// topology invalidates its measurement and ladder, after which its
/// link never transitions again.
#[test]
fn one_degraded_link_escalates_only_its_own_traffic() {
    const N: usize = 8;
    const TOTAL: u64 = 30;
    let mut sc = Scenario::exact_recovery("scale-one-bad-link", N, TOTAL);
    sc.bandwidth_bps = 5e7;
    sc.ns_per_flop = 0.01;
    // the degraded rung moves slowly; slowness is not a fault
    sc.fault_timeout = Duration::from_secs(5);
    sc.compression = Compression::Adaptive;
    sc.adaptive = AdaptiveThresholds {
        activations_below: 3e6,
        full_below: 4e5,
        q4_below: 1.5e5,
        relax_factor: 1.5,
        ..AdaptiveThresholds::default()
    };
    sc.bw_probe_every = 2;
    sc.bw_probe_bytes = 2048;
    let sc = sc.with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(5),
            // 1e5 B/s < q4_below: ->4 escalates straight to full+q4
            action: Action::SetLinkBandwidth { from: 3, to: 4, bps: 1e5 },
        },
        ScriptEvent {
            at: Trigger::BatchDone(15),
            action: Action::Kill { device: 4, revive_after: None },
        },
    ]);
    let spec = FixtureSpec { n_blocks: 20, dim: 8, classes: 4, batch: 4, seed: 11 };
    let out = common::run_twice_deterministic_spec("scale-one-bad-link", &sc, &spec);
    common::assert_trace_contains("scale-one-bad-link", &out, "adaptive: link ->4");
    common::assert_trace_contains("scale-one-bad-link", &out, "tier off -> full+q4");
    // blast radius: the degraded destination is the ONLY ladder that moves
    for l in out.trace.iter().filter(|l| l.contains("adaptive: link") && l.contains("tier")) {
        assert!(
            l.contains("link ->4"),
            "a healthy link's ladder moved:\n{l}\n---\n{}",
            out.trace.join("\n")
        );
    }
    // killing the degraded worker runs case 3 and invalidates its link
    common::assert_trace_contains("scale-one-bad-link", &out, "fault case 3");
    common::assert_trace_contains("scale-one-bad-link", &out, "adaptive: link ->4 invalidated");
    let invalidated = out
        .trace
        .iter()
        .position(|l| l.contains("adaptive: link ->4 invalidated"))
        .expect("invalidation line");
    assert!(
        !out.trace[invalidated + 1..]
            .iter()
            .any(|l| l.contains("adaptive: link ->4") && l.contains("tier")),
        "the evicted destination's ladder must stay dead after invalidation:\n{}",
        out.trace.join("\n")
    );
    common::assert_loss_continuity("scale-one-bad-link", &out, TOTAL);
}

/// Satellite (ISSUE 10): the replica axis at fleet width — 64 devices
/// split into 4 chains by the capacity DP, heterogeneous link topology,
/// two whole replicas dying at successive sync rounds. Run by the CI
/// scale-smoke job under `timeout` (release) via an `--exact` filter.
#[test]
fn replica_r4_64_device_storm() {
    const N: usize = 64;
    const TOTAL: u64 = 48;
    let mut sc = Scenario::exact_recovery("scale-replica-storm", N, TOTAL);
    sc.capacities = ftpipehd::sim::hetero_capacities(N, 10.0, 7);
    sc.ns_per_flop = 0.05;
    sc.latency = Duration::from_micros(20);
    sc.chain_every = 0;
    sc.global_every = 0;
    let sc = sc
        .with_replicas(4, 2)
        .with_link_bw(hetero_link_topology(N, 2e7, 2e8, 13))
        .with_events(vec![
            ScriptEvent {
                at: Trigger::SyncRound(2),
                action: Action::KillReplica { replica: 2 },
            },
            ScriptEvent {
                at: Trigger::SyncRound(4),
                action: Action::KillReplica { replica: 3 },
            },
        ]);
    let spec = FixtureSpec { n_blocks: 16, dim: 8, classes: 4, batch: 4, seed: 11 };
    let out = common::run_twice_deterministic_spec("scale-replica-storm", &sc, &spec);
    assert_eq!(out.recoveries, 2, "both scripted replica deaths must fire");
    common::assert_trace_contains("scale-replica-storm", &out, "script: kill replica 2");
    common::assert_trace_contains("scale-replica-storm", &out, "script: kill replica 3");
    // every batch trains to a finite loss despite losing half the chains
    common::assert_loss_continuity("scale-replica-storm", &out, TOTAL);
    assert!(!out.sync_records.is_empty());
}

#[test]
fn storm_500_devices_completes_and_is_deterministic() {
    const N: usize = 500;
    const TOTAL: u64 = 10;
    let sc = big_cluster_storm(N, TOTAL, 7);
    let spec = FixtureSpec { n_blocks: N + 12, dim: 8, classes: 4, batch: 4, seed: 11 };
    let out = common::run_twice_deterministic_spec("scale-storm", &sc, &spec);
    // the churn generator fires real waves even at this width
    assert!(out.recoveries >= 1, "storm ran without a single probe round");
    common::assert_trace_contains("scale-storm", &out, "fault case 2");
    common::assert_loss_continuity("scale-storm", &out, TOTAL);
    // forward+backward+replication alone cross ~2000 links per batch;
    // anything below this means the storm silently degenerated
    assert!(
        out.events > 20_000,
        "a 500-device storm should be event-dense, got {}",
        out.events
    );
}
