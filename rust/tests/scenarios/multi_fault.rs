//! Family: multiple workers fail simultaneously.
//!
//! Non-adjacent failures keep every dead stage's chain-replica holder
//! alive (recovery from chain replicas only); adjacent failures kill a
//! stage *and* its replica holder, forcing Algorithm 1's CentralBackup
//! fallback through the global replication store.

use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};

use crate::common;

const TOTAL: u64 = 50;
const KILL_AT: u64 = 24;

fn kill(device: usize) -> ScriptEvent {
    ScriptEvent {
        at: Trigger::BatchDone(KILL_AT),
        action: Action::Kill { device, revive_after: None },
    }
}

#[test]
fn multi_fault_non_adjacent_is_deterministic_and_exact() {
    // 5 devices; workers 1 and 3 die at once. Their replica holders
    // (stages 2 and 4) survive, so exact recovery holds.
    let sc = Scenario::exact_recovery("multi-fault", 5, TOTAL)
        .with_events(vec![kill(1), kill(3)]);
    let out = common::run_twice_deterministic("multi-fault", &sc);
    assert_eq!(out.recoveries, 1, "both deaths must be handled in one probe round");
    common::assert_trace_contains("multi-fault", &out, "dead stages [1, 3]");
    common::assert_loss_continuity("multi-fault", &out, TOTAL);

    let baseline = Scenario::exact_recovery("multi-fault-base", 5, TOTAL);
    let baseline_out = common::run_once("multi-fault-base", &baseline);
    common::assert_losses_bit_equal("multi-fault", &out, &baseline_out);
    assert_eq!(
        out.weights_bits(),
        baseline_out.weights_bits(),
        "double-failure recovery must still be lossless"
    );
}

#[test]
fn multi_fault_fetches_match_algorithm_1_plan() {
    let sc = Scenario::exact_recovery("multi-fault-plan", 5, TOTAL)
        .with_events(vec![kill(1), kill(3)]);
    let out = common::run_once("multi-fault-plan", &sc);
    assert_eq!(out.redists.len(), 1);
    let r = &out.redists[0];
    assert_eq!(r.failed, vec![1, 3]);
    assert_eq!(r.new_list, vec![0, 2, 4]);
    common::assert_fetches_match_plan("multi-fault", r);
}

#[test]
fn multi_fault_adjacent_recovers_via_central_backup() {
    // workers 2 and 3 are adjacent: stage 2's chain replica lived on
    // stage 3 — gone with it. Blocks must come from the central node's
    // global backups (global_every = 1 keeps them one batch stale at
    // most; at a quiesced pipeline they are exactly the committed state).
    let sc = Scenario::exact_recovery("multi-fault-adj", 5, TOTAL)
        .with_events(vec![kill(2), kill(3)]);
    let out = common::run_twice_deterministic("multi-fault-adj", &sc);
    assert_eq!(out.recoveries, 1);
    common::assert_trace_contains("multi-fault-adj", &out, "dead stages [2, 3]");
    common::assert_loss_continuity("multi-fault-adj", &out, TOTAL);
    let r = &out.redists[0];
    assert_eq!(r.new_list, vec![0, 1, 4]);
    common::assert_fetches_match_plan("multi-fault-adj", r);
    // at least one survivor had to reach into the central backup: some
    // fetch targets device 0 from a non-central requester, or central
    // self-served (no fetch) — either way the run stays lossless
    let baseline = Scenario::exact_recovery("multi-fault-adj-base", 5, TOTAL);
    let baseline_out = common::run_once("multi-fault-adj-base", &baseline);
    common::assert_losses_bit_equal("multi-fault-adj", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}
