//! Family: the central node dies and reboots from its periodic
//! checkpoint (paper §III-E). The headline claim is that *no committed
//! batch is ever lost*: in the exact regime (inflight 1, replicate every
//! batch, momentum 0) a run that loses its coordinator mid-epoch resumes
//! from the last committed checkpoint, replays only the uncommitted
//! batches, and finishes with final weights **bit-identical** to a run
//! where the coordinator never died. Every scenario here runs twice
//! through `run_twice_deterministic` (byte-identical traces,
//! bit-identical weights).

use ftpipehd::net::Compression;
use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};
use ftpipehd::sim::ScenarioOutcome;
use std::time::Duration;

use crate::common;

const TOTAL: u64 = 60;

/// How many times `batch` was injected (initial run + replays).
fn inject_count(out: &ScenarioOutcome, batch: u64) -> usize {
    let needle = format!("inject batch={batch}");
    out.trace.iter().filter(|l| l.ends_with(&needle)).count()
}

fn kill_central_at(batch: u64, restart_ms: u64) -> ScriptEvent {
    ScriptEvent {
        at: Trigger::BatchDone(batch),
        action: Action::KillCentral {
            restart_after: Some(Duration::from_millis(restart_ms)),
        },
    }
}

#[test]
fn checkpoint_restart_mid_epoch_is_bit_exact_vs_no_fault_run() {
    // checkpoints at committed 9/19/29; death at 33 → resume from 30,
    // replaying exactly the four uncommitted batches 30..=33
    let sc = Scenario::exact_recovery("ckpt-restart-exact", 3, TOTAL)
        .with_checkpoint(10)
        .with_events(vec![kill_central_at(33, 50)]);
    let out = common::run_twice_deterministic("ckpt-restart-exact", &sc);
    assert_eq!(out.restarts, 1);
    assert!(out.checkpoints >= 4, "pre-death + post-restart checkpoints: {}", out.checkpoints);
    common::assert_trace_contains("ckpt-restart-exact", &out, "script: kill central node");
    common::assert_trace_contains("ckpt-restart-exact", &out, "central restart #1");
    common::assert_trace_contains("ckpt-restart-exact", &out, "resuming from batch 30");
    common::assert_loss_continuity("ckpt-restart-exact", &out, TOTAL);

    // zero committed batches lost, zero extra replays: 30..=33 ran
    // twice, everything else exactly once
    for b in 0..TOTAL {
        let want = if (30..=33).contains(&b) { 2 } else { 1 };
        assert_eq!(
            inject_count(&out, b),
            want,
            "batch {b}: unexpected injection count after restart"
        );
    }

    // the restarted run converges to the very same bits as a run whose
    // coordinator never died
    let baseline = Scenario::exact_recovery("ckpt-restart-exact-base", 3, TOTAL);
    let baseline_out = common::run_once("ckpt-restart-exact-base", &baseline);
    common::assert_losses_bit_equal("ckpt-restart-exact", &out, &baseline_out);
    assert_eq!(
        out.weights_bits(),
        baseline_out.weights_bits(),
        "restart must replay to the no-fault weights, bit for bit"
    );
    assert_eq!(baseline_out.restarts, 0);
    assert_eq!(baseline_out.checkpoints, 0);
}

#[test]
fn checkpoint_restart_stale_checkpoint_replays_only_uncommitted_batches() {
    // a sparser schedule: the newest checkpoint (committed 19) is 14
    // batches stale when the coordinator dies at 33
    let sc = Scenario::exact_recovery("ckpt-restart-stale", 3, TOTAL)
        .with_checkpoint(20)
        .with_events(vec![kill_central_at(33, 50)]);
    let out = common::run_twice_deterministic("ckpt-restart-stale", &sc);
    assert_eq!(out.restarts, 1);
    common::assert_trace_contains("ckpt-restart-stale", &out, "resuming from batch 20");
    common::assert_loss_continuity("ckpt-restart-stale", &out, TOTAL);
    for b in 0..TOTAL {
        let want = if (20..=33).contains(&b) { 2 } else { 1 };
        assert_eq!(inject_count(&out, b), want, "batch {b}: stale replay window wrong");
    }
    // staleness costs replay time, never correctness
    let baseline = Scenario::exact_recovery("ckpt-restart-stale-base", 3, TOTAL);
    let baseline_out = common::run_once("ckpt-restart-stale-base", &baseline);
    common::assert_losses_bit_equal("ckpt-restart-stale", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn checkpoint_restart_during_redistribution_reprobes_after_restart() {
    // worker 1 dies for good at 25 → case-3 redistribution starts → the
    // coordinator dies the moment the redistribution begins. The restart
    // handshake doubles as the re-probe: worker 1 is still silent, so
    // the restart replans against the checkpoint topology and recovers.
    let sc = Scenario::exact_recovery("ckpt-restart-midredist", 3, TOTAL)
        .with_checkpoint(10)
        .with_events(vec![
            ScriptEvent {
                at: Trigger::BatchDone(25),
                action: Action::Kill { device: 1, revive_after: None },
            },
            ScriptEvent {
                at: Trigger::RedistributionStart(1),
                action: Action::KillCentral {
                    restart_after: Some(Duration::from_millis(80)),
                },
            },
        ]);
    let out = common::run_twice_deterministic("ckpt-restart-midredist", &sc);
    assert_eq!(out.restarts, 1);
    assert!(out.recoveries >= 1, "the pre-death fault round must have run");
    common::assert_trace_contains("ckpt-restart-midredist", &out, "fault case 3");
    common::assert_trace_contains(
        "ckpt-restart-midredist",
        &out,
        "central restart: dead stages [1]",
    );
    common::assert_loss_continuity("ckpt-restart-midredist", &out, TOTAL);
    // the surviving pipeline is [0, 2] and the replayed run is still
    // bit-exact: redistribution only moves blocks, never changes math
    let last = out.redists.last().expect("restart redistribution");
    assert_eq!(last.failed, vec![1]);
    assert_eq!(last.new_list, vec![0, 2]);
    let baseline = Scenario::exact_recovery("ckpt-restart-midredist-base", 3, TOTAL);
    let baseline_out = common::run_once("ckpt-restart-midredist-base", &baseline);
    common::assert_losses_bit_equal("ckpt-restart-midredist", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn checkpoint_restart_combined_central_and_worker_storm() {
    // one storm at batch 29: worker 2 crashes (restarting 40 ms later,
    // fresh) and the coordinator dies in the same instant, rebooting
    // 100 ms later. The checkpoint at committed 29 was written before
    // the script fired, so the handshake finds a fresh worker 2, warm
    // starts it from the checkpoint, and resumes with nothing lost.
    let sc = Scenario::exact_recovery("ckpt-restart-storm", 3, TOTAL)
        .with_checkpoint(10)
        .with_events(vec![
            ScriptEvent {
                at: Trigger::BatchDone(29),
                action: Action::Kill {
                    device: 2,
                    revive_after: Some(Duration::from_millis(40)),
                },
            },
            kill_central_at(29, 100),
        ]);
    let out = common::run_twice_deterministic("ckpt-restart-storm", &sc);
    assert_eq!(out.restarts, 1);
    common::assert_trace_contains("ckpt-restart-storm", &out, "fresh=true");
    common::assert_trace_contains("ckpt-restart-storm", &out, "resuming from batch 30");
    common::assert_loss_continuity("ckpt-restart-storm", &out, TOTAL);
    let baseline = Scenario::exact_recovery("ckpt-restart-storm-base", 3, TOTAL);
    let baseline_out = common::run_once("ckpt-restart-storm-base", &baseline);
    common::assert_losses_bit_equal("ckpt-restart-storm", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn checkpoint_restart_without_any_checkpoint_replays_from_scratch() {
    // checkpointing off: the reboot falls back to the initial weights
    // and replays the whole run — slower, but still zero committed
    // batches lost and still bit-exact
    let sc = Scenario::exact_recovery("ckpt-restart-none", 3, TOTAL)
        .with_events(vec![kill_central_at(15, 50)]);
    let out = common::run_twice_deterministic("ckpt-restart-none", &sc);
    assert_eq!(out.restarts, 1);
    assert_eq!(out.checkpoints, 0);
    common::assert_trace_contains("ckpt-restart-none", &out, "checkpoint committed=-1");
    common::assert_trace_contains("ckpt-restart-none", &out, "resuming from batch 0");
    common::assert_loss_continuity("ckpt-restart-none", &out, TOTAL);
    for b in 0..TOTAL {
        let want = if b <= 15 { 2 } else { 1 };
        assert_eq!(inject_count(&out, b), want, "batch {b}: full-replay window wrong");
    }
    let baseline = Scenario::exact_recovery("ckpt-restart-none-base", 3, TOTAL);
    let baseline_out = common::run_once("ckpt-restart-none-base", &baseline);
    common::assert_losses_bit_equal("ckpt-restart-none", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn checkpoint_restart_under_full_compression_is_deterministic_and_close() {
    // Compression::Full: replicas travel INT8, so the checkpoint holds
    // dequantized weights and the gradient error-feedback residuals are
    // (deliberately) cleared on restart — bit-exact equality with the
    // no-restart run is impossible by design (DESIGN.md §9). What must
    // hold: the restart path is perfectly deterministic, the restore
    // itself ships f32 (no double quantization), and the final weights
    // stay within quantization-noise distance of the no-restart run.
    let sc = Scenario::exact_recovery("ckpt-restart-q8", 3, TOTAL)
        .with_compression(Compression::Full)
        .with_checkpoint(10)
        .with_events(vec![kill_central_at(33, 50)]);
    let out = common::run_twice_deterministic("ckpt-restart-q8", &sc);
    assert_eq!(out.restarts, 1);
    common::assert_trace_contains("ckpt-restart-q8", &out, "resuming from batch 30");
    common::assert_loss_continuity("ckpt-restart-q8", &out, TOTAL);

    let baseline = Scenario::exact_recovery("ckpt-restart-q8-base", 3, TOTAL)
        .with_compression(Compression::Full);
    let baseline_out = common::run_once("ckpt-restart-q8-base", &baseline);
    // residuals cleared + dequantized restore: weights drift by
    // quantization noise only, never diverge
    let mut max_diff = 0f32;
    for ((ba, a), (bb, b)) in out.final_weights.iter().zip(baseline_out.final_weights.iter()) {
        assert_eq!(ba, bb, "block sets must match");
        for (ta, tb) in a.0.iter().zip(b.0.iter()) {
            for (&xa, &xb) in ta.iter().zip(tb.iter()) {
                assert!(xa.is_finite() && xb.is_finite(), "block {ba}: non-finite weight");
                max_diff = max_diff.max((xa - xb).abs());
            }
        }
    }
    assert!(
        max_diff > 0.0,
        "Q8 restart should not be bit-identical (residuals clear on restart); \
         if it is, the compression path is not engaged"
    );
    assert!(
        max_diff < 0.1,
        "final weights drifted {max_diff} from the no-restart run — restore is \
         injecting more than quantization noise"
    );
    let last = TOTAL - 1;
    let (la, lb) = (out.losses[&last], baseline_out.losses[&last]);
    assert!(
        (la - lb).abs() <= 0.05 * lb.abs().max(0.1),
        "final loss {la} vs no-restart {lb}: beyond quantization tolerance"
    );
}
