//! Deterministic fault-injection scenario suite (DESIGN.md §7).
//!
//! Every test builds a synthetic native model (`sim::fixture`), scripts a
//! failure timeline against the virtual clock (`sim::script`), and drives
//! the full StageWorker protocol stack through the discrete-event runner
//! (`sim::runner`). No artifacts, no PJRT, no wall-clock sleeps: each
//! scenario runs in milliseconds and two invocations produce
//! byte-identical traces and bit-identical final weights.
//!
//! Families (one module each; the CI `scenarios` matrix filters by the
//! family prefix of the test names):
//!
//! * `single_fault`       — one worker dies (case 3), exact recovery
//! * `multi_fault`        — two workers die simultaneously
//! * `mid_redistribution` — a second failure lands during redistribution
//! * `repartition`        — a worker slows down; dynamic re-partition
//! * `churn`              — kill + fast restart (case 2), late rejoin
//! * `chaos`              — seeded randomized kill/slowdown storms
//! * `bandwidth`          — link degradation + INT8 wire compression
//! * `checkpoint_restart` — central-node death + reboot from checkpoint
//! * `coordinator_core`   — shared phase-machine properties + cross-driver conformance
//! * `adaptive`           — bandwidth-driven tier ladder (off → q4)
//! * `replica`            — hybrid pipeline+data parallelism: R chains, weight sync, replica death
//! * `rolling_churn`      — generated waves of kill+revive across a fleet
//! * `correlated`         — a contiguous rack/region slice dies at once
//! * `stragglers`         — p99.9 capacity spikes; slow is not dead
//! * `scale`              — 64- and 500-device clusters, asymmetric links
//!
//! Set `FTPIPEHD_TRACE_DIR` to dump every run's event trace to disk —
//! CI uploads those files on failure so byte-identity diffs are
//! debuggable from the job page.

mod common;

mod adaptive;
mod bandwidth;
mod chaos;
mod checkpoint_restart;
mod churn;
mod coordinator_core;
mod correlated;
mod mid_redistribution;
mod multi_fault;
mod repartition;
mod replica;
mod rolling_churn;
mod scale;
mod single_fault;
mod stragglers;
