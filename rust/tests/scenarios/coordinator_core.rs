//! Family: the shared coordinator phase machine (`coordinator::core`,
//! DESIGN.md §12). Two kinds of guarantees:
//!
//! * **Property tests** over random `PhaseInput` sequences — `step` is
//!   deterministic (same inputs, same phases, same log), and an illegal
//!   input leaves the machine completely untouched (phase, accumulated
//!   acks, and transition log).
//! * **Cross-driver conformance** — the discrete-event sim driver's
//!   `ScenarioOutcome::phase_log` must be exactly the log a hand-driven
//!   `PhaseMachine` produces when fed the same fault story, proving the
//!   driver executes the machine's effect sequence rather than its own
//!   phase logic (the threaded coordinator records the same log into
//!   `RunRecord::phase_log`).

use std::collections::BTreeSet;
use std::time::Duration;

use ftpipehd::coordinator::{
    CoordinatorPhase, PhaseConfig, PhaseEffect, PhaseInput, PhaseMachine, RedistReason,
};
use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};
use ftpipehd::util::prop::{check, G};

use crate::common;

fn ms(x: usize) -> Duration {
    Duration::from_millis(x as u64)
}

/// One random lifecycle input. Ids, batch numbers, and timestamps are
/// arbitrary — the machine must hold its invariants for all of them.
fn arbitrary_input(g: &mut G<'_>) -> PhaseInput {
    match g.usize_in(0, 12) {
        0 => PhaseInput::StartProfiling,
        1 => PhaseInput::TrainingStarted,
        2 => PhaseInput::ProbeAck { id: g.usize_in(1, 4), fresh: g.bool() },
        3 => PhaseInput::FetchDone { id: g.usize_in(1, 4) },
        4 => PhaseInput::WorkerStateReport {
            id: g.usize_in(1, 4),
            committed_bwd: g.usize_in(0, 50) as i64 - 1,
            fresh: g.bool(),
        },
        5 => PhaseInput::FaultDetected {
            overdue: g.usize_in(0, 100) as u64,
            now: ms(g.usize_in(0, 5_000)),
        },
        6 => PhaseInput::DrainForRepartition,
        7 => {
            let expect: BTreeSet<usize> = (1..=g.usize_in(0, 3)).collect();
            PhaseInput::RedistributionStarted {
                expect,
                reason: if g.bool() { RedistReason::Fault } else { RedistReason::Dynamic },
                now: ms(g.usize_in(0, 5_000)),
            }
        }
        8 => PhaseInput::KillCentral,
        9 => PhaseInput::CentralRestarted { now: ms(g.usize_in(0, 5_000)) },
        10 => PhaseInput::SyncDue {
            round: g.usize_in(1, 50) as u64,
            expect: (1..=g.usize_in(0, 3)).collect(),
        },
        11 => PhaseInput::SyncPartial { chain: g.usize_in(0, 4) },
        _ => {
            let overdue = if g.bool() { Some(g.usize_in(0, 100) as u64) } else { None };
            PhaseInput::Poll {
                now: ms(g.usize_in(0, 10_000)),
                overdue,
                inflight: g.usize_in(0, 4),
                peers: g.usize_in(0, 4),
                local_fetch_done: g.bool(),
            }
        }
    }
}

#[test]
fn coordinator_core_step_is_deterministic_and_errors_are_side_effect_free() {
    check("phase-machine-step", 300, |g| {
        let cfg = PhaseConfig {
            probe_window: ms(g.usize_in(1, 2_000)),
            redist_window: ms(g.usize_in(1, 10_000)),
        };
        // a: the machine under test; b: fed the identical sequence
        // (determinism); c: fed only the inputs a accepted (an Err step
        // must therefore be indistinguishable from no step at all)
        let mut a = PhaseMachine::new(cfg);
        let mut b = PhaseMachine::new(cfg);
        let mut c = PhaseMachine::new(cfg);
        let n = g.sized_usize(1, 80);
        for i in 0..n {
            let input = arbitrary_input(g);
            let before = a.phase();
            let log_before = a.log().len();
            let ra = a.step(input.clone());
            let rb = b.step(input.clone());
            if ra != rb {
                return Err(format!("step {i}: divergent results {ra:?} vs {rb:?}"));
            }
            match ra {
                Ok((after, _)) => {
                    if after != a.phase() {
                        return Err(format!("step {i}: returned phase != machine phase"));
                    }
                    c.step(input).map_err(|e| {
                        format!("step {i}: replay of an accepted input rejected: {e}")
                    })?;
                }
                Err(e) => {
                    if e.from != before {
                        return Err(format!("step {i}: error names phase {} != {before}", e.from));
                    }
                    if a.phase() != before {
                        return Err(format!(
                            "step {i}: illegal input moved the machine {before}->{}",
                            a.phase()
                        ));
                    }
                    if a.log().len() != log_before {
                        return Err(format!("step {i}: illegal input appended to the log"));
                    }
                }
            }
        }
        if a.phase() != c.phase() {
            return Err(format!(
                "skipping rejected inputs changed the outcome: {} vs {}",
                a.phase(),
                c.phase()
            ));
        }
        if a.log() != c.log() {
            return Err("skipping rejected inputs changed the transition log".into());
        }
        Ok(())
    });
}

#[test]
fn coordinator_core_down_rejects_everything_but_restart() {
    // from Down, the only way forward is CentralRestarted — by
    // construction a resumed coordinator cannot skip the handshake
    let cfg = PhaseConfig { probe_window: ms(100), redist_window: ms(500) };
    let mut m = PhaseMachine::resuming(cfg);
    assert_eq!(m.phase(), CoordinatorPhase::Down);
    assert!(m.step(PhaseInput::StartProfiling).is_err());
    assert!(m.step(PhaseInput::TrainingStarted).is_err());
    assert!(m.step(PhaseInput::DrainForRepartition).is_err());
    assert!(m.step(PhaseInput::KillCentral).is_err());
    assert!(m
        .step(PhaseInput::FaultDetected { overdue: 0, now: ms(0) })
        .is_err());
    let (phase, _) = m.step(PhaseInput::CentralRestarted { now: ms(0) }).unwrap();
    assert_eq!(phase, CoordinatorPhase::Rejoining);
}

/// The canonical §III-F case-3 story, hand-driven through the pure
/// machine: this test *is* a second driver, and its log must match the
/// sim driver's byte for byte.
fn hand_driven_case3_log(sc: &Scenario) -> Vec<String> {
    let mut m = PhaseMachine::new(PhaseConfig {
        probe_window: sc.probe_window,
        redist_window: sc.redist_window,
    });
    let t0 = ms(1_000);
    // the sim skips profiling (the fixture ships a profile)
    m.step(PhaseInput::TrainingStarted).unwrap();
    // fault: the detector reports an overdue batch on a driver poll and
    // the machine opens the probe window (this is how the sim driver
    // enters Probing — `FaultDetected` is its abort-re-probe path)
    let poll = |now: Duration| PhaseInput::Poll {
        now,
        overdue: Some(21),
        inflight: 1,
        peers: 2,
        local_fetch_done: true,
    };
    let (_, eff) = m.step(poll(t0)).unwrap();
    assert!(matches!(eff[..], [PhaseEffect::SendProbes { .. }]));
    // worker 1 is dead; worker 2 answers the probe
    m.step(PhaseInput::ProbeAck { id: 2, fresh: false }).unwrap();
    // inside the window with one of two acks: the poll stays put
    let (_, eff) = m.step(poll(t0 + ms(1))).unwrap();
    assert!(eff.is_empty(), "premature probe resolution: {eff:?}");
    // the deadline poll resolves with the partial ack set (case 3)
    let (_, eff) = m.step(poll(t0 + sc.probe_window)).unwrap();
    let acks = match &eff[..] {
        [PhaseEffect::ResolveProbe { acks }] => acks.clone(),
        other => panic!("expected ResolveProbe, got {other:?}"),
    };
    assert_eq!(acks.into_iter().collect::<Vec<_>>(), vec![(2, false)]);
    // the driver renumbers and starts the redistribution with the
    // survivor, whose FetchDone completes it
    let t1 = t0 + sc.probe_window + ms(1);
    let expect: BTreeSet<usize> = [2].into_iter().collect();
    m.step(PhaseInput::RedistributionStarted {
        expect,
        reason: RedistReason::Fault,
        now: t1,
    })
    .unwrap();
    m.step(PhaseInput::FetchDone { id: 2 }).unwrap();
    let (phase, eff) = m
        .step(PhaseInput::Poll {
            now: t1 + ms(1),
            overdue: None,
            inflight: 0,
            peers: 1,
            local_fetch_done: true,
        })
        .unwrap();
    assert_eq!(phase, CoordinatorPhase::Training);
    assert!(matches!(eff[..], [PhaseEffect::CommitRedistribution { .. }]));
    m.take_log()
}

#[test]
fn coordinator_core_sim_driver_conforms_to_hand_driven_machine() {
    // worker 1 dies for good at batch 20 of a 3-device exact-recovery
    // run: one case-3 fault round, one redistribution, nothing else
    let sc = Scenario::exact_recovery("core-conf", 3, 40).with_events(vec![ScriptEvent {
        at: Trigger::BatchDone(20),
        action: Action::Kill { device: 1, revive_after: None },
    }]);
    let out = common::run_twice_deterministic("core-conf", &sc);
    assert_eq!(out.recoveries, 1);
    let expected = hand_driven_case3_log(&sc);
    assert_eq!(
        out.phase_log, expected,
        "sim driver's transition log diverges from the pure machine"
    );
}

/// Satellite (ISSUE 10): a machine that is Down or Rejoining must
/// reject replica-sync inputs without any side effect — a sync round
/// cannot open (or accumulate partials) while the coordinator itself is
/// mid-recovery.
#[test]
fn coordinator_core_sync_inputs_rejected_side_effect_free_when_down_or_rejoining() {
    check("sync-rejected-down", 200, |g| {
        let cfg = PhaseConfig {
            probe_window: ms(g.usize_in(1, 500)),
            redist_window: ms(g.usize_in(1, 2_000)),
        };
        for rejoining in [false, true] {
            let mut m = PhaseMachine::resuming(cfg);
            if rejoining {
                m.step(PhaseInput::CentralRestarted { now: ms(0) })
                    .map_err(|e| format!("restart handshake rejected: {e}"))?;
            }
            let before = m.phase();
            let log_before = m.log().len();
            let input = if g.bool() {
                PhaseInput::SyncDue {
                    round: g.usize_in(1, 50) as u64,
                    expect: (1..=g.usize_in(0, 3)).collect(),
                }
            } else {
                PhaseInput::SyncPartial { chain: g.usize_in(0, 4) }
            };
            match m.step(input) {
                Ok(_) => return Err(format!("sync input accepted in phase {before}")),
                Err(e) => {
                    if e.from != before {
                        return Err(format!("error names phase {} != {before}", e.from));
                    }
                    if m.phase() != before {
                        return Err(format!("rejection moved the machine to {}", m.phase()));
                    }
                    if m.log().len() != log_before {
                        return Err("rejection appended to the transition log".into());
                    }
                }
            }
        }
        Ok(())
    });
}

/// Satellite (ISSUE 10): the hand-driven R=2 sync story — one chain's
/// partial per round, premature polls staying put — whose log the
/// replica sim driver must reproduce byte for byte.
fn hand_driven_r2_sync_log(rounds: u64) -> Vec<String> {
    let mut m = PhaseMachine::new(PhaseConfig { probe_window: ms(50), redist_window: ms(2_000) });
    m.step(PhaseInput::TrainingStarted).unwrap();
    for round in 1..=rounds {
        let expect: BTreeSet<usize> = [1].into_iter().collect();
        let (_, eff) = m.step(PhaseInput::SyncDue { round, expect }).unwrap();
        assert!(matches!(eff[..], [PhaseEffect::BeginSync { .. }]), "round {round}: {eff:?}");
        // a poll before the partial lands stays put, silently
        let poll = |now: Duration| PhaseInput::Poll {
            now,
            overdue: None,
            inflight: 0,
            peers: 0,
            local_fetch_done: true,
        };
        let (_, eff) = m.step(poll(ms(round as usize * 10))).unwrap();
        assert!(eff.is_empty(), "premature sync resolution: {eff:?}");
        m.step(PhaseInput::SyncPartial { chain: 1 }).unwrap();
        let (phase, eff) = m.step(poll(ms(round as usize * 10 + 1))).unwrap();
        assert_eq!(phase, CoordinatorPhase::Training);
        match &eff[..] {
            [PhaseEffect::ResolveSync { round: r, chains }] => {
                assert_eq!(*r, round);
                assert_eq!(chains.iter().copied().collect::<Vec<_>>(), vec![1]);
            }
            other => panic!("expected ResolveSync, got {other:?}"),
        }
    }
    m.take_log()
}

#[test]
fn coordinator_core_replica_sync_log_matches_sim_driver() {
    // the healthy R=2 scenario of the replica family: 8 shard batches
    // per chain, synced every 4 -> exactly 2 rounds
    let mut sc = Scenario::exact_recovery("core-replica", 4, 16);
    sc.chain_every = 0;
    sc.global_every = 0;
    sc.capacities = vec![1.0, 1.5, 1.0, 1.5];
    let sc = sc.with_replicas(2, 4);
    let out = common::run_once("core-replica", &sc);
    assert_eq!(
        out.phase_log,
        hand_driven_r2_sync_log(2),
        "replica sim driver's transition log diverges from the pure machine"
    );
}

#[test]
fn coordinator_core_phase_log_is_deterministic_across_runs() {
    let sc = Scenario::exact_recovery("core-det", 3, 30).with_events(vec![ScriptEvent {
        at: Trigger::BatchDone(12),
        action: Action::Kill { device: 2, revive_after: None },
    }]);
    let a = common::run_once("core-det-a", &sc);
    let b = common::run_once("core-det-b", &sc);
    assert_eq!(a.phase_log, b.phase_log, "phase log must replay identically");
    assert!(!a.phase_log.is_empty());
}

#[test]
fn coordinator_core_central_restart_walks_down_rejoining_training() {
    // the central-kill family seen through the machine's eyes: the
    // lifecycle lines must appear in order in the phase log
    let sc = Scenario::exact_recovery("core-restart", 3, 40)
        .with_checkpoint(10)
        .with_events(vec![ScriptEvent {
            at: Trigger::BatchDone(15),
            action: Action::KillCentral { restart_after: Some(ms(50)) },
        }]);
    let out = common::run_twice_deterministic("core-restart", &sc);
    assert_eq!(out.restarts, 1);
    let order = [
        "training-started: idle->training",
        "kill-central: training->central-down",
        "central-restarted: central-down->rejoining",
        "poll: rejoining->training [resolve-rejoin]",
    ];
    let mut at = 0usize;
    for needle in order {
        match out.phase_log[at..].iter().position(|l| l == needle) {
            Some(i) => at += i + 1,
            None => panic!(
                "phase log missing {needle:?} (in order) — log:\n{}",
                out.phase_log.join("\n")
            ),
        }
    }
}
