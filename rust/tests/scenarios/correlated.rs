//! Family: correlated failures — a contiguous rack/region slice dies in
//! one trigger ([`Action::KillSlice`]).
//!
//! Independent-failure families kill one device per fault round; real
//! edge fleets lose a whole switch, rack, or region at once. One
//! `KillSlice` exercises the multi-device arm of every recovery case: a
//! permanent slice loss is a single case-3 re-partition over the
//! survivors (one probe round, one redistribution, fetch traffic per
//! Algorithm 1), and a transient slice blip (power glitch) is a single
//! case-2 round with every slice member alive-but-fresh.

use std::time::Duration;

use ftpipehd::sim::fixture::FixtureSpec;
use ftpipehd::sim::script::{Action, Scenario, ScriptEvent, Trigger};

use crate::common;

const N: usize = 8;
const TOTAL: u64 = 40;

fn fixture() -> FixtureSpec {
    FixtureSpec { n_blocks: 16, dim: 8, classes: 4, batch: 4, seed: 11 }
}

#[test]
fn rack_loss_is_one_case3_repartition() {
    let sc = Scenario::exact_recovery("correlated-loss", N, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(10),
            action: Action::KillSlice { first: 2, last: 4, revive_after: None },
        },
    ]);
    let out = common::run_twice_deterministic_spec("correlated-loss", &sc, &fixture());
    common::assert_trace_contains("correlated-loss", &out, "script: kill slice 2..=4");
    common::assert_trace_contains("correlated-loss", &out, "fault case 3");
    // one probe round sees all three dead at once: exactly one recovery,
    // one redistribution, and the slice is gone from the worker list
    assert_eq!(out.recoveries, 1, "a correlated loss is ONE fault round");
    assert_eq!(out.redists.len(), 1);
    let r = &out.redists[0];
    assert_eq!(r.new_list, vec![0, 1, 5, 6, 7]);
    assert_eq!(r.failed, vec![2, 3, 4]);
    common::assert_fetches_match_plan("correlated-loss", r);
    common::assert_loss_continuity("correlated-loss", &out, TOTAL);
    // exact-recovery base: lossless against a never-faulted baseline
    let baseline = Scenario::exact_recovery("correlated-loss-base", N, TOTAL);
    let baseline_out = common::run_once_spec("correlated-loss-base", &baseline, &fixture());
    common::assert_losses_bit_equal("correlated-loss", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn rack_blip_is_one_case2_round() {
    // the whole slice back 20ms later — inside the 200ms timeout, so the
    // probe finds three alive-but-fresh workers in one round
    let sc = Scenario::exact_recovery("correlated-blip", N, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(10),
            action: Action::KillSlice {
                first: 2,
                last: 4,
                revive_after: Some(Duration::from_millis(20)),
            },
        },
    ]);
    let out = common::run_twice_deterministic_spec("correlated-blip", &sc, &fixture());
    common::assert_trace_contains("correlated-blip", &out, "fault case 2");
    assert_eq!(out.recoveries, 1);
    for r in &out.redists {
        assert!(r.failed.is_empty());
        assert_eq!(r.new_list.len(), N, "a blip must not shrink the fleet");
    }
    common::assert_loss_continuity("correlated-blip", &out, TOTAL);
    let baseline = Scenario::exact_recovery("correlated-blip-base", N, TOTAL);
    let baseline_out = common::run_once_spec("correlated-blip-base", &baseline, &fixture());
    common::assert_losses_bit_equal("correlated-blip", &out, &baseline_out);
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}

#[test]
fn two_sequential_rack_losses_shrink_to_a_core() {
    let sc = Scenario::exact_recovery("correlated-twice", N, TOTAL).with_events(vec![
        ScriptEvent {
            at: Trigger::BatchDone(8),
            action: Action::KillSlice { first: 5, last: 6, revive_after: None },
        },
        ScriptEvent {
            at: Trigger::BatchDone(25),
            action: Action::KillSlice { first: 2, last: 3, revive_after: None },
        },
    ]);
    let out = common::run_twice_deterministic_spec("correlated-twice", &sc, &fixture());
    assert_eq!(out.recoveries, 2);
    assert_eq!(out.redists.len(), 2);
    assert_eq!(out.redists[0].new_list, vec![0, 1, 2, 3, 4, 7]);
    assert_eq!(out.redists[1].new_list, vec![0, 1, 4, 7]);
    for r in &out.redists {
        common::assert_fetches_match_plan("correlated-twice", r);
    }
    common::assert_loss_continuity("correlated-twice", &out, TOTAL);
    let baseline = Scenario::exact_recovery("correlated-twice-base", N, TOTAL);
    let baseline_out = common::run_once_spec("correlated-twice-base", &baseline, &fixture());
    assert_eq!(out.weights_bits(), baseline_out.weights_bits());
}
