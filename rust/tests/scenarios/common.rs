//! Shared plumbing for the scenario families: fixture lifecycle, the
//! run-twice determinism oracle, loss-continuity checks, and the
//! plan_redistribution fetch oracle.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;

use ftpipehd::fault::{plan_redistribution, Source};
use ftpipehd::sim::fixture::{materialize, FixtureSpec};
use ftpipehd::sim::runner::{run_scenario, RedistRecord, ScenarioOutcome};
use ftpipehd::sim::script::Scenario;

fn fixture_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ftpipehd-scn-{tag}-{}", std::process::id()))
}

/// When `FTPIPEHD_TRACE_DIR` is set, persist a run's event trace as
/// `<tag>-run<n>.trace` there — written BEFORE any byte-identity
/// assertion, so a red CI job can upload both runs' traces and the diff
/// is debuggable from the artifacts tab.
pub fn dump_trace(tag: &str, run: usize, out: &ScenarioOutcome) {
    let Ok(dir) = std::env::var("FTPIPEHD_TRACE_DIR") else {
        return;
    };
    if dir.is_empty() {
        return;
    }
    let dir = std::path::Path::new(&dir);
    let _ = std::fs::create_dir_all(dir);
    let mut body = out.trace.join("\n");
    body.push('\n');
    let _ = std::fs::write(dir.join(format!("{tag}-run{run}.trace")), body);
}

/// Run `sc` once against a fresh fixture built from `spec`.
pub fn run_once_spec(tag: &str, sc: &Scenario, spec: &FixtureSpec) -> ScenarioOutcome {
    let dir = fixture_dir(tag);
    materialize(&dir, spec).expect("fixture");
    let out = run_scenario(sc, &dir).expect("scenario run");
    let _ = std::fs::remove_dir_all(&dir);
    dump_trace(tag, 1, &out);
    out
}

/// Run `sc` once against a fresh default fixture.
pub fn run_once(tag: &str, sc: &Scenario) -> ScenarioOutcome {
    run_once_spec(tag, sc, &FixtureSpec::default())
}

/// Run `sc` twice against one fixture built from `spec` and assert
/// byte-identical traces and bit-identical weights — the acceptance
/// criterion of the harness.
pub fn run_twice_deterministic_spec(
    tag: &str,
    sc: &Scenario,
    spec: &FixtureSpec,
) -> ScenarioOutcome {
    let dir = fixture_dir(tag);
    materialize(&dir, spec).expect("fixture");
    let a = run_scenario(sc, &dir).expect("first run");
    // dump run 1 BEFORE attempting run 2: if the second run panics
    // instead of diverging, CI still ships the first run's trace
    dump_trace(tag, 1, &a);
    let b = run_scenario(sc, &dir).expect("second run");
    let _ = std::fs::remove_dir_all(&dir);
    dump_trace(tag, 2, &b);
    assert_eq!(a.trace, b.trace, "{tag}: event traces differ between identical runs");
    assert_eq!(
        a.weights_bits(),
        b.weights_bits(),
        "{tag}: final weights differ between identical runs"
    );
    assert_eq!(a.net_bytes, b.net_bytes, "{tag}: byte accounting differs");
    a
}

/// [`run_twice_deterministic_spec`] with the default fixture.
pub fn run_twice_deterministic(tag: &str, sc: &Scenario) -> ScenarioOutcome {
    run_twice_deterministic_spec(tag, sc, &FixtureSpec::default())
}

/// Every batch of the run completed with a finite loss (recovered-loss
/// continuity: no gaps, no NaNs after any number of recoveries).
pub fn assert_loss_continuity(tag: &str, out: &ScenarioOutcome, total: u64) {
    for b in 0..total {
        let loss = out
            .losses
            .get(&b)
            .unwrap_or_else(|| panic!("{tag}: batch {b} never completed"));
        assert!(loss.is_finite(), "{tag}: batch {b} loss {loss} not finite");
    }
}

/// Bit-exact per-batch loss equality between two runs (the exact-recovery
/// oracle: a replayed batch reproduces the no-fault run's loss).
pub fn assert_losses_bit_equal(tag: &str, a: &ScenarioOutcome, b: &ScenarioOutcome) {
    let bits = |o: &ScenarioOutcome| -> Vec<(u64, u32)> {
        o.losses.iter().map(|(&k, v)| (k, v.to_bits())).collect()
    };
    assert_eq!(bits(a), bits(b), "{tag}: per-batch losses diverge");
}

/// Expected network fetches of a redistribution, recomputed independently
/// with `plan_redistribution` (paper Algorithm 1): requester/target
/// device pairs with the exact block sets. Valid when every alive device
/// still holds its old range (case-3 and dynamic redistributions).
pub fn expected_fetches(
    r: &RedistRecord,
) -> BTreeMap<(usize, usize), BTreeSet<usize>> {
    let mut expect: BTreeMap<(usize, usize), BTreeSet<usize>> = BTreeMap::new();
    for (i_new, &dev) in r.new_list.iter().enumerate() {
        let i_old = r.old_list.iter().position(|&d| d == dev);
        let held: Vec<usize> = match i_old {
            Some(s) if !r.failed.contains(&s) => {
                let (lo, hi) = r.old_ranges[s];
                (lo..=hi).collect()
            }
            _ => vec![],
        };
        let plan =
            plan_redistribution(&r.new_ranges, &r.old_ranges, &r.failed, &held, i_new, i_old);
        for (src, blocks) in &plan.need {
            let target = match src {
                Source::Stage(s) => r.new_list[*s],
                Source::CentralBackup => r.new_list[0],
                Source::LocalBackup => continue,
            };
            if target == dev {
                continue; // served locally (central self-serves escalations)
            }
            expect.entry((dev, target)).or_default().extend(blocks.iter().copied());
        }
    }
    expect
}

/// Aggregate the runner's recorded FetchWeights into the same shape.
pub fn actual_fetches(r: &RedistRecord) -> BTreeMap<(usize, usize), BTreeSet<usize>> {
    let mut got: BTreeMap<(usize, usize), BTreeSet<usize>> = BTreeMap::new();
    for (from, to, blocks) in &r.fetches {
        got.entry((*from, *to)).or_default().extend(blocks.iter().copied());
    }
    got
}

/// Assert the observed fetch traffic of redistribution `r` is exactly
/// what Algorithm 1 plans — no extra fetches, none missing.
pub fn assert_fetches_match_plan(tag: &str, r: &RedistRecord) {
    assert_eq!(
        actual_fetches(r),
        expected_fetches(r),
        "{tag}: redistribution fetch traffic deviates from plan_redistribution \
         (old {:?} -> new {:?}, failed {:?})",
        r.old_ranges,
        r.new_ranges,
        r.failed
    );
}

/// The trace contains a line with this substring.
pub fn assert_trace_contains(tag: &str, out: &ScenarioOutcome, needle: &str) {
    assert!(
        out.trace.iter().any(|l| l.contains(needle)),
        "{tag}: trace has no line containing {needle:?}; trace:\n{}",
        out.trace.join("\n")
    );
}
