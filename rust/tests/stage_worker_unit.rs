//! Unit-level tests of [`StageWorker`]'s control plane driven through a
//! mock transport — no XLA execution, no threads. These pin the protocol
//! behaviours that the slower end-to-end tests exercise only implicitly:
//! probe freshness, replica storage, fetch serving, redistribution
//! staging, commit/reset semantics, and direct weight pushes.

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Duration;

use ftpipehd::config::DeviceConfig;
use ftpipehd::device::SimDevice;
use ftpipehd::manifest::Manifest;
use ftpipehd::net::message::{Message, ReplicaKind, TrainInit};
use ftpipehd::net::Compression;
use ftpipehd::net::{Transport, WireTensor};
use ftpipehd::pipeline::{Flow, StageWorker};
use ftpipehd::runtime::load_all_blocks;

/// Captures every send; never receives.
struct MockNet {
    sent: RefCell<Vec<(usize, Message)>>,
}

impl MockNet {
    fn new() -> Self {
        MockNet { sent: RefCell::new(vec![]) }
    }

    fn take(&self) -> Vec<(usize, Message)> {
        self.sent.borrow_mut().drain(..).collect()
    }
}

impl Transport for MockNet {
    fn my_id(&self) -> usize {
        unreachable!()
    }
    fn send(&self, to: usize, msg: Message) -> anyhow::Result<()> {
        self.sent.borrow_mut().push((to, msg));
        Ok(())
    }
    fn recv_timeout(&self, _: Duration) -> Option<(usize, Message)> {
        None
    }
    fn n_devices(&self) -> usize {
        4
    }
}

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/edgenet-tiny/manifest.json").exists()
}

fn make_worker(device: usize) -> StageWorker {
    let manifest = Arc::new(Manifest::load("artifacts/edgenet-tiny").unwrap());
    let engine = ftpipehd::runtime::Engine::cpu().unwrap();
    let blocks = load_all_blocks(&engine, &manifest).unwrap();
    StageWorker::new(device, manifest, blocks, SimDevice::new(DeviceConfig::default(), 0), None)
}

fn init(ranges: Vec<(usize, usize)>, list: Vec<usize>) -> TrainInit {
    TrainInit {
        committed_forward: -1,
        committed_backward: -1,
        lr: 0.01,
        momentum: 0.9,
        weight_decay: 4e-5,
        epochs: 1,
        batches_per_epoch: 10,
        ranges,
        worker_list: list,
        agg_k: 0,
        chain_every: 0,
        global_every: 0,
        status: 0,
        compression: Compression::Off,
        bw_probe_every: 0,
        bw_probe_bytes: 0,
        tier_floor: ftpipehd::net::quant::Tier::Off,
        tier_ceiling: ftpipehd::net::quant::Tier::FullQ4,
        replica_epoch: 0,
        worker_quota: 0,
    }
}

#[test]
fn probe_reports_fresh_until_initialized() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    w.handle_message(&net, 0, Message::Probe).unwrap();
    match &net.take()[..] {
        [(0, Message::ProbeAck { id: 1, fresh: true })] => {}
        other => panic!("unexpected {other:?}"),
    }
    w.handle_message(&net, 0, Message::InitState(init(vec![(0, 2), (3, 5)], vec![0, 1])))
        .unwrap();
    let _ = net.take(); // drop the bandwidth probe
    w.handle_message(&net, 0, Message::Probe).unwrap();
    match &net.take()[..] {
        [(0, Message::ProbeAck { id: 1, fresh: false })] => {}
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn init_loads_range_weights_and_bandwidth_probe_fires() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    w.handle_message(
        &net,
        0,
        Message::InitState(init(vec![(0, 1), (2, 3), (4, 5)], vec![0, 1, 2])),
    )
    .unwrap();
    assert_eq!(w.params.block_indices(), vec![2, 3]);
    // stage 1's next is stage 2 (device 2): a BwTest must have been sent
    let sent = net.take();
    assert!(
        sent.iter().any(|(to, m)| *to == 2 && matches!(m, Message::BwTest { .. })),
        "bandwidth probe missing: {sent:?}"
    );
}

#[test]
fn replica_push_stored_and_served() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(2);
    w.handle_message(&net, 0, Message::InitState(init(vec![(0, 1), (2, 3), (4, 5)], vec![0, 1, 2])))
        .unwrap();
    net.take();
    // device 1 chain-pushes its blocks 2..3? no — 2 owns 4..5; device 1
    // owns 2..3 and pushes them here
    w.handle_message(
        &net,
        1,
        Message::ReplicaPush {
            kind: ReplicaKind::Chain,
            owner_stage: 1,
            owner_device: 1,
            version: 7,
            blocks: vec![(2, vec![vec![9.0; 4].into()]), (3, vec![vec![8.0; 4].into()])],
        },
    )
    .unwrap();
    assert_eq!(w.backups.len(), 1);
    // a fetch for an owned block + a backed-up block + a missing block
    w.handle_message(&net, 3, Message::FetchWeights { blocks: vec![4, 2, 0] }).unwrap();
    let sent = net.take();
    match &sent[..] {
        [(3, Message::Weights { blocks })] => {
            let idxs: Vec<usize> = blocks.iter().map(|(i, _)| *i).collect();
            assert!(idxs.contains(&4), "own param");
            assert!(idxs.contains(&2), "chain replica");
            assert!(!idxs.contains(&0), "block 0 unknown here");
            // replica content served verbatim
            let b2 = blocks.iter().find(|(i, _)| *i == 2).unwrap();
            assert_eq!(b2.1[0].as_f32().unwrap()[0], 9.0);
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn repartition_stages_fetches_then_commit_swaps() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    w.handle_message(&net, 0, Message::InitState(init(vec![(0, 1), (2, 3), (4, 5)], vec![0, 1, 2])))
        .unwrap();
    net.take();
    // dynamic repartition grows my range to 1..=4: need 1 (from central) and 4 (from stage 2)
    w.handle_message(
        &net,
        0,
        Message::Repartition {
            ranges: vec![(0, 0), (1, 4), (5, 5)],
            worker_list: vec![0, 1, 2],
            failed: vec![],
        },
    )
    .unwrap();
    assert!(!w.fetch_done());
    let sent = net.take();
    let mut to_central = None;
    let mut to_two = None;
    for (to, m) in &sent {
        if let Message::FetchWeights { blocks } = m {
            if *to == 0 {
                to_central = Some(blocks.clone());
            }
            if *to == 2 {
                to_two = Some(blocks.clone());
            }
        }
    }
    assert_eq!(to_central, Some(vec![1]));
    assert_eq!(to_two, Some(vec![4]));

    // replies arrive
    w.handle_message(&net, 0, Message::Weights { blocks: vec![(1, vec![vec![5.0; 3].into()])] })
        .unwrap();
    assert!(!w.fetch_done());
    w.handle_message(&net, 2, Message::Weights { blocks: vec![(4, vec![vec![6.0; 3].into()])] })
        .unwrap();
    assert!(w.fetch_done());
    // FetchDone went to central
    let sent = net.take();
    assert!(sent.iter().any(|(to, m)| *to == 0 && matches!(m, Message::FetchDone { id: 1 })));

    // premature state: must hold OLD params until Commit
    assert_eq!(w.params.block_indices(), vec![2, 3]);
    w.handle_message(&net, 0, Message::Commit).unwrap();
    assert_eq!(w.params.block_indices(), vec![1, 2, 3, 4]);
    assert_eq!(w.params.get(1).unwrap().0[0][0], 5.0);
    assert_eq!(w.params.get(4).unwrap().0[0][0], 6.0);
    assert_eq!(w.status, 0);
}

#[test]
fn peer_missing_block_escalates_to_central() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    w.handle_message(&net, 0, Message::InitState(init(vec![(0, 1), (2, 3), (4, 5)], vec![0, 1, 2])))
        .unwrap();
    net.take();
    w.handle_message(
        &net,
        0,
        Message::Repartition {
            ranges: vec![(0, 0), (1, 4), (5, 5)],
            worker_list: vec![0, 1, 2],
            failed: vec![],
        },
    )
    .unwrap();
    net.take();
    // stage 2 replies WITHOUT block 4 -> worker must escalate to central
    w.handle_message(&net, 2, Message::Weights { blocks: vec![] }).unwrap();
    let sent = net.take();
    let escalated = sent.iter().any(|(to, m)| {
        *to == 0 && matches!(m, Message::FetchWeights { blocks } if blocks == &vec![4])
    });
    assert!(escalated, "escalation missing: {sent:?}");
}

#[test]
fn reset_discards_in_flight_beyond_committed() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    w.handle_message(&net, 0, Message::InitState(init(vec![(0, 2), (3, 5)], vec![0, 1])))
        .unwrap();
    net.take();
    // queue forwards 5..8 without running them
    for b in 5..9u64 {
        w.handle_message(
            &net,
            0,
            Message::Forward {
                batch: b,
                version0: 0,
                is_eval: false,
                data: ftpipehd::net::message::Payload::F32(vec![0.0; 8 * 32].into()),
            },
        )
        .unwrap();
    }
    assert_eq!(w.queued().0, 4);
    w.handle_message(&net, 0, Message::Reset { committed: 6 }).unwrap();
    assert_eq!(w.queued().0, 2, "batches 7,8 discarded, 5,6 kept");
    assert_eq!(w.committed_fwd, 6);
    assert_eq!(w.committed_bwd, 6);
}

#[test]
fn direct_weight_push_overwrites_owned_blocks_only() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    w.handle_message(&net, 0, Message::InitState(init(vec![(0, 2), (3, 5)], vec![0, 1])))
        .unwrap();
    net.take();
    let sizes: Vec<usize> = w.params.get(3).unwrap().0.iter().map(|t| t.len()).collect();
    let push: Vec<WireTensor> = sizes.iter().map(|&n| vec![3.25; n].into()).collect();
    w.handle_message(
        &net,
        0,
        Message::Weights { blocks: vec![(3, push), (0, vec![vec![1.0].into()])] },
    )
    .unwrap();
    assert_eq!(w.params.get(3).unwrap().0[0][0], 3.25, "owned block overwritten");
    assert!(w.params.get(0).is_none(), "unowned block ignored");
}

#[test]
fn wipe_state_simulates_restart() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    w.handle_message(&net, 0, Message::InitState(init(vec![(0, 2), (3, 5)], vec![0, 1])))
        .unwrap();
    net.take();
    assert!(w.initialized);
    w.wipe_state();
    assert!(!w.initialized);
    assert!(w.params.block_indices().is_empty());
    w.handle_message(&net, 0, Message::Probe).unwrap();
    match &net.take()[..] {
        [(0, Message::ProbeAck { fresh: true, .. })] => {}
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn central_restart_pauses_aborts_repart_and_reports_progress() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    // an uninitialized (freshly crashed) worker reports fresh and must
    // NOT pause — it has nothing to pause
    w.handle_message(&net, 0, Message::CentralRestart { committed: 12 }).unwrap();
    match &net.take()[..] {
        [(
            0,
            Message::WorkerState { id: 1, committed_fwd: -1, committed_bwd: -1, fresh: true },
        )] => {}
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(w.status, 0);

    // initialized worker mid-redistribution: the restart aborts the
    // repart (its Commit can never arrive), drops stored replicas, and
    // pauses until the coordinator's reset
    w.handle_message(&net, 0, Message::InitState(init(vec![(0, 1), (2, 3), (4, 5)], vec![0, 1, 2])))
        .unwrap();
    w.handle_message(
        &net,
        1,
        Message::ReplicaPush {
            kind: ReplicaKind::Chain,
            owner_stage: 1,
            owner_device: 1,
            version: 7,
            blocks: vec![(2, vec![vec![9.0; 4].into()])],
        },
    )
    .unwrap();
    w.handle_message(
        &net,
        0,
        Message::Repartition {
            ranges: vec![(0, 0), (1, 4), (5, 5)],
            worker_list: vec![0, 1, 2],
            failed: vec![],
        },
    )
    .unwrap();
    assert!(!w.fetch_done(), "repart in flight");
    assert_eq!(w.backups.len(), 1);
    net.take();
    w.handle_message(&net, 0, Message::CentralRestart { committed: 12 }).unwrap();
    match &net.take()[..] {
        [(0, Message::WorkerState { id: 1, fresh: false, .. })] => {}
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(w.status, 1, "paused until the coordinator's Reset");
    assert!(w.fetch_done(), "aborted repart must not report an open fetch window");
    assert!(w.backups.is_empty(), "replica versions are not comparable across a reboot");
    // the coordinator's reset resumes the stage
    w.handle_message(&net, 0, Message::Reset { committed: 12 }).unwrap();
    assert_eq!(w.status, 0);
    assert_eq!(w.committed_bwd, 12);
}

#[test]
fn shutdown_returns_flow_shutdown() {
    if !artifacts_available() {
        return;
    }
    let net = MockNet::new();
    let mut w = make_worker(1);
    assert_eq!(w.handle_message(&net, 0, Message::Shutdown).unwrap(), Flow::Shutdown);
}
