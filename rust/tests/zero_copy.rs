//! Zero-copy guarantees of the tensor plumbing (the acceptance bar for
//! the event-driven engine): hot-path tensor payloads — activations,
//! gradients, replicated weights — must share allocations end to end.
//! A send through the sim transport performs **zero** f32-buffer copies;
//! the TCP path pays exactly the codec write. Mutation is copy-on-write,
//! so sharing never corrupts a snapshot or a replica.

use std::collections::BTreeMap;
use std::time::Duration;

use ftpipehd::model::{BlockParams, Sgd, SgdConfig, StageParams, VersionStash};
use ftpipehd::net::message::{Message, Payload, ReplicaKind};
use ftpipehd::net::{codec, SimNet, TensorBuf, Transport};
use ftpipehd::replication::{from_wire, to_wire, BackupStore};

fn stage_params(vals: &[f32]) -> StageParams {
    let mut sp = StageParams::default();
    sp.blocks.insert(0, BlockParams::from_vecs(vec![vals.to_vec()]));
    sp
}

#[test]
fn simnet_forward_delivery_shares_the_activation_buffer() {
    let (_net, eps) = SimNet::new(2, vec![1e9], Duration::ZERO);
    let act = TensorBuf::from(vec![0.5f32; 4096]);
    eps[0]
        .send(
            1,
            Message::Forward {
                batch: 3,
                version0: 1,
                is_eval: false,
                data: Payload::F32(act.clone()),
            },
        )
        .unwrap();
    match eps[1].recv_timeout(Duration::from_secs(1)) {
        Some((0, Message::Forward { data: Payload::F32(got), .. })) => {
            assert!(got.ptr_eq(&act), "delivery must be zero-copy");
            // sender handle + receiver handle = 2 references, no hidden copies
            assert_eq!(act.ref_count(), 2);
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn replica_push_through_simnet_shares_stage_weights_end_to_end() {
    let (_net, eps) = SimNet::new(2, vec![1e9], Duration::ZERO);
    let sp = stage_params(&[1.0; 1024]);
    let before = sp.blocks[&0].0[0].clone();

    // owner side: to_wire is refcount bumps
    let wire = to_wire(&sp);
    assert!(wire[0].1[0].as_f32().unwrap().ptr_eq(&before));

    eps[0]
        .send(
            1,
            Message::ReplicaPush {
                kind: ReplicaKind::Chain,
                owner_stage: 1,
                owner_device: 0,
                version: 5,
                blocks: wire,
            },
        )
        .unwrap();

    // receiver side: storing the backup keeps sharing the same buffer
    let mut store = BackupStore::default();
    match eps[1].recv_timeout(Duration::from_secs(1)) {
        Some((0, Message::ReplicaPush { kind, owner_stage, owner_device, version, blocks })) => {
            assert!(
                blocks[0].1[0].as_f32().unwrap().ptr_eq(&before),
                "wire blocks must share the owner's buffer"
            );
            store.store(owner_device, kind, owner_stage, version, from_wire(&blocks));
        }
        other => panic!("unexpected {other:?}"),
    }
    assert!(store.find_block(0).unwrap().0[0].ptr_eq(&before));
}

#[test]
fn optimizer_step_forks_shared_weights_instead_of_corrupting_replicas() {
    let mut sp = stage_params(&[1.0; 8]);
    // replicate: the backup shares the weight buffer
    let wire = to_wire(&sp);
    let replica = wire[0].1[0].as_f32().unwrap().clone();
    assert!(replica.ptr_eq(&sp.blocks[&0].0[0]));

    // the owner's next update must fork, not mutate the replica
    let mut sgd = Sgd::new(SgdConfig { lr: 0.5, momentum: 0.0, weight_decay: 0.0 });
    let mut grads: BTreeMap<usize, Vec<Vec<f32>>> = BTreeMap::new();
    grads.insert(0, vec![vec![1.0; 8]]);
    sgd.step(&mut sp, &grads);

    assert_eq!(replica[0], 1.0, "replica bytes must be frozen at push time");
    assert!((sp.blocks[&0].0[0][0] - 0.5).abs() < 1e-6, "owner updated");
    assert!(!replica.ptr_eq(&sp.blocks[&0].0[0]), "buffers forked on write");

    // a second step with no outstanding sharer mutates in place
    let ptr_before = sp.blocks[&0].0[0].as_slice().as_ptr();
    sgd.step(&mut sp, &grads);
    assert_eq!(
        sp.blocks[&0].0[0].as_slice().as_ptr(),
        ptr_before,
        "unshared weights must update in place (no per-step allocation)"
    );
}

#[test]
fn weight_stash_snapshots_share_until_written() {
    let mut stash = VersionStash::new(4);
    let mut sp = stage_params(&[2.0; 16]);
    stash.on_forward(0, 0, &sp);
    let snap = stash.snapshot(0).unwrap();
    assert!(
        snap.blocks[&0].0[0].ptr_eq(&sp.blocks[&0].0[0]),
        "stash snapshot must share buffers at forward time"
    );
    // weights advance; the stashed version keeps the forward-time bytes
    let mut sgd = Sgd::new(SgdConfig { lr: 1.0, momentum: 0.0, weight_decay: 0.0 });
    let mut grads: BTreeMap<usize, Vec<Vec<f32>>> = BTreeMap::new();
    grads.insert(0, vec![vec![1.0; 16]]);
    sgd.step(&mut sp, &grads);
    assert_eq!(stash.params_for_backward(0).unwrap().blocks[&0].0[0][0], 2.0);
    assert_eq!(sp.blocks[&0].0[0][0], 1.0);
}

#[test]
fn codec_decode_materializes_each_tensor_exactly_once() {
    let act = TensorBuf::from(vec![0.25f32; 2048]);
    let frame = codec::encode(
        7,
        &Message::Forward { batch: 1, version0: 1, is_eval: false, data: Payload::F32(act) },
    );
    let (_, msg) = codec::decode(&frame).unwrap();
    match msg {
        Message::Forward { data: Payload::F32(t), .. } => {
            assert_eq!(t.len(), 2048);
            assert_eq!(t.ref_count(), 1, "decode output must be a single fresh buffer");
        }
        other => panic!("unexpected {other:?}"),
    }
}
