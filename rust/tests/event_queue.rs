//! Property test for the O(log n) event engine (DESIGN.md §11).
//!
//! The reference model is the queue the runner used before the rewrite:
//! a `BTreeMap<(Duration, seq), _>` popped with `pop_first`, purged with
//! `retain`. The heap + generation-tombstone engine must be
//! observationally identical to it under every interleaving of push,
//! scoped push, pop, and per-device purge — same `(time, payload)`
//! delivery sequence, pop for pop, including the final drain. Virtual
//! times are drawn from a tiny range so equal-time collisions (where
//! only the insertion-seq tiebreak keeps the order total) are the
//! common case, not the rare one.

use std::collections::BTreeMap;
use std::time::Duration;

use ftpipehd::sim::queue::EventQueue;
use ftpipehd::util::rng::Rng;

/// The old runner's queue, reconstructed as an executable model.
struct ModelQueue {
    map: BTreeMap<(Duration, u64), (u64, Option<(usize, usize)>)>,
    next_seq: u64,
}

impl ModelQueue {
    fn new() -> ModelQueue {
        ModelQueue { map: BTreeMap::new(), next_seq: 0 }
    }

    fn push(&mut self, at: Duration, id: u64, scope: Option<(usize, usize)>) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.map.insert((at, seq), (id, scope));
    }

    fn purge_device(&mut self, d: usize) {
        // the old kill_central purge: rebuild without anything touching d
        self.map.retain(|_, (_, scope)| match scope {
            Some((from, to)) => *from != d && *to != d,
            None => true,
        });
    }

    fn pop(&mut self) -> Option<(Duration, u64)> {
        self.map.pop_first().map(|((at, _), (id, _))| (at, id))
    }
}

#[test]
fn heap_engine_matches_btreemap_model_under_random_schedules() {
    const N_DEVICES: usize = 6;
    const SCHEDULES: u64 = 200;
    const OPS: usize = 300;
    for schedule in 0..SCHEDULES {
        let mut rng = Rng::new(0xE0E7_0001 ^ schedule.wrapping_mul(0x9E37_79B9));
        let mut model = ModelQueue::new();
        let mut engine: EventQueue<u64> = EventQueue::new(N_DEVICES);
        let mut next_id = 0u64;
        for op in 0..OPS {
            match rng.below(10) {
                // pushes dominate so the queues stay deep enough for
                // purge and tiebreak behaviour to matter
                0..=3 => {
                    let at = Duration::from_millis(rng.below(50));
                    model.push(at, next_id, None);
                    engine.push(at, next_id);
                    next_id += 1;
                }
                4..=7 => {
                    let at = Duration::from_millis(rng.below(50));
                    let from = rng.below(N_DEVICES as u64) as usize;
                    let to = rng.below(N_DEVICES as u64) as usize;
                    model.push(at, next_id, Some((from, to)));
                    engine.push_scoped(at, from, to, next_id);
                    next_id += 1;
                }
                8 => {
                    let d = rng.below(N_DEVICES as u64) as usize;
                    model.purge_device(d);
                    engine.purge_device(d);
                }
                _ => {
                    assert_eq!(
                        engine.pop(),
                        model.pop(),
                        "divergence at schedule {schedule} op {op}"
                    );
                }
            }
        }
        // drain both to the bottom: every surviving entry, in order
        let mut drained = 0usize;
        loop {
            let (a, b) = (engine.pop(), model.pop());
            assert_eq!(a, b, "drain divergence at schedule {schedule} entry {drained}");
            if a.is_none() {
                break;
            }
            drained += 1;
        }
        assert!(engine.is_empty());
    }
}

#[test]
fn purge_then_repush_on_same_link_is_fresh() {
    // the restart_central pattern: purge device 0, then immediately
    // schedule new traffic on the same links — only pre-purge entries die
    let mut model = ModelQueue::new();
    let mut engine: EventQueue<u64> = EventQueue::new(3);
    for (i, (from, to)) in [(0, 1), (1, 0), (1, 2)].into_iter().enumerate() {
        let at = Duration::from_millis(i as u64);
        model.push(at, i as u64, Some((from, to)));
        engine.push_scoped(at, from, to, i as u64);
    }
    model.purge_device(0);
    engine.purge_device(0);
    model.push(Duration::from_millis(0), 100, Some((0, 1)));
    engine.push_scoped(Duration::from_millis(0), 0, 1, 100);
    let mut order = vec![];
    while let Some((at, id)) = engine.pop() {
        assert_eq!(model.pop(), Some((at, id)));
        order.push(id);
    }
    assert_eq!(model.pop(), None);
    assert_eq!(order, vec![100, 2], "post-purge push must outlive the purge");
}
