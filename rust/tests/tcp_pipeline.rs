//! Integration: the stage engine running over REAL TCP sockets — the
//! multi-process deployment path (paper's Flask analogue). A 2-stage
//! pipeline: this thread acts as the central node/stage 0 over a
//! `TcpEndpoint`, a spawned thread runs stage 1 through `run_worker`.
//! Plus the central-restart drill: kill the central's endpoint, rebind
//! its listener, and re-attach the surviving worker over the fresh
//! socket (paper §3.5 over real TCP, not just the sim).

use std::sync::Arc;
use std::time::{Duration, Instant};

use ftpipehd::config::DeviceConfig;
use ftpipehd::device::SimDevice;
use ftpipehd::manifest::Manifest;
use ftpipehd::net::message::{Message, TrainInit};
use ftpipehd::net::{TcpConfig, TcpEndpoint, Transport};
use ftpipehd::pipeline::{run_worker, StageWorker};
use ftpipehd::runtime::{load_all_blocks, Engine, HostTensor};
use ftpipehd::sim::real_clock;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/edgenet-tiny/manifest.json").exists()
}

#[test]
fn two_process_style_pipeline_over_tcp() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Arc::new(Manifest::load("artifacts/edgenet-tiny").unwrap());
    let addrs = vec!["127.0.0.1:46200".to_string(), "127.0.0.1:46201".to_string()];

    // stage 1 worker on its own thread with its own engine + TCP endpoint
    let m2 = manifest.clone();
    let addrs2 = addrs.clone();
    let h = std::thread::spawn(move || {
        let ep = TcpEndpoint::bind(1, addrs2).unwrap();
        let engine = Engine::cpu().unwrap();
        let blocks = load_all_blocks(&engine, &m2).unwrap();
        let sim = SimDevice::new(DeviceConfig::default(), 1);
        let w = StageWorker::new(1, m2, blocks, sim, None);
        run_worker(w, Box::new(ep), None).unwrap();
    });

    // central / stage 0
    let ep = TcpEndpoint::bind(0, addrs).unwrap();
    let engine = Engine::cpu().unwrap();
    let blocks = load_all_blocks(&engine, &manifest).unwrap();
    let sim = SimDevice::new(DeviceConfig::default(), 0);
    let mut central = StageWorker::new(0, manifest.clone(), blocks, sim, None);

    std::thread::sleep(Duration::from_millis(300)); // both listeners up

    let nb = manifest.n_blocks();
    let ranges = vec![(0, nb / 2 - 1), (nb / 2, nb - 1)];
    let ti = TrainInit {
        committed_forward: -1,
        committed_backward: -1,
        lr: 0.01,
        momentum: 0.9,
        weight_decay: 4e-5,
        epochs: 1,
        batches_per_epoch: 8,
        ranges,
        worker_list: vec![0, 1],
        agg_k: 0,
        chain_every: 0,
        global_every: 0,
        status: 0,
        compression: ftpipehd::net::Compression::Off,
        bw_probe_every: 0,
        bw_probe_bytes: 0,
        tier_floor: ftpipehd::net::quant::Tier::Off,
        tier_ceiling: ftpipehd::net::quant::Tier::FullQ4,
        replica_epoch: 0,
        worker_quota: 0,
    };
    ep.send(1, Message::InitState(ti.clone())).unwrap();
    central.apply_init(&ti).unwrap();
    std::thread::sleep(Duration::from_millis(300));

    // train 8 batches through the 2-stage TCP pipeline
    let in_elems: usize = manifest.input_shape.iter().product();
    let lab_elems: usize = manifest.label_shape.iter().product();
    let mut completed = 0u64;
    let mut losses: Vec<f32> = vec![];
    let mut injected = 0u64;
    let deadline = Instant::now() + Duration::from_secs(120);
    while completed < 8 && Instant::now() < deadline {
        while injected < 8 && injected - completed < 2 {
            let x: Vec<f32> = (0..in_elems)
                .map(|i| ((i as u64 + injected * 13) % 17) as f32 * 0.1 - 0.8)
                .collect();
            let labels: Vec<i32> =
                (0..lab_elems).map(|i| ((i as u64 + injected) % 4) as i32).collect();
            ep.send(1, Message::Labels { batch: injected, is_eval: false, data: labels })
                .unwrap();
            central
                .forward_train(&ep, injected, central.version, HostTensor::F32(x.into()))
                .unwrap();
            injected += 1;
        }
        if let Some((_, msg)) = ep.recv_timeout(Duration::from_millis(20)) {
            if let Message::Backward { batch, grad, loss, ncorrect, reports } = msg {
                let done = central
                    .backward(&ep, batch, grad.into_f32(), loss, ncorrect, reports)
                    .unwrap();
                let cb = done.expect("stage 0 completes batches");
                losses.push(cb.loss);
                completed += 1;
            }
        }
    }
    assert_eq!(completed, 8, "TCP pipeline must complete all batches");
    assert!(losses.iter().all(|l| l.is_finite()));

    ep.send(1, Message::Shutdown).unwrap();
    h.join().unwrap();
}

/// Send `msg` and wait for a reply matching `want`, re-sending on each
/// timeout: the peer's old connection may be mid-redial, so a single
/// fire-and-forget send can legitimately land on the floor.
fn send_until_reply(
    me: &TcpEndpoint,
    to: usize,
    msg: Message,
    want: impl Fn(&Message) -> bool,
) -> (usize, Message) {
    for _ in 0..40 {
        me.send(to, msg.clone()).unwrap();
        if let Some((from, got)) = me.recv_timeout(Duration::from_millis(250)) {
            if want(&got) {
                return (from, got);
            }
        }
    }
    panic!("no matching reply to {} from {to}", msg.tag());
}

/// The central dies and comes back on the SAME address: `rebind` retries
/// the listener over the backoff schedule (SO_REUSEADDR rides over the
/// dead socket's lingering state) and the worker's endpoint — which never
/// restarted — re-attaches through its stale-connection redial path. This
/// is transport-level only; the coordinator's CentralRestart/WorkerState
/// protocol semantics are covered by the sim suites.
#[test]
fn central_kill_and_rebind_reattaches_over_tcp() {
    let addrs = vec!["127.0.0.1:46210".to_string(), "127.0.0.1:46211".to_string()];
    let cfg = TcpConfig::patient();

    // bind both listeners up-front (same thread: no startup race), then
    // hand the worker endpoint to a thread that answers the protocol
    let worker = TcpEndpoint::bind_with(1, addrs.clone(), cfg.clone(), real_clock()).unwrap();
    let worker_thread = std::thread::spawn(move || {
        let deadline = Instant::now() + Duration::from_secs(60);
        let mut answered_restart = false;
        loop {
            match worker.recv_timeout(Duration::from_millis(500)) {
                // pre-crash traffic (and any resent duplicates)
                Some((0, Message::Commit)) => {
                    worker.send(0, Message::FetchDone { id: 1 }).unwrap();
                }
                // the restart announcement, over the FRESH listener: reply
                // through the worker's stale outbound connection, which the
                // driver detects as dead and redials transparently
                Some((0, Message::CentralRestart { committed })) => {
                    assert_eq!(committed, 29);
                    worker
                        .send(
                            0,
                            Message::WorkerState {
                                id: 1,
                                committed_fwd: 34,
                                committed_bwd: 33,
                                fresh: false,
                            },
                        )
                        .unwrap();
                    answered_restart = true;
                }
                // the announcements stop once central2 has our state
                None if answered_restart => return worker,
                _ => {}
            }
            assert!(Instant::now() < deadline, "worker never completed the re-attach");
        }
    });

    {
        let central = TcpEndpoint::bind_with(0, addrs.clone(), cfg.clone(), real_clock()).unwrap();
        // pre-crash traffic in both directions so live connections exist
        let (_, got) = send_until_reply(&central, 1, Message::Commit, |m| {
            matches!(m, Message::FetchDone { id: 1 })
        });
        assert!(matches!(got, Message::FetchDone { id: 1 }));
        drop(central);
    }
    // central's endpoint is gone: driver joined, listener closed, port free

    let central2 = TcpEndpoint::rebind(0, addrs, cfg, real_clock()).unwrap();
    let (_, got) = send_until_reply(&central2, 1, Message::CentralRestart { committed: 29 }, |m| {
        matches!(m, Message::WorkerState { .. })
    });
    match got {
        Message::WorkerState { id, committed_fwd, committed_bwd, fresh } => {
            assert_eq!((id, committed_fwd, committed_bwd, fresh), (1, 34, 33, false));
        }
        other => panic!("unexpected {other:?}"),
    }
    let worker = worker_thread.join().unwrap();
    worker.shutdown();
    central2.shutdown();
}
