//! Integration: the stage engine running over REAL TCP sockets — the
//! multi-process deployment path (paper's Flask analogue). A 2-stage
//! pipeline: this thread acts as the central node/stage 0 over a
//! `TcpEndpoint`, a spawned thread runs stage 1 through `run_worker`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use ftpipehd::config::DeviceConfig;
use ftpipehd::device::SimDevice;
use ftpipehd::manifest::Manifest;
use ftpipehd::net::message::{Message, Payload, TrainInit};
use ftpipehd::net::tcp::TcpEndpoint;
use ftpipehd::net::Transport;
use ftpipehd::pipeline::{run_worker, StageWorker};
use ftpipehd::runtime::{load_all_blocks, Engine, HostTensor};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/edgenet-tiny/manifest.json").exists()
}

struct Wrap(TcpEndpoint);

impl Transport for Wrap {
    fn my_id(&self) -> usize {
        self.0.my_id()
    }
    fn send(&self, to: usize, msg: Message) -> anyhow::Result<()> {
        self.0.send(to, msg)
    }
    fn recv_timeout(&self, timeout: Duration) -> Option<(usize, Message)> {
        self.0.recv_timeout(timeout)
    }
    fn n_devices(&self) -> usize {
        self.0.n_devices()
    }
}

#[test]
fn two_process_style_pipeline_over_tcp() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Arc::new(Manifest::load("artifacts/edgenet-tiny").unwrap());
    let addrs = vec!["127.0.0.1:46200".to_string(), "127.0.0.1:46201".to_string()];

    // stage 1 worker on its own thread with its own engine + TCP endpoint
    let m2 = manifest.clone();
    let addrs2 = addrs.clone();
    let h = std::thread::spawn(move || {
        let ep = TcpEndpoint::bind(1, addrs2).unwrap();
        let engine = Engine::cpu().unwrap();
        let blocks = load_all_blocks(&engine, &m2).unwrap();
        let sim = SimDevice::new(DeviceConfig::default(), 1);
        let w = StageWorker::new(1, m2, blocks, sim, None);
        run_worker(w, Box::new(Wrap(ep)), None).unwrap();
    });

    // central / stage 0
    let ep = Wrap(TcpEndpoint::bind(0, addrs).unwrap());
    let engine = Engine::cpu().unwrap();
    let blocks = load_all_blocks(&engine, &manifest).unwrap();
    let sim = SimDevice::new(DeviceConfig::default(), 0);
    let mut central = StageWorker::new(0, manifest.clone(), blocks, sim, None);

    std::thread::sleep(Duration::from_millis(300)); // both listeners up

    let nb = manifest.n_blocks();
    let ranges = vec![(0, nb / 2 - 1), (nb / 2, nb - 1)];
    let ti = TrainInit {
        committed_forward: -1,
        committed_backward: -1,
        lr: 0.01,
        momentum: 0.9,
        weight_decay: 4e-5,
        epochs: 1,
        batches_per_epoch: 8,
        ranges,
        worker_list: vec![0, 1],
        agg_k: 0,
        chain_every: 0,
        global_every: 0,
        status: 0,
        compression: ftpipehd::net::Compression::Off,
        bw_probe_every: 0,
        bw_probe_bytes: 0,
        tier_floor: ftpipehd::net::quant::Tier::Off,
        tier_ceiling: ftpipehd::net::quant::Tier::FullQ4,
        replica_epoch: 0,
        worker_quota: 0,
    };
    ep.send(1, Message::InitState(ti.clone())).unwrap();
    central.apply_init(&ti).unwrap();
    std::thread::sleep(Duration::from_millis(300));

    // train 8 batches through the 2-stage TCP pipeline
    let in_elems: usize = manifest.input_shape.iter().product();
    let lab_elems: usize = manifest.label_shape.iter().product();
    let mut completed = 0u64;
    let mut losses: Vec<f32> = vec![];
    let mut injected = 0u64;
    let deadline = Instant::now() + Duration::from_secs(120);
    while completed < 8 && Instant::now() < deadline {
        while injected < 8 && injected - completed < 2 {
            let x: Vec<f32> = (0..in_elems)
                .map(|i| ((i as u64 + injected * 13) % 17) as f32 * 0.1 - 0.8)
                .collect();
            let labels: Vec<i32> =
                (0..lab_elems).map(|i| ((i as u64 + injected) % 4) as i32).collect();
            ep.send(1, Message::Labels { batch: injected, is_eval: false, data: labels })
                .unwrap();
            central
                .forward_train(&ep, injected, central.version, HostTensor::F32(x.into()))
                .unwrap();
            injected += 1;
        }
        if let Some((_, msg)) = ep.recv_timeout(Duration::from_millis(20)) {
            if let Message::Backward { batch, grad, loss, ncorrect, reports } = msg {
                let done = central
                    .backward(&ep, batch, grad.into_f32(), loss, ncorrect, reports)
                    .unwrap();
                let cb = done.expect("stage 0 completes batches");
                losses.push(cb.loss);
                completed += 1;
            }
        }
    }
    assert_eq!(completed, 8, "TCP pipeline must complete all batches");
    assert!(losses.iter().all(|l| l.is_finite()));

    ep.send(1, Message::Shutdown).unwrap();
    h.join().unwrap();
}
