//! Integration: central-node checkpointing (paper §III-E) — periodic
//! save-to-disk during training, then resume a new run from the
//! checkpoint weights; plus the lr-drop schedule.

use ftpipehd::checkpoint::Checkpoint;
use ftpipehd::config::{DeviceConfig, RunConfig};
use ftpipehd::coordinator::{run_sim, run_sim_full, RunOpts};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/edgenet-tiny/manifest.json").exists()
}

fn cfg(batches: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model_dir = "artifacts/edgenet-tiny".into();
    cfg.devices = vec![DeviceConfig::default(); 3];
    cfg.epochs = 1;
    cfg.batches_per_epoch = batches;
    cfg.eval_batches = 4;
    cfg.bandwidth_bps = vec![1e9];
    cfg.link_latency_s = 0.0;
    cfg
}

#[test]
fn checkpoint_written_and_resumable() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = std::env::temp_dir().join("ftpipehd-ckpt-integration");
    let _ = std::fs::remove_dir_all(&dir);

    let mut c = cfg(40);
    // frequent global replication so the checkpoint can cover all stages
    c.chain_every = Some(5);
    c.global_every = Some(10);
    c.checkpoint = Some((dir.to_string_lossy().to_string(), 20));
    let record = run_sim(&c).expect("run");
    assert!(
        record.events.iter().any(|e| e.kind.contains("checkpoint")),
        "no checkpoint event: {:?}",
        record.events
    );

    let ck = Checkpoint::load(&dir).expect("load checkpoint");
    assert!(ck.state.committed_batch >= 19);
    // all 6 blocks present: central's own + global replicas
    assert_eq!(ck.weights.len(), 6, "checkpoint covers all blocks");

    // resume a fresh run from the checkpoint weights: early accuracy must
    // be far above chance (the model had already learned)
    let c2 = cfg(10);
    let out = run_sim_full(
        &c2,
        RunOpts { initial_weights: Some(ck.weights), ..Default::default() },
    )
    .expect("resume");
    let early: f32 =
        out.record.batches.iter().take(5).map(|b| b.train_acc).sum::<f32>() / 5.0;
    assert!(early > 0.5, "resumed accuracy {early} too low — weights not restored?");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lr_drop_schedule_applies() {
    if !artifacts_available() {
        return;
    }
    let mut c = cfg(10);
    c.epochs = 3;
    c.batches_per_epoch = 10;
    c.lr_drops = vec![(1, 0.001), (2, 0.0001)];
    // no direct observability of workers' lr, but the run must complete
    // and losses stay finite (a broken SetLr would diverge or stall)
    let record = run_sim(&c).expect("run");
    assert_eq!(record.batches.len(), 30);
    assert!(record.batches.iter().all(|b| b.loss.is_finite()));
    // late-epoch updates are tiny: loss variance in epoch 2 should be
    // small relative to epoch 0
    let var = |lo: usize, hi: usize| {
        let xs: Vec<f32> = record.batches[lo..hi].iter().map(|b| b.loss).collect();
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
    };
    assert!(var(20, 30) <= var(0, 10) + 1e-6);
}
