//! Central-node checkpoint durability (paper §III-E).
//!
//! Property tests (artifact-free, run everywhere): arbitrary
//! shapes/values round-trip bit-identically through save/load; truncated
//! or garbage `state.json` and missing tensor files are clean errors,
//! never panics; a crash between tmp-write and rename (a leftover
//! `<dir>.tmp`) is invisible to the loader, which picks the newest
//! *complete* numbered checkpoint. Integration tests (artifact-gated):
//! periodic checkpointing during a real run, resuming via
//! `RunConfig::resume_from` (the restart handshake + warm-start path),
//! and the lr-drop schedule.

use std::collections::BTreeMap;
use std::path::PathBuf;

use ftpipehd::checkpoint::{Checkpoint, CheckpointSink, CheckpointState, DiskSink};
use ftpipehd::config::{DeviceConfig, RunConfig};
use ftpipehd::coordinator::run_sim;
use ftpipehd::model::BlockParams;
use ftpipehd::util::prop::{check, G};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/edgenet-tiny/manifest.json").exists()
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join("ftpipehd-ckpt-it")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

// ---------------------------------------------------------------------
// durability properties
// ---------------------------------------------------------------------

/// A checkpoint with random block ids, tensor counts, shapes, and values
/// (including non-finite ones — durability is about bits, not numerics).
fn random_checkpoint(g: &mut G<'_>) -> Checkpoint {
    let n_blocks = g.usize_in(1, 4);
    let mut shapes: BTreeMap<usize, Vec<Vec<usize>>> = BTreeMap::new();
    let mut weights: BTreeMap<usize, BlockParams> = BTreeMap::new();
    let mut id = 0usize;
    for _ in 0..n_blocks {
        id += g.usize_in(0, 3); // sparse, strictly ordered block ids
        let n_tensors = g.usize_in(1, 3);
        let mut ts: Vec<Vec<usize>> = Vec::new();
        let mut bps: Vec<Vec<f32>> = Vec::new();
        for _ in 0..n_tensors {
            let ndim = g.usize_in(0, 3);
            let shape: Vec<usize> = (0..ndim).map(|_| g.usize_in(1, 4)).collect();
            let n: usize = shape.iter().product();
            let mut data = g.vec_f32(n);
            if !data.is_empty() && g.bool() {
                // plant a hostile value: bit-exactness must survive it
                let i = g.usize_in(0, data.len() - 1);
                data[i] = *g.pick(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0]);
            }
            ts.push(shape);
            bps.push(data);
        }
        shapes.insert(id, ts);
        weights.insert(id, BlockParams::from_vecs(bps));
        id += 1;
    }
    let stages = g.usize_in(1, 3);
    Checkpoint {
        state: CheckpointState {
            committed_batch: g.usize_in(0, 1000) as i64 - 1,
            epoch: g.usize_in(0, 30) as u64,
            lr: *g.pick(&[0.1f32, 0.05, 0.01, 0.00625]),
            ranges: (0..stages).map(|s| (s * 2, s * 2 + 1)).collect(),
            worker_list: (0..stages).collect(),
            shapes,
        },
        weights,
    }
}

fn weight_bits(ck: &Checkpoint) -> Vec<(usize, Vec<Vec<u32>>)> {
    ck.weights
        .iter()
        .map(|(&b, bp)| {
            (b, bp.0.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect())
        })
        .collect()
}

#[test]
fn prop_random_checkpoints_roundtrip_bit_identically() {
    let root = tmpdir("prop-roundtrip");
    std::fs::create_dir_all(&root).unwrap();
    let mut n = 0usize;
    check("checkpoint-roundtrip", 60, |g| {
        n += 1;
        let dir = root.join(format!("case-{n}"));
        let ck = random_checkpoint(g);
        ck.save(&dir).map_err(|e| format!("save: {e:#}"))?;
        let back = Checkpoint::load(&dir).map_err(|e| format!("load: {e:#}"))?;
        if back.state.committed_batch != ck.state.committed_batch
            || back.state.epoch != ck.state.epoch
            || back.state.ranges != ck.state.ranges
            || back.state.worker_list != ck.state.worker_list
            || back.state.shapes != ck.state.shapes
        {
            return Err("state drifted through save/load".into());
        }
        if weight_bits(&back) != weight_bits(&ck) {
            return Err("weights not bit-identical through save/load".into());
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn prop_truncated_state_json_is_a_clean_error() {
    let root = tmpdir("prop-truncated");
    std::fs::create_dir_all(&root).unwrap();
    let mut n = 0usize;
    check("checkpoint-truncated-state", 40, |g| {
        n += 1;
        let dir = root.join(format!("case-{n}"));
        let ck = random_checkpoint(g);
        ck.save(&dir).map_err(|e| format!("save: {e:#}"))?;
        let state = dir.join("state.json");
        let full = std::fs::read(&state).map_err(|e| e.to_string())?;
        // a strict prefix that at least loses the closing brace (a last
        // trailing newline alone could still parse) — a torn write must
        // never load and must never panic
        let cut = g.usize_in(0, full.len().saturating_sub(2));
        std::fs::write(&state, &full[..cut]).map_err(|e| e.to_string())?;
        match Checkpoint::load(&dir) {
            Err(_) => {
                let _ = std::fs::remove_dir_all(&dir);
                Ok(())
            }
            Ok(_) => Err(format!("truncated state.json ({cut}/{} bytes) loaded", full.len())),
        }
    });
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn prop_missing_tensor_file_is_a_clean_error() {
    let root = tmpdir("prop-missing-npy");
    std::fs::create_dir_all(&root).unwrap();
    let mut n = 0usize;
    check("checkpoint-missing-npy", 40, |g| {
        n += 1;
        let dir = root.join(format!("case-{n}"));
        let ck = random_checkpoint(g);
        ck.save(&dir).map_err(|e| format!("save: {e:#}"))?;
        // delete one random tensor file
        let npys: Vec<PathBuf> = std::fs::read_dir(&dir)
            .map_err(|e| e.to_string())?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "npy"))
            .collect();
        let victim = &npys[g.usize_in(0, npys.len() - 1)];
        std::fs::remove_file(victim).map_err(|e| e.to_string())?;
        match Checkpoint::load(&dir) {
            Err(_) => {
                let _ = std::fs::remove_dir_all(&dir);
                Ok(())
            }
            Ok(_) => Err(format!("load succeeded without {victim:?}")),
        }
    });
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn leftover_tmp_from_a_crash_is_ignored_and_the_previous_checkpoint_loads() {
    let root = tmpdir("tmp-leftover");
    let mut sink = DiskSink::new(&root);
    let mut rng = ftpipehd::util::rng::Rng::new(7);
    let mut g = G { rng: &mut rng, size: 8 };
    let mut ck = random_checkpoint(&mut g);
    ck.state.committed_batch = 24;
    sink.save(&ck).unwrap();
    // simulate a crash between tmp-write and rename of a NEWER save:
    // fully-written contents under the staging name, but the commit
    // rename to `ckpt-00000049` never happened
    ck.state.committed_batch = 49;
    ck.save(root.join("ckpt-00000049.tmp")).unwrap();
    let back = sink.load_latest().unwrap().expect("previous good checkpoint");
    assert_eq!(back.state.committed_batch, 24, ".tmp leftover must be invisible");
    // and a later successful save supersedes both
    ck.state.committed_batch = 74;
    sink.save(&ck).unwrap();
    assert_eq!(sink.load_latest().unwrap().unwrap().state.committed_batch, 74);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn loader_picks_highest_numbered_complete_checkpoint() {
    let root = tmpdir("highest-complete");
    let mut sink = DiskSink::new(&root);
    let mut rng = ftpipehd::util::rng::Rng::new(11);
    let mut g = G { rng: &mut rng, size: 8 };
    let mut ck = random_checkpoint(&mut g);
    ck.state.committed_batch = 19;
    sink.save(&ck).unwrap();
    ck.state.committed_batch = 39;
    sink.save(&ck).unwrap();
    // plant an incomplete NEWER one: committed directory name, torn state
    std::fs::create_dir_all(root.join("ckpt-00000059")).unwrap();
    std::fs::write(root.join("ckpt-00000059/state.json"), "{\"committed_ba").unwrap();
    let back = sink.load_latest().unwrap().expect("complete entry exists");
    assert_eq!(back.state.committed_batch, 39, "newest COMPLETE wins, not newest numbered");
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------
// integration (artifact-gated): periodic save during a run + resume
// ---------------------------------------------------------------------

fn cfg(batches: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model_dir = "artifacts/edgenet-tiny".into();
    cfg.devices = vec![DeviceConfig::default(); 3];
    cfg.epochs = 1;
    cfg.batches_per_epoch = batches;
    cfg.eval_batches = 4;
    cfg.bandwidth_bps = vec![1e9];
    cfg.link_latency_s = 0.0;
    cfg
}

#[test]
fn checkpoint_written_and_resumable() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = tmpdir("integration");

    let mut c = cfg(40);
    // frequent global replication so the checkpoint can cover all stages
    c.chain_every = Some(5);
    c.global_every = Some(10);
    c.checkpoint = Some((dir.to_string_lossy().to_string(), 20));
    let record = run_sim(&c).expect("run");
    assert!(
        record.events.iter().any(|e| e.kind.contains("checkpoint")),
        "no checkpoint event: {:?}",
        record.events
    );

    let ck = DiskSink::new(&dir).load_latest().expect("sink").expect("checkpoint");
    assert!(ck.state.committed_batch >= 19);
    // all 6 blocks present: central's own + global replicas
    assert_eq!(ck.weights.len(), 6, "checkpoint covers all blocks");

    // resume through the §III-E restart path: handshake + warm start
    // from the newest complete checkpoint, replaying only what the
    // checkpoint had not committed
    let mut c2 = cfg(60);
    c2.resume_from = Some(dir.to_string_lossy().to_string());
    let record2 = run_sim(&c2).expect("resume");
    assert!(
        record2.events.iter().any(|e| e.kind.contains("resumed from checkpoint")),
        "no resume event: {:?}",
        record2.events
    );
    let replayed = (ck.state.committed_batch + 1).max(0) as usize;
    assert_eq!(
        record2.batches.len(),
        60 - replayed,
        "resume must train exactly the batches past the checkpoint frontier"
    );
    // the model had already learned: resumed accuracy far above chance
    let early: f32 =
        record2.batches.iter().take(5).map(|b| b.train_acc).sum::<f32>() / 5.0;
    assert!(early > 0.5, "resumed accuracy {early} too low — weights not restored?");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lr_drop_schedule_applies() {
    if !artifacts_available() {
        return;
    }
    let mut c = cfg(10);
    c.epochs = 3;
    c.batches_per_epoch = 10;
    c.lr_drops = vec![(1, 0.001), (2, 0.0001)];
    // no direct observability of workers' lr, but the run must complete
    // and losses stay finite (a broken SetLr would diverge or stall)
    let record = run_sim(&c).expect("run");
    assert_eq!(record.batches.len(), 30);
    assert!(record.batches.iter().all(|b| b.loss.is_finite()));
    // late-epoch updates are tiny: loss variance in epoch 2 should be
    // small relative to epoch 0
    let var = |lo: usize, hi: usize| {
        let xs: Vec<f32> = record.batches[lo..hi].iter().map(|b| b.loss).collect();
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
    };
    assert!(var(20, 30) <= var(0, 10) + 1e-6);
}
