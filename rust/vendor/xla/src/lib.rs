//! Vendored **stub** of the `xla` PJRT bindings.
//!
//! The real bindings link against a PJRT CPU plugin and cannot be built in
//! the offline environment, so this crate mirrors the exact API surface
//! `ftpipehd::runtime` uses and keeps the whole workspace compiling and
//! testable. Host-side literal plumbing (creation, reshape, readback) is
//! fully functional; only `PjRtLoadedExecutable::execute` is stubbed — it
//! returns a descriptive error, which surfaces exactly like a missing
//! `artifacts/` directory does (every test and bench that needs real
//! compute already skips in that case).
//!
//! To run real models, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings; no source change is needed.

use std::fmt;
use std::path::PathBuf;

/// Stub error type (mirrors `xla::Error` closely enough for `?`).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------
// literals
// ---------------------------------------------------------------------

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Marker trait for element types supported by the stub.
pub trait NativeType: Copy + Sized {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// A host-side literal: flat data plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape to {dims:?} wants {want} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Read the literal back as a flat host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error::new("literal element type mismatch"))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.data)
            .and_then(|v| v.first().copied())
            .ok_or_else(|| Error::new("empty literal or element type mismatch"))
    }

    /// Decompose a tuple literal. The stub never produces tuples (execute
    /// is stubbed), so this is only reachable with real bindings.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new("stub literal is not a tuple (execution is stubbed)"))
    }
}

// ---------------------------------------------------------------------
// HLO + client + executable
// ---------------------------------------------------------------------

/// Parsed HLO module handle (the stub only records the source path).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: PathBuf,
}

impl HloModuleProto {
    /// "Parse" an HLO text file. Validates readability so missing or
    /// unreadable artifacts fail here, like the real parser would.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::metadata(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { path: PathBuf::from(path) })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    path: PathBuf,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// Stub PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { path: comp.path.clone() })
    }
}

/// Stub loaded executable: execution is not available offline.
pub struct PjRtLoadedExecutable {
    path: PathBuf,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(format!(
            "cannot execute {}: the vendored xla stub has no PJRT backend \
             (swap rust/vendor/xla for the real bindings — see DESIGN.md)",
            self.path.display()
        )))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn missing_hlo_file_errors() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
    }
}
