//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The real `anyhow` cannot be fetched in the offline build environment,
//! so this crate re-implements the (small) surface the project uses:
//!
//! * [`Error`] — an opaque error with a human-readable context chain
//! * [`Result<T>`] — alias with `Error` as the default error type
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` impl (and therefore `?`) possible.

use std::fmt;

/// An error with a chain of context strings (most recent first).
pub struct Error {
    msg: String,
    /// Older causes, outermost context first after `msg`.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), chain: Vec::new() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: context.to_string(), chain }
    }

    /// The context chain below the top-level message (outermost first).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for c in &self.chain {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    /// Anything convertible into [`crate::Error`]. Implemented for every
    /// `std::error::Error` and for `Error` itself (which is possible only
    /// because `Error` does not implement `std::error::Error`).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }
}

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("file missing"));
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(io_err()).context("reading config");
        let e = e.with_context(|| format!("starting run {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "starting run 7");
        let full = format!("{e:#}");
        assert!(full.contains("reading config") && full.contains("file missing"), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<()> {
            ensure!(x < 10, "x too large: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(())
        }
        assert!(f(11).unwrap_err().to_string().contains("11"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        assert!(f(1).is_ok());
        let e = anyhow!("plain {}", 5);
        assert_eq!(e.to_string(), "plain 5");
    }
}
