//! Weight replication schedules + the backup store (paper §III-E).
//!
//! Chain replication: every worker pushes its weights to the next worker
//! (the last worker pushes to the central node) every `chain_every`
//! batches. Global replication: every worker pushes to the central node
//! every `global_every` batches (less frequent; tolerates any number of
//! simultaneous failures at higher central-link cost).

use std::collections::BTreeMap;

use crate::model::params::{BlockParams, StageParams};
use crate::net::message::{DeviceId, ReplicaKind, WireBlock, WireTensor};
use crate::net::quant::{ChannelHint, WeightCoding};

/// How many low bits of a replica version hold the per-epoch sequence
/// number; the high bits hold the coordinator's restart epoch. 48 bits
/// of sequence (~2.8e14 batches) cannot realistically wrap, and 16 bits
/// of epoch survive 65k coordinator restarts.
pub const VERSION_SEQ_BITS: u32 = 48;

/// Compose a wire replica version from the coordinator restart `epoch`
/// and the per-epoch sequence number `seq` (DESIGN.md §9, case-2 wart):
/// because the epoch occupies the high bits, *any* post-restart push
/// outranks *every* pre-restart backup in [`BackupStore`]'s
/// newest-version-wins ordering, no matter how far the old epoch's
/// sequence had advanced. Epoch 0 is the identity (`epoch_version(0, v)
/// == v`), so runs that never restart the coordinator keep their
/// historical version numbers — and their traces — byte-identical.
pub fn epoch_version(epoch: u64, seq: u64) -> u64 {
    (epoch << VERSION_SEQ_BITS) | (seq & ((1u64 << VERSION_SEQ_BITS) - 1))
}

/// The coordinator restart epoch encoded in a wire replica version.
pub fn version_epoch(version: u64) -> u64 {
    version >> VERSION_SEQ_BITS
}

/// The per-epoch sequence number encoded in a wire replica version.
pub fn version_seq(version: u64) -> u64 {
    version & ((1u64 << VERSION_SEQ_BITS) - 1)
}

/// Should a replication fire after completing `batch` (0-based)?
pub fn due(batch: u64, every: Option<u64>) -> bool {
    match every {
        Some(k) if k > 0 => (batch + 1) % k == 0,
        _ => false,
    }
}

/// Chain-replica target of `stage` in an `n`-stage pipeline: the next
/// stage, wrapping the last stage to the central node (stage 0).
pub fn chain_target(stage: usize, n_stages: usize) -> usize {
    if stage + 1 < n_stages {
        stage + 1
    } else {
        0
    }
}

/// One block's tensors as f32 wire tensors — refcount bumps, zero-copy.
pub fn block_to_wire(bp: &BlockParams) -> Vec<WireTensor> {
    bp.0.iter().map(|t| WireTensor::F32(t.clone())).collect()
}

/// One block's tensors under an explicit [`WeightCoding`], with a
/// per-tensor channel hint (from the manifest's shapes — see
/// `StageWorker::block_wire` for the shape-aware caller). `F32` stays
/// zero-copy; `Q8`/`Q4` pay one quantization pass at this sender
/// boundary. The plain-coded path with no error feedback — the Q4
/// replica stream folds residuals in `StageWorker::replica_wire`
/// instead.
pub fn block_to_wire_coded(
    bp: &BlockParams,
    hints: &[ChannelHint],
    coding: WeightCoding,
) -> Vec<WireTensor> {
    bp.0.iter()
        .enumerate()
        .map(|(k, t)| {
            let hint = hints.get(k).copied().unwrap_or(ChannelHint::PerTensor);
            WireTensor::from_weights(t, coding, hint)
        })
        .collect()
}

/// Rebuild one block from wire tensors: f32 arms are moves (shared
/// buffers), q8 arms pay their single receiver-side dequantization.
pub fn block_from_wire(tensors: Vec<WireTensor>) -> BlockParams {
    BlockParams(tensors.into_iter().map(WireTensor::into_f32).collect())
}

/// Serialize a stage's parameters for a replica push. Zero-copy: the
/// wire blocks share the stage's tensor buffers (refcount bumps), so a
/// periodic replication no longer deep-copies the stage's weights — the
/// owner's next optimizer step forks only what the replica still holds.
pub fn to_wire(params: &StageParams) -> Vec<WireBlock> {
    params.blocks.iter().map(|(idx, bp)| (*idx, block_to_wire(bp))).collect()
}

/// Rebuild block params from wire form (f32: shared buffers, zero-copy;
/// q8: dequantized exactly once, here at the receiver boundary).
pub fn from_wire(blocks: &[WireBlock]) -> Vec<(usize, BlockParams)> {
    blocks
        .iter()
        .map(|(idx, tensors)| (*idx, block_from_wire(tensors.clone())))
        .collect()
}

/// One stored backup.
#[derive(Debug, Clone)]
pub struct Backup {
    pub kind: ReplicaKind,
    pub owner_stage: usize,
    pub version: u64,
    pub blocks: Vec<(usize, BlockParams)>,
}

/// Backups held by one device, keyed by the owner's device id. A
/// `BTreeMap` so that [`BackupStore::find_block`]'s scan order — and
/// therefore which replica wins a version tie — is deterministic (the
/// scenario suite asserts bit-identical recoveries across runs).
#[derive(Debug, Clone, Default)]
pub struct BackupStore {
    by_owner: BTreeMap<DeviceId, Backup>,
}

impl BackupStore {
    /// Store/overwrite a backup (newest version wins).
    pub fn store(
        &mut self,
        owner_device: DeviceId,
        kind: ReplicaKind,
        owner_stage: usize,
        version: u64,
        blocks: Vec<(usize, BlockParams)>,
    ) {
        let newer = self
            .by_owner
            .get(&owner_device)
            .map(|b| version >= b.version)
            .unwrap_or(true);
        if newer {
            self.by_owner
                .insert(owner_device, Backup { kind, owner_stage, version, blocks });
        }
    }

    /// Look up a specific block across all held backups (newest first).
    pub fn find_block(&self, block: usize) -> Option<&BlockParams> {
        let mut best: Option<(&Backup, &BlockParams)> = None;
        for b in self.by_owner.values() {
            if let Some((_, bp)) = b.blocks.iter().find(|(i, _)| *i == block) {
                let replace = best.map(|(bb, _)| b.version > bb.version).unwrap_or(true);
                if replace {
                    best = Some((b, bp));
                }
            }
        }
        best.map(|(_, bp)| bp)
    }

    pub fn of_owner(&self, owner_device: DeviceId) -> Option<&Backup> {
        self.by_owner.get(&owner_device)
    }

    pub fn remove_owner(&mut self, owner_device: DeviceId) {
        self.by_owner.remove(&owner_device);
    }

    pub fn len(&self) -> usize {
        self.by_owner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_owner.is_empty()
    }

    pub fn byte_len(&self) -> usize {
        self.by_owner
            .values()
            .map(|b| b.blocks.iter().map(|(_, bp)| bp.byte_len()).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bp(v: f32) -> BlockParams {
        BlockParams::from_vecs(vec![vec![v; 3]])
    }

    #[test]
    fn due_schedule() {
        assert!(!due(0, Some(50)));
        assert!(due(49, Some(50)));
        assert!(due(99, Some(50)));
        assert!(!due(50, Some(50)));
        assert!(!due(49, None));
        assert!(!due(49, Some(0)));
    }

    #[test]
    fn chain_targets() {
        assert_eq!(chain_target(0, 3), 1);
        assert_eq!(chain_target(1, 3), 2);
        assert_eq!(chain_target(2, 3), 0); // last -> central
    }

    #[test]
    fn store_keeps_newest_version() {
        let mut s = BackupStore::default();
        s.store(1, ReplicaKind::Chain, 1, 5, vec![(3, bp(5.0))]);
        s.store(1, ReplicaKind::Chain, 1, 3, vec![(3, bp(3.0))]); // older: ignored
        assert_eq!(s.of_owner(1).unwrap().version, 5);
        assert_eq!(s.find_block(3).unwrap().0[0][0], 5.0);
        s.store(1, ReplicaKind::Global, 1, 9, vec![(3, bp(9.0))]);
        assert_eq!(s.find_block(3).unwrap().0[0][0], 9.0);
    }

    /// DESIGN.md §9 case-2 wart, closed: a worker's *pre-restart* backup
    /// (epoch 0, arbitrarily high sequence) must never shadow the first
    /// *post-restart* push (epoch 1, sequence 0). Before the epoch bits
    /// existed, the stale backup's raw version 1_000_000 would have won
    /// the `version >= b.version` race and resurrected dead weights.
    #[test]
    fn post_restart_push_outranks_stale_pre_restart_backup() {
        assert_eq!(epoch_version(0, 7), 7, "epoch 0 must be the identity");
        assert_eq!(version_epoch(epoch_version(3, 9)), 3);
        assert_eq!(version_seq(epoch_version(3, 9)), 9);
        assert!(epoch_version(1, 0) > epoch_version(0, 1_000_000));

        let mut s = BackupStore::default();
        // stale pre-restart backup: epoch 0, far-advanced sequence
        s.store(1, ReplicaKind::Chain, 1, epoch_version(0, 1_000_000), vec![(3, bp(1.0))]);
        // first push after a coordinator restart: epoch 1, sequence 0
        s.store(1, ReplicaKind::Chain, 1, epoch_version(1, 0), vec![(3, bp(2.0))]);
        assert_eq!(s.find_block(3).unwrap().0[0][0], 2.0, "post-restart push must win");
        // and the stale epoch can never sneak back in
        s.store(1, ReplicaKind::Global, 1, epoch_version(0, 2_000_000), vec![(3, bp(3.0))]);
        assert_eq!(s.find_block(3).unwrap().0[0][0], 2.0);
    }

    #[test]
    fn find_block_across_owners_prefers_newest() {
        let mut s = BackupStore::default();
        s.store(1, ReplicaKind::Chain, 1, 2, vec![(7, bp(2.0))]);
        s.store(2, ReplicaKind::Global, 2, 8, vec![(7, bp(8.0))]);
        assert_eq!(s.find_block(7).unwrap().0[0][0], 8.0);
        assert!(s.find_block(99).is_none());
    }

    #[test]
    fn wire_roundtrip() {
        let mut sp = StageParams::default();
        sp.blocks.insert(2, bp(1.0));
        sp.blocks.insert(5, bp(2.0));
        let wire = to_wire(&sp);
        let back = from_wire(&wire);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, 2);
        assert_eq!(back[1].1, bp(2.0));
    }

    #[test]
    fn to_wire_shares_buffers_with_the_stage() {
        let mut sp = StageParams::default();
        sp.blocks.insert(2, bp(1.0));
        let wire = to_wire(&sp);
        assert!(
            wire[0].1[0].as_f32().unwrap().ptr_eq(&sp.blocks[&2].0[0]),
            "replica push must not deep-copy stage weights"
        );
        let back = from_wire(&wire);
        assert!(back[0].1 .0[0].ptr_eq(&sp.blocks[&2].0[0]));
    }

    #[test]
    fn block_to_wire_coded_selects_the_coding() {
        let bp = BlockParams::from_vecs(vec![vec![0.0, 0.5, 1.0]]);
        let hints = [ChannelHint::PerTensor];
        let wire = block_to_wire_coded(&bp, &hints, WeightCoding::F32);
        assert!(
            wire[0].as_f32().unwrap().ptr_eq(&bp.0[0]),
            "F32 coding must keep replica pushes zero-copy"
        );
        let wire = block_to_wire_coded(&bp, &hints, WeightCoding::Q8);
        let q = wire[0].as_quant().expect("Q8 coding must quantize weight traffic");
        assert_eq!(q.len(), 3);
        assert!(wire[0].byte_len() < 12, "3 f32s must shrink on the wire");
        let back = block_from_wire(wire);
        for (a, b) in [0.0f32, 0.5, 1.0].iter().zip(back.0[0].iter()) {
            assert!((a - b).abs() <= q.tolerance());
        }
    }

    /// Acceptance pin: the replica-push byte ladder. For a realistic
    /// 64x64 weight block, Q4 < Q8 < f32 on the wire, with Q4 ~>= 6x
    /// under f32 even after paying its 64 per-channel pairs (a long 1-D
    /// tensor approaches the full 8x — asserted in `net::quant`).
    #[test]
    fn replica_push_bytes_order_q4_q8_f32() {
        use crate::net::quant::weight_channel_hint;
        let xs: Vec<f32> = (0..4096).map(|i| ((i * 29) % 97) as f32 * 0.1 - 4.0).collect();
        let bp = BlockParams::from_vecs(vec![xs]);
        let hints = [weight_channel_hint(&[64, 64], 4096)];
        let bytes = |coding| -> usize {
            block_to_wire_coded(&bp, &hints, coding).iter().map(|t| t.byte_len()).sum()
        };
        let (f, q8, q4) =
            (bytes(WeightCoding::F32), bytes(WeightCoding::Q8), bytes(WeightCoding::Q4));
        assert!(q4 < q8 && q8 < f, "byte ladder must order q4 {q4} < q8 {q8} < f32 {f}");
        assert!(f >= 6 * q4, "q4 replica push must be ~8x under f32 (got {f} vs {q4})");
        assert!(f >= 3 * q8, "q8 replica push stays ~4x under f32 (got {f} vs {q8})");
        // and the coded forms still roundtrip within their tolerance
        let wire = block_to_wire_coded(&bp, &hints, WeightCoding::Q4);
        let tol = wire[0].as_quant().unwrap().tolerance();
        let back = block_from_wire(wire);
        for (a, b) in bp.0[0].iter().zip(back.0[0].iter()) {
            assert!((a - b).abs() <= tol, "{a} vs {b} (tol {tol})");
        }
    }
}
