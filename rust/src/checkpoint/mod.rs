//! Central-node checkpointing (paper §III-E): "the failure of the central
//! node can be dealt with by simply saving the training states and model
//! weights to the disk periodically, and recovering from them every time
//! it fails."
//!
//! A checkpoint is a directory:
//!
//! ```text
//! <dir>/state.json          committed batch, epoch, lr, partition, worker list
//! <dir>/block{i}_p{k}.npy   every parameter tensor (self-describing npy)
//! ```
//!
//! The npy format makes checkpoints directly loadable from Python
//! (`np.load`) — verified by `python/tests/test_interchange.py`.
//!
//! Persistence goes through the [`CheckpointSink`] seam (DESIGN.md §9):
//! [`DiskSink`] keeps numbered directories under one root and loads the
//! newest *complete* one; [`MemorySink`] is the deterministic in-memory
//! store the virtual-clock scenario runner uses to script central-node
//! crash/restart without touching the filesystem.
//!
//! [`CoordinatorStore`] generalizes the sink over *all* leadership state
//! (DESIGN.md §12): a [`LeaderState`] bundles the checkpoint with the
//! per-link measured bandwidths and compression tiers, the replica
//! version epoch, and the worker-roster snapshot, so `resume_from`
//! restores the full coordinator instead of re-deriving roster and
//! controller state. On disk the extras live in a `leader.json` sidecar
//! next to the numbered checkpoint directories — old checkpoint roots
//! without one still load, with default extras.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::log_warn;
use crate::model::BlockParams;
use crate::net::message::DeviceId;
use crate::net::quant::Tier;
use crate::util::json::{self, Value};
use crate::util::npy;

/// fsync a directory's entry table. A hard requirement on unix, where
/// the write-tmp/rename commit protocol depends on it; a no-op on
/// platforms whose `File::open` cannot open directories (Windows),
/// where crash-durability of directory entries is best-effort anyway.
fn fsync_dir(path: &Path) -> Result<()> {
    #[cfg(unix)]
    std::fs::File::open(path)
        .and_then(|d| d.sync_all())
        .with_context(|| format!("fsync {}", path.display()))?;
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

/// Training state captured alongside the weights (paper Table I subset).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointState {
    pub committed_batch: i64,
    pub epoch: u64,
    pub lr: f32,
    pub ranges: Vec<(usize, usize)>,
    pub worker_list: Vec<usize>,
    /// shapes per (block, tensor) for reconstruction
    pub shapes: BTreeMap<usize, Vec<Vec<usize>>>,
}

/// A complete checkpoint: state + all parameters.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub state: CheckpointState,
    pub weights: BTreeMap<usize, BlockParams>,
}

impl Checkpoint {
    /// Persist atomically: write to `<dir>.tmp`, fsync every file, then
    /// rename. The rename is the commit point — a crash at any earlier
    /// moment leaves only a `<dir>.tmp` leftover (which loaders ignore),
    /// never a committed directory with half-durable contents. Without
    /// the fsyncs the rename could land on disk before the data it
    /// "commits", which is exactly the partial-latest-pointer state the
    /// loader must never observe.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        let tmp = PathBuf::from(format!("{}.tmp", dir.display()));
        if tmp.exists() {
            std::fs::remove_dir_all(&tmp)?;
        }
        std::fs::create_dir_all(&tmp)?;

        for (&b, bp) in &self.weights {
            let shapes = self
                .state
                .shapes
                .get(&b)
                .ok_or_else(|| anyhow!("no shapes for block {b}"))?;
            for (k, (tensor, shape)) in bp.0.iter().zip(shapes).enumerate() {
                npy::write_f32(tmp.join(format!("block{b}_p{k}.npy")), shape, tensor)?;
            }
        }
        std::fs::write(tmp.join("state.json"), self.state_json().to_pretty())?;
        for entry in std::fs::read_dir(&tmp)? {
            let path = entry?.path();
            std::fs::File::open(&path)
                .and_then(|f| f.sync_all())
                .with_context(|| format!("fsync {}", path.display()))?;
        }
        // the directory's own entries must be durable BEFORE the rename
        // commits them, or a committed ckpt-N could surface with files
        // missing — the exact half-durable state the loader must never see
        fsync_dir(&tmp)?;

        if dir.exists() {
            std::fs::remove_dir_all(dir)?;
        }
        std::fs::rename(&tmp, dir).context("committing checkpoint rename")?;
        // make the rename itself durable (directory-entry update in the
        // parent). Best-effort with a warning: a failure here can only
        // lose the *newest* entry across a power cut, never corrupt it —
        // the loader falls back to the previous complete checkpoint.
        if let Some(parent) = dir.parent() {
            if let Err(e) = fsync_dir(parent) {
                log_warn!("fsync of checkpoint parent {} failed: {e:#}", parent.display());
            }
        }
        Ok(())
    }

    fn state_json(&self) -> Value {
        Value::obj(vec![
            ("committed_batch", Value::Num(self.state.committed_batch as f64)),
            ("epoch", Value::Num(self.state.epoch as f64)),
            ("lr", Value::Num(self.state.lr as f64)),
            (
                "ranges",
                Value::Arr(
                    self.state
                        .ranges
                        .iter()
                        .map(|&(a, b)| Value::arr_usize(&[a, b]))
                        .collect(),
                ),
            ),
            ("worker_list", Value::arr_usize(&self.state.worker_list)),
            (
                "shapes",
                Value::Obj(
                    self.state
                        .shapes
                        .iter()
                        .map(|(b, tensors)| {
                            (
                                b.to_string(),
                                Value::Arr(
                                    tensors.iter().map(|s| Value::arr_usize(s)).collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Load a checkpoint directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Checkpoint> {
        let dir = dir.as_ref();
        let raw = std::fs::read_to_string(dir.join("state.json"))
            .with_context(|| format!("reading {}/state.json", dir.display()))?;
        let v = json::parse(&raw).map_err(|e| anyhow!("{e}"))?;
        let usize_pair = |x: &Value| -> Result<(usize, usize)> {
            let a = x.as_arr().ok_or_else(|| anyhow!("range not array"))?;
            // a truncated/corrupt state.json must error, never index-panic
            let lo = a.first().and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("bad range"))?;
            let hi = a.get(1).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("bad range"))?;
            Ok((lo, hi))
        };
        let mut shapes: BTreeMap<usize, Vec<Vec<usize>>> = BTreeMap::new();
        for (k, tensors) in v.req("shapes").map_err(|e| anyhow!("{e}"))?.as_obj().unwrap_or(&[]) {
            let b: usize = k.parse().context("block key")?;
            let mut ts = Vec::new();
            for s in tensors.as_arr().unwrap_or(&[]) {
                ts.push(
                    s.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                );
            }
            shapes.insert(b, ts);
        }
        let state = CheckpointState {
            committed_batch: v.get("committed_batch").and_then(|x| x.as_i64()).unwrap_or(-1),
            epoch: v.get("epoch").and_then(|x| x.as_i64()).unwrap_or(0) as u64,
            lr: v.get("lr").and_then(|x| x.as_f64()).unwrap_or(0.01) as f32,
            ranges: v
                .req("ranges")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(usize_pair)
                .collect::<Result<_>>()?,
            worker_list: v
                .req("worker_list")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            shapes: shapes.clone(),
        };

        let mut weights = BTreeMap::new();
        for (&b, tensors) in &shapes {
            let mut bp = Vec::with_capacity(tensors.len());
            for k in 0..tensors.len() {
                let (shape, data) = npy::read_f32(dir.join(format!("block{b}_p{k}.npy")))?;
                if shape != tensors[k] {
                    return Err(anyhow!(
                        "block {b} tensor {k}: shape {:?} != state.json {:?}",
                        shape,
                        tensors[k]
                    ));
                }
                bp.push(data.into());
            }
            weights.insert(b, BlockParams(bp));
        }
        Ok(Checkpoint { state, weights })
    }
}

// ---------------------------------------------------------------------
// the checkpoint sink seam (DESIGN.md §9)
// ---------------------------------------------------------------------

/// Where periodic central-node checkpoints go and where a restarted
/// central node boots from. Two implementations: [`DiskSink`] (real
/// deployments, numbered directories, crash-safe) and [`MemorySink`]
/// (the deterministic scenario harness — no filesystem, no wall clock).
pub trait CheckpointSink: Send {
    /// Persist `ck`. Returns the committed batch the entry is filed
    /// under.
    fn save(&mut self, ck: &Checkpoint) -> Result<i64>;

    /// The newest *complete* checkpoint, or `None` if nothing usable was
    /// ever persisted. Incomplete entries (a crash mid-save) must be
    /// skipped in favor of the newest complete one, never returned as
    /// errors.
    fn load_latest(&self) -> Result<Option<Checkpoint>>;
}

/// Disk-backed sink: every save lands in `<root>/ckpt-<committed:08>`
/// via [`Checkpoint::save`]'s fsync-then-rename protocol. The loader
/// scans numbered directories newest-first and returns the first one
/// that loads completely — a leftover `ckpt-*.tmp` (crash between write
/// and rename) or a committed-but-corrupt directory falls through to the
/// previous good checkpoint. After each save, entries beyond the newest
/// `keep` are pruned — a multi-day run must not grow one full model copy
/// per period until the disk fills and checkpointing silently dies.
pub struct DiskSink {
    root: PathBuf,
    /// Numbered entries retained after a successful save (min 1).
    keep: usize,
}

impl DiskSink {
    pub fn new(root: impl Into<PathBuf>) -> DiskSink {
        DiskSink { root: root.into(), keep: 4 }
    }

    /// Override the retention count (clamped to at least 1).
    pub fn with_keep(mut self, keep: usize) -> DiskSink {
        self.keep = keep.max(1);
        self
    }

    /// Numbered entries under the root, newest first. `.tmp` leftovers
    /// and foreign names parse-fail and are skipped here.
    fn entries_desc(&self) -> Vec<(i64, PathBuf)> {
        let Ok(rd) = std::fs::read_dir(&self.root) else {
            return vec![];
        };
        let mut out: Vec<(i64, PathBuf)> = rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let n: i64 = name.strip_prefix("ckpt-")?.parse().ok()?;
                Some((n, e.path()))
            })
            .collect();
        out.sort_by(|a, b| b.cmp(a));
        out
    }
}

impl CheckpointSink for DiskSink {
    fn save(&mut self, ck: &Checkpoint) -> Result<i64> {
        let n = ck.state.committed_batch.max(0);
        std::fs::create_dir_all(&self.root)
            .with_context(|| format!("creating {}", self.root.display()))?;
        ck.save(self.root.join(format!("ckpt-{n:08}")))?;
        // prune beyond the newest `keep` entries — only after the new one
        // committed, so retention can never reduce what is recoverable
        for (old, path) in self.entries_desc().into_iter().skip(self.keep) {
            if let Err(e) = std::fs::remove_dir_all(&path) {
                log_warn!("pruning checkpoint ckpt-{old:08} failed: {e}");
            }
        }
        Ok(n)
    }

    fn load_latest(&self) -> Result<Option<Checkpoint>> {
        for (n, path) in self.entries_desc() {
            match Checkpoint::load(&path) {
                Ok(ck) => return Ok(Some(ck)),
                Err(e) => {
                    log_warn!("skipping incomplete checkpoint ckpt-{n:08}: {e:#}");
                }
            }
        }
        Ok(None)
    }
}

/// In-memory sink for the deterministic harness: saves clone the
/// checkpoint (cheap — `BlockParams` share `TensorBuf`s) and loads
/// return the newest entry. Purely deterministic: no filesystem, no
/// clock, no iteration-order dependence.
#[derive(Default)]
pub struct MemorySink {
    saved: Vec<Checkpoint>,
    leaders: Vec<LeaderState>,
}

impl MemorySink {
    pub fn len(&self) -> usize {
        self.saved.len()
    }

    pub fn is_empty(&self) -> bool {
        self.saved.is_empty()
    }

    /// Borrowing peek at the newest entry (the trait method clones).
    pub fn latest(&self) -> Option<&Checkpoint> {
        self.saved.last()
    }
}

impl CheckpointSink for MemorySink {
    fn save(&mut self, ck: &Checkpoint) -> Result<i64> {
        self.saved.push(ck.clone());
        Ok(ck.state.committed_batch)
    }

    fn load_latest(&self) -> Result<Option<Checkpoint>> {
        Ok(self.saved.last().cloned())
    }
}

// ---------------------------------------------------------------------
// the full-leadership store (DESIGN.md §12)
// ---------------------------------------------------------------------

/// Everything a process needs to resume coordinator leadership: the
/// checkpoint (committed frontier, partition, weights) plus the state the
/// old `resume_from` path used to re-derive from scratch — per-link
/// measured bandwidths and compression tiers, the replica version epoch,
/// and the worker-roster snapshot
/// (`crate::coordinator::core::WorkerRoster::snapshot`).
#[derive(Debug, Clone)]
pub struct LeaderState {
    /// Committed training state + weights (paper §III-E).
    pub checkpoint: Checkpoint,
    /// Last measured bandwidth per link, keyed by destination device
    /// (bytes/sec; absent = never measured).
    pub link_bw: Vec<(DeviceId, f64)>,
    /// Per-link adaptive tier overrides in force when the state was
    /// saved (`AdaptivePolicy::overrides`; links at the floor are
    /// absent).
    pub link_tiers: Vec<(DeviceId, Tier)>,
    /// Replica version epoch (bumped once per coordinator restart so
    /// pre-restart backups can never shadow post-restart pushes — see
    /// `crate::replication::epoch_version`).
    pub replica_epoch: u64,
    /// Worker-roster capacity quota on the wire encoding (0 = unlimited).
    pub worker_quota: u64,
    /// Devices admitted to the roster when the state was saved.
    pub admitted: Vec<DeviceId>,
}

impl LeaderState {
    /// Wrap a bare checkpoint with default extras (no measurements, no
    /// tier overrides, epoch 0, unlimited empty roster) — what loading a
    /// pre-§12 checkpoint root yields.
    pub fn around(checkpoint: Checkpoint) -> LeaderState {
        LeaderState {
            checkpoint,
            link_bw: Vec::new(),
            link_tiers: Vec::new(),
            replica_epoch: 0,
            worker_quota: 0,
            admitted: Vec::new(),
        }
    }

    /// The sidecar JSON (tagged with the checkpoint's committed batch so
    /// a stale sidecar is detectable).
    fn extras_json(&self, committed: i64) -> Value {
        Value::obj(vec![
            ("committed_batch", Value::Num(committed as f64)),
            (
                "link_bw",
                Value::Arr(
                    self.link_bw
                        .iter()
                        .map(|&(d, b)| Value::Arr(vec![Value::Num(d as f64), Value::Num(b)]))
                        .collect(),
                ),
            ),
            (
                "link_tiers",
                Value::Arr(
                    self.link_tiers
                        .iter()
                        .map(|&(d, t)| Value::arr_usize(&[d, t.to_u8() as usize]))
                        .collect(),
                ),
            ),
            ("replica_epoch", Value::Num(self.replica_epoch as f64)),
            ("worker_quota", Value::Num(self.worker_quota as f64)),
            ("admitted", Value::arr_usize(&self.admitted)),
        ])
    }

    /// Overlay sidecar extras onto default values (all keys optional,
    /// matching the forward/backward-compatible checkpoint loader).
    /// Sidecars written before per-link tiers carry a dense
    /// `measured_bw` array (index = pipeline link) and one scalar
    /// `tier`; both are translated through the checkpoint's worker list
    /// — link `i` feeds the device at slot `i + 1`, and the fleet-wide
    /// tier becomes one override per worker (the policy's resume clamp
    /// drops floor-valued entries).
    fn apply_extras(&mut self, v: &Value) {
        let wl = &self.checkpoint.state.worker_list;
        if let Some(bw) = v.get("link_bw").and_then(|x| x.as_arr()) {
            self.link_bw = bw
                .iter()
                .filter_map(|pair| {
                    let p = pair.as_arr()?;
                    Some((p.first()?.as_usize()?, p.get(1)?.as_f64()?))
                })
                .collect();
        } else if let Some(bw) = v.get("measured_bw").and_then(|x| x.as_arr()) {
            self.link_bw = bw
                .iter()
                .enumerate()
                .filter_map(|(i, x)| {
                    let b = x.as_f64()?;
                    let dest = wl.get(i + 1)?;
                    (b > 0.0).then_some((*dest, b))
                })
                .collect();
        }
        if let Some(lt) = v.get("link_tiers").and_then(|x| x.as_arr()) {
            self.link_tiers = lt
                .iter()
                .filter_map(|pair| {
                    let p = pair.as_arr()?;
                    let d = p.first()?.as_usize()?;
                    let t = Tier::from_u8(p.get(1)?.as_usize()? as u8)?;
                    Some((d, t))
                })
                .collect();
        } else if let Some(t) =
            v.get("tier").and_then(|x| x.as_usize()).and_then(|t| Tier::from_u8(t as u8))
        {
            self.link_tiers = wl.iter().skip(1).map(|&d| (d, t)).collect();
        }
        if let Some(e) = v.get("replica_epoch").and_then(|x| x.as_usize()) {
            self.replica_epoch = e as u64;
        }
        if let Some(q) = v.get("worker_quota").and_then(|x| x.as_usize()) {
            self.worker_quota = q as u64;
        }
        if let Some(a) = v.get("admitted").and_then(|x| x.as_arr()) {
            self.admitted = a.iter().filter_map(|x| x.as_usize()).collect();
        }
    }
}

/// The [`CheckpointSink`] seam generalized to *all* leadership state:
/// any process holding a `CoordinatorStore` can resume coordination
/// (committed counters, partition, roster, adaptive-controller state,
/// measured bandwidths) without re-deriving anything. `save_leader`
/// subsumes `save`; `load_latest_leader` degrades gracefully to
/// checkpoint-only roots by filling default extras.
pub trait CoordinatorStore: CheckpointSink {
    /// Persist the full leadership state. Returns the committed batch the
    /// underlying checkpoint is filed under.
    fn save_leader(&mut self, st: &LeaderState) -> Result<i64>;

    /// The newest complete leadership state, or `None` when nothing was
    /// ever persisted. Roots written before the store existed (no
    /// sidecar) load with default extras, never error.
    fn load_latest_leader(&self) -> Result<Option<LeaderState>>;
}

impl CoordinatorStore for DiskSink {
    fn save_leader(&mut self, st: &LeaderState) -> Result<i64> {
        let n = self.save(&st.checkpoint)?;
        // sidecar commit mirrors the checkpoint protocol in miniature:
        // tmp write + fsync + rename, so a torn sidecar is impossible
        // (the loader would see either the old or the new one)
        let tmp = self.root.join("leader.json.tmp");
        std::fs::write(&tmp, st.extras_json(n).to_pretty())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::File::open(&tmp)
            .and_then(|f| f.sync_all())
            .with_context(|| format!("fsync {}", tmp.display()))?;
        std::fs::rename(&tmp, self.root.join("leader.json"))
            .context("committing leader.json rename")?;
        if let Err(e) = fsync_dir(&self.root) {
            log_warn!("fsync of store root {} failed: {e:#}", self.root.display());
        }
        Ok(n)
    }

    fn load_latest_leader(&self) -> Result<Option<LeaderState>> {
        let Some(ck) = self.load_latest()? else {
            return Ok(None);
        };
        let mut st = LeaderState::around(ck);
        if let Ok(raw) = std::fs::read_to_string(self.root.join("leader.json")) {
            match json::parse(&raw) {
                Ok(v) => {
                    let tag = v.get("committed_batch").and_then(|x| x.as_i64());
                    if tag == Some(st.checkpoint.state.committed_batch) {
                        st.apply_extras(&v);
                    } else {
                        // the sidecar belongs to a checkpoint that was
                        // pruned or never committed — extras stay default
                        log_warn!(
                            "leader.json tagged for batch {tag:?} != checkpoint {}; ignoring",
                            st.checkpoint.state.committed_batch
                        );
                    }
                }
                Err(e) => log_warn!("unparseable leader.json ignored: {e}"),
            }
        }
        Ok(Some(st))
    }
}

impl CoordinatorStore for MemorySink {
    fn save_leader(&mut self, st: &LeaderState) -> Result<i64> {
        let n = self.save(&st.checkpoint)?;
        self.leaders.push(st.clone());
        Ok(n)
    }

    fn load_latest_leader(&self) -> Result<Option<LeaderState>> {
        if let Some(st) = self.leaders.last() {
            return Ok(Some(st.clone()));
        }
        Ok(self.saved.last().cloned().map(LeaderState::around))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut shapes = BTreeMap::new();
        shapes.insert(0usize, vec![vec![2, 3], vec![3]]);
        shapes.insert(2usize, vec![vec![4]]);
        let mut weights = BTreeMap::new();
        weights.insert(0, BlockParams::from_vecs(vec![vec![1.0; 6], vec![0.5; 3]]));
        weights.insert(2, BlockParams::from_vecs(vec![vec![-2.0; 4]]));
        Checkpoint {
            state: CheckpointState {
                committed_batch: 99,
                epoch: 3,
                lr: 0.01,
                ranges: vec![(0, 1), (2, 5)],
                worker_list: vec![0, 2],
                shapes,
            },
            weights,
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("ftpipehd-ckpt-tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmpdir("roundtrip");
        let ck = sample();
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.state, ck.state);
        assert_eq!(back.weights.len(), 2);
        assert_eq!(back.weights[&0], ck.weights[&0]);
        assert_eq!(back.weights[&2], ck.weights[&2]);
    }

    #[test]
    fn save_is_atomic_overwrite() {
        let dir = tmpdir("atomic");
        let mut ck = sample();
        ck.save(&dir).unwrap();
        ck.state.committed_batch = 150;
        ck.save(&dir).unwrap(); // overwrite
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.state.committed_batch, 150);
        assert!(!PathBuf::from(format!("{}.tmp", dir.display())).exists());
    }

    #[test]
    fn load_missing_fails_cleanly() {
        assert!(Checkpoint::load(tmpdir("missing")).is_err());
    }

    #[test]
    fn shape_mismatch_detected() {
        let dir = tmpdir("mismatch");
        let ck = sample();
        ck.save(&dir).unwrap();
        // corrupt one tensor file with the wrong shape
        crate::util::npy::write_f32(dir.join("block2_p0.npy"), &[5], &[0.0; 5]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
    }

    #[test]
    fn disk_sink_numbers_entries_and_loads_the_newest() {
        let root = tmpdir("sink-newest");
        let mut sink = DiskSink::new(&root);
        let mut ck = sample();
        ck.state.committed_batch = 19;
        assert_eq!(sink.save(&ck).unwrap(), 19);
        ck.state.committed_batch = 39;
        ck.weights.get_mut(&0).unwrap().0[0] = vec![7.0; 6].into();
        assert_eq!(sink.save(&ck).unwrap(), 39);
        assert!(root.join("ckpt-00000019").is_dir());
        assert!(root.join("ckpt-00000039").is_dir());
        let back = sink.load_latest().unwrap().expect("latest");
        assert_eq!(back.state.committed_batch, 39);
        assert_eq!(back.weights[&0].0[0][0], 7.0);
    }

    #[test]
    fn disk_sink_skips_incomplete_newer_entries() {
        let root = tmpdir("sink-incomplete");
        let mut sink = DiskSink::new(&root);
        let mut ck = sample();
        ck.state.committed_batch = 19;
        sink.save(&ck).unwrap();
        // a crash between tmp-write and rename leaves only a .tmp dir
        std::fs::create_dir_all(root.join("ckpt-00000059.tmp")).unwrap();
        std::fs::write(root.join("ckpt-00000059.tmp/state.json"), "{").unwrap();
        // and a committed-looking newer dir may still be incomplete
        // (truncated state, or a tensor file that never made it)
        std::fs::create_dir_all(root.join("ckpt-00000039")).unwrap();
        std::fs::write(root.join("ckpt-00000039/state.json"), "{\"committed").unwrap();
        let back = sink.load_latest().unwrap().expect("fell back to the good entry");
        assert_eq!(back.state.committed_batch, 19);
    }

    #[test]
    fn disk_sink_missing_tensor_file_falls_back() {
        let root = tmpdir("sink-missing-npy");
        let mut sink = DiskSink::new(&root);
        let mut ck = sample();
        ck.state.committed_batch = 9;
        sink.save(&ck).unwrap();
        ck.state.committed_batch = 29;
        sink.save(&ck).unwrap();
        std::fs::remove_file(root.join("ckpt-00000029/block0_p1.npy")).unwrap();
        let back = sink.load_latest().unwrap().expect("older entry still loads");
        assert_eq!(back.state.committed_batch, 9);
    }

    #[test]
    fn disk_sink_empty_or_absent_root_is_none() {
        let sink = DiskSink::new(tmpdir("sink-absent"));
        assert!(sink.load_latest().unwrap().is_none());
        let root = tmpdir("sink-empty");
        std::fs::create_dir_all(&root).unwrap();
        assert!(DiskSink::new(&root).load_latest().unwrap().is_none());
    }

    #[test]
    fn disk_sink_prunes_beyond_keep() {
        let root = tmpdir("sink-prune");
        let mut sink = DiskSink::new(&root).with_keep(2);
        let mut ck = sample();
        for committed in [9i64, 19, 29, 39] {
            ck.state.committed_batch = committed;
            sink.save(&ck).unwrap();
        }
        assert!(!root.join("ckpt-00000009").exists(), "oldest pruned");
        assert!(!root.join("ckpt-00000019").exists(), "second-oldest pruned");
        assert!(root.join("ckpt-00000029").is_dir());
        assert!(root.join("ckpt-00000039").is_dir());
        assert_eq!(sink.load_latest().unwrap().unwrap().state.committed_batch, 39);
    }

    #[test]
    fn disk_store_roundtrips_leader_extras() {
        let root = tmpdir("store-roundtrip");
        let mut sink = DiskSink::new(&root);
        let mut st = LeaderState::around(sample());
        st.link_bw = vec![(2, 1.5e6), (5, 2.5e6)];
        st.link_tiers = vec![(2, Tier::Full), (5, Tier::FullQ4)];
        st.replica_epoch = 3;
        st.worker_quota = 8;
        st.admitted = vec![1, 2];
        sink.save_leader(&st).unwrap();
        let back = sink.load_latest_leader().unwrap().expect("leader state");
        assert_eq!(back.checkpoint.state.committed_batch, 99);
        assert_eq!(back.link_bw, vec![(2, 1.5e6), (5, 2.5e6)]);
        assert_eq!(back.link_tiers, vec![(2, Tier::Full), (5, Tier::FullQ4)]);
        assert_eq!(back.replica_epoch, 3);
        assert_eq!((back.worker_quota, back.admitted.clone()), (8, vec![1, 2]));
    }

    #[test]
    fn disk_store_pre_sidecar_root_loads_with_defaults() {
        let root = tmpdir("store-compat");
        let mut sink = DiskSink::new(&root);
        sink.save(&sample()).unwrap(); // checkpoint-only, no leader.json
        let back = sink.load_latest_leader().unwrap().expect("degrades to defaults");
        assert_eq!(back.checkpoint.state.committed_batch, 99);
        assert!(back.link_tiers.is_empty());
        assert_eq!(back.replica_epoch, 0);
        assert!(back.link_bw.is_empty() && back.admitted.is_empty());
    }

    #[test]
    fn disk_store_translates_legacy_sidecar_keys() {
        let root = tmpdir("store-legacy");
        let mut sink = DiskSink::new(&root);
        sink.save(&sample()).unwrap();
        // a sidecar written before per-link tiers: dense per-link
        // bandwidths plus one fleet-wide tier. sample()'s worker list is
        // [0, 2], so link 0 feeds device 2 and link 1 names no device.
        std::fs::write(
            root.join("leader.json"),
            r#"{"committed_batch": 99, "measured_bw": [3e6, 9e9], "tier": 2,
                "replica_epoch": 5}"#,
        )
        .unwrap();
        let back = sink.load_latest_leader().unwrap().unwrap();
        assert_eq!(back.link_bw, vec![(2, 3e6)], "dense index 0 -> worker slot 1");
        assert_eq!(back.link_tiers, vec![(2, Tier::Full)], "scalar tier fans out per worker");
        assert_eq!(back.replica_epoch, 5);
    }

    #[test]
    fn disk_store_stale_sidecar_is_ignored() {
        let root = tmpdir("store-stale");
        let mut sink = DiskSink::new(&root);
        let mut st = LeaderState::around(sample());
        st.replica_epoch = 7;
        sink.save_leader(&st).unwrap();
        // a NEWER checkpoint saved through the plain sink leaves the
        // sidecar tagged for the old batch — its extras must not leak
        let mut ck = sample();
        ck.state.committed_batch = 150;
        sink.save(&ck).unwrap();
        let back = sink.load_latest_leader().unwrap().unwrap();
        assert_eq!(back.checkpoint.state.committed_batch, 150);
        assert_eq!(back.replica_epoch, 0, "stale sidecar extras must not apply");
    }

    #[test]
    fn memory_store_roundtrips_leader_extras() {
        let mut sink = MemorySink::default();
        assert!(sink.load_latest_leader().unwrap().is_none());
        let mut st = LeaderState::around(sample());
        st.replica_epoch = 2;
        st.admitted = vec![1];
        sink.save_leader(&st).unwrap();
        let back = sink.load_latest_leader().unwrap().unwrap();
        assert_eq!(back.replica_epoch, 2);
        assert_eq!(back.admitted, vec![1]);
        // plain saves still serve checkpoint-only loads with defaults
        let mut ck = sample();
        ck.state.committed_batch = 200;
        sink.save(&ck).unwrap();
        assert_eq!(sink.load_latest().unwrap().unwrap().state.committed_batch, 200);
    }

    #[test]
    fn memory_sink_returns_newest_clone() {
        let mut sink = MemorySink::default();
        assert!(sink.load_latest().unwrap().is_none());
        let mut ck = sample();
        ck.state.committed_batch = 4;
        sink.save(&ck).unwrap();
        ck.state.committed_batch = 9;
        sink.save(&ck).unwrap();
        assert_eq!(sink.len(), 2);
        assert_eq!(sink.latest().unwrap().state.committed_batch, 9);
        assert_eq!(sink.load_latest().unwrap().unwrap().state.committed_batch, 9);
    }
}
