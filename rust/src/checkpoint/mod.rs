//! Central-node checkpointing (paper §III-E): "the failure of the central
//! node can be dealt with by simply saving the training states and model
//! weights to the disk periodically, and recovering from them every time
//! it fails."
//!
//! A checkpoint is a directory:
//!
//! ```text
//! <dir>/state.json          committed batch, epoch, lr, partition, worker list
//! <dir>/block{i}_p{k}.npy   every parameter tensor (self-describing npy)
//! ```
//!
//! The npy format makes checkpoints directly loadable from Python
//! (`np.load`) — verified by `python/tests/test_interchange.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::model::BlockParams;
use crate::util::json::{self, Value};
use crate::util::npy;

/// Training state captured alongside the weights (paper Table I subset).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointState {
    pub committed_batch: i64,
    pub epoch: u64,
    pub lr: f32,
    pub ranges: Vec<(usize, usize)>,
    pub worker_list: Vec<usize>,
    /// shapes per (block, tensor) for reconstruction
    pub shapes: BTreeMap<usize, Vec<Vec<usize>>>,
}

/// A complete checkpoint: state + all parameters.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub state: CheckpointState,
    pub weights: BTreeMap<usize, BlockParams>,
}

impl Checkpoint {
    /// Persist atomically: write to `<dir>.tmp`, then rename.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        let tmp = PathBuf::from(format!("{}.tmp", dir.display()));
        if tmp.exists() {
            std::fs::remove_dir_all(&tmp)?;
        }
        std::fs::create_dir_all(&tmp)?;

        for (&b, bp) in &self.weights {
            let shapes = self
                .state
                .shapes
                .get(&b)
                .ok_or_else(|| anyhow!("no shapes for block {b}"))?;
            for (k, (tensor, shape)) in bp.0.iter().zip(shapes).enumerate() {
                npy::write_f32(tmp.join(format!("block{b}_p{k}.npy")), shape, tensor)?;
            }
        }
        std::fs::write(tmp.join("state.json"), self.state_json().to_pretty())?;

        if dir.exists() {
            std::fs::remove_dir_all(dir)?;
        }
        std::fs::rename(&tmp, dir).context("committing checkpoint rename")?;
        Ok(())
    }

    fn state_json(&self) -> Value {
        Value::obj(vec![
            ("committed_batch", Value::Num(self.state.committed_batch as f64)),
            ("epoch", Value::Num(self.state.epoch as f64)),
            ("lr", Value::Num(self.state.lr as f64)),
            (
                "ranges",
                Value::Arr(
                    self.state
                        .ranges
                        .iter()
                        .map(|&(a, b)| Value::arr_usize(&[a, b]))
                        .collect(),
                ),
            ),
            ("worker_list", Value::arr_usize(&self.state.worker_list)),
            (
                "shapes",
                Value::Obj(
                    self.state
                        .shapes
                        .iter()
                        .map(|(b, tensors)| {
                            (
                                b.to_string(),
                                Value::Arr(
                                    tensors.iter().map(|s| Value::arr_usize(s)).collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Load a checkpoint directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Checkpoint> {
        let dir = dir.as_ref();
        let raw = std::fs::read_to_string(dir.join("state.json"))
            .with_context(|| format!("reading {}/state.json", dir.display()))?;
        let v = json::parse(&raw).map_err(|e| anyhow!("{e}"))?;
        let usize_pair = |x: &Value| -> Result<(usize, usize)> {
            let a = x.as_arr().ok_or_else(|| anyhow!("range not array"))?;
            Ok((
                a[0].as_usize().ok_or_else(|| anyhow!("bad range"))?,
                a[1].as_usize().ok_or_else(|| anyhow!("bad range"))?,
            ))
        };
        let mut shapes: BTreeMap<usize, Vec<Vec<usize>>> = BTreeMap::new();
        for (k, tensors) in v.req("shapes").map_err(|e| anyhow!("{e}"))?.as_obj().unwrap_or(&[]) {
            let b: usize = k.parse().context("block key")?;
            let mut ts = Vec::new();
            for s in tensors.as_arr().unwrap_or(&[]) {
                ts.push(
                    s.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                );
            }
            shapes.insert(b, ts);
        }
        let state = CheckpointState {
            committed_batch: v.get("committed_batch").and_then(|x| x.as_i64()).unwrap_or(-1),
            epoch: v.get("epoch").and_then(|x| x.as_i64()).unwrap_or(0) as u64,
            lr: v.get("lr").and_then(|x| x.as_f64()).unwrap_or(0.01) as f32,
            ranges: v
                .req("ranges")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(usize_pair)
                .collect::<Result<_>>()?,
            worker_list: v
                .req("worker_list")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            shapes: shapes.clone(),
        };

        let mut weights = BTreeMap::new();
        for (&b, tensors) in &shapes {
            let mut bp = Vec::with_capacity(tensors.len());
            for k in 0..tensors.len() {
                let (shape, data) = npy::read_f32(dir.join(format!("block{b}_p{k}.npy")))?;
                if shape != tensors[k] {
                    return Err(anyhow!(
                        "block {b} tensor {k}: shape {:?} != state.json {:?}",
                        shape,
                        tensors[k]
                    ));
                }
                bp.push(data.into());
            }
            weights.insert(b, BlockParams(bp));
        }
        Ok(Checkpoint { state, weights })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut shapes = BTreeMap::new();
        shapes.insert(0usize, vec![vec![2, 3], vec![3]]);
        shapes.insert(2usize, vec![vec![4]]);
        let mut weights = BTreeMap::new();
        weights.insert(0, BlockParams::from_vecs(vec![vec![1.0; 6], vec![0.5; 3]]));
        weights.insert(2, BlockParams::from_vecs(vec![vec![-2.0; 4]]));
        Checkpoint {
            state: CheckpointState {
                committed_batch: 99,
                epoch: 3,
                lr: 0.01,
                ranges: vec![(0, 1), (2, 5)],
                worker_list: vec![0, 2],
                shapes,
            },
            weights,
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("ftpipehd-ckpt-tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmpdir("roundtrip");
        let ck = sample();
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.state, ck.state);
        assert_eq!(back.weights.len(), 2);
        assert_eq!(back.weights[&0], ck.weights[&0]);
        assert_eq!(back.weights[&2], ck.weights[&2]);
    }

    #[test]
    fn save_is_atomic_overwrite() {
        let dir = tmpdir("atomic");
        let mut ck = sample();
        ck.save(&dir).unwrap();
        ck.state.committed_batch = 150;
        ck.save(&dir).unwrap(); // overwrite
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.state.committed_batch, 150);
        assert!(!PathBuf::from(format!("{}.tmp", dir.display())).exists());
    }

    #[test]
    fn load_missing_fails_cleanly() {
        assert!(Checkpoint::load(tmpdir("missing")).is_err());
    }

    #[test]
    fn shape_mismatch_detected() {
        let dir = tmpdir("mismatch");
        let ck = sample();
        ck.save(&dir).unwrap();
        // corrupt one tensor file with the wrong shape
        crate::util::npy::write_f32(dir.join("block2_p0.npy"), &[5], &[0.0; 5]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
    }
}
