//! Dynamic model partitioning — the paper's eqs (4)–(7).
//!
//! The partitioner is PipeDream's dynamic program extended with per-device
//! *computing capacities* `C_k` (eq 1): the execution time of block `j` on
//! device `k` is estimated as `T^0_j * C_k` (eq 3), where `T^0_j` is the
//! centrally-profiled time. Stages are assigned to devices in worker-list
//! order; the pipeline's cost is its slowest component — a stage's compute
//! or twice a boundary's communication time `T_c = D_l / B` (eq 6, doubled
//! for the forward activation + backward gradient crossing the same link).
//!
//! [`optimal_partition`] solves eq (5) exactly in O(L² · N); the
//! brute-force oracle and a property test in `rust/tests/` confirm
//! optimality on small instances.

/// Inclusive block ranges per stage, in worker-list order.
pub type Partition = Vec<(usize, usize)>;

/// Everything the DP needs (paper eqs 1–7).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Profiled fwd+bwd time per block on the central node, in ms (T^0_j).
    pub t0_ms: Vec<f64>,
    /// Output activation bytes per block (D_j).
    pub out_bytes: Vec<u64>,
    /// Capacity per device in worker-list order (C_k; C_0 = 1.0).
    pub capacities: Vec<f64>,
    /// Measured bandwidth (bytes/s) between consecutive devices (B_{k,k+1}).
    pub bandwidth_bps: Vec<f64>,
}

impl CostModel {
    pub fn n_blocks(&self) -> usize {
        self.t0_ms.len()
    }

    pub fn n_devices(&self) -> usize {
        self.capacities.len()
    }

    /// T^k(lo, hi): time of training blocks [lo, hi] on device k (eq 7 + eq 3).
    pub fn stage_time(&self, k: usize, lo: usize, hi: usize) -> f64 {
        self.t0_ms[lo..=hi].iter().sum::<f64>() * self.capacities[k]
    }

    /// T_c over link k -> k+1 for the output of block `l` (eq 6), in ms.
    pub fn comm_time(&self, link: usize, l: usize) -> f64 {
        self.out_bytes[l] as f64 / self.bandwidth_bps[link] * 1e3
    }

    /// The pipeline bottleneck cost of a full partition (the DP objective).
    pub fn cost(&self, partition: &Partition) -> f64 {
        let mut worst: f64 = 0.0;
        for (k, &(lo, hi)) in partition.iter().enumerate() {
            worst = worst.max(self.stage_time(k, lo, hi));
            if k + 1 < partition.len() {
                worst = worst.max(2.0 * self.comm_time(k, hi));
            }
        }
        worst
    }
}

/// Solve eq (4)/(5): minimal bottleneck partition of all blocks over all
/// devices (each stage non-empty). Returns (partition, cost).
pub fn optimal_partition(cm: &CostModel) -> (Partition, f64) {
    let lcount = cm.n_blocks();
    let n = cm.n_devices();
    assert!(lcount >= n, "need at least one block per device ({lcount} < {n})");
    assert_eq!(cm.out_bytes.len(), lcount);
    assert_eq!(cm.bandwidth_bps.len(), n.saturating_sub(1));

    // a[j][s] = best bottleneck for blocks 0..=j on stages 0..=s
    // (paper's A(j, n) with n = s+1 devices).
    const INF: f64 = f64::INFINITY;
    let mut a = vec![vec![INF; n]; lcount];
    let mut choice = vec![vec![usize::MAX; n]; lcount];

    // base case (eq 4): one device = device 0
    for j in 0..lcount {
        a[j][0] = cm.stage_time(0, 0, j);
    }

    for s in 1..n {
        // stage s runs on device s; link (s-1) -> s carries the boundary
        for j in s..lcount {
            // split point l: sub-pipeline covers 0..=l, stage s covers l+1..=j
            for l in (s - 1)..j {
                let cand = a[l][s - 1]
                    .max(2.0 * cm.comm_time(s - 1, l))
                    .max(cm.stage_time(s, l + 1, j));
                if cand < a[j][s] {
                    a[j][s] = cand;
                    choice[j][s] = l;
                }
            }
        }
    }

    // reconstruct
    let mut parts = vec![(0usize, 0usize); n];
    let mut j = lcount - 1;
    for s in (1..n).rev() {
        let l = choice[j][s];
        parts[s] = (l + 1, j);
        j = l;
    }
    parts[0] = (0, j);
    (parts, a[lcount - 1][n - 1])
}

/// PipeDream-style initial partition: same DP but capacity-blind (all
/// devices assumed equal — paper §III-B "average partitioning", and the
/// §IV-D baseline's static partition).
pub fn homogeneous_partition(cm: &CostModel) -> (Partition, f64) {
    let blind = CostModel {
        t0_ms: cm.t0_ms.clone(),
        out_bytes: cm.out_bytes.clone(),
        capacities: vec![1.0; cm.n_devices()],
        bandwidth_bps: cm.bandwidth_bps.clone(),
    };
    let (p, _) = optimal_partition(&blind);
    // report the TRUE cost of the blind partition under the real capacities
    let cost = cm.cost(&p);
    (p, cost)
}

/// Equal-block-count split (test helper / worst-practice baseline).
pub fn uniform_partition(n_blocks: usize, n_stages: usize) -> Partition {
    assert!(n_blocks >= n_stages && n_stages > 0);
    let base = n_blocks / n_stages;
    let extra = n_blocks % n_stages;
    let mut parts = Vec::with_capacity(n_stages);
    let mut lo = 0;
    for s in 0..n_stages {
        let len = base + usize::from(s < extra);
        parts.push((lo, lo + len - 1));
        lo += len;
    }
    parts
}

/// Exhaustive search over all cut placements (test oracle; exponential).
pub fn bruteforce_partition(cm: &CostModel) -> (Partition, f64) {
    let lcount = cm.n_blocks();
    let n = cm.n_devices();
    assert!(lcount >= n);
    let mut best: Option<(Partition, f64)> = None;
    // choose n-1 cut positions out of lcount-1 (cut after block c)
    let mut cuts = vec![0usize; n - 1];
    fn rec(
        cm: &CostModel,
        cuts: &mut Vec<usize>,
        idx: usize,
        min_next: usize,
        best: &mut Option<(Partition, f64)>,
    ) {
        let lcount = cm.n_blocks();
        let n = cm.n_devices();
        if idx == cuts.len() {
            let mut parts = Vec::with_capacity(n);
            let mut lo = 0;
            for &c in cuts.iter() {
                parts.push((lo, c));
                lo = c + 1;
            }
            parts.push((lo, lcount - 1));
            let cost = cm.cost(&parts);
            if best.as_ref().map(|(_, b)| cost < *b).unwrap_or(true) {
                *best = Some((parts, cost));
            }
            return;
        }
        // cut after block c; leave room for the remaining stages
        let remaining = cuts.len() - idx - 1;
        for c in min_next..(lcount - 1 - remaining) {
            cuts[idx] = c;
            rec(cm, cuts, idx + 1, c + 1, best);
        }
    }
    if n == 1 {
        let p = vec![(0, lcount - 1)];
        let cost = cm.cost(&p);
        return (p, cost);
    }
    rec(cm, &mut cuts, 0, 0, &mut best);
    best.unwrap()
}

// ---------------------------------------------------------------------
// replica axis (DESIGN.md §14): devices × replicas
// ---------------------------------------------------------------------

/// Output of the replica-aware solve: the fleet split into R pipeline
/// chains (contiguous, in device order, chain 0 holding device 0) plus
/// the deterministic round-robin data-shard assignment over batch
/// indices (`shard_assignment[c]` = the global batch ids chain `c`
/// trains — disjoint and complete over `0..batches`).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaPlan {
    /// Device indices per chain, contiguous in fleet order.
    pub chains: Vec<Vec<usize>>,
    /// Global batch ids per chain (`b` goes to chain `b % R`).
    pub shard_assignment: Vec<Vec<u64>>,
}

/// Bottleneck cost of one chain: devices in a pipeline contribute
/// throughput `1/C_k` each (capacities are slowdown factors, eq 3), so
/// the chain's aggregate cost is the harmonic combination — more or
/// faster devices always lower it, which is what the balancing DP needs.
pub fn chain_cost(capacities: &[f64]) -> f64 {
    let thru: f64 = capacities.iter().map(|&c| 1.0 / c).sum();
    1.0 / thru
}

/// Split `capacities` (fleet order) into `replicas` contiguous non-empty
/// chains minimizing the worst per-chain [`chain_cost`] — the replica
/// analogue of eq (5): `f[i][k] = min_j max(f[j][k-1], cost(j..i))`.
/// Contiguity keeps device 0 at the head of chain 0 (the coordinator
/// chain) and makes the split independent of map iteration order.
pub fn split_chains(capacities: &[f64], replicas: usize) -> Vec<Vec<usize>> {
    let n = capacities.len();
    assert!(replicas >= 1 && n >= replicas, "{n} devices < {replicas} replicas");
    if replicas == 1 {
        return vec![(0..n).collect()];
    }
    const INF: f64 = f64::INFINITY;
    // f[i][k]: best worst-chain cost for devices 0..i over k+1 chains
    let mut f = vec![vec![INF; replicas]; n + 1];
    let mut cut = vec![vec![usize::MAX; replicas]; n + 1];
    for i in 1..=n {
        f[i][0] = chain_cost(&capacities[0..i]);
    }
    for k in 1..replicas {
        for i in (k + 1)..=n {
            for j in k..i {
                let cand = f[j][k - 1].max(chain_cost(&capacities[j..i]));
                if cand < f[i][k] {
                    f[i][k] = cand;
                    cut[i][k] = j;
                }
            }
        }
    }
    let mut bounds = vec![n];
    let mut i = n;
    for k in (1..replicas).rev() {
        i = cut[i][k];
        bounds.push(i);
    }
    bounds.push(0);
    bounds.reverse();
    bounds.windows(2).map(|w| (w[0]..w[1]).collect()).collect()
}

/// The replica-aware solve: balanced contiguous chains by capacity plus
/// the deterministic round-robin shard assignment (`b -> b % R`).
/// `replicas == 1` reproduces today's single-chain world exactly: one
/// chain of every device, one shard of every batch.
pub fn replica_plan(capacities: &[f64], replicas: usize, batches: u64) -> ReplicaPlan {
    let chains = split_chains(capacities, replicas);
    let mut shard_assignment = vec![Vec::new(); replicas];
    for b in 0..batches {
        shard_assignment[(b % replicas as u64) as usize].push(b);
    }
    ReplicaPlan { chains, shard_assignment }
}

/// Exhaustive chain-split oracle (test-only; exponential): enumerate
/// every composition of the fleet into `replicas` contiguous non-empty
/// groups and return the minimal worst [`chain_cost`].
pub fn bruteforce_replica_chains(capacities: &[f64], replicas: usize) -> (Vec<Vec<usize>>, f64) {
    let n = capacities.len();
    assert!(replicas >= 1 && n >= replicas);
    let mut best: Option<(Vec<Vec<usize>>, f64)> = None;
    // choose replicas-1 cut positions (cut after device c)
    let mut cuts = vec![0usize; replicas - 1];
    fn rec(
        caps: &[f64],
        cuts: &mut Vec<usize>,
        idx: usize,
        min_next: usize,
        best: &mut Option<(Vec<Vec<usize>>, f64)>,
    ) {
        let n = caps.len();
        if idx == cuts.len() {
            let mut chains = Vec::with_capacity(cuts.len() + 1);
            let mut lo = 0;
            for &c in cuts.iter() {
                chains.push((lo..=c).collect::<Vec<_>>());
                lo = c + 1;
            }
            chains.push((lo..n).collect());
            let cost = chains
                .iter()
                .map(|ch| chain_cost(&caps[ch[0]..=ch[ch.len() - 1]]))
                .fold(0.0f64, f64::max);
            if best.as_ref().map(|(_, b)| cost < *b).unwrap_or(true) {
                *best = Some((chains, cost));
            }
            return;
        }
        let remaining = cuts.len() - idx - 1;
        for c in min_next..(n - 1 - remaining) {
            cuts[idx] = c;
            rec(caps, cuts, idx + 1, c + 1, best);
        }
    }
    if replicas == 1 {
        return (vec![(0..n).collect()], chain_cost(capacities));
    }
    rec(capacities, &mut cuts, 0, 0, &mut best);
    best.unwrap()
}

/// Validate a replica plan: every device in exactly one chain (fleet
/// order, device 0 heading chain 0) and the shards a partition of
/// `0..batches` (disjoint + complete).
pub fn validate_replica_plan(
    plan: &ReplicaPlan,
    n_devices: usize,
    batches: u64,
) -> Result<(), String> {
    let flat: Vec<usize> = plan.chains.iter().flatten().copied().collect();
    if flat != (0..n_devices).collect::<Vec<_>>() {
        return Err(format!("chains {:?} are not a fleet-order partition", plan.chains));
    }
    if plan.chains.iter().any(|c| c.is_empty()) {
        return Err("empty chain".into());
    }
    if plan.chains.len() != plan.shard_assignment.len() {
        return Err("chain/shard count mismatch".into());
    }
    let mut all: Vec<u64> = plan.shard_assignment.iter().flatten().copied().collect();
    all.sort_unstable();
    if all != (0..batches).collect::<Vec<_>>() {
        return Err("shards are not a disjoint+complete cover of the batch ids".into());
    }
    Ok(())
}

/// Validate a partition covers blocks `0..n_blocks` contiguously.
pub fn validate_partition(p: &Partition, n_blocks: usize) -> Result<(), String> {
    if p.is_empty() {
        return Err("empty partition".into());
    }
    if p[0].0 != 0 {
        return Err(format!("first stage starts at {}", p[0].0));
    }
    for w in p.windows(2) {
        if w[0].1 + 1 != w[1].0 {
            return Err(format!("gap between {:?} and {:?}", w[0], w[1]));
        }
    }
    for &(lo, hi) in p {
        if lo > hi {
            return Err(format!("empty stage ({lo}, {hi})"));
        }
    }
    if p.last().unwrap().1 != n_blocks - 1 {
        return Err(format!("last stage ends at {}", p.last().unwrap().1));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm(t0: Vec<f64>, caps: Vec<f64>, bw_mbps: f64) -> CostModel {
        let n = t0.len();
        CostModel {
            out_bytes: vec![100_000; n],
            t0_ms: t0,
            bandwidth_bps: vec![bw_mbps * 1e6; caps.len() - 1],
            capacities: caps,
        }
    }

    #[test]
    fn homogeneous_splits_evenly() {
        let m = cm(vec![10.0; 9], vec![1.0, 1.0, 1.0], 1000.0);
        let (p, cost) = optimal_partition(&m);
        assert_eq!(p, vec![(0, 2), (3, 5), (6, 8)]);
        assert!((cost - 30.0).abs() < 1e-9);
    }

    #[test]
    fn slow_device_gets_fewer_blocks() {
        // device 2 is 10x slower: it should receive far fewer blocks
        let m = cm(vec![10.0; 10], vec![1.0, 1.0, 10.0], 1000.0);
        let (p, _) = optimal_partition(&m);
        validate_partition(&p, 10).unwrap();
        let slow_blocks = p[2].1 - p[2].0 + 1;
        assert_eq!(slow_blocks, 1, "partition {p:?}");
        // and the capacity-blind partition is much worse
        let (_, blind_cost) = homogeneous_partition(&m);
        let (_, opt_cost) = optimal_partition(&m);
        assert!(blind_cost > 2.0 * opt_cost, "blind {blind_cost} opt {opt_cost}");
    }

    #[test]
    fn comm_bound_forces_cut_at_small_activation() {
        // block 1 has a tiny output; with a slow link the DP should cut there
        let mut m = cm(vec![10.0, 10.0, 10.0, 10.0], vec![1.0, 1.0], 1000.0);
        m.out_bytes = vec![4_000_000, 100, 4_000_000, 4_000_000];
        m.bandwidth_bps = vec![1e6]; // 1 MB/s: 4MB transfer = 4000ms each way
        let (p, _) = optimal_partition(&m);
        assert_eq!(p[0].1, 1, "should cut after block 1: {p:?}");
    }

    #[test]
    fn dp_matches_bruteforce_on_examples() {
        for (t0, caps) in [
            (vec![5.0, 20.0, 3.0, 8.0, 14.0, 2.0], vec![1.0, 2.0]),
            (vec![5.0, 20.0, 3.0, 8.0, 14.0, 2.0, 9.0], vec![1.0, 0.5, 3.0]),
            (vec![1.0, 1.0, 50.0, 1.0, 1.0], vec![1.0, 1.0, 1.0]),
        ] {
            let m = cm(t0, caps, 10.0);
            let (pd, cd) = optimal_partition(&m);
            let (pb, cb) = bruteforce_partition(&m);
            assert!((cd - cb).abs() < 1e-9, "dp={cd} bf={cb} ({pd:?} vs {pb:?})");
        }
    }

    #[test]
    fn uniform_partition_shapes() {
        assert_eq!(uniform_partition(10, 3), vec![(0, 3), (4, 6), (7, 9)]);
        assert_eq!(uniform_partition(3, 3), vec![(0, 0), (1, 1), (2, 2)]);
        assert_eq!(uniform_partition(5, 1), vec![(0, 4)]);
    }

    #[test]
    fn validate_catches_bad_partitions() {
        assert!(validate_partition(&vec![(0, 2), (3, 4)], 5).is_ok());
        assert!(validate_partition(&vec![(1, 2), (3, 4)], 5).is_err());
        assert!(validate_partition(&vec![(0, 2), (4, 4)], 5).is_err());
        assert!(validate_partition(&vec![(0, 2), (3, 3)], 5).is_err());
    }

    #[test]
    fn replica_plan_r1_is_the_single_chain_world() {
        let plan = replica_plan(&[1.0, 2.0, 0.5], 1, 7);
        assert_eq!(plan.chains, vec![vec![0, 1, 2]]);
        assert_eq!(plan.shard_assignment, vec![(0..7).collect::<Vec<u64>>()]);
        validate_replica_plan(&plan, 3, 7).unwrap();
    }

    #[test]
    fn replica_shards_round_robin() {
        let plan = replica_plan(&[1.0, 1.0, 1.0, 1.0], 2, 5);
        assert_eq!(plan.shard_assignment[0], vec![0, 2, 4]);
        assert_eq!(plan.shard_assignment[1], vec![1, 3]);
        validate_replica_plan(&plan, 4, 5).unwrap();
    }

    #[test]
    fn split_chains_balances_by_capacity() {
        // one fast device (0.5 = 2x speed) vs three slow: the fast device
        // can hold a chain alone while the three slow ones pool
        let chains = split_chains(&[0.5, 2.0, 2.0, 2.0], 2);
        assert_eq!(chains, vec![vec![0], vec![1, 2, 3]]);
        // homogeneous fleet splits evenly
        let chains = split_chains(&[1.0; 6], 3);
        assert_eq!(chains, vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
    }

    #[test]
    fn split_chains_matches_bruteforce_on_examples() {
        for (caps, r) in [
            (vec![1.0, 2.0, 0.5, 3.0, 1.0], 2),
            (vec![1.0, 1.0, 4.0, 0.25, 2.0, 1.0], 3),
            (vec![0.5, 0.5, 0.5, 8.0], 2),
        ] {
            let chains = split_chains(&caps, r);
            let cost = chains
                .iter()
                .map(|ch| chain_cost(&caps[ch[0]..=ch[ch.len() - 1]]))
                .fold(0.0f64, f64::max);
            let (_, bf) = bruteforce_replica_chains(&caps, r);
            assert!((cost - bf).abs() < 1e-12, "dp {cost} vs bf {bf} for {caps:?} R={r}");
        }
    }

    #[test]
    fn chain_cost_is_harmonic() {
        assert!((chain_cost(&[1.0]) - 1.0).abs() < 1e-12);
        // two unit-capacity devices pipeline to half the per-batch cost
        assert!((chain_cost(&[1.0, 1.0]) - 0.5).abs() < 1e-12);
        assert!((chain_cost(&[2.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn validate_replica_plan_catches_bad_plans() {
        let good = replica_plan(&[1.0, 1.0], 2, 4);
        validate_replica_plan(&good, 2, 4).unwrap();
        let mut bad = good.clone();
        bad.shard_assignment[0].push(1); // duplicate batch id
        assert!(validate_replica_plan(&bad, 2, 4).is_err());
        let mut bad = good.clone();
        bad.chains[1] = vec![3]; // not a fleet-order partition
        assert!(validate_replica_plan(&bad, 2, 4).is_err());
        assert!(validate_replica_plan(&good, 2, 5).is_err()); // incomplete shards
    }

    #[test]
    fn single_device_takes_everything() {
        let m = CostModel {
            t0_ms: vec![1.0, 2.0, 3.0],
            out_bytes: vec![10, 10, 10],
            capacities: vec![1.0],
            bandwidth_bps: vec![],
        };
        let (p, cost) = optimal_partition(&m);
        assert_eq!(p, vec![(0, 2)]);
        assert!((cost - 6.0).abs() < 1e-12);
    }
}
