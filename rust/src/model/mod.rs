//! Model state owned by the Rust side: parameter buffers, the SGD
//! optimizer (paper §IV-B: momentum 0.9, weight decay 4e-5), the
//! weight-stashing store for 1F1B, and weight aggregation (paper §III-C).

pub mod aggregate;
pub mod params;
pub mod sgd;
pub mod stash;

pub use aggregate::aggregate_versions;
pub use params::{BlockParams, StageParams};
pub use sgd::{Sgd, SgdConfig};
pub use stash::VersionStash;
