//! SGD with momentum + weight decay — the paper's optimizer (§IV-B).
//!
//! Runs in Rust over the flat `Vec<f32>` buffers (keeping all weight
//! movement — stashing, aggregation, replication — on plain host memory).
//! Update rule (PyTorch convention, which the paper's implementation used):
//!
//! ```text
//! g  <- grad + weight_decay * w
//! v  <- momentum * v + g
//! w  <- w - lr * v
//! ```

use std::collections::BTreeMap;

use super::params::{BlockParams, StageParams};

#[derive(Debug, Clone, Copy)]
pub struct SgdConfig {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { lr: 0.01, momentum: 0.9, weight_decay: 4e-5 }
    }
}

/// Per-stage optimizer state (velocity buffers per owned block).
#[derive(Debug, Clone, Default)]
pub struct Sgd {
    pub cfg: SgdConfig,
    velocity: BTreeMap<usize, BlockParams>,
}

impl Sgd {
    pub fn new(cfg: SgdConfig) -> Sgd {
        Sgd { cfg, velocity: BTreeMap::new() }
    }

    /// Apply one update to block `idx` of `params` given `grads`.
    ///
    /// Weight buffers are copy-on-write: the update runs in place when no
    /// stash snapshot / replica shares the tensor, and forks it exactly
    /// once when one does (the snapshot keeps the pre-update bytes).
    /// Velocity buffers are never shared, so they always mutate in place.
    pub fn step_block(&mut self, idx: usize, params: &mut BlockParams, grads: &[Vec<f32>]) {
        debug_assert_eq!(params.0.len(), grads.len());
        let v = self
            .velocity
            .entry(idx)
            .or_insert_with(|| params.zeros_like());
        let (lr, mu, wd) = (self.cfg.lr, self.cfg.momentum, self.cfg.weight_decay);
        for ((w, g), vel) in params.0.iter_mut().zip(grads).zip(v.0.iter_mut()) {
            for ((wi, gi), vi) in w.make_mut().iter_mut().zip(g).zip(vel.make_mut().iter_mut()) {
                let grad = gi + wd * *wi;
                *vi = mu * *vi + grad;
                *wi -= lr * *vi;
            }
        }
    }

    /// Apply updates to every owned block present in `grads`.
    pub fn step(&mut self, params: &mut StageParams, grads: &BTreeMap<usize, Vec<Vec<f32>>>) {
        for (idx, g) in grads {
            if let Some(p) = params.blocks.get_mut(idx) {
                self.step_block(*idx, p, g);
            }
        }
    }

    /// Drop velocity for blocks no longer owned (after re-partition) and
    /// keep it for retained ones — momentum survives repartition only for
    /// blocks that stayed local, matching a weights-only transfer.
    pub fn retain_blocks(&mut self, keep: &[usize]) {
        let keep: std::collections::BTreeSet<usize> = keep.iter().copied().collect();
        self.velocity.retain(|k, _| keep.contains(k));
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_loss_grad(w: &[f32]) -> Vec<f32> {
        // loss = 0.5 * ||w||^2  ->  grad = w
        w.to_vec()
    }

    #[test]
    fn plain_sgd_descends_quadratic() {
        let mut sgd = Sgd::new(SgdConfig { lr: 0.1, momentum: 0.0, weight_decay: 0.0 });
        let mut p = BlockParams::from_vecs(vec![vec![1.0, -2.0, 3.0]]);
        for _ in 0..100 {
            let g = vec![quad_loss_grad(&p.0[0])];
            sgd.step_block(0, &mut p, &g);
        }
        assert!(p.l2_norm() < 1e-3, "norm={}", p.l2_norm());
    }

    #[test]
    fn momentum_matches_manual_two_steps() {
        let mut sgd = Sgd::new(SgdConfig { lr: 0.1, momentum: 0.9, weight_decay: 0.0 });
        let mut p = BlockParams::from_vecs(vec![vec![1.0]]);
        sgd.step_block(0, &mut p, &[vec![1.0]]); // v=1, w=1-0.1=0.9
        assert!((p.0[0][0] - 0.9).abs() < 1e-6);
        sgd.step_block(0, &mut p, &[vec![1.0]]); // v=1.9, w=0.9-0.19=0.71
        assert!((p.0[0][0] - 0.71).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut sgd = Sgd::new(SgdConfig { lr: 0.1, momentum: 0.0, weight_decay: 0.5 });
        let mut p = BlockParams::from_vecs(vec![vec![2.0]]);
        sgd.step_block(0, &mut p, &[vec![0.0]]); // g = 0 + 0.5*2 = 1; w = 2 - 0.1
        assert!((p.0[0][0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn retain_blocks_drops_velocity() {
        let mut sgd = Sgd::new(SgdConfig::default());
        let mut p = BlockParams::from_vecs(vec![vec![1.0]]);
        sgd.step_block(3, &mut p, &[vec![1.0]]);
        sgd.step_block(4, &mut p, &[vec![1.0]]);
        sgd.retain_blocks(&[4]);
        assert!(sgd.velocity.contains_key(&4));
        assert!(!sgd.velocity.contains_key(&3));
    }
}
