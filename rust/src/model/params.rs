//! Parameter buffers. Parameters live as shared [`TensorBuf`]s per tensor
//! — the exact representation that is fed to XLA, stashed per weight
//! version, replicated over the network, and redistributed on failure.
//! Because the buffers are reference-counted, stashing a weight version,
//! building a replica push, and serving a weight fetch are all refcount
//! bumps; the optimizer mutates through copy-on-write so outstanding
//! snapshots keep their bytes.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::manifest::Manifest;
use crate::net::TensorBuf;

/// All tensors of one block, in manifest order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BlockParams(pub Vec<TensorBuf>);

impl BlockParams {
    /// Build from owned host vectors (initial weights, checkpoints, ...).
    pub fn from_vecs(tensors: Vec<Vec<f32>>) -> BlockParams {
        BlockParams(tensors.into_iter().map(TensorBuf::new).collect())
    }

    pub fn num_elements(&self) -> usize {
        self.0.iter().map(|t| t.len()).sum()
    }

    pub fn byte_len(&self) -> usize {
        self.num_elements() * 4
    }

    /// Elementwise in-place axpy over all tensors: self += alpha * other.
    /// Copy-on-write: forks any tensor still shared with a snapshot.
    pub fn axpy(&mut self, alpha: f32, other: &BlockParams) {
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            for (x, y) in a.make_mut().iter_mut().zip(b.iter()) {
                *x += alpha * y;
            }
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for t in &mut self.0 {
            for x in t.make_mut().iter_mut() {
                *x *= alpha;
            }
        }
    }

    pub fn zeros_like(&self) -> BlockParams {
        BlockParams(self.0.iter().map(|t| TensorBuf::zeros(t.len())).collect())
    }

    pub fn l2_norm(&self) -> f64 {
        self.0
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

impl From<Vec<Vec<f32>>> for BlockParams {
    fn from(tensors: Vec<Vec<f32>>) -> BlockParams {
        BlockParams::from_vecs(tensors)
    }
}

/// The parameters a device currently owns: a map block-index -> tensors.
/// Kept as a BTreeMap so iteration order is deterministic and stage
/// reassignment (dynamic re-partition / recovery) is a cheap map edit.
/// Cloning a `StageParams` (weight stashing does this once per version)
/// clones the map structure but shares every tensor buffer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageParams {
    pub blocks: BTreeMap<usize, BlockParams>,
}

impl StageParams {
    /// Load the initial weights for blocks [lo, hi] from the manifest.
    pub fn load_range(manifest: &Manifest, lo: usize, hi: usize) -> Result<StageParams> {
        if hi >= manifest.n_blocks() || lo > hi {
            bail!("bad block range [{lo}, {hi}]");
        }
        let mut blocks = BTreeMap::new();
        for i in lo..=hi {
            blocks.insert(i, BlockParams::from_vecs(manifest.load_init_params(i)?));
        }
        Ok(StageParams { blocks })
    }

    pub fn get(&self, block: usize) -> Option<&BlockParams> {
        self.blocks.get(&block)
    }

    pub fn byte_len(&self) -> usize {
        self.blocks.values().map(|b| b.byte_len()).sum()
    }

    pub fn block_indices(&self) -> Vec<usize> {
        self.blocks.keys().copied().collect()
    }

    /// Keep only blocks in [lo, hi]; returns the evicted blocks.
    pub fn retain_range(&mut self, lo: usize, hi: usize) -> BTreeMap<usize, BlockParams> {
        let mut evicted = BTreeMap::new();
        let keys: Vec<usize> = self.blocks.keys().copied().collect();
        for k in keys {
            if k < lo || k > hi {
                if let Some(v) = self.blocks.remove(&k) {
                    evicted.insert(k, v);
                }
            }
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bp(vals: &[&[f32]]) -> BlockParams {
        BlockParams::from_vecs(vals.iter().map(|v| v.to_vec()).collect())
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = bp(&[&[1.0, 2.0], &[3.0]]);
        let b = bp(&[&[10.0, 20.0], &[30.0]]);
        a.axpy(0.5, &b);
        assert_eq!(a, bp(&[&[6.0, 12.0], &[18.0]]));
        a.scale(2.0);
        assert_eq!(a, bp(&[&[12.0, 24.0], &[36.0]]));
    }

    #[test]
    fn l2_norm() {
        let a = bp(&[&[3.0], &[4.0]]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn retain_range_evicts() {
        let mut sp = StageParams::default();
        for i in 0..5 {
            sp.blocks.insert(i, bp(&[&[i as f32]]));
        }
        let evicted = sp.retain_range(1, 3);
        assert_eq!(sp.block_indices(), vec![1, 2, 3]);
        assert_eq!(evicted.keys().copied().collect::<Vec<_>>(), vec![0, 4]);
    }

    #[test]
    fn stage_clone_shares_buffers_and_mutation_forks() {
        let mut sp = StageParams::default();
        sp.blocks.insert(0, bp(&[&[1.0, 2.0]]));
        let snap = sp.clone();
        assert!(
            sp.blocks[&0].0[0].ptr_eq(&snap.blocks[&0].0[0]),
            "clone must share tensor allocations"
        );
        sp.blocks.get_mut(&0).unwrap().scale(2.0);
        assert_eq!(snap.blocks[&0].0[0][0], 1.0, "snapshot unchanged after COW");
        assert_eq!(sp.blocks[&0].0[0][0], 2.0);
    }
}
