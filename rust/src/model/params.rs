//! Parameter buffers. Parameters live as plain `Vec<f32>` per tensor —
//! the exact representation that is fed to XLA, stashed per weight
//! version, replicated over the network, and redistributed on failure.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::manifest::Manifest;

/// All tensors of one block, in manifest order.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockParams(pub Vec<Vec<f32>>);

impl BlockParams {
    pub fn num_elements(&self) -> usize {
        self.0.iter().map(|t| t.len()).sum()
    }

    pub fn byte_len(&self) -> usize {
        self.num_elements() * 4
    }

    /// Elementwise in-place axpy over all tensors: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &BlockParams) {
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += alpha * y;
            }
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for t in &mut self.0 {
            for x in t.iter_mut() {
                *x *= alpha;
            }
        }
    }

    pub fn zeros_like(&self) -> BlockParams {
        BlockParams(self.0.iter().map(|t| vec![0.0; t.len()]).collect())
    }

    pub fn l2_norm(&self) -> f64 {
        self.0
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// The parameters a device currently owns: a map block-index -> tensors.
/// Kept as a BTreeMap so iteration order is deterministic and stage
/// reassignment (dynamic re-partition / recovery) is a cheap map edit.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageParams {
    pub blocks: BTreeMap<usize, BlockParams>,
}

impl StageParams {
    /// Load the initial weights for blocks [lo, hi] from the manifest.
    pub fn load_range(manifest: &Manifest, lo: usize, hi: usize) -> Result<StageParams> {
        if hi >= manifest.n_blocks() || lo > hi {
            bail!("bad block range [{lo}, {hi}]");
        }
        let mut blocks = BTreeMap::new();
        for i in lo..=hi {
            blocks.insert(i, BlockParams(manifest.load_init_params(i)?));
        }
        Ok(StageParams { blocks })
    }

    pub fn get(&self, block: usize) -> Option<&BlockParams> {
        self.blocks.get(&block)
    }

    pub fn byte_len(&self) -> usize {
        self.blocks.values().map(|b| b.byte_len()).sum()
    }

    pub fn block_indices(&self) -> Vec<usize> {
        self.blocks.keys().copied().collect()
    }

    /// Keep only blocks in [lo, hi]; returns the evicted blocks.
    pub fn retain_range(&mut self, lo: usize, hi: usize) -> BTreeMap<usize, BlockParams> {
        let mut evicted = BTreeMap::new();
        let keys: Vec<usize> = self.blocks.keys().copied().collect();
        for k in keys {
            if k < lo || k > hi {
                if let Some(v) = self.blocks.remove(&k) {
                    evicted.insert(k, v);
                }
            }
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bp(vals: &[&[f32]]) -> BlockParams {
        BlockParams(vals.iter().map(|v| v.to_vec()).collect())
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = bp(&[&[1.0, 2.0], &[3.0]]);
        let b = bp(&[&[10.0, 20.0], &[30.0]]);
        a.axpy(0.5, &b);
        assert_eq!(a, bp(&[&[6.0, 12.0], &[18.0]]));
        a.scale(2.0);
        assert_eq!(a, bp(&[&[12.0, 24.0], &[36.0]]));
    }

    #[test]
    fn l2_norm() {
        let a = bp(&[&[3.0], &[4.0]]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn retain_range_evicts() {
        let mut sp = StageParams::default();
        for i in 0..5 {
            sp.blocks.insert(i, bp(&[&[i as f32]]));
        }
        let evicted = sp.retain_range(1, 3);
        assert_eq!(sp.block_indices(), vec![1, 2, 3]);
        assert_eq!(evicted.keys().copied().collect::<Vec<_>>(), vec![0, 4]);
    }
}
