//! Weight aggregation (paper §III-C, Fig. 2).
//!
//! At stage `i` of an `n`-stage asynchronous pipeline, `n - i` weight
//! versions are live concurrently (each in-flight batch trains "its own"
//! weights through stashing). The paper's observation: these can be viewed
//! as `n - i` independent trainings forked from a common ancestor, so
//! periodically averaging them recovers the accuracy lost to staleness.
//! The aggregation interval must be a multiple of `n - i`.

use super::params::StageParams;

/// Average `versions` (equal weights). All snapshots must cover the same
/// block set with identical tensor shapes. Returns None if empty.
pub fn aggregate_versions(versions: &[&StageParams]) -> Option<StageParams> {
    let first = *versions.first()?;
    let mut acc = first.clone();
    let k = versions.len() as f32;
    for other in &versions[1..] {
        for (idx, bp) in &mut acc.blocks {
            let o = other
                .blocks
                .get(idx)
                .expect("aggregation: snapshots must cover the same blocks");
            bp.axpy(1.0, o);
        }
    }
    for bp in acc.blocks.values_mut() {
        bp.scale(1.0 / k);
    }
    Some(acc)
}

/// Number of concurrent weight versions at stage `i` of `n` (paper: n-i).
pub fn concurrent_versions(stage: usize, n_stages: usize) -> usize {
    (n_stages - stage).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::BlockParams;

    fn params(vals: &[f32]) -> StageParams {
        let mut sp = StageParams::default();
        sp.blocks.insert(0, BlockParams::from_vecs(vec![vals.to_vec()]));
        sp
    }

    #[test]
    fn average_of_three() {
        let a = params(&[1.0, 10.0]);
        let b = params(&[2.0, 20.0]);
        let c = params(&[3.0, 30.0]);
        let avg = aggregate_versions(&[&a, &b, &c]).unwrap();
        assert_eq!(avg.blocks[&0].0[0], vec![2.0, 20.0]);
    }

    #[test]
    fn aggregation_does_not_corrupt_source_snapshots() {
        // acc starts as a shared clone of the first snapshot; axpy/scale
        // must copy-on-write instead of mutating the snapshot in place
        let a = params(&[1.0]);
        let b = params(&[3.0]);
        let avg = aggregate_versions(&[&a, &b]).unwrap();
        assert_eq!(avg.blocks[&0].0[0][0], 2.0);
        assert_eq!(a.blocks[&0].0[0][0], 1.0, "snapshot a mutated");
        assert_eq!(b.blocks[&0].0[0][0], 3.0, "snapshot b mutated");
    }

    #[test]
    fn single_version_is_identity() {
        let a = params(&[4.0]);
        let avg = aggregate_versions(&[&a]).unwrap();
        assert_eq!(avg, a);
    }

    #[test]
    fn empty_is_none() {
        assert!(aggregate_versions(&[]).is_none());
    }

    #[test]
    fn concurrent_version_counts() {
        // 3-stage pipeline (paper Fig. 2): stage 0 sees 3 versions, stage 2 sees 1.
        assert_eq!(concurrent_versions(0, 3), 3);
        assert_eq!(concurrent_versions(1, 3), 2);
        assert_eq!(concurrent_versions(2, 3), 1);
    }
}
