//! Weight stashing (PipeDream, adopted by the paper §III-C).
//!
//! Under asynchronous 1F1B a stage forwards batch `b` with some weight
//! version `v`, but by the time `b`'s gradient returns the weights have
//! advanced. Weight stashing keeps the version used at forward time so the
//! backward pass of the same batch runs against identical weights.
//!
//! The stash also doubles as the version ring used by **weight
//! aggregation**: the last `n - i` versions at stage `i` are the "n-i
//! independent concurrent trainings" the paper averages (Fig. 2).
//!
//! Snapshots are cheap: `StageParams::clone` shares every tensor buffer
//! (`TensorBuf` is `Arc`-backed), and the optimizer's next update forks
//! only the tensors a live snapshot still references (copy-on-write).

use std::collections::{BTreeMap, VecDeque};

use super::params::StageParams;

/// Versioned snapshots of a stage's parameters.
#[derive(Debug, Clone, Default)]
pub struct VersionStash {
    /// batch id -> weight version used at its forward pass.
    by_batch: BTreeMap<u64, u64>,
    /// version -> snapshot (kept while any in-flight batch references it,
    /// plus a ring of recent versions for aggregation).
    snapshots: BTreeMap<u64, StageParams>,
    /// recency ring of versions (newest last).
    ring: VecDeque<u64>,
    /// how many recent versions to keep for aggregation.
    keep_recent: usize,
}

impl VersionStash {
    pub fn new(keep_recent: usize) -> VersionStash {
        VersionStash { keep_recent: keep_recent.max(1), ..Default::default() }
    }

    /// Record that `batch` was forwarded with `version`, snapshotting the
    /// current params if this version has no snapshot yet.
    pub fn on_forward(&mut self, batch: u64, version: u64, current: &StageParams) {
        self.by_batch.insert(batch, version);
        self.snapshots.entry(version).or_insert_with(|| current.clone());
        if self.ring.back() != Some(&version) {
            self.ring.push_back(version);
        }
        self.gc();
    }

    /// The weights to use for `batch`'s backward pass (stashed version).
    pub fn params_for_backward(&self, batch: u64) -> Option<&StageParams> {
        let v = self.by_batch.get(&batch)?;
        self.snapshots.get(v)
    }

    pub fn version_of(&self, batch: u64) -> Option<u64> {
        self.by_batch.get(&batch).copied()
    }

    /// Mark `batch` done (its backward completed); drops the reference.
    pub fn on_backward_done(&mut self, batch: u64) {
        self.by_batch.remove(&batch);
        self.gc();
    }

    /// The most recent `k` distinct snapshot versions (oldest first).
    pub fn recent_versions(&self, k: usize) -> Vec<u64> {
        let n = self.ring.len();
        self.ring.iter().skip(n.saturating_sub(k)).copied().collect()
    }

    pub fn snapshot(&self, version: u64) -> Option<&StageParams> {
        self.snapshots.get(&version)
    }

    /// In-flight batches (forwarded, not yet backwarded).
    pub fn in_flight(&self) -> usize {
        self.by_batch.len()
    }

    /// Clear all in-flight references (used when the fault handler discards
    /// batches after `committed_id`, paper §III-F "reset the training state").
    pub fn discard_after(&mut self, committed: i64) {
        self.by_batch.retain(|&b, _| (b as i64) <= committed);
        self.gc();
    }

    pub fn clear(&mut self) {
        self.by_batch.clear();
        self.snapshots.clear();
        self.ring.clear();
    }

    fn gc(&mut self) {
        // Keep: versions referenced by in-flight batches + `keep_recent` ring.
        let live: std::collections::BTreeSet<u64> = self
            .by_batch
            .values()
            .copied()
            .chain(self.recent_versions(self.keep_recent))
            .collect();
        self.snapshots.retain(|v, _| live.contains(v));
        while self.ring.len() > self.keep_recent.max(8) {
            self.ring.pop_front();
        }
    }

    pub fn snapshot_count(&self) -> usize {
        self.snapshots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::BlockParams;

    fn params(v: f32) -> StageParams {
        let mut sp = StageParams::default();
        sp.blocks.insert(0, BlockParams::from_vecs(vec![vec![v]]));
        sp
    }

    #[test]
    fn backward_sees_forward_version() {
        let mut st = VersionStash::new(2);
        st.on_forward(0, 0, &params(1.0));
        // weights advance to version 1 before batch 0's backward
        st.on_forward(1, 1, &params(2.0));
        let p = st.params_for_backward(0).unwrap();
        assert_eq!(p.blocks[&0].0[0][0], 1.0);
        let p = st.params_for_backward(1).unwrap();
        assert_eq!(p.blocks[&0].0[0][0], 2.0);
    }

    #[test]
    fn gc_drops_unreferenced_old_versions() {
        let mut st = VersionStash::new(2);
        for v in 0..10u64 {
            st.on_forward(v, v, &params(v as f32));
            st.on_backward_done(v);
        }
        // only the keep_recent ring survives
        assert!(st.snapshot_count() <= 2, "kept {}", st.snapshot_count());
        assert_eq!(st.recent_versions(2), vec![8, 9]);
    }

    #[test]
    fn in_flight_counts() {
        let mut st = VersionStash::new(2);
        st.on_forward(0, 0, &params(0.0));
        st.on_forward(1, 0, &params(0.0));
        assert_eq!(st.in_flight(), 2);
        st.on_backward_done(0);
        assert_eq!(st.in_flight(), 1);
    }

    #[test]
    fn discard_after_clears_tail() {
        let mut st = VersionStash::new(4);
        for b in 0..5u64 {
            st.on_forward(b, b, &params(b as f32));
        }
        st.discard_after(1);
        assert_eq!(st.in_flight(), 2); // batches 0 and 1
        assert!(st.params_for_backward(3).is_none());
    }
}
