//! Loader for `artifacts/<model>/manifest.json` produced by `python/compile/aot.py`.
//!
//! The manifest is the single source of truth the Rust side has about the
//! model: block inventory, parameter shapes + initial-weight files, per
//! block FLOPs (used by the partitioner as the cost model seed) and
//! activation sizes `D_j` (used for the communication term, paper eq (6)).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// Element type of an activation buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One parameter tensor of a block.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub shape: Vec<usize>,
    pub size: usize,
    /// Path to the f32-LE initial weights, resolved against the model dir.
    pub init_path: PathBuf,
}

/// Whether a block is a plain chain block or the fused head.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockKind {
    Block,
    Head,
}

/// One partitionable unit (paper: "layer").
#[derive(Debug, Clone)]
pub struct BlockInfo {
    pub index: usize,
    pub name: String,
    pub kind: BlockKind,
    /// fwd/bwd for `Block`, step/eval for `Head` — resolved paths.
    pub fwd: Option<PathBuf>,
    pub bwd: Option<PathBuf>,
    pub step: Option<PathBuf>,
    pub eval: Option<PathBuf>,
    /// Built-in pure-Rust op instead of HLO artifacts ("affine"/"head").
    /// Used by the deterministic scenario fixtures (`sim::fixture`), which
    /// must run without a PJRT backend.
    pub native: Option<String>,
    pub params: Vec<ParamInfo>,
    pub in_shape: Vec<usize>,
    pub in_dtype: Dtype,
    pub out_shape: Vec<usize>,
    pub flops_fwd: u64,
    pub flops_bwd: u64,
    /// Output activation bytes — the `D_j` of paper eq (6).
    pub out_bytes: u64,
    pub param_bytes: u64,
    /// Whether the bwd artifact emits an input gradient (false for block 0).
    pub has_gx: bool,
}

/// Parsed manifest for one compiled model.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub dir: PathBuf,
    pub batch_size: usize,
    pub input_shape: Vec<usize>,
    pub input_dtype: Dtype,
    pub label_shape: Vec<usize>,
    pub label_dtype: Dtype,
    /// Number of predictions per batch (batch, or batch*seq for LM).
    pub acc_denom: usize,
    pub param_count: u64,
    pub blocks: Vec<BlockInfo>,
    /// From manifest `meta`: number of classes (vision models).
    pub n_classes: Option<usize>,
    /// From manifest `meta`: vocabulary size (LM models).
    pub vocab: Option<usize>,
    /// From manifest `meta`: sequence length (LM models).
    pub seq: Option<usize>,
}

fn shape_of(v: &Value) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("shape is not an array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("shape item not usize")))
        .collect()
}

fn u64_of(v: &Value, key: &str) -> Result<u64> {
    Ok(v.req(key)?
        .as_f64()
        .ok_or_else(|| anyhow!("{key} not a number"))? as u64)
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let v = json::parse(&raw).map_err(|e| anyhow!("{e}"))?;

        let input = v.req("input")?;
        let labels = v.req("labels")?;
        let mut blocks = Vec::new();
        for b in v.req("blocks")?.as_arr().ok_or_else(|| anyhow!("blocks not array"))? {
            let kind = match b.req("kind")?.as_str() {
                Some("block") => BlockKind::Block,
                Some("head") => BlockKind::Head,
                other => bail!("bad block kind {other:?}"),
            };
            let path_of = |key: &str| -> Option<PathBuf> {
                b.get(key).and_then(|x| x.as_str()).map(|s| dir.join(s))
            };
            let mut params = Vec::new();
            for p in b.req("params")?.as_arr().ok_or_else(|| anyhow!("params not array"))? {
                params.push(ParamInfo {
                    shape: shape_of(p.req("shape")?)?,
                    size: p.req("size")?.as_usize().ok_or_else(|| anyhow!("size"))?,
                    init_path: dir.join(
                        p.req("init")?.as_str().ok_or_else(|| anyhow!("init"))?,
                    ),
                });
            }
            blocks.push(BlockInfo {
                index: b.req("index")?.as_usize().ok_or_else(|| anyhow!("index"))?,
                name: b.req("name")?.as_str().unwrap_or("").to_string(),
                kind,
                fwd: path_of("fwd"),
                bwd: path_of("bwd"),
                step: path_of("step"),
                eval: path_of("eval"),
                native: b.get("native").and_then(|x| x.as_str()).map(String::from),
                params,
                in_shape: shape_of(b.req("in_shape")?)?,
                in_dtype: Dtype::from_str(b.req("in_dtype")?.as_str().unwrap_or("f32"))?,
                out_shape: shape_of(b.req("out_shape")?)?,
                flops_fwd: u64_of(b, "flops_fwd")?,
                flops_bwd: u64_of(b, "flops_bwd")?,
                out_bytes: u64_of(b, "out_bytes")?,
                param_bytes: u64_of(b, "param_bytes")?,
                has_gx: b.req("has_gx")?.as_bool().unwrap_or(true),
            });
        }
        if blocks.is_empty() {
            bail!("manifest has no blocks");
        }
        // Invariants the rest of the system relies on.
        for (i, b) in blocks.iter().enumerate() {
            if b.index != i {
                bail!("block index mismatch: {} at position {i}", b.index);
            }
            let is_last = i + 1 == blocks.len();
            if is_last != (b.kind == BlockKind::Head) {
                bail!("head must be exactly the last block");
            }
        }

        let meta = v.get("meta");
        let meta_usize =
            |k: &str| meta.and_then(|m| m.get(k)).and_then(|x| x.as_usize());

        Ok(Manifest {
            n_classes: meta_usize("n_classes"),
            vocab: meta_usize("vocab"),
            seq: meta_usize("seq"),
            model: v.req("model")?.as_str().unwrap_or("").to_string(),
            dir,
            batch_size: v.req("batch_size")?.as_usize().ok_or_else(|| anyhow!("batch_size"))?,
            input_shape: shape_of(input.req("shape")?)?,
            input_dtype: Dtype::from_str(input.req("dtype")?.as_str().unwrap_or("f32"))?,
            label_shape: shape_of(labels.req("shape")?)?,
            label_dtype: Dtype::from_str(labels.req("dtype")?.as_str().unwrap_or("i32"))?,
            acc_denom: v.req("acc_denom")?.as_usize().ok_or_else(|| anyhow!("acc_denom"))?,
            param_count: u64_of(&v, "param_count")?,
            blocks,
        })
    }

    /// Number of partitionable blocks (including the head).
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn head(&self) -> &BlockInfo {
        self.blocks.last().unwrap()
    }

    /// Load the initial f32 weights of block `i` from the init/*.bin files.
    pub fn load_init_params(&self, i: usize) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::new();
        for p in &self.blocks[i].params {
            let bytes = std::fs::read(&p.init_path)
                .with_context(|| format!("reading {}", p.init_path.display()))?;
            if bytes.len() != p.size * 4 {
                bail!(
                    "init file {} has {} bytes, expected {}",
                    p.init_path.display(),
                    bytes.len(),
                    p.size * 4
                );
            }
            let mut v = vec![0f32; p.size];
            for (j, c) in bytes.chunks_exact(4).enumerate() {
                v[j] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Total parameter bytes in blocks [lo, hi] inclusive — used by the
    /// memory-cap emulation and replication cost accounting.
    pub fn param_bytes_range(&self, lo: usize, hi: usize) -> u64 {
        self.blocks[lo..=hi].iter().map(|b| b.param_bytes).sum()
    }
}
