//! Model profiling + capacity estimation (paper §III-B "model profiling"
//! and §III-D eqs (1)–(3)).
//!
//! At the offline stage the central node runs every block's forward and
//! backward ten times with example inputs and records the average — these
//! are the `T^0_j` the partitioner scales by each worker's capacity. At
//! the online stage workers report their measured per-batch execution
//! time piggybacked on gradients; [`CapacityEstimator`] turns those into
//! `C_i` (eq 1).

use std::collections::HashMap;

use anyhow::Result;

use crate::manifest::{Dtype, Manifest};
use crate::net::message::{DeviceId, ExecReport};
use crate::runtime::{BlockRuntime, HostTensor};
use crate::sim::clock::{real_clock, Clock};

/// Average fwd+bwd wall-time per block, in ms (`T^0_j`).
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub t0_ms: Vec<f64>,
    pub out_bytes: Vec<u64>,
}

impl ModelProfile {
    /// Deterministic profile derived from the manifest's per-block flop
    /// counts at `ns_per_flop` — what the scenario runner uses instead of
    /// measured execution (the same cost model its modeled devices
    /// charge, so online capacity estimates are exact by construction).
    pub fn from_flops(manifest: &Manifest, ns_per_flop: f64) -> ModelProfile {
        ModelProfile {
            t0_ms: manifest
                .blocks
                .iter()
                .map(|b| (b.flops_fwd + b.flops_bwd) as f64 * ns_per_flop / 1e6)
                .collect(),
            out_bytes: manifest.blocks.iter().map(|b| b.out_bytes).collect(),
        }
    }
}

fn dummy_input(shape_elems: usize, dtype: Dtype) -> HostTensor {
    match dtype {
        Dtype::F32 => HostTensor::F32(
            (0..shape_elems).map(|i| ((i % 13) as f32) * 0.05 - 0.3).collect(),
        ),
        Dtype::I32 => HostTensor::I32((0..shape_elems).map(|i| (i % 5) as i32).collect()),
    }
}

/// Profile every block `reps` times on the calling thread's runtime
/// (paper uses 10 reps to wash out measurement noise).
pub fn profile_model(
    manifest: &Manifest,
    blocks: &[BlockRuntime],
    reps: usize,
) -> Result<ModelProfile> {
    profile_model_with_clock(manifest, blocks, reps, &*real_clock())
}

/// [`profile_model`] with an explicit time source — measurements read
/// the [`Clock`] seam, so a virtual clock yields scripted timings.
pub fn profile_model_with_clock(
    manifest: &Manifest,
    blocks: &[BlockRuntime],
    reps: usize,
    clock: &dyn Clock,
) -> Result<ModelProfile> {
    let mut t0_ms = Vec::with_capacity(blocks.len());
    for (i, b) in blocks.iter().enumerate() {
        let params = manifest.load_init_params(i)?;
        let in_elems: usize = b.info.in_shape.iter().product();
        let x = dummy_input(in_elems, b.info.in_dtype);
        let lab_elems: usize = manifest.label_shape.iter().product();
        let labels = HostTensor::I32(vec![0i32; lab_elems]);

        let mut total = 0.0f64;
        if b.is_head() {
            let xs = x.as_f32()?.to_vec();
            // one unmeasured warmup (first execution pays one-time costs)
            b.head_step(&params, &xs, &labels, &manifest.label_shape)?;
            for _ in 0..reps {
                let t0 = clock.now();
                b.head_step(&params, &xs, &labels, &manifest.label_shape)?;
                total += clock.now().saturating_sub(t0).as_secs_f64() * 1e3;
            }
        } else {
            let y = b.forward(&params, &x)?; // warmup fwd
            let gy0 = vec![1e-3f32; y.len()];
            b.backward(&params, &x, &gy0)?; // warmup bwd
            for _ in 0..reps {
                let t0 = clock.now();
                let y = b.forward(&params, &x)?;
                let gy = vec![1e-3f32; y.len()];
                b.backward(&params, &x, &gy)?;
                total += clock.now().saturating_sub(t0).as_secs_f64() * 1e3;
            }
        }
        t0_ms.push(total / reps as f64);
    }
    Ok(ModelProfile {
        t0_ms,
        out_bytes: manifest.blocks.iter().map(|b| b.out_bytes).collect(),
    })
}

/// Tracks the latest execution report per device and estimates capacities.
#[derive(Debug, Clone, Default)]
pub struct CapacityEstimator {
    latest: HashMap<DeviceId, ExecReport>,
}

impl CapacityEstimator {
    pub fn ingest(&mut self, report: &ExecReport) {
        self.latest.insert(report.device, report.clone());
    }

    pub fn has_report(&self, device: DeviceId) -> bool {
        self.latest.contains_key(&device)
    }

    /// Eq (1): `C_i = avg_exec_i / sum_{j in stage_i} T^0_j`, where
    /// `range` is the device's current block range. Devices without a
    /// report default to 1.0 (the paper's initial assumption).
    pub fn capacity(
        &self,
        device: DeviceId,
        range: (usize, usize),
        t0_ms: &[f64],
    ) -> f64 {
        match self.latest.get(&device) {
            Some(r) => {
                let base: f64 = t0_ms[range.0..=range.1].iter().sum();
                if base <= 0.0 {
                    1.0
                } else {
                    (r.avg_ms / base).max(0.05)
                }
            }
            None => 1.0,
        }
    }

    /// Capacities for a worker list given each device's current range.
    /// Device `worker_list[0]` (central) is pinned to 1.0 per the paper.
    ///
    /// `central_ratio` is the central node's own online-time / profiled-
    /// time ratio. In the in-process simulation all XLA clients share the
    /// host's cores, so every device's online time is inflated by the
    /// same contention factor relative to the unloaded offline profile;
    /// dividing by the central node's ratio cancels it (the paper's
    /// devices are separate machines, where this factor is 1).
    pub fn capacities(
        &self,
        worker_list: &[DeviceId],
        ranges: &[(usize, usize)],
        t0_ms: &[f64],
        central_ratio: f64,
    ) -> Vec<f64> {
        let norm = central_ratio.max(0.05);
        worker_list
            .iter()
            .enumerate()
            .map(|(stage, &d)| {
                if stage == 0 {
                    1.0
                } else {
                    (self.capacity(d, ranges[stage], t0_ms) / norm).max(0.05)
                }
            })
            .collect()
    }

    pub fn clear_device(&mut self, device: DeviceId) {
        self.latest.remove(&device);
    }
}

/// Accumulates a device's own per-batch execution time between reports.
#[derive(Debug, Clone, Default)]
pub struct ExecWindow {
    sum_ms: f64,
    count: u32,
}

impl ExecWindow {
    pub fn record(&mut self, ms: f64) {
        self.sum_ms += ms;
        self.count += 1;
    }

    /// Produce a report and reset the window (None if nothing recorded).
    pub fn take_report(&mut self, device: DeviceId) -> Option<ExecReport> {
        if self.count == 0 {
            return None;
        }
        let r = ExecReport { device, avg_ms: self.sum_ms / self.count as f64, batches: self.count };
        self.sum_ms = 0.0;
        self.count = 0;
        Some(r)
    }

    /// Peek without resetting.
    pub fn current_avg(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum_ms / self.count as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_from_report() {
        let mut est = CapacityEstimator::default();
        est.ingest(&ExecReport { device: 2, avg_ms: 50.0, batches: 10 });
        let t0 = vec![5.0, 5.0, 10.0, 5.0];
        // device 2 owns blocks [1,2] -> base 15ms; measured 50 -> C=3.33
        let c = est.capacity(2, (1, 2), &t0);
        assert!((c - 50.0 / 15.0).abs() < 1e-9);
        // unknown device defaults to 1.0
        assert_eq!(est.capacity(9, (0, 1), &t0), 1.0);
    }

    #[test]
    fn central_pinned_to_one() {
        let mut est = CapacityEstimator::default();
        est.ingest(&ExecReport { device: 0, avg_ms: 1000.0, batches: 1 });
        est.ingest(&ExecReport { device: 1, avg_ms: 20.0, batches: 1 });
        let caps = est.capacities(&[0, 1], &[(0, 0), (1, 1)], &[10.0, 10.0], 1.0);
        assert_eq!(caps[0], 1.0);
        assert!((caps[1] - 2.0).abs() < 1e-9);
        // contention normalization: central running 2x slower than its
        // profile means workers' ratios halve
        let caps = est.capacities(&[0, 1], &[(0, 0), (1, 1)], &[10.0, 10.0], 2.0);
        assert!((caps[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn exec_window_averages_and_resets() {
        let mut w = ExecWindow::default();
        assert!(w.take_report(1).is_none());
        w.record(10.0);
        w.record(20.0);
        let r = w.take_report(1).unwrap();
        assert_eq!(r.avg_ms, 15.0);
        assert_eq!(r.batches, 2);
        assert!(w.take_report(1).is_none());
    }
}
