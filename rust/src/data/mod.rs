//! Synthetic datasets (the offline substitution for MNIST/CIFAR10 and a
//! text corpus — DESIGN.md §3).
//!
//! * [`SynthVision`] — class-conditional mixture: each class has a fixed
//!   random template pattern; a sample is `template[y] + sigma * noise`.
//!   Learnable by `edgenet` (accuracy climbs the same way the paper's
//!   MNIST/CIFAR10 curves do) and fully deterministic per (seed, index),
//!   so epochs, train/val splits, and "old vs new data" mixes reproduce.
//! * [`SynthLm`] — Zipf-Markov token stream for `pipeformer`: a random
//!   sparse transition matrix with Zipfian stationary mass; next-token
//!   prediction has learnable structure (low achievable cross-entropy).

use crate::util::rng::Rng;

/// One training batch as fed to the pipeline's first stage.
#[derive(Debug, Clone)]
pub struct Batch {
    /// f32 inputs (vision) — empty when the model takes tokens.
    pub x_f32: Vec<f32>,
    /// i32 inputs (tokens) — empty for vision.
    pub x_i32: Vec<i32>,
    pub labels: Vec<i32>,
}

/// Deterministic class-mixture vision dataset.
#[derive(Debug, Clone)]
pub struct SynthVision {
    pub dim: usize,
    pub n_classes: usize,
    pub noise: f32,
    templates: Vec<Vec<f32>>,
    seed: u64,
}

impl SynthVision {
    /// `domain` selects an independent template set — used by the
    /// continuous-learning experiment ("new environment" = new domain).
    pub fn new(dim: usize, n_classes: usize, noise: f32, seed: u64, domain: u64) -> SynthVision {
        let mut rng = Rng::new(seed ^ (domain.wrapping_mul(0x9E37_79B9)));
        let templates = (0..n_classes)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        SynthVision { dim, n_classes, noise, templates, seed }
    }

    /// Sample `index` is fully determined by (seed, split, index).
    pub fn sample(&self, split: u64, index: u64) -> (Vec<f32>, i32) {
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x2545F491_4F6CDD1D)
                .wrapping_add(split.wrapping_mul(0x9E3779B9_7F4A7C15))
                .wrapping_add(index),
        );
        let y = rng.below(self.n_classes as u64) as i32;
        let t = &self.templates[y as usize];
        let x = t
            .iter()
            .map(|&ti| ti + self.noise * rng.normal() as f32)
            .collect();
        (x, y)
    }

    /// Batch `b` of `batch_size` samples from `split` (0=train, 1=val).
    pub fn batch(&self, split: u64, b: u64, batch_size: usize) -> Batch {
        let mut x = Vec::with_capacity(batch_size * self.dim);
        let mut labels = Vec::with_capacity(batch_size);
        for i in 0..batch_size as u64 {
            let (xi, y) = self.sample(split, b * batch_size as u64 + i);
            x.extend_from_slice(&xi);
            labels.push(y);
        }
        Batch { x_f32: x, x_i32: vec![], labels }
    }
}

/// Zipf-Markov language-model stream.
#[derive(Debug, Clone)]
pub struct SynthLm {
    pub vocab: usize,
    pub seq: usize,
    /// per-token successor candidates (sparse transitions)
    successors: Vec<Vec<u32>>,
    seed: u64,
}

impl SynthLm {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> SynthLm {
        let mut rng = Rng::new(seed ^ 0x5E2D_58D8_B3BC_E8EE);
        let branch = 4; // each token has 4 likely successors
        let successors = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab as u64) as u32).collect())
            .collect();
        SynthLm { vocab, seq, successors, seed }
    }

    /// Generate sequence `index`: tokens[0..seq] plus the shifted labels.
    pub fn sequence(&self, split: u64, index: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0xD6E8_FEB8_6659_FD93)
                .wrapping_add(split.wrapping_mul(0xCA5A_8263_95121157))
                .wrapping_add(index),
        );
        let mut toks = Vec::with_capacity(self.seq + 1);
        let mut cur = rng.below(self.vocab as u64) as u32;
        toks.push(cur as i32);
        for _ in 0..self.seq {
            // 85%: one of the likely successors (Zipf-ish weights);
            // 15%: uniform random token.
            cur = if rng.next_f64() < 0.85 {
                let s = &self.successors[cur as usize];
                let w: Vec<f64> = (0..s.len()).map(|i| 1.0 / (i + 1) as f64).collect();
                s[rng.weighted(&w)]
            } else {
                rng.below(self.vocab as u64) as u32
            };
            toks.push(cur as i32);
        }
        let inputs = toks[..self.seq].to_vec();
        let labels = toks[1..=self.seq].to_vec();
        (inputs, labels)
    }

    pub fn batch(&self, split: u64, b: u64, batch_size: usize) -> Batch {
        let mut x = Vec::with_capacity(batch_size * self.seq);
        let mut labels = Vec::with_capacity(batch_size * self.seq);
        for i in 0..batch_size as u64 {
            let (xi, yi) = self.sequence(split, b * batch_size as u64 + i);
            x.extend_from_slice(&xi);
            labels.extend_from_slice(&yi);
        }
        Batch { x_f32: vec![], x_i32: x, labels }
    }
}

/// A data source the training driver can pull batches from.
pub trait DataSource: Send {
    fn train_batch(&self, b: u64, batch_size: usize) -> Batch;
    fn val_batch(&self, b: u64, batch_size: usize) -> Batch;
}

impl DataSource for SynthVision {
    fn train_batch(&self, b: u64, batch_size: usize) -> Batch {
        self.batch(0, b, batch_size)
    }
    fn val_batch(&self, b: u64, batch_size: usize) -> Batch {
        self.batch(1, b, batch_size)
    }
}

impl DataSource for SynthLm {
    fn train_batch(&self, b: u64, batch_size: usize) -> Batch {
        self.batch(0, b, batch_size)
    }
    fn val_batch(&self, b: u64, batch_size: usize) -> Batch {
        self.batch(1, b, batch_size)
    }
}

/// Mix of two vision domains (continuous learning §IV-F: old + new data).
pub struct MixedVision {
    pub old: SynthVision,
    pub new: SynthVision,
    /// fraction of samples drawn from the new domain
    pub new_frac: f64,
    pub seed: u64,
}

impl DataSource for MixedVision {
    fn train_batch(&self, b: u64, batch_size: usize) -> Batch {
        let mut rng = Rng::new(self.seed.wrapping_add(b.wrapping_mul(0x9E37)));
        let mut x = Vec::new();
        let mut labels = Vec::new();
        for i in 0..batch_size as u64 {
            let idx = b * batch_size as u64 + i;
            let (xi, y) = if rng.next_f64() < self.new_frac {
                self.new.sample(0, idx)
            } else {
                self.old.sample(0, idx)
            };
            x.extend_from_slice(&xi);
            labels.push(y);
        }
        Batch { x_f32: x, x_i32: vec![], labels }
    }

    fn val_batch(&self, b: u64, batch_size: usize) -> Batch {
        // validate on the NEW domain: that's what §IV-F measures
        self.new.batch(1, b, batch_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vision_deterministic() {
        let d1 = SynthVision::new(16, 4, 0.3, 7, 0);
        let d2 = SynthVision::new(16, 4, 0.3, 7, 0);
        let b1 = d1.batch(0, 3, 8);
        let b2 = d2.batch(0, 3, 8);
        assert_eq!(b1.x_f32, b2.x_f32);
        assert_eq!(b1.labels, b2.labels);
    }

    #[test]
    fn vision_splits_differ() {
        let d = SynthVision::new(16, 4, 0.3, 7, 0);
        assert_ne!(d.batch(0, 0, 8).x_f32, d.batch(1, 0, 8).x_f32);
    }

    #[test]
    fn vision_domains_differ() {
        let a = SynthVision::new(16, 4, 0.0, 7, 0);
        let b = SynthVision::new(16, 4, 0.0, 7, 1);
        // zero noise -> samples are pure templates; domains must differ
        assert_ne!(a.batch(0, 0, 4).x_f32, b.batch(0, 0, 4).x_f32);
    }

    #[test]
    fn vision_labels_in_range() {
        let d = SynthVision::new(8, 10, 0.1, 1, 0);
        let b = d.batch(0, 0, 100);
        assert!(b.labels.iter().all(|&y| (0..10).contains(&y)));
        // all classes appear in a large batch
        let mut seen = [false; 10];
        for &y in &b.labels {
            seen[y as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 8);
    }

    #[test]
    fn lm_shapes_and_shift() {
        let d = SynthLm::new(32, 8, 3);
        let (x, y) = d.sequence(0, 0);
        assert_eq!(x.len(), 8);
        assert_eq!(y.len(), 8);
        // labels are inputs shifted by one
        assert_eq!(&x[1..], &y[..7]);
    }

    #[test]
    fn lm_batch_layout() {
        let d = SynthLm::new(32, 8, 3);
        let b = d.batch(0, 1, 4);
        assert_eq!(b.x_i32.len(), 32);
        assert_eq!(b.labels.len(), 32);
        assert!(b.x_i32.iter().all(|&t| (0..32).contains(&t)));
    }

    #[test]
    fn lm_has_markov_structure() {
        // successors of a token should be hit far more often than chance
        let d = SynthLm::new(64, 128, 5);
        let mut hits = 0;
        let mut total = 0;
        for i in 0..20 {
            let (x, y) = d.sequence(0, i);
            for (a, b) in x.iter().zip(&y[0..]) {
                total += 1;
                if d.successors[*a as usize].contains(&(*b as u32)) {
                    hits += 1;
                }
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.5, "markov fraction {frac}");
    }

    #[test]
    fn mixed_val_is_new_domain() {
        let old = SynthVision::new(8, 3, 0.0, 1, 0);
        let new = SynthVision::new(8, 3, 0.0, 1, 1);
        let mix = MixedVision { old, new: new.clone(), new_frac: 0.5, seed: 2 };
        assert_eq!(mix.val_batch(0, 4).x_f32, new.batch(1, 0, 4).x_f32);
    }
}
