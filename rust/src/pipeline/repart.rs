//! Client-side state of an in-progress re-partition (between the
//! `Repartition` and `Commit` control events) — the receiving half of the
//! paper's Algorithm-1 redistribution protocol (§III-D/F).
//!
//! [`super::stage::StageWorker`] builds a [`Repart`] from the fetch plan,
//! sends the `FetchWeights` requests, and feeds `Weights` replies back in;
//! `Repart` tracks which blocks are still missing, which requests are
//! outstanding at which peer, and which blocks were already escalated to
//! the central node's global backup. Staged blocks hold shared
//! [`BlockParams`] buffers — staging a fetched or locally-backed-up block
//! never copies tensor data.

use std::collections::{BTreeMap, BTreeSet};

use crate::model::BlockParams;
use crate::net::message::{DeviceId, WireBlock};

/// An open request window at one device: how many `FetchWeights`
/// messages are still unanswered, and the union of blocks they asked.
#[derive(Debug, Default, Clone)]
pub(crate) struct Outstanding {
    pub replies_pending: usize,
    pub asked: Vec<usize>,
}

/// In-progress re-partition state.
pub(crate) struct Repart {
    /// The partition being installed.
    pub ranges: Vec<(usize, usize)>,
    pub worker_list: Vec<DeviceId>,
    /// Blocks still missing (awaiting `Weights` replies).
    pub needed: BTreeSet<usize>,
    /// Blocks fetched/staged so far (installed atomically at commit).
    pub staged: BTreeMap<usize, BlockParams>,
    /// Open request windows per device.
    pub outstanding: BTreeMap<DeviceId, Outstanding>,
    /// Blocks already escalated to the central node's global backup.
    pub escalated: BTreeSet<usize>,
}

impl Repart {
    pub fn new(ranges: Vec<(usize, usize)>, worker_list: Vec<DeviceId>) -> Repart {
        Repart {
            ranges,
            worker_list,
            needed: BTreeSet::new(),
            staged: BTreeMap::new(),
            outstanding: BTreeMap::new(),
            escalated: BTreeSet::new(),
        }
    }

    pub fn central(&self) -> DeviceId {
        self.worker_list[0]
    }

    /// Stage a block that is already satisfied (local backup, self-serve).
    pub fn stage(&mut self, block: usize, params: BlockParams) {
        self.staged.insert(block, params);
        self.needed.remove(&block);
    }

    /// Record that `block` must be fetched (optionally via escalation).
    pub fn mark_needed(&mut self, block: usize, escalated: bool) {
        self.needed.insert(block);
        if escalated {
            self.escalated.insert(block);
        }
    }

    /// Record one outstanding `FetchWeights` request of `blocks` at `dev`.
    /// Call exactly once per message sent — replies are counted against it.
    pub fn mark_requested(&mut self, dev: DeviceId, blocks: impl IntoIterator<Item = usize>) {
        let o = self.outstanding.entry(dev).or_default();
        o.replies_pending += 1;
        o.asked.extend(blocks);
    }

    /// Integrate a `Weights` reply from `from`: stage everything that was
    /// still needed, then close one request window. Blocks `from` was
    /// asked for but did not serve are only reported once its LAST open
    /// request has answered — an earlier reply must not condemn blocks a
    /// still-in-flight reply may yet deliver.
    pub fn record_reply(&mut self, from: DeviceId, blocks: Vec<WireBlock>) -> Vec<usize> {
        for (idx, tensors) in blocks {
            if self.needed.remove(&idx) {
                // f32 tensors stage as shared buffers; quantized ones pay
                // their one dequantization here, at the receiver boundary
                self.staged.insert(idx, crate::replication::block_from_wire(tensors));
            }
        }
        let Some(o) = self.outstanding.get_mut(&from) else {
            return Vec::new();
        };
        o.replies_pending = o.replies_pending.saturating_sub(1);
        if o.replies_pending > 0 {
            return Vec::new();
        }
        let asked = self.outstanding.remove(&from).unwrap().asked;
        asked.into_iter().filter(|b| self.needed.contains(b)).collect()
    }

    /// All blocks staged — ready for `FetchDone` / commit.
    pub fn is_complete(&self) -> bool {
        self.needed.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bp(v: f32) -> BlockParams {
        BlockParams::from_vecs(vec![vec![v; 2]])
    }

    fn wire(idx: usize, v: f32) -> WireBlock {
        (idx, crate::replication::block_to_wire(&bp(v)))
    }

    #[test]
    fn reply_stages_and_reports_missing() {
        let mut rp = Repart::new(vec![(0, 1), (2, 5)], vec![0, 7]);
        rp.mark_needed(2, false);
        rp.mark_needed(3, false);
        rp.mark_requested(9, [2, 3]);
        assert!(!rp.is_complete());
        let missing = rp.record_reply(9, vec![wire(2, 1.0)]);
        assert_eq!(missing, vec![3], "unserved block must surface for escalation");
        assert!(rp.staged.contains_key(&2));
        assert!(!rp.is_complete());
        rp.mark_requested(0, missing.iter().copied());
        let missing = rp.record_reply(0, vec![wire(3, 2.0)]);
        assert!(missing.is_empty());
        assert!(rp.is_complete());
    }

    #[test]
    fn two_requests_to_one_device_wait_for_both_replies() {
        // stage-source fetch [2] and an escalation [3] both go to central:
        // the first reply must NOT condemn block 3 as unserved while the
        // second reply is still in flight (that would silently restore
        // initial weights over a live replica).
        let mut rp = Repart::new(vec![(0, 5)], vec![0]);
        rp.mark_needed(2, false);
        rp.mark_needed(3, true);
        rp.mark_requested(0, [2]);
        rp.mark_requested(0, [3]);
        let missing = rp.record_reply(0, vec![wire(2, 1.0)]);
        assert!(missing.is_empty(), "block 3 still has a reply in flight");
        assert!(!rp.is_complete());
        let missing = rp.record_reply(0, vec![wire(3, 2.0)]);
        assert!(missing.is_empty());
        assert!(rp.is_complete());
        // and if the last reply does NOT serve it, it surfaces then
        let mut rp = Repart::new(vec![(0, 5)], vec![0]);
        rp.mark_needed(4, false);
        rp.mark_requested(0, [4]);
        rp.mark_requested(0, std::iter::empty::<usize>());
        assert!(rp.record_reply(0, vec![]).is_empty());
        assert_eq!(rp.record_reply(0, vec![]), vec![4], "unserved after final reply");
    }

    #[test]
    fn unsolicited_blocks_are_ignored() {
        let mut rp = Repart::new(vec![(0, 3)], vec![0]);
        rp.mark_needed(1, false);
        rp.record_reply(5, vec![wire(9, 3.0)]);
        assert!(!rp.staged.contains_key(&9));
        assert!(!rp.is_complete());
    }

    #[test]
    fn local_stage_satisfies_without_request() {
        let mut rp = Repart::new(vec![(0, 0)], vec![0]);
        rp.mark_needed(0, true);
        rp.stage(0, bp(4.0));
        assert!(rp.is_complete());
        assert!(rp.escalated.contains(&0));
    }
}
