//! Per-stage compute + the stage event loop (paper §III-C).
//!
//! Each device runs a [`StageWorker`]: it owns the compiled block
//! executables (all blocks — re-partitioning only moves *weights*, never
//! code), the parameters of its current block range, the weight stash,
//! the optimizer, the replica store, and the device capacity simulator.
//!
//! The worker is event-driven: incoming messages are classified into
//! [`Event`]s at the network boundary and handled by [`StageWorker::on_event`];
//! [`StageWorker::pump`] asks the 1F1B [`Schedule`] for the next compute
//! step. Weight stashing + the version ring give weight aggregation its
//! inputs (paper Fig. 2); vertical sync is tracked through the `version0`
//! tag each batch carries. All tensor movement — queued activations,
//! stashed weights, replica pushes, redistribution staging — shares
//! `TensorBuf` allocations; the optimizer mutates copy-on-write.
//!
//! The same struct serves the central node (stage 0): the coordinator
//! drives it directly instead of through [`run_worker`].

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::device::SimDevice;
use crate::fault::{plan_redistribution, RedistPlan, Source};
use crate::manifest::Manifest;
use crate::model::{aggregate_versions, BlockParams, Sgd, SgdConfig, StageParams, VersionStash};
use crate::net::message::{
    DeviceId, ExecReport, Message, Payload, ReplicaKind, TrainInit, WireBlock, WireTensor,
};
use crate::net::quant::{
    weight_channel_hint, Bits, ChannelHint, Compression, QTensor, Residual, Tier, WeightCoding,
};
use crate::net::{TensorBuf, Transport};
use crate::replication::{self, BackupStore};
use crate::runtime::{BlockRuntime, HostTensor};
use crate::sim::clock::{real_clock, SharedClock};

use super::events::{ControlEvent, DataEvent, Event, Flow};
use super::repart::Repart;
use super::schedule::{PendingBackward, PendingForward, Schedule, Step, StepKind};
use super::trace::{TraceEvent, TraceKind, TraceSink};

/// Completion info surfaced at stage 0 when a batch's gradient lands.
#[derive(Debug, Clone)]
pub struct CompletedBatch {
    pub batch: u64,
    pub loss: f32,
    pub ncorrect: f32,
    pub reports: Vec<ExecReport>,
}

pub struct StageWorker {
    pub device_id: DeviceId,
    pub manifest: Arc<Manifest>,
    pub blocks_rt: Vec<BlockRuntime>,
    pub sim: SimDevice,
    pub trace: TraceSink,
    /// Time source for bandwidth probes (real by default; the scenario
    /// runner swaps in its virtual clock).
    pub clock: SharedClock,

    // --- pipeline topology ---
    pub worker_list: Vec<DeviceId>,
    pub ranges: Vec<(usize, usize)>,

    // --- stage state ---
    pub params: StageParams,
    pub sgd: Sgd,
    pub stash: VersionStash,
    pub version: u64,
    /// Coordinator restart epoch from `TrainInit`, folded into the high
    /// bits of every outgoing replica version
    /// ([`replication::epoch_version`]) so a backup taken before a
    /// coordinator restart can never shadow a post-restart push.
    pub replica_epoch: u64,
    pub initialized: bool,
    pub status: u8,

    /// 1F1B queues + batch-keyed stashes (labels, activations, timings).
    sched: Schedule,

    pub committed_fwd: i64,
    pub committed_bwd: i64,

    // --- schedules ---
    pub agg_k: u32,
    pub chain_every: u64,
    pub global_every: u64,
    bwd_count: u64,

    // --- profiling report window (rolling, paper §III-D) ---
    exec_window: std::collections::VecDeque<f64>,

    // --- replication store ---
    pub backups: BackupStore,

    repart: Option<Repart>,
    /// outstanding bandwidth probe to the next worker (paper §III-B):
    /// the clock time the probe was sent, plus the probed destination
    /// (reported back to the coordinator so its per-link ladder is keyed
    /// by device, not by boot-time stage index).
    bw_probe: Option<(Duration, DeviceId)>,

    /// Wire-compression policy (cluster-wide, distributed via TrainInit).
    pub compression: Compression,
    /// Default wire tier: the policy's initial tier for static policies,
    /// coordinator-driven via `SetCompression` under
    /// [`Compression::Adaptive`] (DESIGN.md §10) — applied to every
    /// destination without a [`StageWorker::tier_links`] override.
    /// Decoding never depends on it — tensors self-describe their arm.
    pub tier: Tier,
    /// Per-destination tier overrides from the coordinator's per-link
    /// controller: forwards, grads, and replica pushes toward device `d`
    /// encode at `tier_links[d]` (falling back to [`StageWorker::tier`]),
    /// so one degraded link escalates only its own traffic. Replaced
    /// wholesale by every `SetCompression` — stale overrides cannot
    /// linger across topology changes.
    tier_links: BTreeMap<DeviceId, Tier>,
    /// Band the effective tier is clamped into, from `TrainInit`: a
    /// stale or misdirected `SetCompression` can never push a stage
    /// outside the operator's floor/ceiling (DESIGN.md §10).
    tier_floor: Tier,
    tier_ceiling: Tier,
    /// Periodic bandwidth re-measurement cadence (TrainInit; 0 = off).
    bw_probe_every: u64,
    /// Fixed periodic-probe payload (TrainInit; 0 = auto-size from the
    /// last measurement — see [`StageWorker::probe_bytes`]).
    bw_probe_bytes: u64,
    /// Newest bandwidth this stage measured on its next-hop link
    /// (bytes/sec; 0 = never measured). Sizes the next auto probe.
    last_bw_bps: f64,
    /// Error-feedback state for this stage's outgoing gradient edge (to
    /// its previous stage) — only updated when gradients are quantized.
    grad_residual: Residual,
    /// Error-feedback state per (block, tensor) of the Q4 replica-push
    /// stream — bounds the accumulated 4-bit quantization bias across
    /// repeated pushes of slowly-moving weights (DESIGN.md §10).
    push_residuals: BTreeMap<(usize, usize), Residual>,
}

/// Bounds of the auto-sized periodic bandwidth probe (scheduled by
/// `TrainInit::bw_probe_every`). A `bps = payload / rtt` echo is
/// latency-capped at `payload / (2 * latency)`, so a probe must carry
/// several bandwidth-delay products to measure a fast link — but a big
/// probe would drown the degraded link it is trying to measure. The
/// auto size targets [`BW_PROBE_TARGET_S`] of transfer at the *last*
/// measured rate, clamped to these bounds (the one-shot init probe is
/// always the 64 KiB maximum).
pub const BW_PROBE_MIN_BYTES: u64 = 2048;
pub const BW_PROBE_MAX_BYTES: u64 = 65536;
/// Target transfer time of an auto-sized probe (seconds of payload at
/// the last measured bandwidth).
pub const BW_PROBE_TARGET_S: f64 = 0.05;

/// Per-tensor channel hints of one block, derived from the manifest's
/// declared shapes (2-D weights earn per-channel scales) — the single
/// hint source for both the replica-push and restore wire paths.
fn block_hints(manifest: &Manifest, block: usize) -> Vec<ChannelHint> {
    manifest.blocks[block]
        .params
        .iter()
        .map(|p| weight_channel_hint(&p.shape, p.size))
        .collect()
}

impl StageWorker {
    pub fn new(
        device_id: DeviceId,
        manifest: Arc<Manifest>,
        blocks_rt: Vec<BlockRuntime>,
        sim: SimDevice,
        trace: TraceSink,
    ) -> StageWorker {
        StageWorker {
            device_id,
            manifest,
            blocks_rt,
            sim,
            trace,
            clock: real_clock(),
            worker_list: vec![],
            ranges: vec![],
            params: StageParams::default(),
            sgd: Sgd::new(SgdConfig::default()),
            stash: VersionStash::new(4),
            version: 0,
            replica_epoch: 0,
            initialized: false,
            status: 0,
            sched: Schedule::new(),
            committed_fwd: -1,
            committed_bwd: -1,
            agg_k: 0,
            chain_every: 0,
            global_every: 0,
            bwd_count: 0,
            exec_window: std::collections::VecDeque::new(),
            backups: BackupStore::default(),
            repart: None,
            bw_probe: None,
            compression: Compression::Off,
            tier: Tier::Off,
            tier_links: BTreeMap::new(),
            tier_floor: Tier::Off,
            tier_ceiling: Tier::FullQ4,
            bw_probe_every: 0,
            bw_probe_bytes: 0,
            last_bw_bps: 0.0,
            grad_residual: Residual::default(),
            push_residuals: BTreeMap::new(),
        }
    }

    // ------------------------------------------------------------------
    // topology helpers
    // ------------------------------------------------------------------

    pub fn n_stages(&self) -> usize {
        self.worker_list.len()
    }

    pub fn my_stage(&self) -> Option<usize> {
        self.worker_list.iter().position(|&d| d == self.device_id)
    }

    pub fn my_range(&self) -> Option<(usize, usize)> {
        self.my_stage().map(|s| self.ranges[s])
    }

    pub fn is_last_stage(&self) -> bool {
        self.my_stage().map(|s| s + 1 == self.n_stages()).unwrap_or(false)
    }

    fn next_device(&self) -> Option<DeviceId> {
        let s = self.my_stage()?;
        self.worker_list.get(s + 1).copied()
    }

    fn prev_device(&self) -> Option<DeviceId> {
        let s = self.my_stage()?;
        s.checked_sub(1).map(|p| self.worker_list[p])
    }

    fn central_device(&self) -> DeviceId {
        self.worker_list[0]
    }

    fn emit(&self, kind: TraceKind, batch: u64) {
        if let Some(t) = &self.trace {
            t.lock().unwrap().push(TraceEvent {
                device: self.device_id,
                stage: self.my_stage().unwrap_or(usize::MAX),
                kind,
                batch,
                version: self.version,
            });
        }
    }

    // ------------------------------------------------------------------
    // initialization
    // ------------------------------------------------------------------

    /// Apply the training-init state (paper Table I). Loads this stage's
    /// initial weights from the manifest unless we are in fault-recovery
    /// (status = 1), where weights arrive via redistribution instead.
    pub fn apply_init(&mut self, t: &TrainInit) -> Result<()> {
        self.worker_list = t.worker_list.clone();
        self.ranges = t.ranges.clone();
        self.sgd = Sgd::new(SgdConfig {
            lr: t.lr,
            momentum: t.momentum,
            weight_decay: t.weight_decay,
        });
        self.stash = VersionStash::new(self.n_stages().max(2));
        self.version = 0;
        self.replica_epoch = t.replica_epoch;
        self.committed_fwd = t.committed_forward;
        self.committed_bwd = t.committed_backward;
        self.agg_k = t.agg_k;
        self.chain_every = t.chain_every;
        self.global_every = t.global_every;
        self.status = t.status;
        self.compression = t.compression;
        self.tier_floor = t.tier_floor;
        self.tier_ceiling = t.tier_ceiling;
        // the clamp makes a floor effective at init, with no broadcast:
        // every stage (including one re-inited mid-recovery) boots
        // inside the band
        self.tier = t.compression.initial_tier().clamp(t.tier_floor, t.tier_ceiling);
        self.tier_links.clear(); // per-link overrides arrive via SetCompression
        self.bw_probe_every = t.bw_probe_every;
        self.bw_probe_bytes = t.bw_probe_bytes;
        self.grad_residual.clear();
        self.push_residuals.clear();
        if t.status == 0 {
            if let Some((lo, hi)) = self.my_range() {
                self.params = StageParams::load_range(&self.manifest, lo, hi)?;
            }
        }
        self.initialized = true;
        Ok(())
    }

    // ------------------------------------------------------------------
    // compute: forward
    // ------------------------------------------------------------------

    /// Receiver boundary: an incoming payload becomes a host tensor —
    /// f32/i32 arms move their buffers; a quantized activation pays its
    /// single dequantization write here, before entering the schedule.
    fn payload_to_tensor(p: Payload) -> HostTensor {
        match p {
            Payload::F32(v) => HostTensor::F32(v),
            Payload::I32(v) => HostTensor::I32(v),
            Payload::Quant(q) => HostTensor::F32(q.dequantize()),
        }
    }

    /// The effective tier for traffic toward `to`: the per-link override
    /// when the coordinator issued one, the default [`StageWorker::tier`]
    /// otherwise, always clamped into the operator's band.
    pub fn tier_for(&self, to: DeviceId) -> Tier {
        self.tier_links
            .get(&to)
            .copied()
            .unwrap_or(self.tier)
            .clamp(self.tier_floor, self.tier_ceiling)
    }

    /// Sender boundary: an outgoing activation is quantized iff the
    /// destination link's tier compresses the data plane (i32 token
    /// payloads stay raw).
    fn tensor_to_payload(&self, to: DeviceId, t: HostTensor) -> Payload {
        match t {
            HostTensor::F32(v) if self.tier_for(to).data_plane() => {
                Payload::Quant(QTensor::quantize(&v))
            }
            HostTensor::F32(v) => Payload::F32(v),
            HostTensor::I32(v) => Payload::I32(v),
        }
    }

    /// Sender boundary for gradients: quantize with error feedback (the
    /// residual keeps this step's quantization error and folds it into
    /// the next step's gradient), or pass f32 through untouched.
    fn encode_grad(&mut self, to: DeviceId, g: Vec<f32>) -> WireTensor {
        if self.tier_for(to).data_plane() {
            WireTensor::Quant(self.grad_residual.fold(&g))
        } else {
            WireTensor::F32(g.into())
        }
    }

    /// Install a coordinator-issued tier table (`Compression::Adaptive`):
    /// `tier` for every unlisted destination plus per-link overrides,
    /// each clamped into the band. The override map is *replaced*, so a
    /// table from after a topology change cannot leave stale per-device
    /// entries behind. Residuals carry per-encoding error, so any
    /// effective change clears them — stale error from another coding
    /// must not leak into the first sends of the new table (and clearing
    /// keeps replays reproducible).
    pub fn apply_compression(&mut self, tier: Tier, links: &[(DeviceId, Tier)]) {
        let tier = tier.clamp(self.tier_floor, self.tier_ceiling);
        let links: BTreeMap<DeviceId, Tier> = links
            .iter()
            .map(|&(d, t)| (d, t.clamp(self.tier_floor, self.tier_ceiling)))
            .filter(|&(_, t)| t != tier)
            .collect();
        if self.tier == tier && self.tier_links == links {
            return; // no effective change: keep residual state
        }
        self.tier = tier;
        self.tier_links = links;
        self.grad_residual.clear();
        self.push_residuals.clear();
    }

    /// [`StageWorker::apply_compression`] with no per-link overrides —
    /// the single-tier form static policies and tests use.
    pub fn set_tier(&mut self, tier: Tier) {
        self.apply_compression(tier, &[]);
    }

    /// One block's tensors coded for restore traffic (fetch replies /
    /// warm-starts): never coarser than Q8 — the receiver trains on
    /// these bytes.
    fn block_wire(&self, block: usize, bp: &BlockParams, coding: WeightCoding) -> Vec<WireTensor> {
        replication::block_to_wire_coded(bp, &block_hints(&self.manifest, block), coding)
    }

    /// The stage's parameters as replica-push wire blocks at `coding`
    /// (the replica coding of the destination link's tier). The Q4 arm
    /// folds a per-(block, tensor) error-feedback residual, so the 4-bit
    /// bias of repeated pushes of slowly-moving weights stays bounded
    /// instead of locking in (DESIGN.md §10).
    fn replica_wire(&mut self, coding: WeightCoding) -> Vec<WireBlock> {
        let manifest = self.manifest.clone();
        let mut out = Vec::with_capacity(self.params.blocks.len());
        for (&idx, bp) in &self.params.blocks {
            let hints = block_hints(&manifest, idx);
            let tensors = if coding == WeightCoding::Q4 {
                bp.0.iter()
                    .enumerate()
                    .map(|(k, t)| {
                        let hint = hints.get(k).copied().unwrap_or(ChannelHint::PerTensor);
                        let r = self.push_residuals.entry((idx, k)).or_default();
                        WireTensor::Quant(
                            r.fold_with(t, |v| QTensor::quantize_weights(v, hint, Bits::B4)),
                        )
                    })
                    .collect()
            } else {
                replication::block_to_wire_coded(bp, &hints, coding)
            };
            out.push((idx, tensors));
        }
        out
    }

    /// Training forward for one batch through this stage's blocks.
    /// Returns `Some(CompletedBatch)` only in the degenerate 1-stage case.
    pub fn forward_train(
        &mut self,
        t: &dyn Transport,
        batch: u64,
        version0: u64,
        x: HostTensor,
    ) -> Result<Option<CompletedBatch>> {
        let (lo, hi) = self.my_range().context("not in worker list")?;
        let last = self.is_last_stage();

        if !last {
            // stash the weights used for this forward (PipeDream weight
            // stashing; the snapshot shares buffers with the live params)
            self.stash.on_forward(batch, self.version, &self.params);
            let params = self
                .stash
                .snapshot(self.version)
                .unwrap_or(&self.params);
            // activation stash: cloning a HostTensor shares its TensorBuf
            let mut inputs: Vec<HostTensor> = Vec::with_capacity(hi - lo + 1);
            let mut cur = x;
            let flops = self.range_flops(lo, hi, true, false);
            let blocks_rt = &self.blocks_rt;
            let (out, ms) = {
                let mut run = || -> Result<HostTensor> {
                    for idx in lo..=hi {
                        inputs.push(cur.clone());
                        let p = params.get(idx).context("missing block params")?;
                        let y = blocks_rt[idx].forward(&p.0, &cur)?;
                        cur = HostTensor::F32(y.into());
                    }
                    Ok(cur.clone())
                };
                let (res, dur) = self.sim.execute_flops(flops, &mut run);
                (res?, dur.as_secs_f64() * 1e3)
            };
            self.sched.stash_acts(batch, inputs);
            self.committed_fwd = self.committed_fwd.max(batch as i64);
            self.sched.stash_fwd_ms(batch, ms); // merged at backward time
            self.emit(TraceKind::Forward, batch);
            let next = self.next_device().context("no next stage")?;
            t.send(
                next,
                Message::Forward {
                    batch,
                    version0,
                    is_eval: false,
                    data: self.tensor_to_payload(next, out),
                },
            )?;
            return Ok(None);
        }

        // ---- last stage: fused forward + loss + backward (1F1B) ----
        let labels = self
            .sched
            .take_labels(batch, false)
            .context("labels not available for last-stage forward")?;
        let label_t = HostTensor::I32(labels);
        let head_idx = self.manifest.n_blocks() - 1;
        debug_assert_eq!(hi, head_idx);

        let params = &self.params;
        let label_shape = &self.manifest.label_shape;
        struct LastOut {
            grads: BTreeMap<usize, Vec<Vec<f32>>>,
            gx_out: Option<Vec<f32>>,
            loss: f32,
            ncorrect: f32,
        }
        let flops = self.range_flops(lo, hi, true, true);
        let blocks_rt = &self.blocks_rt;
        let (out, ms) = {
            let mut run = || -> Result<LastOut> {
                // forward through my non-head blocks, saving inputs
                let mut inputs: Vec<HostTensor> = Vec::with_capacity(hi - lo + 1);
                let mut cur = x.clone();
                for idx in lo..hi {
                    inputs.push(cur.clone());
                    let p = params.get(idx).context("missing block params")?;
                    let y = blocks_rt[idx].forward(&p.0, &cur)?;
                    cur = HostTensor::F32(y.into());
                }
                // fused head step
                let hp = params.get(head_idx).context("missing head params")?;
                let hs =
                    blocks_rt[head_idx].head_step(&hp.0, cur.as_f32()?, &label_t, label_shape)?;
                let mut grads: BTreeMap<usize, Vec<Vec<f32>>> = BTreeMap::new();
                grads.insert(head_idx, hs.grad_params);
                // backward through my remaining blocks with the SAME weights
                let mut gy = hs.grad_input;
                let mut have_gx = true;
                for idx in (lo..hi).rev() {
                    let p = params.get(idx).unwrap();
                    let xin = &inputs[idx - lo];
                    let (g, gx) = blocks_rt[idx].backward(&p.0, xin, &gy)?;
                    grads.insert(idx, g);
                    match gx {
                        Some(g2) => {
                            gy = g2;
                            have_gx = true;
                        }
                        None => have_gx = false,
                    }
                }
                let gx_out = (have_gx && lo != 0).then_some(gy); // block 0 has no input grad
                Ok(LastOut { grads, gx_out, loss: hs.loss, ncorrect: hs.ncorrect })
            };
            let (res, dur) = self.sim.execute_flops(flops, &mut run);
            (res?, dur.as_secs_f64() * 1e3)
        };

        // apply updates
        self.sgd.step(&mut self.params, &out.grads);
        self.version += 1;
        self.bwd_count += 1;
        self.committed_fwd = self.committed_fwd.max(batch as i64);
        self.committed_bwd = self.committed_bwd.max(batch as i64);
        self.record_exec(ms);
        self.emit(TraceKind::Forward, batch);
        self.emit(TraceKind::Backward, batch);

        let report = self.current_report();
        self.maybe_replicate(t, batch)?;

        if let Some(prev) = self.prev_device() {
            let grad = self.encode_grad(prev, out.gx_out.unwrap_or_default());
            t.send(
                prev,
                Message::Backward {
                    batch,
                    grad,
                    loss: out.loss,
                    ncorrect: out.ncorrect,
                    reports: vec![report],
                },
            )?;
            Ok(None)
        } else {
            // single-stage pipeline: completion happens here
            Ok(Some(CompletedBatch {
                batch,
                loss: out.loss,
                ncorrect: out.ncorrect,
                reports: vec![report],
            }))
        }
    }

    /// Evaluation forward (no stashing / no state): last stage computes
    /// loss + accuracy and reports to the central node.
    pub fn forward_eval(
        &mut self,
        t: &dyn Transport,
        batch: u64,
        x: HostTensor,
    ) -> Result<Option<(f32, f32)>> {
        let (lo, hi) = self.my_range().context("not in worker list")?;
        let last = self.is_last_stage();
        let head_idx = self.manifest.n_blocks() - 1;
        let end = if last { hi - 1 } else { hi };

        let mut cur = x;
        for idx in lo..=end {
            if last && idx == head_idx {
                break;
            }
            let p = self.params.get(idx).context("missing block params")?;
            let y = self.blocks_rt[idx].forward(&p.0, &cur)?;
            cur = HostTensor::F32(y.into());
        }
        if !last {
            let next = self.next_device().context("no next stage")?;
            t.send(
                next,
                Message::Forward {
                    batch,
                    version0: 0,
                    is_eval: true,
                    data: self.tensor_to_payload(next, cur),
                },
            )?;
            return Ok(None);
        }
        let labels = self
            .sched
            .take_labels(batch, true)
            .context("labels not available for eval")?;
        let hp = self.params.get(head_idx).context("missing head params")?;
        let (loss, nc) = self.blocks_rt[head_idx].head_eval(
            &hp.0,
            cur.as_f32()?,
            &HostTensor::I32(labels),
            &self.manifest.label_shape,
        )?;
        if self.my_stage() == Some(0) {
            Ok(Some((loss, nc)))
        } else {
            t.send(self.central_device(), Message::EvalResult { batch, loss, ncorrect: nc })?;
            Ok(None)
        }
    }

    // ------------------------------------------------------------------
    // compute: backward (non-last stages)
    // ------------------------------------------------------------------

    /// Backward for one batch. At stage 0 returns the completed batch.
    pub fn backward(
        &mut self,
        t: &dyn Transport,
        batch: u64,
        gy_in: TensorBuf,
        loss: f32,
        ncorrect: f32,
        mut reports: Vec<ExecReport>,
    ) -> Result<Option<CompletedBatch>> {
        let (lo, hi) = self.my_range().context("not in worker list")?;
        let stage = self.my_stage().unwrap();

        // weight stashing: backward runs against the forward-time weights
        let stashed = self
            .stash
            .params_for_backward(batch)
            .unwrap_or(&self.params);
        let inputs = self
            .sched
            .take_acts(batch)
            .with_context(|| format!("no saved activations for batch {batch}"))?;

        let flops = self.range_flops(lo, hi, false, true);
        let blocks_rt = &self.blocks_rt;
        struct BwdOut {
            grads: BTreeMap<usize, Vec<Vec<f32>>>,
            gx_out: Option<Vec<f32>>,
        }
        let (out, ms) = {
            let mut run = || -> Result<BwdOut> {
                let mut grads = BTreeMap::new();
                // `cur` owns the newest grad-input; the incoming gradient
                // is read straight from the shared buffer (no copy)
                let mut cur: Option<Vec<f32>> = None;
                let mut have_gx = true;
                for idx in (lo..=hi).rev() {
                    let gy: &[f32] = cur.as_deref().unwrap_or(&gy_in);
                    let p = stashed.get(idx).context("stash missing block")?;
                    let xin = &inputs[idx - lo];
                    let (g, gx) = blocks_rt[idx].backward(&p.0, xin, gy)?;
                    grads.insert(idx, g);
                    match gx {
                        Some(g2) => {
                            cur = Some(g2);
                            have_gx = true;
                        }
                        None => have_gx = false,
                    }
                }
                let gx_out = if have_gx { cur } else { None };
                Ok(BwdOut { grads, gx_out })
            };
            let (res, dur) = self.sim.execute_flops(flops, &mut run);
            (res?, dur.as_secs_f64() * 1e3)
        };

        // gradients apply to the CURRENT weights (PipeDream async rule)
        self.sgd.step(&mut self.params, &out.grads);
        self.version += 1;
        self.bwd_count += 1;
        self.stash.on_backward_done(batch);
        self.committed_bwd = self.committed_bwd.max(batch as i64);
        let fwd_part = self.sched.take_fwd_ms(batch);
        self.record_exec(fwd_part + ms);
        self.emit(TraceKind::Backward, batch);

        self.maybe_aggregate();
        // probe before the replica push so the echo times the bare link,
        // not the push it would otherwise queue behind
        self.maybe_measure_bw(t, batch)?;
        self.maybe_replicate(t, batch)?;

        if stage == 0 {
            return Ok(Some(CompletedBatch { batch, loss, ncorrect, reports }));
        }
        reports.push(self.current_report());
        let prev = self.prev_device().unwrap();
        let grad = self.encode_grad(prev, out.gx_out.unwrap_or_default());
        t.send(prev, Message::Backward { batch, grad, loss, ncorrect, reports })?;
        Ok(None)
    }

    /// Weight aggregation (paper §III-C): stage `i` of `n` averages its
    /// `n - i` concurrently-live weight versions every `agg_k * (n - i)`
    /// backward steps.
    fn maybe_aggregate(&mut self) {
        if self.agg_k == 0 {
            return;
        }
        let stage = match self.my_stage() {
            Some(s) => s,
            None => return,
        };
        let m = self.n_stages().saturating_sub(stage);
        if m < 2 {
            return; // last stage has a single live version
        }
        let interval = self.agg_k as u64 * m as u64;
        if self.bwd_count == 0 || self.bwd_count % interval != 0 {
            return;
        }
        let versions = self.stash.recent_versions(m);
        let mut snaps: Vec<&StageParams> = versions
            .iter()
            .filter_map(|v| self.stash.snapshot(*v))
            .collect();
        let current = self.params.clone(); // shares buffers
        snaps.push(&current);
        if snaps.len() < 2 {
            return;
        }
        if let Some(avg) = aggregate_versions(&snaps) {
            self.params = avg;
            self.version += 1;
            self.emit(TraceKind::Aggregate, self.bwd_count);
        }
    }

    /// Chain/global replication triggers after `batch`'s backward. The
    /// replica payload shares the stage's weight buffers (zero-copy).
    fn maybe_replicate(&mut self, t: &dyn Transport, batch: u64) -> Result<()> {
        let stage = match self.my_stage() {
            Some(s) => s,
            None => return Ok(()),
        };
        if stage == 0 {
            return Ok(()); // the central node persists locally (paper §III-E)
        }
        let chain_due = replication::due(batch, self.nonzero(self.chain_every));
        let global_due = replication::due(batch, self.nonzero(self.global_every));
        if !chain_due && !global_due {
            return Ok(());
        }
        // each push encodes at its own destination link's tier; when both
        // targets share a coding the blocks are encoded once and the
        // sends share bytes (the pre-per-link behavior — and the Q4
        // error-feedback residual must fold exactly once per round, which
        // holds either way since distinct codings mean at most one is Q4)
        let chain_info = chain_due.then(|| {
            let target = self.worker_list[replication::chain_target(stage, self.n_stages())];
            (target, self.tier_for(target).replica_coding())
        });
        let global_info = global_due.then(|| {
            let central = self.central_device();
            (central, self.tier_for(central).replica_coding())
        });
        let chain_wire = chain_info.map(|(_, c)| self.replica_wire(c));
        let global_wire = match (chain_info, global_info, &chain_wire) {
            (Some((_, cc)), Some((_, gc)), Some(w)) if cc == gc => Some(w.clone()),
            (_, Some((_, gc)), _) => Some(self.replica_wire(gc)),
            _ => None,
        };
        if let (Some((target, _)), Some(wire)) = (chain_info, chain_wire) {
            t.send(
                target,
                Message::ReplicaPush {
                    kind: ReplicaKind::Chain,
                    owner_stage: stage,
                    owner_device: self.device_id,
                    version: replication::epoch_version(self.replica_epoch, self.version),
                    blocks: wire,
                },
            )?;
        }
        if let (Some((central, _)), Some(wire)) = (global_info, global_wire) {
            t.send(
                central,
                Message::ReplicaPush {
                    kind: ReplicaKind::Global,
                    owner_stage: stage,
                    owner_device: self.device_id,
                    version: replication::epoch_version(self.replica_epoch, self.version),
                    blocks: wire,
                },
            )?;
        }
        Ok(())
    }

    fn nonzero(&self, v: u64) -> Option<u64> {
        (v > 0).then_some(v)
    }

    // ------------------------------------------------------------------
    // execution-time reporting (paper §III-D "execution profiling")
    // ------------------------------------------------------------------

    fn record_exec(&mut self, ms: f64) {
        self.exec_window.push_back(ms);
        while self.exec_window.len() > 8 {
            self.exec_window.pop_front();
        }
    }

    /// Rolling average of this stage's per-batch execution time (ms).
    pub fn avg_exec_ms(&self) -> Option<f64> {
        (!self.exec_window.is_empty())
            .then(|| self.exec_window.iter().sum::<f64>() / self.exec_window.len() as f64)
    }

    fn current_report(&self) -> ExecReport {
        let n = self.exec_window.len().max(1);
        let avg = self.exec_window.iter().sum::<f64>() / n as f64;
        ExecReport { device: self.device_id, avg_ms: avg, batches: n as u32 }
    }

    /// Manifest flop count of blocks [lo, hi] for the selected passes —
    /// the cost charged by a modeled [`SimDevice`].
    fn range_flops(&self, lo: usize, hi: usize, fwd: bool, bwd: bool) -> u64 {
        self.manifest.blocks[lo..=hi]
            .iter()
            .map(|b| {
                (if fwd { b.flops_fwd } else { 0 }) + (if bwd { b.flops_bwd } else { 0 })
            })
            .sum()
    }

    /// Swap the time source (the scenario runner installs its virtual
    /// clock right after construction).
    pub fn set_clock(&mut self, clock: SharedClock) {
        self.clock = clock;
    }

    // ------------------------------------------------------------------
    // the event loop
    // ------------------------------------------------------------------

    /// Preview the step [`Self::pump`] would run, honoring the same
    /// gates (initialized, not in recovery, part of the pipeline).
    pub fn next_step_kind(&self) -> Option<StepKind> {
        if !self.initialized || self.status == 1 || self.my_stage().is_none() {
            return None;
        }
        self.sched.peek_kind(self.is_last_stage())
    }

    /// The flop cost a step of `kind` will charge on this stage (the
    /// last stage's training forward is the fused fwd+loss+bwd step).
    pub fn step_flops(&self, kind: &StepKind) -> u64 {
        let Some((lo, hi)) = self.my_range() else { return 0 };
        match kind {
            StepKind::Backward { .. } => self.range_flops(lo, hi, false, true),
            StepKind::Forward { is_eval, .. } => {
                let fused = self.is_last_stage() && !is_eval;
                self.range_flops(lo, hi, true, fused)
            }
        }
    }

    /// Run at most one compute step (backward preferred — 1F1B).
    pub fn pump(&mut self, t: &dyn Transport) -> Result<bool> {
        Ok(self.pump_completed(t)?.0)
    }

    /// [`Self::pump`], surfacing the completed batch when this stage is
    /// the pipeline head (stage 0) — the deterministic runner drives the
    /// central node's stage through this instead of a bespoke path.
    pub fn pump_completed(
        &mut self,
        t: &dyn Transport,
    ) -> Result<(bool, Option<CompletedBatch>)> {
        if !self.initialized || self.status == 1 || self.my_stage().is_none() {
            return Ok((false, None));
        }
        match self.sched.next_step(self.is_last_stage()) {
            Some(Step::Backward(b)) => {
                let cb = self.backward(t, b.batch, b.grad, b.loss, b.ncorrect, b.reports)?;
                Ok((true, cb))
            }
            Some(Step::Forward(f)) => {
                if f.is_eval {
                    self.forward_eval(t, f.batch, f.data)?;
                    Ok((true, None))
                } else {
                    let cb = self.forward_train(t, f.batch, f.version0, f.data)?;
                    Ok((true, cb))
                }
            }
            None => Ok((false, None)),
        }
    }

    pub fn queued(&self) -> (usize, usize) {
        self.sched.queued()
    }

    /// Handle one raw message: classify, then dispatch (kept as the
    /// boundary API so transports and tests stay message-oriented).
    pub fn handle_message(
        &mut self,
        t: &dyn Transport,
        from: DeviceId,
        msg: Message,
    ) -> Result<Flow> {
        self.on_event(t, Event::from_message(from, msg))
    }

    /// Dispatch one classified event.
    pub fn on_event(&mut self, t: &dyn Transport, ev: Event) -> Result<Flow> {
        match ev {
            Event::Data(d) => self.on_data(d)?,
            Event::Control(c) => self.on_control(t, c)?,
            Event::Shutdown => return Ok(Flow::Shutdown),
        }
        Ok(Flow::Continue)
    }

    /// Data plane: enqueue only — compute happens in [`Self::pump`].
    fn on_data(&mut self, ev: DataEvent) -> Result<()> {
        match ev {
            DataEvent::Forward { batch, version0, is_eval, data } => {
                if self.status == 0 || is_eval {
                    self.sched.push_forward(PendingForward {
                        batch,
                        version0,
                        is_eval,
                        data: Self::payload_to_tensor(data),
                    });
                }
            }
            DataEvent::Labels { batch, is_eval, data } => {
                self.sched.put_labels(batch, is_eval, data);
            }
            DataEvent::Backward { batch, grad, loss, ncorrect, reports } => {
                if self.status == 0 {
                    self.sched.push_backward(PendingBackward {
                        batch,
                        grad,
                        loss,
                        ncorrect,
                        reports,
                    });
                }
            }
            // coordinator-only; a worker may legitimately see it late
            DataEvent::EvalResult { .. } => {}
        }
        Ok(())
    }

    /// Control plane: init, probing, redistribution, replication, resets.
    fn on_control(&mut self, t: &dyn Transport, ev: ControlEvent) -> Result<()> {
        match ev {
            ControlEvent::Probe { from } => {
                t.send(from, Message::ProbeAck { id: self.device_id, fresh: !self.initialized })?;
            }
            ControlEvent::Init(ti) => {
                self.apply_init(&ti)?;
                self.measure_bandwidth(t)?;
            }
            ControlEvent::Repartition { ranges, worker_list, failed } => {
                self.begin_repartition(t, ranges, worker_list, failed)?;
            }
            ControlEvent::FetchWeights { from, blocks } => {
                self.serve_fetch(t, from, &blocks)?;
            }
            ControlEvent::Weights { from, blocks } => {
                self.handle_weights(t, from, blocks)?;
            }
            ControlEvent::ReplicaPush { kind, owner_stage, owner_device, version, blocks } => {
                self.backups.store(
                    owner_device,
                    kind,
                    owner_stage,
                    version,
                    replication::from_wire(&blocks),
                );
            }
            ControlEvent::Commit => {
                self.apply_commit()?;
            }
            ControlEvent::Reset { committed } => {
                self.apply_reset(committed);
            }
            ControlEvent::BwTest { from, payload_bytes } => {
                t.send(from, Message::BwAck { payload_bytes })?;
            }
            ControlEvent::BwAck { payload_bytes } => {
                if let (Some((t0, to)), Some(stage)) = (self.bw_probe.take(), self.my_stage()) {
                    let dt = self.clock.now().saturating_sub(t0).as_secs_f64().max(1e-6);
                    let bps = payload_bytes as f64 / dt;
                    self.last_bw_bps = bps; // sizes the next auto probe
                    t.send(self.central_device(), Message::BwReport { stage, bps, to })?;
                }
            }
            ControlEvent::SetLr { lr } => {
                self.sgd.set_lr(lr);
            }
            ControlEvent::SetCompression { tier, links } => {
                self.apply_compression(tier, &links);
            }
            ControlEvent::CentralRestart { from, committed } => {
                // The coordinator rebooted from its checkpoint. Anything
                // only the old coordinator could complete is dead weight:
                // an in-flight redistribution will never see its Commit,
                // and stored replica versions are no longer comparable
                // with the version numbering the restarted cluster will
                // use. Work past the checkpoint's committed batch is
                // uncommitted by definition — drop it now so the
                // coordinator reconciles against a quiesced stage.
                self.repart = None;
                self.backups = BackupStore::default();
                if self.initialized {
                    self.status = 1;
                    self.sched.reset(committed);
                    self.stash.discard_after(committed);
                }
                t.send(from, Message::WorkerState {
                    id: self.device_id,
                    committed_fwd: self.committed_fwd,
                    committed_bwd: self.committed_bwd,
                    fresh: !self.initialized,
                })?;
            }
            // coordinator-only events a worker may legitimately see late:
            ControlEvent::ProbeAck { .. }
            | ControlEvent::FetchDone { .. }
            | ControlEvent::BwReport { .. }
            | ControlEvent::WorkerState { .. } => {}
        }
        Ok(())
    }

    /// Reset the training state (paper §III-F last phase): discard every
    /// batch beyond `committed` and return to normal status.
    pub fn apply_reset(&mut self, committed: i64) {
        self.committed_fwd = committed;
        self.committed_bwd = committed;
        self.sched.reset(committed);
        self.stash.discard_after(committed);
        // replayed batches re-quantize from a clean slate, so a reset is
        // reproducible independent of what was in flight before it
        self.grad_residual.clear();
        self.push_residuals.clear();
        // per-link overrides may name devices the recovery just removed;
        // drop them — the coordinator rebroadcasts its pruned table right
        // after recovery whenever any link is still escalated
        self.tier_links.clear();
        self.bw_probe = None; // an in-flight probe's ack may never come
        self.status = 0;
    }

    // ------------------------------------------------------------------
    // re-partition / redistribution protocol (paper §III-D + Algorithm 1)
    // ------------------------------------------------------------------

    /// Start a re-partition: plan with Algorithm 1, stage local/backup
    /// blocks immediately, issue FetchWeights for the rest.
    pub fn begin_repartition(
        &mut self,
        t: &dyn Transport,
        ranges: Vec<(usize, usize)>,
        worker_list: Vec<DeviceId>,
        failed: Vec<usize>,
    ) -> Result<()> {
        self.status = 1;
        let i_new = match worker_list.iter().position(|&d| d == self.device_id) {
            Some(i) => i,
            None => {
                // not part of the new pipeline (shouldn't happen for alive
                // devices) — just accept and idle
                self.repart = None;
                return Ok(());
            }
        };
        let i_cur_old = self.my_stage();
        let held = self.params.block_indices();
        let p_cur = if self.ranges.is_empty() { ranges.clone() } else { self.ranges.clone() };
        let plan: RedistPlan =
            plan_redistribution(&ranges, &p_cur, &failed, &held, i_new, i_cur_old);

        let mut rp = Repart::new(ranges, worker_list);
        for (src, blocks) in &plan.need {
            match src {
                Source::LocalBackup => {
                    for &b in blocks {
                        match self.backups.find_block(b) {
                            Some(bp) => rp.stage(b, bp.clone()),
                            // replica never arrived: escalate to central
                            None => rp.mark_needed(b, true),
                        }
                    }
                }
                Source::CentralBackup => {
                    for &b in blocks {
                        rp.mark_needed(b, true);
                    }
                }
                Source::Stage(s) => {
                    let dev = rp.worker_list[*s];
                    for &b in blocks {
                        rp.mark_needed(b, false);
                    }
                    rp.mark_requested(dev, blocks.iter().copied());
                }
            }
        }

        // fire the fetches (one message per device, matching the one
        // request window mark_requested opened for it)
        let central = rp.central();
        for (dev, o) in rp.outstanding.clone() {
            t.send(dev, Message::FetchWeights { blocks: o.asked })?;
        }
        let escalated: Vec<usize> = rp.escalated.iter().copied().collect();
        if !escalated.is_empty() && self.device_id != central {
            rp.mark_requested(central, escalated.iter().copied());
            t.send(central, Message::FetchWeights { blocks: escalated })?;
        } else if !escalated.is_empty() {
            // I AM the central node: serve from my own global backups; a
            // block no backup ever covered falls back to its initial
            // weights (a fresh sub-model is better than a dead pipeline —
            // the paper assumes replication already ran at least once).
            for b in escalated {
                let bp = match self.backups.find_block(b) {
                    Some(bp) => bp.clone(),
                    None => {
                        crate::log_warn!(
                            "block {b}: no replica anywhere; restoring initial weights"
                        );
                        BlockParams::from_vecs(self.manifest.load_init_params(b)?)
                    }
                };
                rp.stage(b, bp);
            }
        }

        let done = rp.is_complete();
        self.repart = Some(rp);
        if done {
            self.fetch_complete(t)?;
        }
        Ok(())
    }

    /// Serve a FetchWeights request from current params, then backups —
    /// shared f32 buffers (no weight copies), or quantized payloads at
    /// the tier's *restore* coding (at most Q8 — never the Q4 replica
    /// coding: the requester trains on these bytes).
    pub fn serve_fetch(&self, t: &dyn Transport, from: DeviceId, blocks: &[usize]) -> Result<()> {
        let coding = self.tier_for(from).restore_coding();
        let mut found: Vec<WireBlock> = Vec::new();
        for &b in blocks {
            if let Some(bp) = self.params.get(b) {
                found.push((b, self.block_wire(b, bp, coding)));
            } else if let Some(bp) = self.backups.find_block(b) {
                found.push((b, self.block_wire(b, bp, coding)));
            }
        }
        t.send(from, Message::Weights { blocks: found })?;
        Ok(())
    }

    /// Measure bandwidth to the next worker by timing a 64 KiB echo
    /// (paper §III-B; the analogue of its ping3 measurement).
    pub fn measure_bandwidth(&mut self, t: &dyn Transport) -> Result<()> {
        self.measure_bandwidth_sized(t, 65536)
    }

    /// [`StageWorker::measure_bandwidth`] with a caller-chosen payload —
    /// the periodic re-probes pick theirs via `probe_bytes` (fixed or
    /// auto-sized) so a degraded link is not drowned by its own
    /// measurement while a fast link still clears its latency floor.
    pub fn measure_bandwidth_sized(&mut self, t: &dyn Transport, bytes: usize) -> Result<()> {
        if let Some(next) = self.next_device() {
            self.bw_probe = Some((self.clock.now(), next));
            t.send(next, Message::BwTest {
                payload_bytes: bytes as u32,
                data: vec![0u8; bytes],
            })?;
        }
        Ok(())
    }

    /// Payload of the next periodic probe: the configured fixed size,
    /// or — when 0 — auto-sized to [`BW_PROBE_TARGET_S`] of transfer at
    /// the last measured rate (clamped), so a fast link is measured
    /// above its latency floor while a degraded link is not drowned by
    /// its own measurement. Deterministic: a pure function of the last
    /// deterministic measurement.
    fn probe_bytes(&self) -> usize {
        if self.bw_probe_bytes > 0 {
            return self.bw_probe_bytes as usize;
        }
        if self.last_bw_bps <= 0.0 {
            return BW_PROBE_MAX_BYTES as usize; // nothing measured yet
        }
        ((self.last_bw_bps * BW_PROBE_TARGET_S) as u64)
            .clamp(BW_PROBE_MIN_BYTES, BW_PROBE_MAX_BYTES) as usize
    }

    /// The periodic re-measurement schedule (`bw_probe_every`, paper
    /// §III-B made periodic): fires after the backward of every N-th
    /// batch on stages that have a next link, unless a probe is still
    /// in flight. Feeds the coordinator's adaptive compression policy.
    fn maybe_measure_bw(&mut self, t: &dyn Transport, batch: u64) -> Result<()> {
        if self.bw_probe_every == 0 || (batch + 1) % self.bw_probe_every != 0 {
            return Ok(());
        }
        if self.bw_probe.is_some() {
            return Ok(()); // previous probe unanswered: don't stack echoes
        }
        let bytes = self.probe_bytes();
        self.measure_bandwidth_sized(t, bytes)
    }

    /// Integrate a Weights reply; escalate still-missing blocks to central.
    ///
    /// Outside a re-partition, a Weights push overwrites the local params
    /// directly — this is how pre-trained weights reach workers in the
    /// paper's continuous-training mode (Table I).
    pub fn handle_weights(
        &mut self,
        t: &dyn Transport,
        from: DeviceId,
        blocks: Vec<WireBlock>,
    ) -> Result<()> {
        let Some(mut rp) = self.repart.take() else {
            // Accept a pushed block if it is inside my current range —
            // overwriting a held block (continuous training) or filling
            // a missing one (a checkpoint warm-start after the pushing
            // coordinator rebooted reaches a stage that lost its state).
            // Blocks outside my range are someone else's; ignore them.
            let range = self.my_range();
            for (idx, tensors) in blocks {
                let mine = range.is_some_and(|(lo, hi)| idx >= lo && idx <= hi);
                if mine || self.params.get(idx).is_some() {
                    self.params.blocks.insert(idx, replication::block_from_wire(tensors));
                }
            }
            return Ok(());
        };
        // blocks we asked `from` for but didn't get:
        //  * from a peer -> escalate to the central node's global backup
        //  * from central itself -> nothing anywhere: fall back to the
        //    initial weights so recovery always terminates
        let unserved = rp.record_reply(from, blocks);
        let central = rp.central();
        if !unserved.is_empty() {
            if from == central {
                for b in unserved {
                    crate::log_warn!(
                        "block {b}: central has no replica; restoring initial weights"
                    );
                    let bp = BlockParams::from_vecs(self.manifest.load_init_params(b)?);
                    rp.stage(b, bp);
                }
            } else {
                let missing: Vec<usize> =
                    unserved.into_iter().filter(|b| !rp.escalated.contains(b)).collect();
                if !missing.is_empty() {
                    for &b in &missing {
                        rp.escalated.insert(b);
                    }
                    rp.mark_requested(central, missing.iter().copied());
                    t.send(central, Message::FetchWeights { blocks: missing })?;
                }
            }
        }
        let done = rp.is_complete();
        self.repart = Some(rp);
        if done {
            self.fetch_complete(t)?;
        }
        Ok(())
    }

    fn fetch_complete(&mut self, t: &dyn Transport) -> Result<()> {
        let central = self.repart.as_ref().unwrap().central();
        if self.device_id == central {
            // the coordinator tracks its own completion directly
            return Ok(());
        }
        t.send(central, Message::FetchDone { id: self.device_id })?;
        Ok(())
    }

    /// Has this device staged everything it needs (pre-Commit)?
    pub fn fetch_done(&self) -> bool {
        self.repart.as_ref().map(|r| r.is_complete()).unwrap_or(true)
    }

    /// Commit: swap to the new sub-model (paper's commit message — only
    /// now may the old sub-model be dropped).
    pub fn apply_commit(&mut self) -> Result<()> {
        let Some(rp) = self.repart.take() else {
            self.status = 0;
            return Ok(());
        };
        if !rp.is_complete() {
            bail!(
                "device {}: commit before fetch completion ({} missing)",
                self.device_id,
                rp.needed.len()
            );
        }
        let i_new = rp.worker_list.iter().position(|&d| d == self.device_id);
        self.worker_list = rp.worker_list;
        self.ranges = rp.ranges;
        if let Some(i) = i_new {
            let (lo, hi) = self.ranges[i];
            self.params.retain_range(lo, hi);
            for (idx, bp) in rp.staged {
                if idx >= lo && idx <= hi {
                    self.params.blocks.insert(idx, bp);
                }
            }
            self.sgd.retain_blocks(&self.params.block_indices());
        } else {
            self.params = StageParams::default();
        }
        self.stash = VersionStash::new(self.n_stages().max(2));
        self.sched.on_commit();
        // the stage's input shape (and thus its gradient edge) may have
        // changed with the new range — stale quantization error must not
        // leak into the first gradients (or replica pushes) of the new
        // partition
        self.grad_residual.clear();
        self.push_residuals.clear();
        self.status = 0;
        self.initialized = true;
        Ok(())
    }

    /// Snapshot everything this (central) stage can see into a §III-E
    /// checkpoint: its own parameters plus the newest replicas in its
    /// backup store, with manifest-derived shapes. Completeness of the
    /// other stages depends on the replication period — exactly the
    /// paper's checkpoint tradeoff. Shared by the threaded coordinator
    /// and the deterministic scenario runner so the harness provably
    /// checkpoints the same bytes the real driver would.
    pub fn snapshot_checkpoint(&self, committed: i64, epoch: u64) -> crate::checkpoint::Checkpoint {
        use crate::checkpoint::{Checkpoint, CheckpointState};
        let mut weights: BTreeMap<usize, BlockParams> = BTreeMap::new();
        for (&b, bp) in &self.params.blocks {
            weights.insert(b, bp.clone());
        }
        for b in 0..self.manifest.n_blocks() {
            if weights.contains_key(&b) {
                continue;
            }
            if let Some(bp) = self.backups.find_block(b) {
                weights.insert(b, bp.clone());
            }
        }
        let mut shapes: BTreeMap<usize, Vec<Vec<usize>>> = BTreeMap::new();
        for &b in weights.keys() {
            shapes.insert(
                b,
                self.manifest.blocks[b].params.iter().map(|p| p.shape.clone()).collect(),
            );
        }
        Checkpoint {
            state: CheckpointState {
                committed_batch: committed,
                epoch,
                lr: self.sgd.cfg.lr,
                ranges: self.ranges.clone(),
                worker_list: self.worker_list.clone(),
                shapes,
            },
            weights,
        }
    }

    /// Simulate a crash-restart: all in-memory state is lost (the process
    /// came back up but knows nothing — paper §III-F case 2).
    pub fn wipe_state(&mut self) {
        self.params = StageParams::default();
        self.sgd = Sgd::new(self.sgd.cfg);
        self.stash = VersionStash::new(2);
        self.version = 0;
        self.replica_epoch = 0;
        self.initialized = false;
        self.status = 0;
        self.sched.clear();
        self.committed_fwd = -1;
        self.committed_bwd = -1;
        self.bwd_count = 0;
        self.exec_window.clear();
        self.backups = BackupStore::default();
        self.repart = None;
        self.bw_probe = None;
        self.compression = Compression::Off;
        self.tier = Tier::Off;
        self.tier_links.clear();
        self.tier_floor = Tier::Off;
        self.tier_ceiling = Tier::FullQ4;
        self.bw_probe_every = 0;
        self.bw_probe_bytes = 0;
        self.last_bw_bps = 0.0;
        self.grad_residual.clear();
        self.push_residuals.clear();
    }

    /// State bytes currently held (memory accounting for the device cap).
    pub fn memory_bytes(&self) -> u64 {
        (self.params.byte_len() + self.backups.byte_len() + self.sched.acts_bytes()) as u64
    }
}

/// The worker-device main loop (stages >= 1). The central node drives its
/// own loop in [`crate::coordinator`].
///
/// The loop is the standard event-pump shape: classify + handle every
/// queued message, then run at most one compute step, repeat.
///
/// `kill_watch` (sim mode): when the fault injector marks this device
/// dead, the loop wipes all in-memory state — when (if) the device is
/// revived it behaves exactly like a freshly-restarted process (paper
/// case 2: probes back `fresh`, weights restored from its chain replica).
pub fn run_worker(
    mut w: StageWorker,
    endpoint: Box<dyn Transport>,
    kill_watch: Option<crate::net::sim::SimNet>,
) -> Result<()> {
    let mut was_dead = false;
    loop {
        if let Some(net) = &kill_watch {
            if net.is_dead(w.device_id) {
                if !was_dead {
                    w.wipe_state();
                    was_dead = true;
                }
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            was_dead = false;
        }
        // wait briefly for a message, then drain whatever else queued up
        if let Some((from, msg)) = endpoint.recv_timeout(Duration::from_millis(2)) {
            if w.handle_message(&*endpoint, from, msg)? == Flow::Shutdown {
                return Ok(());
            }
            while let Some((from, msg)) = endpoint.recv_timeout(Duration::ZERO) {
                if w.handle_message(&*endpoint, from, msg)? == Flow::Shutdown {
                    return Ok(());
                }
            }
        }
        w.pump(&*endpoint)?;
    }
}
