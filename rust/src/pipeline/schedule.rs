//! 1F1B queueing and per-batch stash policy (paper §III-C).
//!
//! The [`Schedule`] owns everything keyed by batch id on a stage: the
//! pending forward/backward queues, the (train and eval) label stores the
//! last stage matches forwards against, the activation stash the backward
//! pass replays, and the forward-time samples merged into fwd+bwd
//! execution reports. Policy lives in [`Schedule::next_step`]: a pending
//! backward always preempts a pending forward (PipeDream's 1F1B rule),
//! and the last stage only starts a forward whose labels have arrived.
//!
//! Queued tensors are `TensorBuf`-backed, so holding a batch in a queue
//! or in the activation stash shares buffers instead of copying them.

use std::collections::{HashMap, VecDeque};

use crate::net::message::ExecReport;
use crate::net::TensorBuf;
use crate::runtime::HostTensor;

/// A forward waiting to run on this stage.
#[derive(Debug)]
pub struct PendingForward {
    pub batch: u64,
    pub version0: u64,
    pub is_eval: bool,
    pub data: HostTensor,
}

/// A backward waiting to run on this stage.
#[derive(Debug)]
pub struct PendingBackward {
    pub batch: u64,
    pub grad: TensorBuf,
    pub loss: f32,
    pub ncorrect: f32,
    pub reports: Vec<ExecReport>,
}

/// The next compute step the 1F1B policy selects.
#[derive(Debug)]
pub enum Step {
    Backward(PendingBackward),
    Forward(PendingForward),
}

/// The *kind* of the step [`Schedule::next_step`] would select — a
/// non-consuming preview used by the scenario runner to price a step
/// (flops → virtual time) before executing it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    Backward { batch: u64 },
    Forward { batch: u64, is_eval: bool },
}

/// Batch-keyed stage state + the 1F1B selection policy.
#[derive(Debug, Default)]
pub struct Schedule {
    pending_fwd: VecDeque<PendingForward>,
    pending_bwd: VecDeque<PendingBackward>,
    labels: HashMap<u64, Vec<i32>>,
    eval_labels: HashMap<u64, Vec<i32>>,
    /// batch -> per-block inputs saved at forward time (for backward).
    acts: HashMap<u64, Vec<HostTensor>>,
    /// forward-time of in-flight batches, merged into one fwd+bwd sample
    /// at backward time (the paper reports per-batch execution time).
    fwd_ms: HashMap<u64, f64>,
}

impl Schedule {
    pub fn new() -> Schedule {
        Schedule::default()
    }

    // ---- intake ----

    pub fn push_forward(&mut self, f: PendingForward) {
        self.pending_fwd.push_back(f);
    }

    pub fn push_backward(&mut self, b: PendingBackward) {
        self.pending_bwd.push_back(b);
    }

    pub fn put_labels(&mut self, batch: u64, is_eval: bool, data: Vec<i32>) {
        if is_eval {
            self.eval_labels.insert(batch, data);
        } else {
            self.labels.insert(batch, data);
        }
    }

    // ---- policy ----

    /// Select the next step: backward first (1F1B); otherwise the oldest
    /// runnable forward. On the last stage a forward is runnable only
    /// once its labels arrived (`last_stage` gates the label check).
    pub fn next_step(&mut self, last_stage: bool) -> Option<Step> {
        if let Some(b) = self.pending_bwd.pop_front() {
            return Some(Step::Backward(b));
        }
        let pos = self.position_of_runnable_forward(last_stage)?;
        Some(Step::Forward(self.pending_fwd.remove(pos).unwrap()))
    }

    /// Preview what [`Self::next_step`] would return, without consuming.
    pub fn peek_kind(&self, last_stage: bool) -> Option<StepKind> {
        if let Some(b) = self.pending_bwd.front() {
            return Some(StepKind::Backward { batch: b.batch });
        }
        let pos = self.position_of_runnable_forward(last_stage)?;
        let f = &self.pending_fwd[pos];
        Some(StepKind::Forward { batch: f.batch, is_eval: f.is_eval })
    }

    fn position_of_runnable_forward(&self, last_stage: bool) -> Option<usize> {
        if !last_stage {
            return (!self.pending_fwd.is_empty()).then_some(0);
        }
        self.pending_fwd.iter().position(|f| {
            if f.is_eval {
                self.eval_labels.contains_key(&f.batch)
            } else {
                self.labels.contains_key(&f.batch)
            }
        })
    }

    /// (pending forwards, pending backwards) — for tests/introspection.
    pub fn queued(&self) -> (usize, usize) {
        (self.pending_fwd.len(), self.pending_bwd.len())
    }

    // ---- per-batch stashes ----

    pub fn take_labels(&mut self, batch: u64, is_eval: bool) -> Option<Vec<i32>> {
        if is_eval {
            self.eval_labels.remove(&batch)
        } else {
            self.labels.remove(&batch)
        }
    }

    pub fn stash_acts(&mut self, batch: u64, inputs: Vec<HostTensor>) {
        self.acts.insert(batch, inputs);
    }

    pub fn take_acts(&mut self, batch: u64) -> Option<Vec<HostTensor>> {
        self.acts.remove(&batch)
    }

    pub fn stash_fwd_ms(&mut self, batch: u64, ms: f64) {
        self.fwd_ms.insert(batch, ms);
    }

    pub fn take_fwd_ms(&mut self, batch: u64) -> f64 {
        self.fwd_ms.remove(&batch).unwrap_or(0.0)
    }

    /// Bytes held by the activation stash (device memory accounting).
    pub fn acts_bytes(&self) -> usize {
        self.acts.values().flat_map(|v| v.iter()).map(|t| t.byte_len()).sum()
    }

    // ---- lifecycle ----

    /// Fault reset (paper §III-F): discard every batch beyond `committed`.
    /// Labels for FUTURE batches stay — the central node already shipped
    /// them and will not resend.
    pub fn reset(&mut self, committed: i64) {
        self.pending_fwd.retain(|f| f.is_eval || (f.batch as i64) <= committed);
        self.pending_bwd.retain(|b| (b.batch as i64) <= committed);
        self.acts.retain(|&b, _| (b as i64) <= committed);
        self.fwd_ms.retain(|&b, _| (b as i64) <= committed);
        self.labels.retain(|&b, _| (b as i64) > committed);
    }

    /// Commit of a new partition: training queues and stashes restart;
    /// queued eval forwards survive (eval is version-independent).
    pub fn on_commit(&mut self) {
        self.pending_fwd.retain(|f| f.is_eval);
        self.pending_bwd.clear();
        self.acts.clear();
        self.fwd_ms.clear();
    }

    /// Crash-restart: everything is gone.
    pub fn clear(&mut self) {
        self.pending_fwd.clear();
        self.pending_bwd.clear();
        self.labels.clear();
        self.eval_labels.clear();
        self.acts.clear();
        self.fwd_ms.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fwd(batch: u64, is_eval: bool) -> PendingForward {
        PendingForward {
            batch,
            version0: 0,
            is_eval,
            data: HostTensor::F32(vec![0.0; 4].into()),
        }
    }

    fn bwd(batch: u64) -> PendingBackward {
        PendingBackward {
            batch,
            grad: vec![0.0; 4].into(),
            loss: 1.0,
            ncorrect: 0.0,
            reports: vec![],
        }
    }

    #[test]
    fn backward_preempts_forward() {
        let mut s = Schedule::new();
        s.push_forward(fwd(0, false));
        s.push_backward(bwd(1));
        match s.next_step(false) {
            Some(Step::Backward(b)) => assert_eq!(b.batch, 1),
            other => panic!("1F1B violated: {other:?}"),
        }
        match s.next_step(false) {
            Some(Step::Forward(f)) => assert_eq!(f.batch, 0),
            other => panic!("forward lost: {other:?}"),
        }
        assert!(s.next_step(false).is_none());
    }

    #[test]
    fn last_stage_waits_for_labels() {
        let mut s = Schedule::new();
        s.push_forward(fwd(5, false));
        assert!(s.next_step(true).is_none(), "no labels yet");
        s.put_labels(5, false, vec![1, 2]);
        assert!(matches!(s.next_step(true), Some(Step::Forward(f)) if f.batch == 5));
        // eval forwards gate on eval labels, independently of train labels
        s.push_forward(fwd(6, true));
        s.put_labels(6, false, vec![0]);
        assert!(s.next_step(true).is_none());
        s.put_labels(6, true, vec![0]);
        assert!(matches!(s.next_step(true), Some(Step::Forward(f)) if f.is_eval));
    }

    #[test]
    fn non_last_stage_runs_forwards_fifo_without_labels() {
        let mut s = Schedule::new();
        s.push_forward(fwd(2, false));
        s.push_forward(fwd(3, false));
        assert!(matches!(s.next_step(false), Some(Step::Forward(f)) if f.batch == 2));
        assert!(matches!(s.next_step(false), Some(Step::Forward(f)) if f.batch == 3));
    }

    #[test]
    fn reset_discards_beyond_committed_but_keeps_future_labels() {
        let mut s = Schedule::new();
        for b in 5..9 {
            s.push_forward(fwd(b, false));
            s.stash_acts(b, vec![]);
            s.stash_fwd_ms(b, 1.0);
        }
        s.put_labels(6, false, vec![1]);
        s.put_labels(8, false, vec![1]);
        s.reset(6);
        assert_eq!(s.queued().0, 2, "batches 7,8 discarded; 5,6 kept");
        assert!(s.take_acts(8).is_none());
        assert!(s.take_acts(6).is_some());
        assert!(s.take_labels(8, false).is_some(), "future labels must survive reset");
        assert!(s.take_labels(6, false).is_none(), "committed labels dropped");
    }

    #[test]
    fn peek_kind_previews_without_consuming() {
        let mut s = Schedule::new();
        assert_eq!(s.peek_kind(false), None);
        s.push_forward(fwd(3, false));
        assert_eq!(s.peek_kind(false), Some(StepKind::Forward { batch: 3, is_eval: false }));
        s.push_backward(bwd(2));
        // 1F1B: the preview agrees with next_step's backward-first policy
        assert_eq!(s.peek_kind(false), Some(StepKind::Backward { batch: 2 }));
        assert!(matches!(s.next_step(false), Some(Step::Backward(b)) if b.batch == 2));
        assert_eq!(s.peek_kind(false), Some(StepKind::Forward { batch: 3, is_eval: false }));
        // last stage: no preview until labels arrive
        assert_eq!(s.peek_kind(true), None);
        s.put_labels(3, false, vec![1]);
        assert_eq!(s.peek_kind(true), Some(StepKind::Forward { batch: 3, is_eval: false }));
    }

    #[test]
    fn commit_keeps_only_eval_forwards() {
        let mut s = Schedule::new();
        s.push_forward(fwd(0, false));
        s.push_forward(fwd(1, true));
        s.push_backward(bwd(0));
        s.stash_acts(0, vec![]);
        s.on_commit();
        let (f, b) = s.queued();
        assert_eq!((f, b), (1, 0));
        assert!(s.take_acts(0).is_none());
    }
}
