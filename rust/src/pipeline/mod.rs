//! The asynchronous 1F1B pipeline stage engine (paper §III-C).
//!
//! Each device runs a [`StageWorker`]: it owns the compiled block
//! executables (all blocks — re-partitioning only moves *weights*, never
//! code), the parameters of its current block range, the weight stash,
//! the optimizer, the replica store, and the device capacity simulator.
//!
//! Scheduling is 1F1B by construction: the worker always prefers a
//! pending backward over a pending forward (PipeDream's rule), and the
//! central node's in-flight semaphore caps the number of concurrent
//! batches at the stage count. Weight stashing + the version ring give
//! weight aggregation its inputs (paper Fig. 2); vertical sync is tracked
//! through the `version0` tag each batch carries.
//!
//! The same struct serves the central node (stage 0): the coordinator
//! drives it directly instead of through [`run_worker`].

pub mod trace;

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::device::SimDevice;
use crate::fault::{plan_redistribution, RedistPlan, Source};
use crate::manifest::Manifest;
use crate::model::{aggregate_versions, BlockParams, Sgd, SgdConfig, StageParams, VersionStash};
use crate::net::message::{DeviceId, ExecReport, Message, Payload, ReplicaKind, TrainInit, WireBlock};
use crate::net::Transport;
use crate::replication::{self, BackupStore};
use crate::runtime::{BlockRuntime, HostTensor};
use trace::{TraceEvent, TraceKind, TraceSink};

/// Completion info surfaced at stage 0 when a batch's gradient lands.
#[derive(Debug, Clone)]
pub struct CompletedBatch {
    pub batch: u64,
    pub loss: f32,
    pub ncorrect: f32,
    pub reports: Vec<ExecReport>,
}

/// What `handle_message` tells the caller to do next.
#[derive(Debug, PartialEq, Eq)]
pub enum Flow {
    Continue,
    Shutdown,
}

#[derive(Debug)]
struct PendingForward {
    batch: u64,
    version0: u64,
    is_eval: bool,
    data: HostTensor,
}

#[derive(Debug)]
struct PendingBackward {
    batch: u64,
    grad: Vec<f32>,
    loss: f32,
    ncorrect: f32,
    reports: Vec<ExecReport>,
}

/// In-progress re-partition (between Repartition and Commit).
struct Repart {
    ranges: Vec<(usize, usize)>,
    worker_list: Vec<DeviceId>,
    /// blocks still missing (awaiting Weights replies)
    needed: BTreeSet<usize>,
    /// blocks fetched/staged so far
    staged: BTreeMap<usize, BlockParams>,
    /// outstanding request -> blocks asked of that device
    outstanding: BTreeMap<DeviceId, Vec<usize>>,
    /// already escalated to central
    escalated: BTreeSet<usize>,
}

pub struct StageWorker {
    pub device_id: DeviceId,
    pub manifest: Arc<Manifest>,
    pub blocks_rt: Vec<BlockRuntime>,
    pub sim: SimDevice,
    pub trace: TraceSink,

    // --- pipeline topology ---
    pub worker_list: Vec<DeviceId>,
    pub ranges: Vec<(usize, usize)>,

    // --- stage state ---
    pub params: StageParams,
    pub sgd: Sgd,
    pub stash: VersionStash,
    pub version: u64,
    pub initialized: bool,
    pub status: u8,

    /// batch -> per-block inputs (for backward)
    acts: HashMap<u64, Vec<HostTensor>>,
    labels: HashMap<u64, Vec<i32>>,
    eval_labels: HashMap<u64, Vec<i32>>,
    pending_fwd: VecDeque<PendingForward>,
    pending_bwd: VecDeque<PendingBackward>,

    pub committed_fwd: i64,
    pub committed_bwd: i64,

    // --- schedules ---
    pub agg_k: u32,
    pub chain_every: u64,
    pub global_every: u64,
    bwd_count: u64,

    // --- profiling report window (rolling) ---
    exec_window: VecDeque<f64>,
    /// forward-time of in-flight batches, merged into one fwd+bwd sample
    /// at backward time (the paper reports per-batch execution time).
    fwd_ms: HashMap<u64, f64>,

    // --- replication store ---
    pub backups: BackupStore,

    repart: Option<Repart>,
    /// outstanding bandwidth probe to the next worker (paper §III-B)
    bw_probe: Option<std::time::Instant>,
}

impl StageWorker {
    pub fn new(
        device_id: DeviceId,
        manifest: Arc<Manifest>,
        blocks_rt: Vec<BlockRuntime>,
        sim: SimDevice,
        trace: TraceSink,
    ) -> StageWorker {
        StageWorker {
            device_id,
            manifest,
            blocks_rt,
            sim,
            trace,
            worker_list: vec![],
            ranges: vec![],
            params: StageParams::default(),
            sgd: Sgd::new(SgdConfig::default()),
            stash: VersionStash::new(4),
            version: 0,
            initialized: false,
            status: 0,
            acts: HashMap::new(),
            labels: HashMap::new(),
            eval_labels: HashMap::new(),
            pending_fwd: VecDeque::new(),
            pending_bwd: VecDeque::new(),
            committed_fwd: -1,
            committed_bwd: -1,
            agg_k: 0,
            chain_every: 0,
            global_every: 0,
            bwd_count: 0,
            exec_window: VecDeque::new(),
            fwd_ms: HashMap::new(),
            backups: BackupStore::default(),
            repart: None,
            bw_probe: None,
        }
    }

    // ------------------------------------------------------------------
    // topology helpers
    // ------------------------------------------------------------------

    pub fn n_stages(&self) -> usize {
        self.worker_list.len()
    }

    pub fn my_stage(&self) -> Option<usize> {
        self.worker_list.iter().position(|&d| d == self.device_id)
    }

    pub fn my_range(&self) -> Option<(usize, usize)> {
        self.my_stage().map(|s| self.ranges[s])
    }

    pub fn is_last_stage(&self) -> bool {
        self.my_stage().map(|s| s + 1 == self.n_stages()).unwrap_or(false)
    }

    fn next_device(&self) -> Option<DeviceId> {
        let s = self.my_stage()?;
        self.worker_list.get(s + 1).copied()
    }

    fn prev_device(&self) -> Option<DeviceId> {
        let s = self.my_stage()?;
        s.checked_sub(1).map(|p| self.worker_list[p])
    }

    fn central_device(&self) -> DeviceId {
        self.worker_list[0]
    }

    fn emit(&self, kind: TraceKind, batch: u64) {
        if let Some(t) = &self.trace {
            t.lock().unwrap().push(TraceEvent {
                device: self.device_id,
                stage: self.my_stage().unwrap_or(usize::MAX),
                kind,
                batch,
                version: self.version,
            });
        }
    }

    // ------------------------------------------------------------------
    // initialization
    // ------------------------------------------------------------------

    /// Apply the training-init state (paper Table I). Loads this stage's
    /// initial weights from the manifest unless we are in fault-recovery
    /// (status = 1), where weights arrive via redistribution instead.
    pub fn apply_init(&mut self, t: &TrainInit) -> Result<()> {
        self.worker_list = t.worker_list.clone();
        self.ranges = t.ranges.clone();
        self.sgd = Sgd::new(SgdConfig {
            lr: t.lr,
            momentum: t.momentum,
            weight_decay: t.weight_decay,
        });
        self.stash = VersionStash::new(self.n_stages().max(2));
        self.version = 0;
        self.committed_fwd = t.committed_forward;
        self.committed_bwd = t.committed_backward;
        self.agg_k = t.agg_k;
        self.chain_every = t.chain_every;
        self.global_every = t.global_every;
        self.status = t.status;
        if t.status == 0 {
            if let Some((lo, hi)) = self.my_range() {
                self.params = StageParams::load_range(&self.manifest, lo, hi)?;
            }
        }
        self.initialized = true;
        Ok(())
    }

    // ------------------------------------------------------------------
    // compute: forward
    // ------------------------------------------------------------------

    fn payload_to_tensor(p: Payload) -> HostTensor {
        match p {
            Payload::F32(v) => HostTensor::F32(v),
            Payload::I32(v) => HostTensor::I32(v),
        }
    }

    fn tensor_to_payload(t: HostTensor) -> Payload {
        match t {
            HostTensor::F32(v) => Payload::F32(v),
            HostTensor::I32(v) => Payload::I32(v),
        }
    }

    fn block_params(&self, source: &StageParams, idx: usize) -> Result<Vec<Vec<f32>>> {
        Ok(source
            .get(idx)
            .with_context(|| format!("device {} missing params for block {idx}", self.device_id))?
            .0
            .clone())
    }

    /// Training forward for one batch through this stage's blocks.
    /// Returns `Some(CompletedBatch)` only in the degenerate 1-stage case.
    pub fn forward_train(
        &mut self,
        t: &dyn Transport,
        batch: u64,
        version0: u64,
        x: HostTensor,
    ) -> Result<Option<CompletedBatch>> {
        let (lo, hi) = self.my_range().context("not in worker list")?;
        let last = self.is_last_stage();

        if !last {
            // stash the weights used for this forward (PipeDream weight stashing)
            self.stash.on_forward(batch, self.version, &self.params);
            // perf: borrow the snapshot just stashed instead of cloning the
            // whole StageParams again (EXPERIMENTS.md §Perf L3-1)
            let params = self
                .stash
                .snapshot(self.version)
                .unwrap_or(&self.params);
            let mut inputs: Vec<HostTensor> = Vec::with_capacity(hi - lo + 1);
            let mut cur = x;
            let blocks_rt = &self.blocks_rt;
            let (out, ms) = {
                let mut run = || -> Result<HostTensor> {
                    for idx in lo..=hi {
                        inputs.push(cur.clone());
                        let p = params.get(idx).context("missing block params")?;
                        let y = blocks_rt[idx].forward(&p.0, &cur)?;
                        cur = HostTensor::F32(y);
                    }
                    Ok(cur.clone())
                };
                let (res, dur) = self.sim.execute(&mut run);
                (res?, dur.as_secs_f64() * 1e3)
            };
            self.acts.insert(batch, inputs);
            self.committed_fwd = self.committed_fwd.max(batch as i64);
            self.fwd_ms.insert(batch, ms); // merged into one sample at backward
            self.emit(TraceKind::Forward, batch);
            let next = self.next_device().context("no next stage")?;
            t.send(
                next,
                Message::Forward {
                    batch,
                    version0,
                    is_eval: false,
                    data: Self::tensor_to_payload(out),
                },
            )?;
            return Ok(None);
        }

        // ---- last stage: fused forward + loss + backward (1F1B) ----
        let labels = self
            .labels
            .remove(&batch)
            .context("labels not available for last-stage forward")?;
        let label_t = HostTensor::I32(labels);
        let head_idx = self.manifest.n_blocks() - 1;
        debug_assert_eq!(hi, head_idx);

        // perf: borrow instead of cloning the stage's parameters — the
        // closure only reads them, and `sim` is a disjoint field.
        let params = &self.params;
        let label_shape = self.manifest.label_shape.clone();
        struct LastOut {
            grads: BTreeMap<usize, Vec<Vec<f32>>>,
            gx_out: Option<Vec<f32>>,
            loss: f32,
            ncorrect: f32,
        }
        let blocks_rt = &self.blocks_rt;
        let (out, ms) = {
            let mut run = || -> Result<LastOut> {
                // forward through my non-head blocks, saving inputs
                let mut inputs: Vec<HostTensor> = Vec::with_capacity(hi - lo + 1);
                let mut cur = x.clone();
                for idx in lo..hi {
                    inputs.push(cur.clone());
                    let p = params.get(idx).context("missing block params")?;
                    let y = blocks_rt[idx].forward(&p.0, &cur)?;
                    cur = HostTensor::F32(y);
                }
                // fused head step
                let hp = params.get(head_idx).context("missing head params")?;
                let hx = cur.as_f32()?.to_vec();
                let hs = blocks_rt[head_idx].head_step(&hp.0, &hx, &label_t, &label_shape)?;
                let mut grads: BTreeMap<usize, Vec<Vec<f32>>> = BTreeMap::new();
                grads.insert(head_idx, hs.grad_params);
                // backward through my remaining blocks with the SAME weights
                let mut gy = hs.grad_input;
                let mut gx_out = Some(gy.clone());
                for idx in (lo..hi).rev() {
                    let p = params.get(idx).unwrap();
                    let xin = &inputs[idx - lo];
                    let (g, gx) = blocks_rt[idx].backward(&p.0, xin, &gy)?;
                    grads.insert(idx, g);
                    match gx {
                        Some(g2) => {
                            gy = g2;
                            gx_out = Some(gy.clone());
                        }
                        None => gx_out = None,
                    }
                }
                if lo == 0 {
                    gx_out = None; // block 0 produces no input grad
                }
                Ok(LastOut { grads, gx_out, loss: hs.loss, ncorrect: hs.ncorrect })
            };
            let (res, dur) = self.sim.execute(&mut run);
            (res?, dur.as_secs_f64() * 1e3)
        };

        // apply updates
        self.sgd.step(&mut self.params, &out.grads);
        self.version += 1;
        self.bwd_count += 1;
        self.committed_fwd = self.committed_fwd.max(batch as i64);
        self.committed_bwd = self.committed_bwd.max(batch as i64);
        self.record_exec(ms);
        self.emit(TraceKind::Forward, batch);
        self.emit(TraceKind::Backward, batch);

        let report = self.current_report();
        self.maybe_replicate(t, batch)?;

        if let Some(prev) = self.prev_device() {
            t.send(
                prev,
                Message::Backward {
                    batch,
                    grad: out.gx_out.unwrap_or_default(),
                    loss: out.loss,
                    ncorrect: out.ncorrect,
                    reports: vec![report],
                },
            )?;
            Ok(None)
        } else {
            // single-stage pipeline: completion happens here
            Ok(Some(CompletedBatch {
                batch,
                loss: out.loss,
                ncorrect: out.ncorrect,
                reports: vec![report],
            }))
        }
    }

    /// Evaluation forward (no stashing / no state): last stage computes
    /// loss + accuracy and reports to the central node.
    pub fn forward_eval(&mut self, t: &dyn Transport, batch: u64, x: HostTensor) -> Result<Option<(f32, f32)>> {
        let (lo, hi) = self.my_range().context("not in worker list")?;
        let last = self.is_last_stage();
        let head_idx = self.manifest.n_blocks() - 1;
        let end = if last { hi - 1 } else { hi };

        let mut cur = x;
        for idx in lo..=end {
            if last && idx == head_idx {
                break;
            }
            let p = self.block_params(&self.params, idx)?;
            let y = self.blocks_rt[idx].forward(&p, &cur)?;
            cur = HostTensor::F32(y);
        }
        if !last {
            let next = self.next_device().context("no next stage")?;
            t.send(
                next,
                Message::Forward { batch, version0: 0, is_eval: true, data: Self::tensor_to_payload(cur) },
            )?;
            return Ok(None);
        }
        let labels = self
            .eval_labels
            .remove(&batch)
            .context("labels not available for eval")?;
        let hp = self.block_params(&self.params, head_idx)?;
        let (loss, nc) = self.blocks_rt[head_idx].head_eval(
            &hp,
            cur.as_f32()?,
            &HostTensor::I32(labels),
            &self.manifest.label_shape.clone(),
        )?;
        if self.my_stage() == Some(0) {
            Ok(Some((loss, nc)))
        } else {
            t.send(self.central_device(), Message::EvalResult { batch, loss, ncorrect: nc })?;
            Ok(None)
        }
    }

    // ------------------------------------------------------------------
    // compute: backward (non-last stages)
    // ------------------------------------------------------------------

    /// Backward for one batch. At stage 0 returns the completed batch.
    pub fn backward(
        &mut self,
        t: &dyn Transport,
        batch: u64,
        gy_in: Vec<f32>,
        loss: f32,
        ncorrect: f32,
        mut reports: Vec<ExecReport>,
    ) -> Result<Option<CompletedBatch>> {
        let (lo, hi) = self.my_range().context("not in worker list")?;
        let stage = self.my_stage().unwrap();

        // weight stashing: backward runs against the forward-time weights
        // (perf: borrowed, not cloned — EXPERIMENTS.md §Perf L3-1)
        let stashed = self
            .stash
            .params_for_backward(batch)
            .unwrap_or(&self.params);
        let inputs = self
            .acts
            .remove(&batch)
            .with_context(|| format!("no saved activations for batch {batch}"))?;

        let blocks_rt = &self.blocks_rt;
        struct BwdOut {
            grads: BTreeMap<usize, Vec<Vec<f32>>>,
            gx_out: Option<Vec<f32>>,
        }
        let (out, ms) = {
            let mut run = || -> Result<BwdOut> {
                let mut grads = BTreeMap::new();
                let mut gy = gy_in.clone();
                let mut gx_out = Some(gy.clone());
                for idx in (lo..=hi).rev() {
                    let p = stashed.get(idx).context("stash missing block")?;
                    let xin = &inputs[idx - lo];
                    let (g, gx) = blocks_rt[idx].backward(&p.0, xin, &gy)?;
                    grads.insert(idx, g);
                    match gx {
                        Some(g2) => {
                            gy = g2;
                            gx_out = Some(gy.clone());
                        }
                        None => gx_out = None,
                    }
                }
                Ok(BwdOut { grads, gx_out })
            };
            let (res, dur) = self.sim.execute(&mut run);
            (res?, dur.as_secs_f64() * 1e3)
        };

        // gradients apply to the CURRENT weights (PipeDream async rule)
        self.sgd.step(&mut self.params, &out.grads);
        self.version += 1;
        self.bwd_count += 1;
        self.stash.on_backward_done(batch);
        self.committed_bwd = self.committed_bwd.max(batch as i64);
        let fwd_part = self.fwd_ms.remove(&batch).unwrap_or(0.0);
        self.record_exec(fwd_part + ms);
        self.emit(TraceKind::Backward, batch);

        self.maybe_aggregate();
        self.maybe_replicate(t, batch)?;

        if stage == 0 {
            return Ok(Some(CompletedBatch { batch, loss, ncorrect, reports }));
        }
        reports.push(self.current_report());
        let prev = self.prev_device().unwrap();
        t.send(
            prev,
            Message::Backward {
                batch,
                grad: out.gx_out.unwrap_or_default(),
                loss,
                ncorrect,
                reports,
            },
        )?;
        Ok(None)
    }

    /// Weight aggregation (paper §III-C): stage `i` of `n` averages its
    /// `n - i` concurrently-live weight versions every `agg_k * (n - i)`
    /// backward steps.
    fn maybe_aggregate(&mut self) {
        if self.agg_k == 0 {
            return;
        }
        let stage = match self.my_stage() {
            Some(s) => s,
            None => return,
        };
        let m = self.n_stages().saturating_sub(stage);
        if m < 2 {
            return; // last stage has a single live version
        }
        let interval = self.agg_k as u64 * m as u64;
        if self.bwd_count == 0 || self.bwd_count % interval != 0 {
            return;
        }
        let versions = self.stash.recent_versions(m);
        let mut snaps: Vec<&StageParams> = versions
            .iter()
            .filter_map(|v| self.stash.snapshot(*v))
            .collect();
        let current = self.params.clone();
        snaps.push(&current);
        if snaps.len() < 2 {
            return;
        }
        if let Some(avg) = aggregate_versions(&snaps) {
            self.params = avg;
            self.version += 1;
            self.emit(TraceKind::Aggregate, self.bwd_count);
        }
    }

    /// Chain/global replication triggers after `batch`'s backward.
    fn maybe_replicate(&mut self, t: &dyn Transport, batch: u64) -> Result<()> {
        let stage = match self.my_stage() {
            Some(s) => s,
            None => return Ok(()),
        };
        if stage == 0 {
            return Ok(()); // the central node persists locally (paper §III-E)
        }
        let wire: Option<Vec<WireBlock>> = if replication::due(batch, self.nonzero(self.chain_every))
            || replication::due(batch, self.nonzero(self.global_every))
        {
            Some(replication::to_wire(&self.params))
        } else {
            None
        };
        if let Some(wire) = wire {
            if replication::due(batch, self.nonzero(self.chain_every)) {
                let target_stage = replication::chain_target(stage, self.n_stages());
                let target = self.worker_list[target_stage];
                t.send(
                    target,
                    Message::ReplicaPush {
                        kind: ReplicaKind::Chain,
                        owner_stage: stage,
                        owner_device: self.device_id,
                        version: self.version,
                        blocks: wire.clone(),
                    },
                )?;
            }
            if replication::due(batch, self.nonzero(self.global_every)) {
                t.send(
                    self.central_device(),
                    Message::ReplicaPush {
                        kind: ReplicaKind::Global,
                        owner_stage: stage,
                        owner_device: self.device_id,
                        version: self.version,
                        blocks: wire,
                    },
                )?;
            }
        }
        Ok(())
    }

    fn nonzero(&self, v: u64) -> Option<u64> {
        (v > 0).then_some(v)
    }

    // ------------------------------------------------------------------
    // execution-time reporting (paper §III-D "execution profiling")
    // ------------------------------------------------------------------

    fn record_exec(&mut self, ms: f64) {
        self.exec_window.push_back(ms);
        while self.exec_window.len() > 8 {
            self.exec_window.pop_front();
        }
    }

    /// Rolling average of this stage's per-batch execution time (ms).
    pub fn avg_exec_ms(&self) -> Option<f64> {
        (!self.exec_window.is_empty())
            .then(|| self.exec_window.iter().sum::<f64>() / self.exec_window.len() as f64)
    }

    fn current_report(&self) -> ExecReport {
        let n = self.exec_window.len().max(1);
        let avg = self.exec_window.iter().sum::<f64>() / n as f64;
        ExecReport { device: self.device_id, avg_ms: avg, batches: n as u32 }
    }

    // ------------------------------------------------------------------
    // scheduling
    // ------------------------------------------------------------------

    /// Run at most one compute step (backward preferred — 1F1B).
    pub fn pump(&mut self, t: &dyn Transport) -> Result<bool> {
        if !self.initialized || self.status == 1 || self.my_stage().is_none() {
            return Ok(false);
        }
        if let Some(b) = self.pending_bwd.pop_front() {
            self.backward(t, b.batch, b.grad, b.loss, b.ncorrect, b.reports)?;
            return Ok(true);
        }
        // last stage can only run a forward whose labels have arrived
        if let Some(pos) = self.position_of_runnable_forward() {
            let f = self.pending_fwd.remove(pos).unwrap();
            if f.is_eval {
                self.forward_eval(t, f.batch, f.data)?;
            } else {
                self.forward_train(t, f.batch, f.version0, f.data)?;
            }
            return Ok(true);
        }
        Ok(false)
    }

    fn position_of_runnable_forward(&self) -> Option<usize> {
        if !self.is_last_stage() {
            return (!self.pending_fwd.is_empty()).then_some(0);
        }
        self.pending_fwd.iter().position(|f| {
            if f.is_eval {
                self.eval_labels.contains_key(&f.batch)
            } else {
                self.labels.contains_key(&f.batch)
            }
        })
    }

    pub fn queued(&self) -> (usize, usize) {
        (self.pending_fwd.len(), self.pending_bwd.len())
    }

    // ------------------------------------------------------------------
    // control-plane handling
    // ------------------------------------------------------------------

    /// Handle one message (used by worker loops; the central driver
    /// handles data-plane messages itself and delegates control here).
    pub fn handle_message(
        &mut self,
        t: &dyn Transport,
        from: DeviceId,
        msg: Message,
    ) -> Result<Flow> {
        match msg {
            Message::Forward { batch, version0, is_eval, data } => {
                if self.status == 0 || is_eval {
                    self.pending_fwd.push_back(PendingForward {
                        batch,
                        version0,
                        is_eval,
                        data: Self::payload_to_tensor(data),
                    });
                }
            }
            Message::Labels { batch, is_eval, data } => {
                if is_eval {
                    self.eval_labels.insert(batch, data);
                } else {
                    self.labels.insert(batch, data);
                }
            }
            Message::Backward { batch, grad, loss, ncorrect, reports } => {
                if self.status == 0 {
                    self.pending_bwd.push_back(PendingBackward { batch, grad, loss, ncorrect, reports });
                }
            }
            Message::Probe => {
                t.send(from, Message::ProbeAck { id: self.device_id, fresh: !self.initialized })?;
            }
            Message::InitState(ti) => {
                self.apply_init(&ti)?;
                self.measure_bandwidth(t)?;
            }
            Message::Repartition { ranges, worker_list, failed } => {
                self.begin_repartition(t, ranges, worker_list, failed)?;
            }
            Message::FetchWeights { blocks } => {
                self.serve_fetch(t, from, &blocks)?;
            }
            Message::Weights { blocks } => {
                self.handle_weights(t, from, blocks)?;
            }
            Message::ReplicaPush { kind, owner_stage, owner_device, version, blocks } => {
                self.backups.store(
                    owner_device,
                    kind,
                    owner_stage,
                    version,
                    replication::from_wire(&blocks),
                );
            }
            Message::Commit => {
                self.apply_commit()?;
            }
            Message::Reset { committed } => {
                self.apply_reset(committed);
            }
            Message::BwTest { payload_bytes, .. } => {
                t.send(from, Message::BwAck { payload_bytes })?;
            }
            Message::BwAck { payload_bytes } => {
                if let (Some(t0), Some(stage)) = (self.bw_probe.take(), self.my_stage()) {
                    let dt = t0.elapsed().as_secs_f64().max(1e-6);
                    let bps = payload_bytes as f64 / dt;
                    t.send(self.central_device(), Message::BwReport { stage, bps })?;
                }
            }
            Message::SetLr { lr } => {
                self.sgd.set_lr(lr);
            }
            Message::Shutdown => return Ok(Flow::Shutdown),
            // coordinator-only messages a worker may legitimately see late:
            Message::ProbeAck { .. }
            | Message::EvalResult { .. }
            | Message::FetchDone { .. }
            | Message::BwReport { .. } => {}
        }
        Ok(Flow::Continue)
    }

    /// Reset the training state (paper §III-F last phase): discard every
    /// batch beyond `committed` and return to normal status.
    pub fn apply_reset(&mut self, committed: i64) {
        self.committed_fwd = committed;
        self.committed_bwd = committed;
        self.pending_fwd.retain(|f| f.is_eval || (f.batch as i64) <= committed);
        self.pending_bwd.retain(|b| (b.batch as i64) <= committed);
        self.acts.retain(|&b, _| (b as i64) <= committed);
        self.fwd_ms.retain(|&b, _| (b as i64) <= committed);
        self.labels.retain(|&b, _| (b as i64) > committed); // labels for future batches stay
        self.stash.discard_after(committed);
        self.status = 0;
    }

    // ------------------------------------------------------------------
    // re-partition / redistribution protocol (paper §III-D + Algorithm 1)
    // ------------------------------------------------------------------

    /// Start a re-partition: plan with Algorithm 1, stage local/backup
    /// blocks immediately, issue FetchWeights for the rest.
    pub fn begin_repartition(
        &mut self,
        t: &dyn Transport,
        ranges: Vec<(usize, usize)>,
        worker_list: Vec<DeviceId>,
        failed: Vec<usize>,
    ) -> Result<()> {
        self.status = 1;
        let i_new = match worker_list.iter().position(|&d| d == self.device_id) {
            Some(i) => i,
            None => {
                // not part of the new pipeline (shouldn't happen for alive
                // devices) — just accept and idle
                self.repart = None;
                return Ok(());
            }
        };
        let i_cur_old = self.my_stage();
        let held = self.params.block_indices();
        let p_cur = if self.ranges.is_empty() { ranges.clone() } else { self.ranges.clone() };
        let plan: RedistPlan =
            plan_redistribution(&ranges, &p_cur, &failed, &held, i_new, i_cur_old);

        let mut rp = Repart {
            ranges,
            worker_list,
            needed: BTreeSet::new(),
            staged: BTreeMap::new(),
            outstanding: BTreeMap::new(),
            escalated: BTreeSet::new(),
        };

        for (src, blocks) in &plan.need {
            match src {
                Source::LocalBackup => {
                    for &b in blocks {
                        match self.backups.find_block(b) {
                            Some(bp) => {
                                rp.staged.insert(b, bp.clone());
                            }
                            None => {
                                // replica never arrived: escalate to central
                                rp.needed.insert(b);
                                rp.escalated.insert(b);
                            }
                        }
                    }
                }
                Source::CentralBackup => {
                    for &b in blocks {
                        rp.needed.insert(b);
                        rp.escalated.insert(b);
                    }
                }
                Source::Stage(s) => {
                    let dev = rp.worker_list[*s];
                    for &b in blocks {
                        rp.needed.insert(b);
                    }
                    rp.outstanding.entry(dev).or_default().extend(blocks.iter().copied());
                }
            }
        }

        // fire the fetches
        let central = rp.worker_list[0];
        for (dev, blocks) in rp.outstanding.clone() {
            t.send(dev, Message::FetchWeights { blocks })?;
        }
        let escalated: Vec<usize> = rp.escalated.iter().copied().collect();
        if !escalated.is_empty() && self.device_id != central {
            rp.outstanding.entry(central).or_default().extend(escalated.iter().copied());
            t.send(central, Message::FetchWeights { blocks: escalated })?;
        } else if !escalated.is_empty() {
            // I AM the central node: serve from my own global backups; a
            // block no backup ever covered falls back to its initial
            // weights (a fresh sub-model is better than a dead pipeline —
            // the paper assumes replication already ran at least once).
            for b in escalated {
                let bp = match self.backups.find_block(b) {
                    Some(bp) => bp.clone(),
                    None => {
                        crate::log_warn!(
                            "block {b}: no replica anywhere; restoring initial weights"
                        );
                        BlockParams(self.manifest.load_init_params(b)?)
                    }
                };
                rp.staged.insert(b, bp);
                rp.needed.remove(&b);
            }
        }

        let done = rp.needed.is_empty();
        self.repart = Some(rp);
        if done {
            self.fetch_complete(t)?;
        }
        Ok(())
    }

    /// Serve a FetchWeights request from current params, then backups.
    pub fn serve_fetch(&self, t: &dyn Transport, from: DeviceId, blocks: &[usize]) -> Result<()> {
        let mut found: Vec<WireBlock> = Vec::new();
        for &b in blocks {
            if let Some(bp) = self.params.get(b) {
                found.push((b, bp.0.clone()));
            } else if let Some(bp) = self.backups.find_block(b) {
                found.push((b, bp.0.clone()));
            }
        }
        t.send(from, Message::Weights { blocks: found })?;
        Ok(())
    }

    /// Measure bandwidth to the next worker by timing a 64 KiB echo
    /// (paper §III-B; the analogue of its ping3 measurement).
    pub fn measure_bandwidth(&mut self, t: &dyn Transport) -> Result<()> {
        if let Some(next) = self.next_device() {
            let payload = vec![0u8; 65536];
            self.bw_probe = Some(std::time::Instant::now());
            t.send(next, Message::BwTest { payload_bytes: 65536, data: payload })?;
        }
        Ok(())
    }

    /// Integrate a Weights reply; escalate still-missing blocks to central.
    ///
    /// Outside a re-partition, a Weights push overwrites the local params
    /// directly — this is how pre-trained weights reach workers in the
    /// paper's continuous-training mode (Table I).
    pub fn handle_weights(
        &mut self,
        t: &dyn Transport,
        from: DeviceId,
        blocks: Vec<WireBlock>,
    ) -> Result<()> {
        if self.repart.is_none() {
            for (idx, tensors) in blocks {
                if self.params.get(idx).is_some() {
                    self.params.blocks.insert(idx, BlockParams(tensors));
                }
            }
            return Ok(());
        }
        let Some(rp) = &mut self.repart else { return Ok(()) };
        for (idx, tensors) in blocks {
            if rp.needed.remove(&idx) {
                rp.staged.insert(idx, BlockParams(tensors));
            }
        }
        // blocks we asked `from` for but didn't get:
        //  * from a peer -> escalate to the central node's global backup
        //  * from central itself -> nothing anywhere: fall back to the
        //    initial weights so recovery always terminates
        if let Some(asked) = rp.outstanding.remove(&from) {
            let central = rp.worker_list[0];
            if from == central {
                let missing: Vec<usize> =
                    asked.into_iter().filter(|b| rp.needed.contains(b)).collect();
                for b in missing {
                    crate::log_warn!(
                        "block {b}: central has no replica; restoring initial weights"
                    );
                    rp.staged.insert(b, BlockParams(self.manifest.load_init_params(b)?));
                    rp.needed.remove(&b);
                }
            } else {
                let missing: Vec<usize> = asked
                    .into_iter()
                    .filter(|b| rp.needed.contains(b) && !rp.escalated.contains(b))
                    .collect();
                if !missing.is_empty() {
                    for &b in &missing {
                        rp.escalated.insert(b);
                    }
                    rp.outstanding
                        .entry(central)
                        .or_default()
                        .extend(missing.iter().copied());
                    t.send(central, Message::FetchWeights { blocks: missing })?;
                }
            }
        }
        if self.repart.as_ref().map(|r| r.needed.is_empty()).unwrap_or(false) {
            self.fetch_complete(t)?;
        }
        Ok(())
    }

    fn fetch_complete(&mut self, t: &dyn Transport) -> Result<()> {
        let central = self.repart.as_ref().unwrap().worker_list[0];
        if self.device_id == central {
            // the coordinator tracks its own completion directly
            return Ok(());
        }
        t.send(central, Message::FetchDone { id: self.device_id })?;
        Ok(())
    }

    /// Has this device staged everything it needs (pre-Commit)?
    pub fn fetch_done(&self) -> bool {
        self.repart.as_ref().map(|r| r.needed.is_empty()).unwrap_or(true)
    }

    /// Commit: swap to the new sub-model (paper's commit message — only
    /// now may the old sub-model be dropped).
    pub fn apply_commit(&mut self) -> Result<()> {
        let Some(rp) = self.repart.take() else {
            self.status = 0;
            return Ok(());
        };
        if !rp.needed.is_empty() {
            bail!(
                "device {}: commit before fetch completion ({} missing)",
                self.device_id,
                rp.needed.len()
            );
        }
        let i_new = rp.worker_list.iter().position(|&d| d == self.device_id);
        self.worker_list = rp.worker_list;
        self.ranges = rp.ranges;
        if let Some(i) = i_new {
            let (lo, hi) = self.ranges[i];
            self.params.retain_range(lo, hi);
            for (idx, bp) in rp.staged {
                if idx >= lo && idx <= hi {
                    self.params.blocks.insert(idx, bp);
                }
            }
            self.sgd.retain_blocks(&self.params.block_indices());
        } else {
            self.params = StageParams::default();
        }
        self.stash = VersionStash::new(self.n_stages().max(2));
        self.acts.clear();
        self.fwd_ms.clear();
        self.pending_fwd.retain(|f| f.is_eval);
        self.pending_bwd.clear();
        self.status = 0;
        self.initialized = true;
        Ok(())
    }

    /// Simulate a crash-restart: all in-memory state is lost (the process
    /// came back up but knows nothing — paper §III-F case 2).
    pub fn wipe_state(&mut self) {
        self.params = StageParams::default();
        self.sgd = Sgd::new(self.sgd.cfg);
        self.stash = VersionStash::new(2);
        self.version = 0;
        self.initialized = false;
        self.status = 0;
        self.acts.clear();
        self.labels.clear();
        self.eval_labels.clear();
        self.pending_fwd.clear();
        self.pending_bwd.clear();
        self.committed_fwd = -1;
        self.committed_bwd = -1;
        self.bwd_count = 0;
        self.exec_window.clear();
        self.fwd_ms.clear();
        self.backups = BackupStore::default();
        self.repart = None;
        self.bw_probe = None;
    }

    /// State bytes currently held (memory accounting for the device cap).
    pub fn memory_bytes(&self) -> u64 {
        (self.params.byte_len()
            + self.backups.byte_len()
            + self.acts.values().flat_map(|v| v.iter()).map(|t| t.byte_len()).sum::<usize>())
            as u64
    }
}

/// The worker-device main loop (stages >= 1). The central node drives its
/// own loop in [`crate::coordinator`].
///
/// `kill_watch` (sim mode): when the fault injector marks this device
/// dead, the loop wipes all in-memory state — when (if) the device is
/// revived it behaves exactly like a freshly-restarted process (paper
/// case 2: probes back `fresh`, weights restored from its chain replica).
pub fn run_worker(
    mut w: StageWorker,
    endpoint: Box<dyn Transport>,
    kill_watch: Option<crate::net::sim::SimNet>,
) -> Result<()> {
    let mut was_dead = false;
    loop {
        if let Some(net) = &kill_watch {
            if net.is_dead(w.device_id) {
                if !was_dead {
                    w.wipe_state();
                    was_dead = true;
                }
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            was_dead = false;
        }
        // wait briefly for a message, then drain whatever else queued up
        if let Some((from, msg)) = endpoint.recv_timeout(Duration::from_millis(2)) {
            if w.handle_message(&*endpoint, from, msg)? == Flow::Shutdown {
                return Ok(());
            }
            while let Some((from, msg)) = endpoint.recv_timeout(Duration::ZERO) {
                if w.handle_message(&*endpoint, from, msg)? == Flow::Shutdown {
                    return Ok(());
                }
            }
        }
        w.pump(&*endpoint)?;
    }
}
