//! The asynchronous 1F1B pipeline engine (paper §III-C), event-driven.
//!
//! Module map:
//!
//! - [`events`] — the typed [`Event`] vocabulary every incoming message
//!   is classified into (data plane / control plane / shutdown)
//! - [`schedule`] — 1F1B queueing + per-batch stashes (labels,
//!   activations, forward timings) and the backward-first policy
//! - [`stage`] — [`StageWorker`]: per-stage compute, weight stashing,
//!   aggregation, replication triggers, and the worker event loop
//! - `repart` — client-side state of an in-progress redistribution
//!   (between `Repartition` and `Commit`)
//! - [`trace`] — schedule trace recording for the Fig.-2 assertions
//!
//! Data flow: a transport delivers a [`crate::net::Message`]; the worker
//! loop classifies it ([`Event::from_message`]) and hands it to
//! [`StageWorker::on_event`], which either enqueues data-plane work into
//! the [`schedule::Schedule`] or runs a control-plane handler.
//! [`StageWorker::pump`] then executes at most one compute step chosen by
//! the 1F1B policy. All tensor payloads are `TensorBuf`-backed, so
//! queueing, stashing, and replicating share buffers instead of copying.

pub mod events;
mod repart;
pub mod schedule;
pub mod stage;
pub mod trace;

pub use events::{ControlEvent, DataEvent, Event, Flow};
pub use schedule::{PendingBackward, PendingForward, Schedule, Step, StepKind};
pub use stage::{run_worker, CompletedBatch, StageWorker};
