//! Typed events — the engine's internal vocabulary.
//!
//! Every incoming [`Message`] is classified exactly once (at the network
//! boundary) into an [`Event`]: data plane, control plane, or shutdown.
//! The stage event loop ([`super::stage::StageWorker::on_event`]) and the
//! coordinator's phases dispatch on these enums instead of re-matching
//! raw messages ad hoc, so the data-plane fast path and the control-plane
//! protocol handlers are separated by type, not by convention.

use crate::net::message::{
    DeviceId, ExecReport, Message, Payload, ReplicaKind, TrainInit, WireBlock,
};
use crate::net::quant::Tier;
use crate::net::TensorBuf;

/// What an event handler tells its caller to do next.
#[derive(Debug, PartialEq, Eq)]
pub enum Flow {
    Continue,
    Shutdown,
}

/// A classified incoming message.
#[derive(Debug)]
pub enum Event {
    Data(DataEvent),
    Control(ControlEvent),
    Shutdown,
}

/// Hot-path traffic: activations, labels, gradients, eval results. The
/// tensor payloads stay `TensorBuf`-backed — classification moves them,
/// never copies them. A quantized gradient is the one exception by
/// design: classification is the receiver boundary, so the INT8 wire
/// tensor pays its single dequantization write here and compute code
/// downstream only ever sees f32 (forward payloads dequantize at the
/// schedule intake instead, `StageWorker::payload_to_tensor`).
#[derive(Debug)]
pub enum DataEvent {
    Forward {
        batch: u64,
        version0: u64,
        is_eval: bool,
        data: Payload,
    },
    Labels {
        batch: u64,
        is_eval: bool,
        data: Vec<i32>,
    },
    Backward {
        batch: u64,
        grad: TensorBuf,
        loss: f32,
        ncorrect: f32,
        reports: Vec<ExecReport>,
    },
    EvalResult {
        batch: u64,
        loss: f32,
        ncorrect: f32,
    },
}

/// Protocol traffic: init, probing, re-partition/redistribution,
/// replication, bandwidth measurement, resets.
#[derive(Debug)]
pub enum ControlEvent {
    Probe {
        from: DeviceId,
    },
    ProbeAck {
        id: DeviceId,
        fresh: bool,
    },
    Init(TrainInit),
    Repartition {
        ranges: Vec<(usize, usize)>,
        worker_list: Vec<DeviceId>,
        failed: Vec<usize>,
    },
    FetchWeights {
        from: DeviceId,
        blocks: Vec<usize>,
    },
    Weights {
        from: DeviceId,
        blocks: Vec<WireBlock>,
    },
    ReplicaPush {
        kind: ReplicaKind,
        owner_stage: usize,
        owner_device: DeviceId,
        version: u64,
        blocks: Vec<WireBlock>,
    },
    FetchDone {
        id: DeviceId,
    },
    Commit,
    Reset {
        committed: i64,
    },
    /// The echo payload itself is dropped at classification — only the
    /// advertised size matters for the ack.
    BwTest {
        from: DeviceId,
        payload_bytes: u32,
    },
    BwAck {
        payload_bytes: u32,
    },
    BwReport {
        stage: usize,
        bps: f64,
        /// Probed destination device (0 = unknown, pre-v7 sender).
        to: DeviceId,
    },
    SetLr {
        lr: f32,
    },
    /// The central node rebooted from its checkpoint (paper §III-E);
    /// `committed` is the checkpoint's newest committed batch.
    CentralRestart {
        from: DeviceId,
        committed: i64,
    },
    /// A worker's progress report answering [`ControlEvent::CentralRestart`].
    WorkerState {
        id: DeviceId,
        committed_fwd: i64,
        committed_bwd: i64,
        fresh: bool,
    },
    /// Coordinator-issued wire-tier table (`Compression::Adaptive`,
    /// DESIGN.md §10): `tier` for every unlisted destination plus the
    /// per-link overrides, installed for outgoing tensors.
    SetCompression {
        tier: Tier,
        links: Vec<(DeviceId, Tier)>,
    },
}

impl Event {
    /// Classify one wire message. Total: every `Message` variant maps to
    /// exactly one event (the codec round-trip tests plus this keep the
    /// two vocabularies in sync).
    pub fn from_message(from: DeviceId, msg: Message) -> Event {
        match msg {
            Message::Forward { batch, version0, is_eval, data } => {
                Event::Data(DataEvent::Forward { batch, version0, is_eval, data })
            }
            Message::Labels { batch, is_eval, data } => {
                Event::Data(DataEvent::Labels { batch, is_eval, data })
            }
            Message::Backward { batch, grad, loss, ncorrect, reports } => {
                // f32 arm: a move. q8 arm: the single dequantize write.
                Event::Data(DataEvent::Backward {
                    batch,
                    grad: grad.into_f32(),
                    loss,
                    ncorrect,
                    reports,
                })
            }
            Message::EvalResult { batch, loss, ncorrect } => {
                Event::Data(DataEvent::EvalResult { batch, loss, ncorrect })
            }
            Message::Probe => Event::Control(ControlEvent::Probe { from }),
            Message::ProbeAck { id, fresh } => Event::Control(ControlEvent::ProbeAck { id, fresh }),
            Message::InitState(ti) => Event::Control(ControlEvent::Init(ti)),
            Message::Repartition { ranges, worker_list, failed } => {
                Event::Control(ControlEvent::Repartition { ranges, worker_list, failed })
            }
            Message::FetchWeights { blocks } => {
                Event::Control(ControlEvent::FetchWeights { from, blocks })
            }
            Message::Weights { blocks } => Event::Control(ControlEvent::Weights { from, blocks }),
            Message::ReplicaPush { kind, owner_stage, owner_device, version, blocks } => {
                Event::Control(ControlEvent::ReplicaPush {
                    kind,
                    owner_stage,
                    owner_device,
                    version,
                    blocks,
                })
            }
            Message::FetchDone { id } => Event::Control(ControlEvent::FetchDone { id }),
            Message::Commit => Event::Control(ControlEvent::Commit),
            Message::Reset { committed } => Event::Control(ControlEvent::Reset { committed }),
            Message::BwTest { payload_bytes, .. } => {
                Event::Control(ControlEvent::BwTest { from, payload_bytes })
            }
            Message::BwAck { payload_bytes } => {
                Event::Control(ControlEvent::BwAck { payload_bytes })
            }
            Message::BwReport { stage, bps, to } => {
                Event::Control(ControlEvent::BwReport { stage, bps, to })
            }
            Message::SetLr { lr } => Event::Control(ControlEvent::SetLr { lr }),
            Message::CentralRestart { committed } => {
                Event::Control(ControlEvent::CentralRestart { from, committed })
            }
            Message::WorkerState { id, committed_fwd, committed_bwd, fresh } => {
                Event::Control(ControlEvent::WorkerState {
                    id,
                    committed_fwd,
                    committed_bwd,
                    fresh,
                })
            }
            Message::SetCompression { tier, links } => {
                Event::Control(ControlEvent::SetCompression { tier, links })
            }
            Message::Shutdown => Event::Shutdown,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_is_total_and_zero_copy() {
        let t = TensorBuf::from(vec![1.0; 64]);
        match Event::from_message(
            3,
            Message::Forward {
                batch: 9,
                version0: 2,
                is_eval: false,
                data: Payload::F32(t.clone()),
            },
        ) {
            Event::Data(DataEvent::Forward { batch: 9, data: Payload::F32(got), .. }) => {
                assert!(got.ptr_eq(&t), "classification must move, not copy");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(Event::from_message(0, Message::Shutdown), Event::Shutdown));
        assert!(matches!(
            Event::from_message(1, Message::Probe),
            Event::Control(ControlEvent::Probe { from: 1 })
        ));
        assert!(matches!(
            Event::from_message(2, Message::BwTest { payload_bytes: 64, data: vec![0; 64] }),
            Event::Control(ControlEvent::BwTest { from: 2, payload_bytes: 64 })
        ));
    }
}
