//! Schedule trace recording — used by `rust/tests/pipeline_schedule.rs`
//! to assert the 1F1B / weight-stashing / aggregation behaviour that the
//! paper's Fig. 2 illustrates.

use std::sync::{Arc, Mutex};

use crate::net::message::DeviceId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    Forward,
    Backward,
    Aggregate,
}

#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub device: DeviceId,
    pub stage: usize,
    pub kind: TraceKind,
    /// batch id (for Aggregate: the bwd_count that triggered it)
    pub batch: u64,
    /// weight version AFTER the event
    pub version: u64,
}

/// Shared sink; None disables tracing.
pub type TraceSink = Option<Arc<Mutex<Vec<TraceEvent>>>>;

pub fn new_sink() -> (TraceSink, Arc<Mutex<Vec<TraceEvent>>>) {
    let v = Arc::new(Mutex::new(Vec::new()));
    (Some(v.clone()), v)
}
