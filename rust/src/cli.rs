//! Hand-rolled CLI argument parsing (clap is not available offline).
//!
//! Flags are `--key value` (or `--flag` for booleans). Unknown keys error.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

/// Parsed flags: `--key value` pairs plus positional arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean-style if next is another flag or end
                if i + 1 >= argv.len() || argv[i + 1].starts_with("--") {
                    out.flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else {
                    out.flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Millisecond duration flag, e.g. `--net-down-ttl-ms 250`.
    pub fn get_duration_ms(&self, key: &str, default: Duration) -> Result<Duration> {
        match self.get(key) {
            Some(v) => v
                .parse::<u64>()
                .map(Duration::from_millis)
                .map_err(|_| anyhow!("--{key} expects milliseconds, got {v:?}")),
            None => Ok(default),
        }
    }

    /// Comma-separated f64 list, e.g. `--capacities 1,2.5,10`.
    pub fn get_f64_list(&self, key: &str) -> Result<Option<Vec<f64>>> {
        match self.get(key) {
            Some(v) => {
                let parsed: Result<Vec<f64>, _> =
                    v.split(',').map(|x| x.trim().parse::<f64>()).collect();
                match parsed {
                    Ok(list) if !list.is_empty() => Ok(Some(list)),
                    _ => bail!("--{key} expects a comma-separated number list, got {v:?}"),
                }
            }
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_mixed() {
        let a = args(&["train", "--model", "artifacts/edgenet", "--verbose", "--epochs", "3"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("model"), Some("artifacts/edgenet"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get_usize("epochs", 1).unwrap(), 3);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn parses_lists() {
        let a = args(&["--capacities", "1,2.5,10"]);
        assert_eq!(a.get_f64_list("capacities").unwrap(), Some(vec![1.0, 2.5, 10.0]));
        assert!(args(&["--capacities", "a,b"]).get_f64_list("capacities").is_err());
    }

    #[test]
    fn rejects_bad_numbers() {
        assert!(args(&["--epochs", "x"]).get_usize("epochs", 1).is_err());
    }

    #[test]
    fn parses_durations_in_ms() {
        let a = args(&["--net-down-ttl-ms", "250"]);
        assert_eq!(
            a.get_duration_ms("net-down-ttl-ms", Duration::ZERO).unwrap(),
            Duration::from_millis(250)
        );
        assert_eq!(
            a.get_duration_ms("missing", Duration::from_secs(1)).unwrap(),
            Duration::from_secs(1)
        );
        assert!(args(&["--net-down-ttl-ms", "fast"])
            .get_duration_ms("net-down-ttl-ms", Duration::ZERO)
            .is_err());
    }
}
