//! Simulated heterogeneous device (DESIGN.md §3 substitution table).
//!
//! Every block execution *actually runs* on XLA-CPU; the capacity model
//! then stretches its wall time by the device's capacity factor (paper
//! eq (1): `C_i` = ratio of this device's execution time to the central
//! node's). Time variation = slow sinusoidal drift + per-execution
//! log-normal noise, which is what exercises the paper's periodic dynamic
//! re-partition. A memory cap reproduces the §IV-F Raspberry-Pi OOM.

use std::time::{Duration, Instant};

use crate::config::DeviceConfig;
use crate::util::rng::Rng;

/// Capacity model of one device.
pub struct SimDevice {
    pub cfg: DeviceConfig,
    rng: Rng,
    start: Instant,
}

impl SimDevice {
    pub fn new(cfg: DeviceConfig, seed: u64) -> SimDevice {
        SimDevice { cfg, rng: Rng::new(seed ^ 0xDE71CE), start: Instant::now() }
    }

    /// Current capacity factor (>= 1.0 is slower than the central node).
    pub fn capacity_now(&mut self) -> f64 {
        let t = self.start.elapsed().as_secs_f64();
        let drift = if self.cfg.drift_amp > 0.0 {
            1.0 + self.cfg.drift_amp
                * (2.0 * std::f64::consts::PI * t / self.cfg.drift_period_s).sin()
        } else {
            1.0
        };
        let noise = if self.cfg.noise > 0.0 {
            (self.cfg.noise * self.rng.normal()).exp()
        } else {
            1.0
        };
        (self.cfg.capacity * drift * noise).max(0.05)
    }

    /// Run `f`, then sleep the extra time a device `capacity`× slower than
    /// this host would have needed. Returns (result, simulated duration).
    pub fn execute<T>(&mut self, f: impl FnOnce() -> T) -> (T, Duration) {
        let cap = self.capacity_now();
        let t0 = Instant::now();
        let out = f();
        let real = t0.elapsed();
        let simulated = real.mul_f64(cap);
        if simulated > real {
            std::thread::sleep(simulated - real);
        }
        (out, simulated.max(real))
    }

    /// Memory-cap check: would `bytes` of state fit on this device?
    pub fn fits_memory(&self, bytes: u64) -> bool {
        match self.cfg.mem_cap_bytes {
            Some(cap) => bytes <= cap,
            None => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_capacity_adds_no_delay() {
        let mut d = SimDevice::new(DeviceConfig::with_capacity(1.0), 1);
        let t0 = Instant::now();
        let ((), dur) = d.execute(|| std::thread::sleep(Duration::from_millis(10)));
        assert!(t0.elapsed() < Duration::from_millis(30));
        assert!(dur >= Duration::from_millis(10));
    }

    #[test]
    fn slow_device_stretches_time() {
        let mut d = SimDevice::new(DeviceConfig::with_capacity(4.0), 2);
        let t0 = Instant::now();
        let ((), dur) = d.execute(|| std::thread::sleep(Duration::from_millis(10)));
        let real = t0.elapsed();
        assert!(real >= Duration::from_millis(35), "real={real:?}");
        assert!(dur >= Duration::from_millis(39), "dur={dur:?}");
    }

    #[test]
    fn noise_varies_capacity() {
        let mut cfg = DeviceConfig::with_capacity(2.0);
        cfg.noise = 0.2;
        let mut d = SimDevice::new(cfg, 3);
        let caps: Vec<f64> = (0..20).map(|_| d.capacity_now()).collect();
        let all_same = caps.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12);
        assert!(!all_same);
        // centered near 2.0
        let mean = caps.iter().sum::<f64>() / caps.len() as f64;
        assert!(mean > 1.2 && mean < 3.2, "mean={mean}");
    }

    #[test]
    fn drift_is_periodic_and_bounded() {
        let mut cfg = DeviceConfig::with_capacity(1.0);
        cfg.drift_amp = 0.5;
        cfg.drift_period_s = 0.05;
        let mut d = SimDevice::new(cfg, 4);
        let mut lo = f64::MAX;
        let mut hi = f64::MIN;
        for _ in 0..50 {
            let c = d.capacity_now();
            lo = lo.min(c);
            hi = hi.max(c);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(hi > 1.2, "hi={hi}");
        assert!(lo < 0.8, "lo={lo}");
        assert!(lo >= 0.05);
    }

    #[test]
    fn memory_cap() {
        let mut cfg = DeviceConfig::default();
        cfg.mem_cap_bytes = Some(1000);
        let d = SimDevice::new(cfg, 5);
        assert!(d.fits_memory(1000));
        assert!(!d.fits_memory(1001));
        let d2 = SimDevice::new(DeviceConfig::default(), 6);
        assert!(d2.fits_memory(u64::MAX));
    }
}
