//! Simulated heterogeneous device (DESIGN.md §3 substitution table).
//!
//! Every block execution *actually runs* on XLA-CPU; the capacity model
//! then stretches its wall time by the device's capacity factor (paper
//! eq (1): `C_i` = ratio of this device's execution time to the central
//! node's). Time variation = slow sinusoidal drift + per-execution
//! log-normal noise, which is what exercises the paper's periodic dynamic
//! re-partition. A memory cap reproduces the §IV-F Raspberry-Pi OOM.
//!
//! Two time models:
//!
//! * **Wall** (default) — measure the closure's real duration, stretch by
//!   the capacity factor, sleep the difference. Used by the live
//!   simulation (`coordinator::run_sim_full`) and the TCP deployment.
//! * **Modeled** — charge `flops × ns_per_flop × capacity` without
//!   measuring or sleeping. All time is read from the [`Clock`] seam, so
//!   execution reports (and therefore capacity estimates and partition
//!   decisions) are bit-for-bit deterministic — this is what the
//!   scenario runner (`sim::runner`) uses on its virtual timeline.

use std::time::{Duration, Instant};

use crate::config::DeviceConfig;
use crate::sim::clock::{real_clock, SharedClock};
use crate::util::rng::Rng;

/// Capacity model of one device.
pub struct SimDevice {
    pub cfg: DeviceConfig,
    rng: Rng,
    clock: SharedClock,
    start: Duration,
    /// `Some(ns_per_flop)` switches to the modeled time model.
    modeled_ns_per_flop: Option<f64>,
}

impl SimDevice {
    /// Wall-time device (production default).
    pub fn new(cfg: DeviceConfig, seed: u64) -> SimDevice {
        SimDevice::with_clock(cfg, seed, real_clock(), None)
    }

    /// Device on an explicit clock, optionally with modeled compute cost.
    pub fn with_clock(
        cfg: DeviceConfig,
        seed: u64,
        clock: SharedClock,
        modeled_ns_per_flop: Option<f64>,
    ) -> SimDevice {
        let start = clock.now();
        SimDevice { cfg, rng: Rng::new(seed ^ 0xDE71CE), clock, start, modeled_ns_per_flop }
    }

    /// Current capacity factor (>= 1.0 is slower than the central node).
    pub fn capacity_now(&mut self) -> f64 {
        let t = self.clock.now().saturating_sub(self.start).as_secs_f64();
        let drift = if self.cfg.drift_amp > 0.0 {
            1.0 + self.cfg.drift_amp
                * (2.0 * std::f64::consts::PI * t / self.cfg.drift_period_s).sin()
        } else {
            1.0
        };
        let noise = if self.cfg.noise > 0.0 {
            (self.cfg.noise * self.rng.normal()).exp()
        } else {
            1.0
        };
        (self.cfg.capacity * drift * noise).max(0.05)
    }

    /// Run `f`, then sleep the extra time a device `capacity`× slower than
    /// this host would have needed. Returns (result, simulated duration).
    pub fn execute<T>(&mut self, f: impl FnOnce() -> T) -> (T, Duration) {
        let cap = self.capacity_now();
        let t0 = Instant::now();
        let out = f();
        let real = t0.elapsed();
        let simulated = real.mul_f64(cap);
        if simulated > real {
            std::thread::sleep(simulated - real);
        }
        (out, simulated.max(real))
    }

    /// Run `f`, charging its cost from `flops` when this device uses the
    /// modeled time model (no measurement, no sleep — the scenario runner
    /// advances virtual time by the returned duration). Wall-time devices
    /// ignore `flops` and behave exactly like [`Self::execute`].
    pub fn execute_flops<T>(&mut self, flops: u64, f: impl FnOnce() -> T) -> (T, Duration) {
        match self.modeled_ns_per_flop {
            None => self.execute(f),
            Some(ns_per_flop) => {
                let cap = self.capacity_now();
                let out = f();
                let ns = (flops as f64 * ns_per_flop * cap).max(1.0);
                (out, Duration::from_nanos(ns as u64))
            }
        }
    }

    /// The modeled duration of `flops` at the current capacity, without
    /// running anything (the runner prices a step before executing it).
    /// None when this device measures wall time instead.
    pub fn modeled_cost(&mut self, flops: u64) -> Option<Duration> {
        let ns_per_flop = self.modeled_ns_per_flop?;
        let cap = self.capacity_now();
        let ns = (flops as f64 * ns_per_flop * cap).max(1.0);
        Some(Duration::from_nanos(ns as u64))
    }

    /// Memory-cap check: would `bytes` of state fit on this device?
    pub fn fits_memory(&self, bytes: u64) -> bool {
        match self.cfg.mem_cap_bytes {
            Some(cap) => bytes <= cap,
            None => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::VirtualClock;

    #[test]
    fn unit_capacity_adds_no_delay() {
        let mut d = SimDevice::new(DeviceConfig::with_capacity(1.0), 1);
        let t0 = Instant::now();
        let ((), dur) = d.execute(|| std::thread::sleep(Duration::from_millis(10)));
        assert!(t0.elapsed() < Duration::from_millis(30));
        assert!(dur >= Duration::from_millis(10));
    }

    #[test]
    fn slow_device_stretches_time() {
        let mut d = SimDevice::new(DeviceConfig::with_capacity(4.0), 2);
        let t0 = Instant::now();
        let ((), dur) = d.execute(|| std::thread::sleep(Duration::from_millis(10)));
        let real = t0.elapsed();
        assert!(real >= Duration::from_millis(35), "real={real:?}");
        assert!(dur >= Duration::from_millis(39), "dur={dur:?}");
    }

    #[test]
    fn noise_varies_capacity() {
        let mut cfg = DeviceConfig::with_capacity(2.0);
        cfg.noise = 0.2;
        let mut d = SimDevice::new(cfg, 3);
        let caps: Vec<f64> = (0..20).map(|_| d.capacity_now()).collect();
        let all_same = caps.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12);
        assert!(!all_same);
        // centered near 2.0
        let mean = caps.iter().sum::<f64>() / caps.len() as f64;
        assert!(mean > 1.2 && mean < 3.2, "mean={mean}");
    }

    #[test]
    fn drift_is_periodic_and_bounded() {
        let mut cfg = DeviceConfig::with_capacity(1.0);
        cfg.drift_amp = 0.5;
        cfg.drift_period_s = 0.05;
        let mut d = SimDevice::new(cfg, 4);
        let mut lo = f64::MAX;
        let mut hi = f64::MIN;
        for _ in 0..50 {
            let c = d.capacity_now();
            lo = lo.min(c);
            hi = hi.max(c);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(hi > 1.2, "hi={hi}");
        assert!(lo < 0.8, "lo={lo}");
        assert!(lo >= 0.05);
    }

    #[test]
    fn modeled_cost_is_deterministic_and_sleepless() {
        let clock = VirtualClock::shared();
        let mut d = SimDevice::with_clock(
            DeviceConfig::with_capacity(3.0),
            7,
            clock.clone(),
            Some(2.0), // 2 ns per flop
        );
        let t0 = Instant::now();
        let ((), dur) = d.execute_flops(1_000_000, || {});
        assert!(t0.elapsed() < Duration::from_millis(50), "modeled mode must not sleep");
        // 1e6 flops * 2 ns * capacity 3.0 = 6 ms, exactly, every time
        assert_eq!(dur, Duration::from_nanos(6_000_000));
        assert_eq!(d.modeled_cost(1_000_000), Some(Duration::from_nanos(6_000_000)));
        let ((), dur2) = d.execute_flops(1_000_000, || {});
        assert_eq!(dur, dur2);
    }

    #[test]
    fn drift_follows_the_virtual_clock() {
        let clock = VirtualClock::shared();
        let mut cfg = DeviceConfig::with_capacity(1.0);
        cfg.drift_amp = 0.5;
        cfg.drift_period_s = 4.0;
        let mut d = SimDevice::with_clock(cfg, 8, clock.clone(), Some(1.0));
        let c0 = d.capacity_now();
        clock.advance(Duration::from_secs(1)); // quarter period: sin = 1
        let c1 = d.capacity_now();
        assert!((c0 - 1.0).abs() < 1e-9, "c0={c0}");
        assert!((c1 - 1.5).abs() < 1e-9, "c1={c1}");
    }

    #[test]
    fn memory_cap() {
        let mut cfg = DeviceConfig::default();
        cfg.mem_cap_bytes = Some(1000);
        let d = SimDevice::new(cfg, 5);
        assert!(d.fits_memory(1000));
        assert!(!d.fits_memory(1001));
        let d2 = SimDevice::new(DeviceConfig::default(), 6);
        assert!(d2.fits_memory(u64::MAX));
    }
}
