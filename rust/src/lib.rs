//! FTPipeHD: fault-tolerant pipeline-parallel distributed training for
//! heterogeneous edge devices — Rust coordinator (Layer 3).
//!
//! See DESIGN.md (repo root) for the event-driven architecture and the
//! zero-copy tensor plumbing. Module map:
//!
//! - [`util`] — offline substrates: JSON, RNG, logging, property tests, bench kit
//! - [`config`] — run configuration (baseline engines are a config toggle,
//!   [`config::Engine`] — there is no separate baselines module)
//! - [`manifest`] — model manifest loader (`artifacts/<model>/manifest.json`)
//! - [`runtime`] — PJRT engine: load HLO text, compile, execute
//! - [`model`] — parameter store (`TensorBuf`-backed, copy-on-write),
//!   SGD+momentum, weight versioning/aggregation
//! - [`data`] — synthetic datasets (vision mixture, Zipf-Markov LM)
//! - [`net`] — shared `TensorBuf`, messages, codec, `Transport` (SimNet +
//!   the event-driven TCP reactor, DESIGN.md §13), and the quantized wire
//!   formats + adaptive compression policy (`net::quant`, DESIGN.md §8/§10)
//! - [`device`] — simulated heterogeneous devices (capacity, memory, faults)
//! - [`profile`] — block profiler + capacity estimation (paper eqs 1–3)
//! - [`partition`] — heterogeneity-aware DP partitioner (paper eqs 4–7)
//! - [`pipeline`] — event-driven async 1F1B engine: typed events,
//!   1F1B schedule, per-stage compute (stashing, vertical sync, aggregation)
//! - [`replication`] — chain + global weight replication (zero-copy pushes)
//! - [`fault`] — failure detection, Algorithm 1 redistribution, recovery
//! - [`checkpoint`] — checkpoint persistence + the [`checkpoint::CoordinatorStore`]
//!   seam (full leadership state behind `DiskSink`/`MemorySink`, DESIGN.md §9/§12)
//! - [`coordinator`] — central-node leadership: the shared pure phase
//!   machine ([`coordinator::PhaseMachine`], DESIGN.md §12) plus its
//!   threaded driver — offline bootstrap, steady-state training,
//!   repartition/recovery, worker admission
//! - [`sim`] — deterministic scenario simulation: the virtual/real
//!   [`sim::Clock`] seam, synthetic native models, and the
//!   discrete-event scenario runner behind `rust/tests/scenarios/`
//! - [`metrics`] — run records and writers

pub mod util;
pub mod cli;
pub mod config;
pub mod data;
pub mod device;
pub mod model;
pub mod net;
pub mod partition;
pub mod profile;

pub mod checkpoint;
pub mod coordinator;
pub mod fault;
pub mod manifest;
pub mod metrics;
pub mod pipeline;
pub mod replication;
pub mod runtime;
pub mod sim;
