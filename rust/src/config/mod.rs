//! Run configuration: devices, links, training hyper-parameters, schedules.
//!
//! Configs are plain structs with builder-style setters (used by the
//! examples/benches) and can be loaded from JSON (used by the CLI).
//! Defaults follow the paper's §IV setup: SGD momentum 0.9, weight decay
//! 4e-5, chain replication every 50 batches, global every 100, dynamic
//! re-partition after 10 batches of epoch 0 and then every 100.

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::net::TcpConfig;
use crate::util::json::Value;

/// Wire-compression policy (off / activations-only / full / full+q4 /
/// adaptive). Defined next to the quantizer in `net::quant`; re-exported
/// here because it is a run-level policy knob selected per message class
/// in [`RunConfig`].
pub use crate::net::quant::Compression;
/// Bandwidth thresholds of the adaptive tier ladder (see
/// `net::quant::AdaptivePolicy`); re-exported for [`RunConfig`] parsing.
pub use crate::net::quant::AdaptiveThresholds;

/// One participating device. `capacity` follows the paper's eq (1): the
/// ratio of this device's per-layer execution time to the central node's
/// (1.0 = as fast as central; 10.0 = ten times slower).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    /// Base capacity multiplier (>= 1.0 is slower than central).
    pub capacity: f64,
    /// Relative amplitude of slow sinusoidal capacity drift (0.0 = static).
    pub drift_amp: f64,
    /// Drift period in seconds.
    pub drift_period_s: f64,
    /// Multiplicative log-normal noise sigma per execution (0.0 = none).
    pub noise: f64,
    /// Memory cap in bytes (None = unlimited). Exceeding it at stage
    /// construction emulates the paper's Raspberry-Pi OOM (§IV-F).
    pub mem_cap_bytes: Option<u64>,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            capacity: 1.0,
            drift_amp: 0.0,
            drift_period_s: 60.0,
            noise: 0.0,
            mem_cap_bytes: None,
        }
    }
}

impl DeviceConfig {
    pub fn with_capacity(c: f64) -> Self {
        DeviceConfig { capacity: c, ..Default::default() }
    }
}

/// Which training engine drives the run (FTPipeHD or a baseline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Full FTPipeHD: dynamic partition + aggregation + fault tolerance.
    FtPipeHd,
    /// PipeDream-style: capacity-blind uniform-cost partition, static.
    PipeDream,
    /// ResPipe-style fault tolerance: chain replication, neighbor takeover.
    ResPipe,
    /// Whole model on device 0.
    SingleDevice,
    /// GPipe-style synchronous pipeline (ablation).
    SyncPipeline,
}

/// A planned fault injection (for experiments; None = no fault).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Device index to kill (1-based worker index in the worker list).
    pub kill_device: usize,
    /// Fire when this batch id starts its backward pass at the central node.
    pub at_batch: u64,
    /// If true the device "restarts" and probes healthy-but-stateless
    /// (paper case 2); if false it stays dead (case 3 path).
    pub restarts: bool,
}

/// Complete configuration of a training run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Directory with `manifest.json` (one compiled model).
    pub model_dir: String,
    /// Device 0 is the central node; the rest are workers.
    pub devices: Vec<DeviceConfig>,
    /// Link bandwidth in bytes/sec between consecutive devices i -> i+1
    /// (and the same value i+1 -> i). Length = devices.len()-1, or one
    /// value broadcast to all links. The paper measures these with ping3.
    pub bandwidth_bps: Vec<f64>,
    /// One-way link latency in seconds (per message).
    pub link_latency_s: f64,
    /// Wire compression: `Off` (f32 everywhere), `Activations` (forward
    /// activations + backward gradients with error feedback), `Full`
    /// (also replica pushes and weight-fetch replies, per-channel scales
    /// on 2-D blocks), `FullQ4` (`Full` with 4-bit replica pushes), or
    /// `Adaptive` (the coordinator walks that ladder per measured link
    /// bandwidth — see [`RunConfig::adaptive`], DESIGN.md §10).
    pub compression: Compression,
    /// Tier thresholds for `Compression::Adaptive` (ignored otherwise).
    pub adaptive: AdaptiveThresholds,
    /// Re-measure link bandwidth every N batches (0 = only at init).
    /// Required for `Adaptive` to see mid-run degradation.
    pub bw_probe_every: u64,
    /// Fixed payload of those periodic probes; 0 (default) auto-sizes
    /// from the last measurement — a fixed small echo is latency-capped
    /// at `payload / rtt` and would mis-rank fast links.
    pub bw_probe_bytes: u64,

    // --- training hyper-parameters (paper §IV-B) ---
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub epochs: usize,
    pub batches_per_epoch: usize,
    /// Validation batches evaluated at each epoch end (0 = skip eval).
    pub eval_batches: usize,

    // --- pipeline ---
    /// Max in-flight batches (the paper's semaphore); None = n_stages.
    pub inflight_limit: Option<usize>,
    /// Weight aggregation interval factor k: stage i aggregates every
    /// k*(n-i) backward steps. None disables aggregation.
    pub agg_interval_k: Option<usize>,

    // --- dynamic re-partition (paper §III-D) ---
    /// Re-partition after this many batches of epoch 0 (paper: 10).
    pub repartition_first: Option<u64>,
    /// Then every this many batches (paper: 100).
    pub repartition_every: Option<u64>,

    // --- replication + fault tolerance (paper §III-E/F) ---
    /// Chain replication period in batches (paper: 50). None disables.
    pub chain_every: Option<u64>,
    /// Global replication period in batches (paper: 100). None disables.
    pub global_every: Option<u64>,
    /// Central-node gradient timeout that triggers the fault handler.
    pub fault_timeout_ms: u64,
    pub fault: Option<FaultPlan>,

    /// Learning-rate schedule: at the START of `epoch`, set lr to the
    /// value (paper §IV-C changes lr at epoch 130).
    pub lr_drops: Vec<(usize, f32)>,
    /// Central-node checkpointing (paper §III-E: periodic save-to-disk
    /// tolerates central failure): (directory, every N batches). The
    /// directory holds numbered `ckpt-*` entries (see
    /// [`crate::checkpoint::DiskSink`]).
    pub checkpoint: Option<(String, u64)>,
    /// Boot from the newest complete checkpoint under this directory
    /// (paper §III-E: "recovering from them every time it fails"):
    /// committed frontier, partition, learning rate, and weights come
    /// from the checkpoint; profiling is skipped in favor of the
    /// manifest's flop counts. An empty/absent directory starts fresh.
    pub resume_from: Option<String>,
    /// Admission quota: the coordinator's
    /// [`crate::coordinator::WorkerRoster`] admits at most this many
    /// workers (the central node is not counted); `None` = unlimited,
    /// the historical behavior. A config whose device list already
    /// exceeds the quota is rejected at validate time.
    pub max_workers: Option<usize>,
    /// Pipeline replicas (hybrid pipeline + data parallelism, DESIGN.md
    /// §14): the fleet is split into this many balanced chains, each fed
    /// a disjoint round-robin data shard and synchronized by periodic
    /// weight averaging. 1 (the default) is the historical single-chain
    /// behavior — every trace stays byte-identical.
    pub replicas: usize,
    /// Cross-replica weight sync period in committed batches per chain
    /// (0 = never; required >= 1 when `replicas > 1`).
    pub sync_every: u64,

    pub engine: Engine,
    pub seed: u64,
    /// Print per-batch progress.
    pub verbose: bool,

    /// TCP transport tuning for multi-process deployments (ignored by
    /// the in-process sim transport). JSON section `"net"`: \
    /// `{"connect_attempts", "connect_backoff_ms", "connect_timeout_ms",
    /// "down_ttl_ms", "coalesce_frames", "flush_on_drop_ms"}` — so
    /// deployments tune dial backoff and queueing without recompiling.
    pub net: TcpConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model_dir: "artifacts/edgenet".into(),
            devices: vec![DeviceConfig::default(); 3],
            bandwidth_bps: vec![12.5e6], // ~100 Mbps WiFi
            link_latency_s: 0.002,
            compression: Compression::Off,
            adaptive: AdaptiveThresholds::default(),
            bw_probe_every: 0,
            bw_probe_bytes: 0,
            lr: 0.01,
            momentum: 0.9,
            weight_decay: 4e-5,
            epochs: 1,
            batches_per_epoch: 100,
            eval_batches: 10,
            inflight_limit: None,
            agg_interval_k: Some(4),
            repartition_first: Some(10),
            repartition_every: Some(100),
            chain_every: Some(50),
            global_every: Some(100),
            fault_timeout_ms: 30_000,
            fault: None,
            lr_drops: vec![],
            checkpoint: None,
            resume_from: None,
            max_workers: None,
            replicas: 1,
            sync_every: 0,
            engine: Engine::FtPipeHd,
            seed: 0,
            verbose: false,
            net: TcpConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Bandwidth of directed link i -> i+1.
    pub fn bandwidth(&self, link: usize) -> f64 {
        if self.bandwidth_bps.len() == 1 {
            self.bandwidth_bps[0]
        } else {
            self.bandwidth_bps[link]
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.devices.is_empty() {
            return Err(anyhow!("need at least one device"));
        }
        if self.bandwidth_bps.len() != 1
            && self.bandwidth_bps.len() + 1 != self.devices.len()
        {
            return Err(anyhow!(
                "bandwidth_bps must have 1 or n_devices-1 entries (got {})",
                self.bandwidth_bps.len()
            ));
        }
        if self.devices[0].capacity != 1.0 {
            return Err(anyhow!("device 0 (central) capacity must be 1.0 (paper eq 1)"));
        }
        if let Some(f) = &self.fault {
            if f.kill_device == 0 || f.kill_device >= self.devices.len() {
                return Err(anyhow!("fault.kill_device must be a worker index"));
            }
        }
        if self.compression == Compression::Adaptive {
            self.adaptive.validate()?;
        }
        if let Some(q) = self.max_workers {
            let workers = self.devices.len().saturating_sub(1);
            if workers > q {
                return Err(anyhow!(
                    "max_workers {q} cannot admit the {workers} configured workers"
                ));
            }
        }
        if self.replicas == 0 {
            return Err(anyhow!("replicas must be >= 1"));
        }
        if self.replicas > 1 {
            if self.devices.len() < self.replicas {
                return Err(anyhow!(
                    "{} devices cannot form {} replica chains",
                    self.devices.len(),
                    self.replicas
                ));
            }
            if self.sync_every == 0 {
                return Err(anyhow!("replicas > 1 requires sync_every >= 1"));
            }
        }
        Ok(())
    }

    /// Parse from a JSON object (all fields optional; see Default).
    pub fn from_json(v: &Value) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        let getf = |v: &Value, k: &str| v.get(k).and_then(|x| x.as_f64());
        let getu = |v: &Value, k: &str| v.get(k).and_then(|x| x.as_usize());
        if let Some(s) = v.get("model_dir").and_then(|x| x.as_str()) {
            c.model_dir = s.to_string();
        }
        if let Some(devs) = v.get("devices").and_then(|x| x.as_arr()) {
            c.devices = devs
                .iter()
                .map(|d| {
                    let mut dc = DeviceConfig::default();
                    if let Some(x) = getf(d, "capacity") {
                        dc.capacity = x;
                    }
                    if let Some(x) = getf(d, "drift_amp") {
                        dc.drift_amp = x;
                    }
                    if let Some(x) = getf(d, "drift_period_s") {
                        dc.drift_period_s = x;
                    }
                    if let Some(x) = getf(d, "noise") {
                        dc.noise = x;
                    }
                    if let Some(x) = getf(d, "mem_cap_bytes") {
                        dc.mem_cap_bytes = Some(x as u64);
                    }
                    dc
                })
                .collect();
        }
        if let Some(b) = v.get("bandwidth_bps").and_then(|x| x.as_arr()) {
            c.bandwidth_bps = b.iter().filter_map(|x| x.as_f64()).collect();
        }
        if let Some(x) = getf(v, "link_latency_s") {
            c.link_latency_s = x;
        }
        if let Some(s) = v.get("compression").and_then(|x| x.as_str()) {
            c.compression = Compression::parse(s).ok_or_else(|| {
                anyhow!("unknown compression {s:?} (off|activations|full|full+q4|adaptive)")
            })?;
        }
        if let Some(a) = v.get("adaptive") {
            if *a != Value::Null {
                if let Some(x) = getf(a, "activations_below") {
                    c.adaptive.activations_below = x;
                }
                if let Some(x) = getf(a, "full_below") {
                    c.adaptive.full_below = x;
                }
                if let Some(x) = getf(a, "q4_below") {
                    c.adaptive.q4_below = x;
                }
                if let Some(x) = getf(a, "relax_factor") {
                    c.adaptive.relax_factor = x;
                }
                if let Some(s) = a.get("tier_floor").and_then(|x| x.as_str()) {
                    c.adaptive.tier_floor = crate::net::quant::Tier::parse(s).ok_or_else(|| {
                        anyhow!("unknown tier_floor {s:?} (off|activations|full|full+q4)")
                    })?;
                }
                if let Some(s) = a.get("tier_ceiling").and_then(|x| x.as_str()) {
                    c.adaptive.tier_ceiling = crate::net::quant::Tier::parse(s).ok_or_else(|| {
                        anyhow!("unknown tier_ceiling {s:?} (off|activations|full|full+q4)")
                    })?;
                }
            }
        }
        if let Some(x) = getu(v, "bw_probe_every") {
            c.bw_probe_every = x as u64;
        }
        if let Some(x) = getu(v, "bw_probe_bytes") {
            c.bw_probe_bytes = x as u64;
        }
        if let Some(x) = getf(v, "lr") {
            c.lr = x as f32;
        }
        if let Some(x) = getf(v, "momentum") {
            c.momentum = x as f32;
        }
        if let Some(x) = getf(v, "weight_decay") {
            c.weight_decay = x as f32;
        }
        if let Some(x) = getu(v, "epochs") {
            c.epochs = x;
        }
        if let Some(x) = getu(v, "batches_per_epoch") {
            c.batches_per_epoch = x;
        }
        if let Some(x) = getu(v, "eval_batches") {
            c.eval_batches = x;
        }
        if let Some(x) = getu(v, "inflight_limit") {
            c.inflight_limit = Some(x);
        }
        if v.get("agg_interval_k") == Some(&Value::Null) {
            c.agg_interval_k = None;
        } else if let Some(x) = getu(v, "agg_interval_k") {
            c.agg_interval_k = Some(x);
        }
        if let Some(x) = getu(v, "repartition_first") {
            c.repartition_first = Some(x as u64);
        }
        if let Some(x) = getu(v, "repartition_every") {
            c.repartition_every = Some(x as u64);
        }
        if let Some(x) = getu(v, "chain_every") {
            c.chain_every = Some(x as u64);
        }
        if let Some(x) = getu(v, "global_every") {
            c.global_every = Some(x as u64);
        }
        if let Some(x) = getu(v, "fault_timeout_ms") {
            c.fault_timeout_ms = x as u64;
        }
        if let Some(f) = v.get("fault") {
            if *f != Value::Null {
                c.fault = Some(FaultPlan {
                    kill_device: getu(f, "kill_device")
                        .ok_or_else(|| anyhow!("fault.kill_device required"))?,
                    at_batch: getu(f, "at_batch")
                        .ok_or_else(|| anyhow!("fault.at_batch required"))? as u64,
                    restarts: f.get("restarts").and_then(|x| x.as_bool()).unwrap_or(false),
                });
            }
        }
        if let Some(ckpt) = v.get("checkpoint") {
            if *ckpt != Value::Null {
                let dir = ckpt
                    .get("dir")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("checkpoint.dir required"))?;
                let every =
                    getu(ckpt, "every").ok_or_else(|| anyhow!("checkpoint.every required"))?;
                c.checkpoint = Some((dir.to_string(), every as u64));
            }
        }
        if let Some(s) = v.get("resume_from").and_then(|x| x.as_str()) {
            c.resume_from = Some(s.to_string());
        }
        if let Some(x) = getu(v, "max_workers") {
            c.max_workers = Some(x);
        }
        if let Some(x) = getu(v, "replicas") {
            c.replicas = x;
        }
        if let Some(x) = getu(v, "sync_every") {
            c.sync_every = x as u64;
        }
        if let Some(s) = v.get("engine").and_then(|x| x.as_str()) {
            c.engine = match s {
                "ftpipehd" => Engine::FtPipeHd,
                "pipedream" => Engine::PipeDream,
                "respipe" => Engine::ResPipe,
                "single" => Engine::SingleDevice,
                "sync" => Engine::SyncPipeline,
                other => return Err(anyhow!("unknown engine {other:?}")),
            };
        }
        if let Some(x) = getu(v, "seed") {
            c.seed = x as u64;
        }
        if let Some(x) = v.get("verbose").and_then(|x| x.as_bool()) {
            c.verbose = x;
        }
        if let Some(n) = v.get("net") {
            if *n != Value::Null {
                let ms = |x: usize| Duration::from_millis(x as u64);
                let mut b = c.net.to_builder();
                if let Some(x) = getu(n, "connect_attempts") {
                    b = b.connect_attempts(x as u32);
                }
                if let Some(x) = getu(n, "connect_backoff_ms") {
                    b = b.connect_backoff(ms(x));
                }
                if let Some(x) = getu(n, "connect_timeout_ms") {
                    b = b.connect_timeout(ms(x));
                }
                if let Some(x) = getu(n, "down_ttl_ms") {
                    b = b.down_ttl(ms(x));
                }
                if let Some(x) = getu(n, "coalesce_frames") {
                    b = b.coalesce_frames(x);
                }
                if let Some(x) = getu(n, "flush_on_drop_ms") {
                    b = b.flush_on_drop(ms(x));
                }
                c.net = b.build();
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &str) -> Result<RunConfig> {
        let raw = std::fs::read_to_string(path)?;
        let v = crate::util::json::parse(&raw).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_json() {
        let v = json::parse(
            r#"{
              "model_dir": "artifacts/edgenet",
              "devices": [{"capacity":1.0},{"capacity":2.5},{"capacity":10.0,"noise":0.05}],
              "bandwidth_bps": [12500000, 2000000],
              "lr": 0.1, "epochs": 3, "batches_per_epoch": 50,
              "engine": "pipedream",
              "compression": "full",
              "fault": {"kill_device": 1, "at_batch": 205}
            }"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.devices.len(), 3);
        assert_eq!(c.devices[2].capacity, 10.0);
        assert_eq!(c.engine, Engine::PipeDream);
        assert_eq!(c.compression, Compression::Full);
        assert_eq!(c.fault.as_ref().unwrap().at_batch, 205);
        assert_eq!(c.bandwidth(1), 2_000_000.0);
    }

    #[test]
    fn parse_net_section() {
        let v = json::parse(
            r#"{
              "net": {"connect_attempts": 9, "connect_backoff_ms": 25,
                      "connect_timeout_ms": 800, "down_ttl_ms": 250,
                      "coalesce_frames": 4, "flush_on_drop_ms": 500}
            }"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.net.connect_attempts(), 9);
        assert_eq!(c.net.connect_backoff(), Duration::from_millis(25));
        assert_eq!(c.net.connect_timeout(), Duration::from_millis(800));
        assert_eq!(c.net.down_ttl(), Duration::from_millis(250));
        assert_eq!(c.net.coalesce_frames(), 4);
        assert_eq!(c.net.flush_on_drop(), Duration::from_millis(500));
        // partial sections override only what they name; absent/null
        // sections keep the defaults
        let v = json::parse(r#"{"net": {"down_ttl_ms": 10}}"#).unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.net.down_ttl(), Duration::from_millis(10));
        assert_eq!(c.net.connect_attempts(), TcpConfig::default().connect_attempts());
        let v = json::parse(r#"{"net": null}"#).unwrap();
        assert_eq!(RunConfig::from_json(&v).unwrap().net, TcpConfig::default());
    }

    #[test]
    fn compression_defaults_off_and_rejects_unknown() {
        assert_eq!(RunConfig::default().compression, Compression::Off);
        let v = json::parse(r#"{"compression": "activations"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&v).unwrap().compression, Compression::Activations);
        let v = json::parse(r#"{"compression": "zstd"}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn parse_adaptive_compression_with_thresholds() {
        let v = json::parse(
            r#"{
              "compression": "adaptive",
              "bw_probe_every": 5,
              "bw_probe_bytes": 2048,
              "adaptive": {"activations_below": 3e6, "full_below": 4e5,
                           "q4_below": 1.5e5, "relax_factor": 2.0}
            }"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.compression, Compression::Adaptive);
        assert_eq!(c.bw_probe_every, 5);
        assert_eq!(c.bw_probe_bytes, 2048);
        assert_eq!(c.adaptive.full_below, 4e5);
        assert_eq!(c.adaptive.relax_factor, 2.0);
        // the band defaults to the whole ladder when unspecified
        assert_eq!(c.adaptive.tier_floor, crate::net::quant::Tier::Off);
        assert_eq!(c.adaptive.tier_ceiling, crate::net::quant::Tier::FullQ4);
        // full+q4 is a legal static policy too
        let v = json::parse(r#"{"compression": "full+q4"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&v).unwrap().compression, Compression::FullQ4);
        // unordered thresholds are rejected at validate time
        let v = json::parse(
            r#"{"compression": "adaptive", "adaptive": {"q4_below": 9e9}}"#,
        )
        .unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn parse_adaptive_tier_band() {
        let v = json::parse(
            r#"{"compression": "adaptive",
                "adaptive": {"tier_floor": "activations", "tier_ceiling": "full"}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.adaptive.tier_floor, crate::net::quant::Tier::Activations);
        assert_eq!(c.adaptive.tier_ceiling, crate::net::quant::Tier::Full);
        // unknown tier name is a parse error, not a silent default
        let v = json::parse(
            r#"{"compression": "adaptive", "adaptive": {"tier_floor": "fastest"}}"#,
        )
        .unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        // an inverted band dies at validate time
        let v = json::parse(
            r#"{"compression": "adaptive",
                "adaptive": {"tier_floor": "full", "tier_ceiling": "activations"}}"#,
        )
        .unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn parse_checkpoint_and_resume() {
        let v = json::parse(
            r#"{"checkpoint": {"dir": "/tmp/ck", "every": 25}, "resume_from": "/tmp/ck"}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.checkpoint, Some(("/tmp/ck".to_string(), 25)));
        assert_eq!(c.resume_from.as_deref(), Some("/tmp/ck"));
        // an incomplete checkpoint object is an error, not a silent skip
        let v = json::parse(r#"{"checkpoint": {"dir": "/tmp/ck"}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        // explicit null disables cleanly
        let v = json::parse(r#"{"checkpoint": null}"#).unwrap();
        assert_eq!(RunConfig::from_json(&v).unwrap().checkpoint, None);
    }

    #[test]
    fn parse_and_validate_max_workers() {
        let v = json::parse(r#"{"max_workers": 8}"#).unwrap();
        assert_eq!(RunConfig::from_json(&v).unwrap().max_workers, Some(8));
        assert_eq!(RunConfig::default().max_workers, None);
        // quota below the configured worker count dies at validate time
        let v = json::parse(
            r#"{"devices": [{"capacity":1.0},{"capacity":2.0},{"capacity":2.0}],
                "max_workers": 1}"#,
        )
        .unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn parse_and_validate_replicas() {
        // default: single chain, no sync — the historical world
        assert_eq!(RunConfig::default().replicas, 1);
        assert_eq!(RunConfig::default().sync_every, 0);
        let v = json::parse(r#"{"replicas": 2, "sync_every": 10}"#).unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!((c.replicas, c.sync_every), (2, 10));
        // replicas > 1 without a sync period dies at validate time
        let v = json::parse(r#"{"replicas": 2}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        // zero replicas is nonsense
        let mut c = RunConfig::default();
        c.replicas = 0;
        assert!(c.validate().is_err());
        // more chains than devices is impossible
        let v = json::parse(r#"{"replicas": 4, "sync_every": 5}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err(), "3 default devices < 4 replicas");
    }

    #[test]
    fn rejects_bad_central_capacity() {
        let mut c = RunConfig::default();
        c.devices[0].capacity = 2.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_bad_fault_index() {
        let mut c = RunConfig::default();
        c.fault = Some(FaultPlan { kill_device: 0, at_batch: 1, restarts: false });
        assert!(c.validate().is_err());
    }
}
