//! The central node's steady-state phase: batch injection, the event
//! dispatch loop, completion accounting, evaluation, and checkpointing.
//!
//! [`Central`] wraps the stage-0 [`StageWorker`] plus everything only the
//! coordinator holds (dataset, profile, capacity estimator, fault
//! detector, metrics). Incoming traffic is classified into the same
//! [`Event`] vocabulary the workers use; the steady-state loop
//! ([`Central::run_training`]) is the standard pump: inject up to the
//! in-flight limit, drain events, run stage-0 compute, check the fault
//! detector and the re-partition/checkpoint schedules.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::checkpoint::{CoordinatorStore, LeaderState};
use crate::config::{Engine, RunConfig};
use crate::coordinator::core::{PhaseMachine, WorkerRoster};
use crate::data::DataSource;
use crate::fault::FaultDetector;
use crate::manifest::{Dtype, Manifest};
use crate::metrics::{BatchRecord, EpochRecord, RunClock, RunRecord};
use crate::model::BlockParams;
use crate::net::message::{DeviceId, Message, TrainInit};
use crate::net::quant::AdaptivePolicy;
use crate::net::{SimEndpoint, SimNet, Transport};
use crate::partition::Partition;
use crate::pipeline::{CompletedBatch, ControlEvent, DataEvent, Event, StageWorker};
use crate::profile::{CapacityEstimator, ModelProfile};
use crate::runtime::HostTensor;
use crate::{log_info, log_warn};

use std::sync::Arc;

pub(crate) struct Central {
    pub(crate) cfg: RunConfig,
    pub(crate) manifest: Arc<Manifest>,
    pub(crate) worker: StageWorker,
    pub(crate) endpoint: SimEndpoint,
    pub(crate) net: SimNet,
    pub(crate) profile: ModelProfile,
    pub(crate) estimator: CapacityEstimator,
    pub(crate) detector: FaultDetector,
    /// Per-link bandwidth from BwReports, keyed by destination device
    /// (not boot-time stage index — the key survives renumbering, and a
    /// worker admitted beyond the boot roster gets an entry instead of
    /// being silently dropped by a fixed-size guard). Pruned on every
    /// worker-list change ([`crate::coordinator::core::prune_link_state`]).
    pub(crate) measured_bw: BTreeMap<DeviceId, f64>,
    /// Per-link tier controller for `Compression::Adaptive` (None
    /// otherwise): each BwReport feeds its destination's ladder, and any
    /// ladder change broadcasts the full per-link table in
    /// `SetCompression` (DESIGN.md §10).
    pub(crate) adaptive: Option<AdaptivePolicy>,
    pub(crate) record: RunRecord,
    pub(crate) clock: RunClock,
    // training pointers
    pub(crate) next_inject: u64,
    pub(crate) inflight: usize,
    pub(crate) completed: i64,
    pub(crate) total_batches: u64,
    pub(crate) last_completion_s: f64,
    // per-epoch accumulators
    pub(crate) epoch_correct: f64,
    pub(crate) epoch_batches: u64,
    // fault plan
    pub(crate) fault_armed: bool,
    pub(crate) last_checkpoint: u64,
    /// Coordinator state store (paper §III-E plus DESIGN.md §12) — the
    /// disk store in real runs, None when checkpointing is off. The same
    /// seam the deterministic harness fills with its in-memory store.
    pub(crate) store: Option<Box<dyn CoordinatorStore>>,
    pub(crate) data: Box<dyn DataSource>,
    /// The shared phase machine ([`crate::coordinator::core`]): this
    /// driver feeds it observations and executes the effects it returns;
    /// `sim::runner` drives the very same transitions.
    pub(crate) machine: PhaseMachine,
    /// Worker admission roster, capacity-bounded by `cfg.max_workers`.
    pub(crate) roster: WorkerRoster,
    /// Replica version epoch (DESIGN.md §9): bumped once per coordinator
    /// restart so a stale pre-restart backup can never outrank a
    /// post-restart push in the replica version race.
    pub(crate) replica_epoch: u64,
}

impl Central {
    pub(crate) fn n_stages(&self) -> usize {
        self.worker.n_stages()
    }

    fn last_device(&self) -> DeviceId {
        *self.worker.worker_list.last().unwrap()
    }

    fn limit(&self) -> usize {
        match self.cfg.engine {
            Engine::SyncPipeline => 1,
            _ => self.cfg.inflight_limit.unwrap_or(self.n_stages()),
        }
    }

    // ------------------------------------------------------------------
    // injection
    // ------------------------------------------------------------------

    fn inject_one(&mut self) -> Result<()> {
        let batch = self.next_inject;
        let data = self.data.train_batch(batch, self.manifest.batch_size);
        // labels go straight to the last stage (central holds the data)
        if self.n_stages() > 1 {
            self.endpoint.send(
                self.last_device(),
                Message::Labels { batch, is_eval: false, data: data.labels.clone() },
            )?;
        } else {
            self.worker.handle_message(&self.endpoint, 0, Message::Labels {
                batch,
                is_eval: false,
                data: data.labels.clone(),
            })?;
        }
        // the input tensor is moved (not copied) into the pipeline
        let x = match self.manifest.input_dtype {
            Dtype::F32 => HostTensor::F32(data.x_f32.into()),
            Dtype::I32 => HostTensor::I32(data.x_i32),
        };
        let done = self
            .worker
            .forward_train(&self.endpoint, batch, self.worker.version, x)?;
        self.detector.arm(batch);
        self.inflight += 1;
        self.next_inject += 1;
        if let Some(cb) = done {
            // single-stage pipeline completes synchronously
            self.on_complete(cb)?;
        }
        // fault injection: kill the worker while this batch is in flight
        if let Some(f) = self.cfg.fault.clone() {
            if !self.fault_armed && batch + 1 >= f.at_batch {
                self.fault_armed = true;
                let dev = f.kill_device;
                log_info!("FAULT INJECTION: killing device {dev} at batch {batch}");
                self.record.event(&self.clock, format!("kill device {dev}"));
                self.net.kill(dev);
                if f.restarts {
                    // the device restarts (empty state) almost immediately
                    let net = self.net.clone();
                    std::thread::spawn(move || {
                        std::thread::sleep(Duration::from_millis(300));
                        net.revive(dev);
                    });
                }
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // completion
    // ------------------------------------------------------------------

    pub(crate) fn on_complete(&mut self, cb: CompletedBatch) -> Result<()> {
        self.detector.disarm(cb.batch);
        self.inflight = self.inflight.saturating_sub(1);
        self.completed = self.completed.max(cb.batch as i64);
        for r in &cb.reports {
            self.estimator.ingest(r);
        }
        let now = self.clock.now_s();
        let wall_ms = (now - self.last_completion_s) * 1e3;
        self.last_completion_s = now;
        let acc = cb.ncorrect / self.manifest.acc_denom as f32;
        self.epoch_correct += cb.ncorrect as f64;
        self.epoch_batches += 1;
        if self.cfg.verbose {
            log_info!(
                "batch {} loss={:.4} acc={:.3} wall={:.1}ms inflight={}",
                cb.batch,
                cb.loss,
                acc,
                wall_ms,
                self.inflight
            );
        }
        self.record.batches.push(BatchRecord {
            batch: cb.batch,
            loss: cb.loss,
            train_acc: acc,
            wall_ms,
            at_s: now,
        });
        Ok(())
    }

    // ------------------------------------------------------------------
    // event dispatch
    // ------------------------------------------------------------------

    /// Handle one incoming message at the central node: classify into the
    /// shared [`Event`] vocabulary and dispatch.
    pub(crate) fn on_message(&mut self, from: DeviceId, msg: Message) -> Result<()> {
        self.on_event(Event::from_message(from, msg))
    }

    /// Central-specific event handling; everything else shares the
    /// stage-0 worker's handlers.
    pub(crate) fn on_event(&mut self, ev: Event) -> Result<()> {
        match ev {
            Event::Data(DataEvent::Backward { batch, grad, loss, ncorrect, reports }) => {
                if self.worker.status == 0 {
                    let done = self
                        .worker
                        .backward(&self.endpoint, batch, grad, loss, ncorrect, reports)?;
                    if let Some(cb) = done {
                        self.on_complete(cb)?;
                    }
                }
            }
            // eval results are consumed by `pump_for` during evaluation;
            // one arriving outside an eval window is stale — drop it
            Event::Data(DataEvent::EvalResult { .. }) => {}
            Event::Control(ControlEvent::BwReport { stage, bps, to }) => {
                // key by the probed destination device; fall back to
                // resolving the reporter's stage against the *live*
                // worker list for pre-v7 reports (to == 0). Reports for
                // devices no longer in the pipeline are stale — drop
                // them instead of resurrecting a pruned link.
                let dest = if to != 0 {
                    to
                } else {
                    self.worker.worker_list.get(stage + 1).copied().unwrap_or(0)
                };
                if dest != 0 && self.worker.worker_list.contains(&dest) {
                    self.measured_bw.insert(dest, bps);
                    self.maybe_adapt(dest, bps)?;
                }
            }
            Event::Control(ControlEvent::Weights { from, blocks }) => {
                self.worker.handle_weights(&self.endpoint, from, blocks)?;
            }
            other => {
                // control traffic shared with workers (replica pushes into
                // the global store, fetch serving, probes, bw tests, ...)
                self.worker.on_event(&self.endpoint, other)?;
            }
        }
        Ok(())
    }

    /// Feed one link measurement to the per-link adaptive controller; on
    /// a ladder change, install the new table on the local stage and
    /// broadcast `SetCompression` to every worker. A no-op for static
    /// policies. Only the reported destination's ladder can move — every
    /// other link keeps its tier (the one-bad-link blast radius fix).
    pub(crate) fn maybe_adapt(&mut self, dest: DeviceId, bps: f64) -> Result<()> {
        let Some(policy) = self.adaptive.as_mut() else {
            return Ok(());
        };
        let old = policy.tier_for(dest);
        if let Some(tier) = policy.observe(dest, bps) {
            let floor = policy.thresholds().tier_floor;
            let links = policy.overrides();
            log_info!(
                "adaptive compression: link ->{dest} {bps:.0} B/s, tier {} -> {}",
                old.name(),
                tier.name()
            );
            self.record.event(
                &self.clock,
                format!(
                    "adaptive: link ->{dest} {bps:.0} B/s; tier {} -> {}",
                    old.name(),
                    tier.name()
                ),
            );
            self.worker.apply_compression(floor, &links);
            self.broadcast_compression(floor, &links);
        }
        Ok(())
    }

    /// Broadcast the current per-link table to every worker,
    /// log-and-continue per peer: under the TCP transport's down-peer
    /// fast-fail a known-dead peer fails synchronously, and one dead
    /// peer must not crash the coordinator mid-broadcast — the fault
    /// detector owns death, and the post-recovery rebroadcast re-aligns
    /// any peer that missed a table.
    fn broadcast_compression(&mut self, tier: crate::net::quant::Tier, links: &[(DeviceId, crate::net::quant::Tier)]) {
        let peers: Vec<DeviceId> =
            self.worker.worker_list.iter().copied().filter(|&d| d != 0).collect();
        broadcast_compression(&self.endpoint, &peers, tier, links);
    }

    /// Re-send the adaptive controller's current per-link table to
    /// `peers` and the local stage (no-op for static policies, or when
    /// every ladder sits at the floor — exactly the state a reset or
    /// re-inited worker already boots in). Recovery calls this after its
    /// Resets: the controller won't repeat an unchanged table on its own.
    pub(crate) fn rebroadcast_tier(&mut self, peers: &[DeviceId]) -> Result<()> {
        let Some(policy) = self.adaptive.as_ref() else {
            return Ok(());
        };
        let links = policy.overrides();
        if links.is_empty() {
            return Ok(());
        }
        let floor = policy.thresholds().tier_floor;
        self.worker.apply_compression(floor, &links);
        broadcast_compression(&self.endpoint, peers, floor, &links);
        Ok(())
    }

    /// Drain the inbox for up to `dur`, dispatching everything. Returns
    /// the eval results observed. Deadlines run on the [`RunClock`]'s
    /// time source (the `Clock` seam), not raw wall time.
    pub(crate) fn pump_for(&mut self, dur: Duration) -> Result<Vec<(u64, f32, f32)>> {
        let deadline = self.clock.raw_now() + dur;
        let mut evals = Vec::new();
        loop {
            let left = deadline.saturating_sub(self.clock.raw_now());
            match self.endpoint.recv_timeout(left.min(Duration::from_millis(5))) {
                Some((from, msg)) => match Event::from_message(from, msg) {
                    Event::Data(DataEvent::EvalResult { batch, loss, ncorrect }) => {
                        evals.push((batch, loss, ncorrect));
                    }
                    ev => self.on_event(ev)?,
                },
                None => {}
            }
            if self.clock.raw_now() >= deadline {
                return Ok(evals);
            }
        }
    }

    /// Wait until all in-flight batches complete (or a fault fires).
    pub(crate) fn drain(&mut self) -> Result<()> {
        let deadline =
            self.clock.raw_now() + Duration::from_millis(self.cfg.fault_timeout_ms * 2);
        while self.inflight > 0 {
            if let Some((from, msg)) = self.endpoint.recv_timeout(Duration::from_millis(5)) {
                self.on_message(from, msg)?;
            }
            if let Some(b) = self.detector.overdue() {
                self.handle_fault(b)?;
            }
            if self.clock.raw_now() > deadline {
                bail!("drain timed out with {} in flight", self.inflight);
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // evaluation (forward-only through the pipeline)
    // ------------------------------------------------------------------

    fn evaluate(&mut self) -> Result<(f32, f32)> {
        let nb = self.cfg.eval_batches as u64;
        if nb == 0 {
            return Ok((f32::NAN, f32::NAN));
        }
        self.drain()?;
        let mut results: Vec<(f32, f32)> = Vec::new();
        for b in 0..nb {
            let data = self.data.val_batch(b, self.manifest.batch_size);
            if self.n_stages() > 1 {
                self.endpoint.send(
                    self.last_device(),
                    Message::Labels { batch: b, is_eval: true, data: data.labels.clone() },
                )?;
            } else {
                self.worker.handle_message(&self.endpoint, 0, Message::Labels {
                    batch: b,
                    is_eval: true,
                    data: data.labels.clone(),
                })?;
            }
            let x = match self.manifest.input_dtype {
                Dtype::F32 => HostTensor::F32(data.x_f32.into()),
                Dtype::I32 => HostTensor::I32(data.x_i32),
            };
            if let Some((loss, nc)) = self.worker.forward_eval(&self.endpoint, b, x)? {
                results.push((loss, nc));
            }
        }
        // collect results coming back from the last stage
        let deadline = self.clock.raw_now() + Duration::from_secs(120);
        while results.len() < nb as usize {
            let evals = self.pump_for(Duration::from_millis(20))?;
            for (_, l, c) in evals {
                results.push((l, c));
            }
            if self.clock.raw_now() > deadline {
                log_warn!("eval timed out: {}/{} results", results.len(), nb);
                break;
            }
        }
        if results.is_empty() {
            return Ok((f32::NAN, f32::NAN));
        }
        let n = results.len() as f32;
        let loss = results.iter().map(|(l, _)| l).sum::<f32>() / n;
        let acc = results.iter().map(|(_, c)| c).sum::<f32>()
            / (n * self.manifest.acc_denom as f32);
        Ok((loss, acc))
    }

    // ------------------------------------------------------------------
    // checkpointing (paper §III-E)
    // ------------------------------------------------------------------

    /// Save everything the coordinator holds — its own stage + the newest
    /// global/chain replicas, per-link bandwidths and tiers, the
    /// replica epoch, and the admission roster — through the
    /// [`CoordinatorStore`]. Completeness of the worker stages depends on
    /// the replication period — exactly the paper's §III-E tradeoff. The
    /// snapshot itself is [`StageWorker::snapshot_checkpoint`], shared
    /// with the deterministic harness.
    fn save_checkpoint(&mut self, epoch: u64) -> Result<()> {
        // single gate, before any snapshot work is done
        let Some(store) = self.store.as_mut() else {
            return Ok(());
        };
        let checkpoint = self.worker.snapshot_checkpoint(self.completed, epoch);
        let n_blocks = checkpoint.weights.len();
        let (worker_quota, admitted) = self.roster.snapshot();
        let st = LeaderState {
            checkpoint,
            link_bw: self.measured_bw.iter().map(|(&d, &b)| (d, b)).collect(),
            link_tiers: self.adaptive.as_ref().map(|p| p.overrides()).unwrap_or_default(),
            replica_epoch: self.replica_epoch,
            worker_quota,
            admitted,
        };
        store.save_leader(&st)?;
        self.record.event(
            &self.clock,
            format!("checkpoint at batch {} ({} blocks)", self.completed, n_blocks),
        );
        Ok(())
    }

    pub(crate) fn train_init(
        &self,
        ranges: Partition,
        worker_list: Vec<DeviceId>,
        status: u8,
    ) -> TrainInit {
        let agg = match self.cfg.engine {
            Engine::FtPipeHd => self.cfg.agg_interval_k.unwrap_or(0) as u32,
            _ => 0,
        };
        let (chain, global) = match self.cfg.engine {
            Engine::FtPipeHd => (
                self.cfg.chain_every.unwrap_or(0),
                self.cfg.global_every.unwrap_or(0),
            ),
            Engine::ResPipe => (self.cfg.chain_every.unwrap_or(0), 0),
            _ => (0, 0),
        };
        TrainInit {
            committed_forward: -1,
            committed_backward: -1,
            lr: self.cfg.lr,
            momentum: self.cfg.momentum,
            weight_decay: self.cfg.weight_decay,
            epochs: self.cfg.epochs as u64,
            batches_per_epoch: self.cfg.batches_per_epoch as u64,
            ranges,
            worker_list,
            agg_k: agg,
            chain_every: chain,
            global_every: global,
            status,
            compression: self.cfg.compression,
            bw_probe_every: self.cfg.bw_probe_every,
            bw_probe_bytes: self.cfg.bw_probe_bytes,
            tier_floor: self.cfg.adaptive.tier_floor,
            tier_ceiling: self.cfg.adaptive.tier_ceiling,
            replica_epoch: self.replica_epoch,
            worker_quota: self.roster.quota_wire(),
            replicas: self.cfg.replicas as u64,
            sync_every: self.cfg.sync_every,
        }
    }

    // ------------------------------------------------------------------
    // the steady-state training phase
    // ------------------------------------------------------------------

    /// Drive training to completion: the online stage of the paper's
    /// protocol, with fault detection and the dynamic re-partition and
    /// checkpoint schedules folded into the loop.
    pub(crate) fn run_training(&mut self) -> Result<()> {
        self.record.event(&self.clock, "training start".to_string());

        let repart_first = match self.cfg.engine {
            Engine::FtPipeHd => self.cfg.repartition_first,
            _ => None,
        };
        let repart_every = match self.cfg.engine {
            Engine::FtPipeHd => self.cfg.repartition_every,
            _ => None,
        };
        let mut next_repart: Option<u64> = repart_first;
        let batches_per_epoch = self.cfg.batches_per_epoch as u64;
        // a resumed run (paper §III-E restart) starts mid-schedule: pick
        // up in the epoch the committed frontier belongs to
        let mut epoch = (self.completed + 1).max(0) as u64 / batches_per_epoch.max(1);
        let checkpoint_every = self.cfg.checkpoint.as_ref().map(|(_, e)| *e).unwrap_or(0);

        while self.completed + 1 < self.total_batches as i64 {
            // inject up to the in-flight limit
            while self.next_inject < self.total_batches
                && self.inflight < self.limit()
                && self.worker.status == 0
            {
                // stop at epoch boundary until eval runs
                if self.next_inject / batches_per_epoch > epoch {
                    break;
                }
                self.inject_one()?;
            }

            // receive
            if let Some((from, msg)) = self.endpoint.recv_timeout(Duration::from_millis(2)) {
                self.on_message(from, msg)?;
                while let Some((from, msg)) = self.endpoint.recv_timeout(Duration::ZERO) {
                    self.on_message(from, msg)?;
                }
            }
            // let the stage-0 worker compute queued backwards (it computes
            // inline in dispatch; pump for queued forwards in 1-stage mode)
            self.worker.pump(&self.endpoint)?;

            // fault detection
            if let Some(b) = self.detector.overdue() {
                self.handle_fault(b)?;
            }

            // dynamic re-partition schedule
            if let Some(at) = next_repart {
                if self.completed >= at as i64 {
                    self.dynamic_repartition()?;
                    next_repart = repart_every.map(|e| at + e);
                }
            }

            // epoch boundary: drain + evaluate
            let done_in_epoch = (self.completed + 1) as u64;
            if done_in_epoch >= (epoch + 1) * batches_per_epoch {
                let train_acc = (self.epoch_correct
                    / (self.epoch_batches.max(1) as f64 * self.manifest.acc_denom as f64))
                    as f32;
                let (val_loss, val_acc) = self.evaluate()?;
                let at_s = self.clock.now_s();
                log_info!(
                    "epoch {epoch}: train_acc={train_acc:.3} val_loss={val_loss:.4} \
                     val_acc={val_acc:.3} ({at_s:.1}s)"
                );
                self.record.epochs.push(EpochRecord {
                    epoch,
                    train_acc,
                    val_loss,
                    val_acc,
                    at_s,
                });
                self.epoch_correct = 0.0;
                self.epoch_batches = 0;
                epoch += 1;
                // learning-rate schedule (paper §IV-C)
                let drops = self.cfg.lr_drops.clone();
                for &(at_epoch, lr) in &drops {
                    if at_epoch as u64 == epoch {
                        log_info!("epoch {epoch}: setting lr to {lr}");
                        self.worker.sgd.set_lr(lr);
                        for &d in self.worker.worker_list.clone().iter().filter(|&&d| d != 0) {
                            self.endpoint.send(d, Message::SetLr { lr })?;
                        }
                    }
                }
            }

            // central-node checkpoint (paper §III-E: periodic save-to-disk)
            if checkpoint_every > 0 {
                let done = (self.completed + 1) as u64;
                if done > 0 && done % checkpoint_every == 0 && self.last_checkpoint != done {
                    self.last_checkpoint = done;
                    self.save_checkpoint(epoch)?;
                }
            }
        }

        self.record.event(&self.clock, "training done".to_string());
        // the machine's transition log is the conformance artifact shared
        // with the deterministic harness (ScenarioOutcome::phase_log)
        self.record.phase_log = self.machine.take_log();
        Ok(())
    }

    // ------------------------------------------------------------------
    // final-weights collection
    // ------------------------------------------------------------------

    /// Fetch every stage's trained weights back to the central node.
    pub(crate) fn collect_final_weights(&mut self) -> Result<BTreeMap<usize, BlockParams>> {
        let mut final_weights: BTreeMap<usize, BlockParams> = BTreeMap::new();
        for (b, bp) in &self.worker.params.blocks {
            final_weights.insert(*b, bp.clone());
        }
        let peers: Vec<(usize, DeviceId)> = self
            .worker
            .worker_list
            .iter()
            .enumerate()
            .filter(|(_, &d)| d != 0)
            .map(|(s, &d)| (s, d))
            .collect();
        for &(stage, dev) in &peers {
            let (lo, hi) = self.worker.ranges[stage];
            self.endpoint
                .send(dev, Message::FetchWeights { blocks: (lo..=hi).collect() })?;
        }
        let deadline = self.clock.raw_now() + Duration::from_secs(30);
        let mut expect: usize = peers
            .iter()
            .map(|&(s, _)| self.worker.ranges[s].1 - self.worker.ranges[s].0 + 1)
            .sum();
        while expect > 0 && self.clock.raw_now() < deadline {
            if let Some((_, Message::Weights { blocks })) =
                self.endpoint.recv_timeout(Duration::from_millis(10))
            {
                for (idx, tensors) in blocks {
                    let bp = crate::replication::block_from_wire(tensors);
                    if final_weights.insert(idx, bp).is_none() {
                        expect -= 1;
                    }
                }
            }
        }
        Ok(final_weights)
    }
}

/// Send the per-link tier table to every peer, absorbing per-peer send
/// errors. During a dead-peer window (the TCP transport's `down_ttl`
/// fast-fail makes sends to a known-dead peer fail synchronously) a
/// broadcast must still reach every live worker — propagating the first
/// `Err` with `?` would crash the coordinator over a death the fault
/// detector already owns. Returns the number of peers that could not be
/// reached, for callers that want to log or count.
pub(crate) fn broadcast_compression(
    endpoint: &dyn Transport,
    peers: &[DeviceId],
    tier: crate::net::quant::Tier,
    links: &[(DeviceId, crate::net::quant::Tier)],
) -> usize {
    let mut failed = 0;
    for &d in peers {
        if let Err(e) = endpoint.send(d, Message::SetCompression {
            tier,
            links: links.to_vec(),
        }) {
            log_warn!("SetCompression to {d} failed ({e}); fault detector owns recovery");
            failed += 1;
        }
    }
    failed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::quant::Tier;
    use std::sync::Mutex;

    /// A stub transport whose sends to one designated peer fail
    /// synchronously — the shape of the TCP endpoint's `down_ttl`
    /// fast-fail during a dead-peer window.
    struct FlakyEndpoint {
        dead: DeviceId,
        sent: Mutex<Vec<(DeviceId, Message)>>,
    }

    impl Transport for FlakyEndpoint {
        fn my_id(&self) -> DeviceId {
            0
        }
        fn send(&self, to: DeviceId, msg: Message) -> Result<()> {
            if to == self.dead {
                bail!("peer {to} is down");
            }
            self.sent.lock().unwrap().push((to, msg));
            Ok(())
        }
        fn recv_timeout(&self, _timeout: Duration) -> Option<(DeviceId, Message)> {
            None
        }
        fn n_devices(&self) -> usize {
            4
        }
    }

    /// Satellite: a broadcast during a dead-peer window must not error
    /// out mid-fanout — every live peer still gets the full table, the
    /// dead peer is counted, and nothing propagates as `Err`.
    #[test]
    fn broadcast_survives_a_dead_peer_mid_fanout() {
        let ep = FlakyEndpoint { dead: 2, sent: Mutex::new(Vec::new()) };
        let links = vec![(2, Tier::Full), (3, Tier::FullQ4)];
        let failed = broadcast_compression(&ep, &[1, 2, 3], Tier::Off, &links);
        assert_eq!(failed, 1, "exactly the dead peer fails");
        let sent = ep.sent.lock().unwrap();
        assert_eq!(
            sent.iter().map(|(d, _)| *d).collect::<Vec<_>>(),
            vec![1, 3],
            "live peers after the dead one must still be reached"
        );
        for (_, msg) in sent.iter() {
            match msg {
                Message::SetCompression { tier, links: got } => {
                    assert_eq!(*tier, Tier::Off);
                    assert_eq!(got, &links, "every live peer gets the full table");
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn broadcast_with_all_peers_live_reports_zero_failures() {
        let ep = FlakyEndpoint { dead: 99, sent: Mutex::new(Vec::new()) };
        let failed = broadcast_compression(&ep, &[1, 2, 3], Tier::Activations, &[]);
        assert_eq!(failed, 0);
        assert_eq!(ep.sent.lock().unwrap().len(), 3);
    }
}
