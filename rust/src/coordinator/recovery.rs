//! The repartition/recovery phase: dynamic re-partition scheduling
//! (paper §III-D) and the fault-tolerance handler's three cases (§III-F).
//!
//! Both paths funnel into the shared `Repartition -> fetch -> FetchDone
//! -> Commit` protocol ([`Central::run_redistribution`]), driven by the
//! same [`Event`] vocabulary as steady-state traffic. Weight movement is
//! `TensorBuf`-backed end to end: serving a fetch, staging a reply, and
//! committing the new sub-model all share buffers.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::Engine;
use crate::fault::renumber_worker_list;
use crate::net::message::{DeviceId, Message};
use crate::net::Transport;
use crate::partition::{optimal_partition, CostModel, Partition};
use crate::pipeline::{ControlEvent, DataEvent, Event};
use crate::{log_info, log_warn};

use super::central::Central;
use super::core::{prune_link_state, PhaseEffect, PhaseInput, RedistReason};

impl Central {
    // ------------------------------------------------------------------
    // capacity-aware cost model (paper eqs 1-3)
    // ------------------------------------------------------------------

    pub(crate) fn current_cost_model(
        &self,
        worker_list: &[DeviceId],
        old_ranges: &[(usize, usize)],
    ) -> CostModel {
        // central's own online/offline ratio cancels host-contention in sim
        let central_ratio = match (self.worker.avg_exec_ms(), self.worker.my_range()) {
            (Some(avg), Some((lo, hi))) => {
                let base: f64 = self.profile.t0_ms[lo..=hi].iter().sum();
                if base > 0.0 {
                    avg / base
                } else {
                    1.0
                }
            }
            _ => 1.0,
        };
        let caps = self
            .estimator
            .capacities(worker_list, old_ranges, &self.profile.t0_ms, central_ratio);
        let n = worker_list.len();
        let mut bw = Vec::with_capacity(n.saturating_sub(1));
        for link in 0..n.saturating_sub(1) {
            // pipeline link `link` feeds the device at slot link+1 of the
            // candidate list — look its measurement up by device id
            let measured =
                self.measured_bw.get(&worker_list[link + 1]).copied().unwrap_or(0.0);
            bw.push(if measured > 0.0 {
                measured
            } else {
                self.cfg
                    .bandwidth(link.min(self.cfg.bandwidth_bps.len().saturating_sub(1)))
            });
        }
        CostModel {
            t0_ms: self.profile.t0_ms.clone(),
            out_bytes: self.profile.out_bytes.clone(),
            capacities: caps,
            bandwidth_bps: bw,
        }
    }

    // ------------------------------------------------------------------
    // dynamic re-partition (paper §III-D)
    // ------------------------------------------------------------------

    /// Drain, recompute the optimal cuts from live capacity estimates, and
    /// run the redistribution protocol if the partition changed.
    pub(crate) fn dynamic_repartition(&mut self) -> Result<()> {
        // the shared machine gates the drain window (Training -> Draining)
        self.machine.step(PhaseInput::DrainForRepartition)?;
        self.drain()?;
        // a clean drain polls into RunDynamicRepartition; if a fault fired
        // mid-drain the machine already went Probing -> Training and this
        // poll is a no-op — skip the replan, the next schedule tick retries
        let (_, effects) = self.machine.step(PhaseInput::Poll {
            now: self.clock.raw_now(),
            overdue: self.detector.overdue(),
            inflight: self.inflight,
            peers: self.worker.worker_list.len().saturating_sub(1),
            local_fetch_done: self.worker.fetch_done(),
        })?;
        if !effects.iter().any(|e| matches!(e, PhaseEffect::RunDynamicRepartition)) {
            return Ok(());
        }
        let worker_list = self.worker.worker_list.clone();
        let old_ranges = self.worker.ranges.clone();
        let cm = self.current_cost_model(&worker_list, &old_ranges);
        let (new_ranges, cost) = optimal_partition(&cm);
        self.record
            .event(&self.clock, format!("repartition check: caps={:?}", cm.capacities));
        if new_ranges == old_ranges {
            return Ok(());
        }
        log_info!(
            "dynamic re-partition at batch {}: {:?} -> {:?} (predicted bottleneck {:.1}ms)",
            self.completed,
            old_ranges,
            new_ranges,
            cost
        );
        self.record.event(&self.clock, format!("repartition {new_ranges:?}"));
        self.run_redistribution(new_ranges.clone(), worker_list, vec![], RedistReason::Dynamic)?;
        self.record.partitions.push((self.completed.max(0) as u64, new_ranges));
        Ok(())
    }

    // ------------------------------------------------------------------
    // the shared redistribution protocol
    // ------------------------------------------------------------------

    /// The shared Repartition -> fetch -> FetchDone -> Commit protocol.
    /// The [`crate::coordinator::core::PhaseMachine`] owns the FetchDone
    /// tally and the deadline; this driver only moves bytes and executes
    /// the commit/abort effect the poll resolves to.
    pub(crate) fn run_redistribution(
        &mut self,
        ranges: Partition,
        worker_list: Vec<DeviceId>,
        failed: Vec<usize>,
        reason: RedistReason,
    ) -> Result<()> {
        let workers: Vec<DeviceId> =
            worker_list.iter().copied().filter(|&d| d != self.worker.device_id).collect();
        for &d in &workers {
            self.endpoint.send(
                d,
                Message::Repartition {
                    ranges: ranges.clone(),
                    worker_list: worker_list.clone(),
                    failed: failed.clone(),
                },
            )?;
        }
        self.worker.begin_repartition(
            &self.endpoint,
            ranges.clone(),
            worker_list.clone(),
            failed,
        )?;

        let expect: BTreeSet<DeviceId> = workers.iter().copied().collect();
        self.machine.step(PhaseInput::RedistributionStarted {
            expect,
            reason,
            now: self.clock.raw_now(),
        })?;

        // await FetchDone from every worker + our own completion
        loop {
            match self.endpoint.recv_timeout(Duration::from_millis(5)) {
                Some((from, msg)) => match Event::from_message(from, msg) {
                    Event::Control(ControlEvent::FetchDone { id }) => {
                        self.machine.step(PhaseInput::FetchDone { id })?;
                    }
                    ev => self.on_event(ev)?,
                },
                None => {}
            }
            let (_, effects) = self.machine.step(PhaseInput::Poll {
                now: self.clock.raw_now(),
                overdue: None,
                inflight: self.inflight,
                peers: workers.len(),
                local_fetch_done: self.worker.fetch_done(),
            })?;
            for eff in effects {
                match eff {
                    PhaseEffect::CommitRedistribution { .. } => {
                        // commit everywhere (paper's commit message)
                        for &d in &workers {
                            self.endpoint.send(d, Message::Commit)?;
                        }
                        self.worker.apply_commit()?;
                        // the committed list is the live topology now:
                        // measurements and tier ladders keyed to departed
                        // devices are stale — drop them here so every
                        // worker-list change (repartition, rejoin, case-3
                        // eviction) funnels through one invalidation point
                        let dropped = prune_link_state(
                            &mut self.measured_bw,
                            self.adaptive.as_mut(),
                            &self.worker.worker_list,
                        );
                        if !dropped.is_empty() {
                            log_info!(
                                "adaptive: links {dropped:?} invalidated by topology change"
                            );
                        }
                        return Ok(());
                    }
                    PhaseEffect::AbortRedistribution => {
                        // driver policy: the threaded coordinator treats a
                        // stalled redistribution as fatal — there is no
                        // virtual fabric to rewind, so failing the run
                        // beats the sim's re-probe (DESIGN.md §12)
                        bail!(
                            "redistribution timed out ({} workers expected)",
                            workers.len()
                        );
                    }
                    _ => {}
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // central-node restart reconciliation (paper §III-E)
    // ------------------------------------------------------------------

    /// Re-announce a rebooted coordinator to `peers` and collect each
    /// worker's progress report for reconciliation against the
    /// checkpoint's `committed` batch. Workers pause (status 1), abort
    /// protocol state the dead coordinator can no longer complete, and
    /// drop uncommitted work on receipt — see
    /// `StageWorker`'s `CentralRestart` handler. Returns
    /// id -> (committed backward batch, fresh); a missing id is a worker
    /// that is dead *now* and should be treated as a §III-F case-3
    /// failure of the checkpoint topology.
    pub(crate) fn restart_handshake(
        &mut self,
        peers: &[DeviceId],
        committed: i64,
    ) -> Result<BTreeMap<DeviceId, (i64, bool)>> {
        for &d in peers {
            self.endpoint.send(d, Message::CentralRestart { committed })?;
        }
        // the machine (stepped into Rejoining by the resume path) owns
        // the ack set and the window; loop until its poll resolves
        let reports: BTreeMap<DeviceId, (i64, bool)> = loop {
            match self.endpoint.recv_timeout(Duration::from_millis(10)) {
                Some((from, msg)) => match Event::from_message(from, msg) {
                    Event::Control(ControlEvent::WorkerState {
                        id,
                        committed_bwd,
                        fresh,
                        ..
                    }) => {
                        self.machine.step(PhaseInput::WorkerStateReport {
                            id,
                            committed_bwd,
                            fresh,
                        })?;
                    }
                    // stale pre-reboot data traffic: discard
                    Event::Data(DataEvent::Backward { .. })
                    | Event::Data(DataEvent::Forward { .. }) => {}
                    ev => self.on_event(ev)?,
                },
                None => {}
            }
            let (_, effects) = self.machine.step(PhaseInput::Poll {
                now: self.clock.raw_now(),
                overdue: None,
                inflight: 0,
                peers: peers.len(),
                local_fetch_done: true,
            })?;
            if let Some(PhaseEffect::ResolveRejoin { acks }) = effects
                .into_iter()
                .find(|e| matches!(e, PhaseEffect::ResolveRejoin { .. }))
            {
                break acks;
            }
        };
        for (&d, &(bwd, fresh)) in &reports {
            log_info!(
                "restart reconcile: worker {d} committed_bwd={bwd} fresh={fresh} \
                 (checkpoint committed={committed})"
            );
            self.record.event(
                &self.clock,
                format!("restart reconcile: worker {d} committed_bwd={bwd} fresh={fresh}"),
            );
        }
        let silent: Vec<DeviceId> =
            peers.iter().copied().filter(|d| !reports.contains_key(d)).collect();
        if !silent.is_empty() {
            // A silent worker is a dead worker. The threaded bootstrap
            // cannot reach here with one (the readiness barrier just
            // required every worker to ack), so until resume learns to
            // replan a case-3 redistribution against the checkpoint
            // topology (ROADMAP: TCP central re-attach), failing fast
            // beats warm-starting a pipeline with a dead stage and
            // waiting for the fault detector to rediscover it.
            bail!(
                "restart handshake: workers {silent:?} did not answer; cannot resume \
                 onto a pipeline with dead stages (replan-on-resume is a known follow-up)"
            );
        }
        Ok(reports)
    }

    // ------------------------------------------------------------------
    // fault tolerance (paper §III-F)
    // ------------------------------------------------------------------

    pub(crate) fn handle_fault(&mut self, overdue_batch: u64) -> Result<()> {
        let t_start = self.clock.raw_now();
        log_warn!(
            "FAULT: no gradient for batch {overdue_batch} within timeout; probing workers"
        );
        self.record.event(&self.clock, format!("fault detected at batch {overdue_batch}"));
        self.worker.status = 1;

        // probe all current workers; the machine opens the probe window
        // (FaultDetected -> Probing + SendProbes) and owns the ack tally
        let worker_list = self.worker.worker_list.clone();
        let peers: Vec<DeviceId> = worker_list
            .iter()
            .copied()
            .filter(|&d| d != self.worker.device_id)
            .collect();
        let (_, open) = self.machine.step(PhaseInput::FaultDetected {
            overdue: overdue_batch,
            now: t_start,
        })?;
        if open.iter().any(|e| matches!(e, PhaseEffect::SendProbes { .. })) {
            for &d in &peers {
                self.endpoint.send(d, Message::Probe)?;
            }
        }
        let acks: BTreeMap<DeviceId, bool> = loop {
            match self.endpoint.recv_timeout(Duration::from_millis(10)) {
                Some((from, msg)) => match Event::from_message(from, msg) {
                    Event::Control(ControlEvent::ProbeAck { id, fresh }) => {
                        self.machine.step(PhaseInput::ProbeAck { id, fresh })?;
                    }
                    // stale data traffic during recovery: discard
                    Event::Data(DataEvent::Backward { .. })
                    | Event::Data(DataEvent::Forward { .. }) => {}
                    ev => self.on_event(ev)?,
                },
                None => {}
            }
            let (_, effects) = self.machine.step(PhaseInput::Poll {
                now: self.clock.raw_now(),
                overdue: None,
                inflight: self.inflight,
                peers: peers.len(),
                local_fetch_done: true,
            })?;
            if let Some(PhaseEffect::ResolveProbe { acks }) = effects
                .into_iter()
                .find(|e| matches!(e, PhaseEffect::ResolveProbe { .. }))
            {
                break acks;
            }
        };
        let dead: Vec<DeviceId> =
            peers.iter().copied().filter(|d| !acks.contains_key(d)).collect();
        let fresh: Vec<DeviceId> =
            acks.iter().filter(|(_, &f)| f).map(|(&d, _)| d).collect();
        let detect_s = self.clock.raw_now().saturating_sub(t_start).as_secs_f64();
        // Table III's "recover overhead" is the work AFTER the failed
        // worker is identified (renumber + re-partition + weight
        // redistribution + reset); detection/probing cost is identical
        // across systems and reported separately as an event.
        let t_redist = self.clock.raw_now();

        let committed = self.completed;
        if dead.is_empty() && fresh.is_empty() {
            // CASE 1: everyone fine — restart from the failed batch
            log_info!("fault case 1: all workers healthy; restarting from batch {}", committed + 1);
            self.record.event(&self.clock, "fault case 1: restart".to_string());
        } else if dead.is_empty() {
            // CASE 2: a worker restarted and lost its state — re-send the
            // state variables, let it re-fetch weights from its chain
            // replica holder, same partition.
            log_info!("fault case 2: restarted worker(s) {fresh:?}; restoring from replicas");
            self.record.event(&self.clock, format!("fault case 2: restore {fresh:?}"));
            // a restarted worker re-enters the roster before re-init
            for &d in &fresh {
                self.roster.readmit(d)?;
            }
            let ti = self.train_init(self.worker.ranges.clone(), worker_list.clone(), 1);
            for &d in &fresh {
                self.endpoint.send(d, Message::InitState(ti.clone()))?;
            }
            // tiny pause so InitState lands before Repartition
            self.clock.sleep(Duration::from_millis(50));
            self.run_redistribution(
                self.worker.ranges.clone(),
                worker_list,
                vec![],
                RedistReason::Fault,
            )?;
        } else {
            // CASE 3: dead worker(s) — renumber, re-partition, redistribute
            let failed_stages: Vec<usize> = worker_list
                .iter()
                .enumerate()
                .filter(|(_, d)| dead.contains(d))
                .map(|(s, _)| s)
                .collect();
            log_info!("fault case 3: dead stages {failed_stages:?}; re-partitioning");
            self.record
                .event(&self.clock, format!("fault case 3: dead stages {failed_stages:?}"));
            let new_list = renumber_worker_list(&worker_list, &failed_stages);
            let old_ranges = self.worker.ranges.clone();
            let new_ranges = if self.cfg.engine == Engine::ResPipe {
                // ResPipe-style recovery: the failed stage's successor
                // absorbs its whole range — no re-partitioning.
                respipe_merge(&old_ranges, &failed_stages)
            } else {
                // FTPipeHD: dynamic scheduler over the alive devices
                let alive_old_ranges: Vec<(usize, usize)> = old_ranges
                    .iter()
                    .enumerate()
                    .filter(|(s, _)| !failed_stages.contains(s))
                    .map(|(_, &r)| r)
                    .collect();
                let cm = self.current_cost_model(&new_list, &alive_old_ranges);
                optimal_partition(&cm).0
            };
            for &d in &dead {
                self.estimator.clear_device(d);
                // an evicted worker must explicitly re-admit (case 2)
                // before the coordinator accepts it again
                self.roster.evict(d);
            }
            self.run_redistribution(new_ranges.clone(), new_list, failed_stages, RedistReason::Fault)?;
            self.record.partitions.push((committed.max(0) as u64, new_ranges));
        }

        // reset the training state everywhere (paper: discard batches
        // beyond the last committed one, status back to 0)
        let peers_now: Vec<DeviceId> = self
            .worker
            .worker_list
            .clone()
            .into_iter()
            .filter(|&d| d != self.worker.device_id)
            .collect();
        for &d in &peers_now {
            self.endpoint.send(d, Message::Reset { committed })?;
        }
        // a worker re-inited during this recovery fell back to the
        // policy's initial tier — re-align everyone with the adaptive
        // controller's current rung (mirrors the scenario runner's
        // reset_all; `observe` only fires on a *change*, so without this
        // a restored worker would send f32 over the degraded link forever)
        self.rebroadcast_tier(&peers_now)?;
        self.worker.apply_reset(committed);
        self.detector.clear();
        self.inflight = 0;
        self.next_inject = (committed + 1) as u64;

        let overhead = self.clock.raw_now().saturating_sub(t_redist).as_secs_f64();
        self.record.recovery_overhead_s = Some(overhead);
        self.record.event(
            &self.clock,
            format!("recovery complete: detect+probe {detect_s:.3}s, redistribute {overhead:.3}s"),
        );
        log_info!(
            "recovery complete (detect+probe {detect_s:.3}s, redistribute {overhead:.3}s); \
             resuming from batch {}",
            self.next_inject
        );
        Ok(())
    }
}

/// ResPipe recovery: the next alive worker absorbs each failed stage's
/// range (no re-partition). Returns the merged ranges for the alive stages.
pub(crate) fn respipe_merge(old_ranges: &[(usize, usize)], failed: &[usize]) -> Partition {
    let mut merged: Vec<(usize, usize)> = Vec::new();
    let n = old_ranges.len();
    let mut s = 0;
    while s < n {
        if failed.contains(&s) {
            s += 1;
            continue;
        }
        merged.push(old_ranges[s]);
        s += 1;
    }
    // extend each survivor backward to cover preceding failed ranges
    // (the failed stage's NEXT worker takes over its blocks)
    let mut out: Vec<(usize, usize)> = Vec::new();
    let mut expect = 0usize;
    for &(lo, hi) in &merged {
        let lo2 = expect.min(lo);
        out.push((lo2, hi));
        expect = hi + 1;
    }
    // a failed LAST stage falls to the central node (stage 0): extend the
    // final survivor forward
    if let Some(last) = out.last_mut() {
        let total_hi = old_ranges.last().unwrap().1;
        if last.1 < total_hi {
            last.1 = total_hi;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respipe_merge_middle_failure() {
        let old = vec![(0, 3), (4, 7), (8, 11)];
        // stage 1 dies: its successor (old stage 2) absorbs blocks 4..=7
        assert_eq!(respipe_merge(&old, &[1]), vec![(0, 3), (4, 11)]);
    }

    #[test]
    fn respipe_merge_last_failure() {
        let old = vec![(0, 3), (4, 7), (8, 11)];
        // last stage dies: trailing blocks fall to the last survivor
        assert_eq!(respipe_merge(&old, &[2]), vec![(0, 3), (4, 11)]);
    }

    #[test]
    fn respipe_merge_two_failures() {
        let old = vec![(0, 2), (3, 5), (6, 8), (9, 11)];
        assert_eq!(respipe_merge(&old, &[1, 2]), vec![(0, 2), (3, 11)]);
    }
}
