//! The offline phase (paper §III-B): stand up the simulated cluster,
//! profile the model, compute the initial (capacity-blind) partition,
//! run the worker-readiness barrier, broadcast the training-init state,
//! and push warm-start weights for continuous training.
//!
//! Produces a ready [`Central`] plus the spawned worker handles; the
//! steady-state phase ([`Central::run_training`]) takes over from there.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::checkpoint::{CoordinatorStore, DiskSink, LeaderState};
use crate::config::RunConfig;
use crate::data::{DataSource, SynthLm, SynthVision};
use crate::device::SimDevice;
use crate::fault::FaultDetector;
use crate::manifest::{Dtype, Manifest};
use crate::metrics::{RunClock, RunRecord};
use crate::net::message::{DeviceId, Message};
use crate::net::{SimNet, Transport};
use crate::partition::{homogeneous_partition, CostModel};
use crate::pipeline::{run_worker, StageWorker};
use crate::profile::{profile_model, CapacityEstimator, ModelProfile};
use crate::runtime::{load_all_blocks, Engine as XlaEngine};
use crate::log_info;

use super::central::Central;
use super::core::{CoordinatorPhase, PhaseConfig, PhaseInput, PhaseMachine, WorkerRoster};
use super::RunOpts;

/// Build the default synthetic data source for a compiled model.
pub fn default_datasource(manifest: &Manifest, seed: u64) -> Box<dyn DataSource> {
    match manifest.input_dtype {
        Dtype::F32 => {
            let dim: usize = manifest.input_shape.iter().skip(1).product();
            let classes = manifest.n_classes.unwrap_or(10);
            Box::new(SynthVision::new(dim, classes, 0.6, seed, 0))
        }
        Dtype::I32 => {
            let vocab = manifest.vocab.unwrap_or(512);
            let seq = manifest.seq.unwrap_or(64);
            Box::new(SynthLm::new(vocab, seq, seed))
        }
    }
}

/// A bootstrapped cluster, ready for the steady-state phase.
pub(crate) struct Boot {
    pub central: Central,
    pub handles: Vec<std::thread::JoinHandle<Result<()>>>,
    pub net: SimNet,
    pub collect_final_weights: bool,
}

/// Bootstrap outcome: a ready cluster, or an immediate OOM record (the
/// single-device memory-cap emulation, paper §IV-F).
pub(crate) enum BootResult {
    Ready(Box<Boot>),
    Oom(RunRecord),
}

/// Load the newest complete leadership state for a resume (paper §III-E:
/// "recovering from them every time it fails"), validating the embedded
/// checkpoint against the cluster being stood up AND the model it will
/// warm-start: stage count, block-id range, and tensor shapes must all
/// match the manifest, or the operator pointed `resume_from` at the
/// wrong run — refuse cleanly here instead of index-panicking or
/// diverging mid-training. `None` when nothing usable exists — the run
/// then starts fresh instead of failing, so a crash-looped central node
/// that never managed a first checkpoint still comes up. Roots written
/// before the leader sidecar existed load with default extras.
fn load_resume(cfg: &RunConfig, n: usize, manifest: &Manifest) -> Result<Option<LeaderState>> {
    let Some(dir) = &cfg.resume_from else {
        return Ok(None);
    };
    let Some(st) = DiskSink::new(dir).load_latest_leader()? else {
        log_info!("resume_from {dir}: no complete checkpoint; starting fresh");
        return Ok(None);
    };
    let ck = &st.checkpoint;
    if ck.state.worker_list.len() != n || ck.state.ranges.len() != n {
        bail!(
            "checkpoint topology ({} stages) does not match the configured cluster \
             ({n} devices); refusing to resume",
            ck.state.worker_list.len()
        );
    }
    let n_blocks = manifest.n_blocks();
    if ck.state.ranges.iter().any(|&(lo, hi)| lo > hi || hi >= n_blocks) {
        bail!(
            "checkpoint partition {:?} does not fit this model ({n_blocks} blocks); \
             is resume_from pointing at a different model's checkpoints?",
            ck.state.ranges
        );
    }
    for (&b, bp) in &ck.weights {
        if b >= n_blocks {
            bail!("checkpoint holds block {b} but the model has {n_blocks}; wrong model?");
        }
        let want: Vec<usize> = manifest.blocks[b].params.iter().map(|p| p.size).collect();
        let got: Vec<usize> = bp.0.iter().map(|t| t.len()).collect();
        if want != got {
            bail!(
                "checkpoint block {b} tensor sizes {got:?} do not match the model's \
                 {want:?}; is resume_from pointing at a different model's checkpoints?"
            );
        }
    }
    log_info!(
        "resuming from checkpoint: committed batch {}, {} blocks, lr {}, \
         replica epoch {}",
        ck.state.committed_batch,
        ck.weights.len(),
        ck.state.lr,
        st.replica_epoch
    );
    Ok(Some(st))
}

/// Run the whole offline phase for `cfg`.
pub(crate) fn bootstrap(cfg: &RunConfig, mut opts: RunOpts) -> Result<BootResult> {
    cfg.validate()?;
    if cfg.replicas > 1 {
        // Replicated training is a sim-runner capability for now: the
        // threaded coordinator drives exactly one pipeline chain
        // (DESIGN.md §14 tracks lifting this).
        bail!("replicas = {} is not supported by the threaded coordinator", cfg.replicas);
    }
    crate::util::logging::init_from_env();
    let manifest = Arc::new(Manifest::load(&cfg.model_dir)?);
    let n = cfg.n_devices();
    if manifest.n_blocks() < n {
        bail!("{} blocks < {} devices", manifest.n_blocks(), n);
    }
    let resume = load_resume(cfg, n, &manifest)?;
    // the checkpoint's lr (possibly past lr-drops) overrides the config's
    let mut cfg_eff = cfg.clone();
    if let Some(st) = &resume {
        cfg_eff.lr = st.checkpoint.state.lr;
    }
    let cfg = &cfg_eff;

    let (net, mut endpoints) = SimNet::new(
        n,
        cfg.bandwidth_bps.clone(),
        Duration::from_secs_f64(cfg.link_latency_s),
    );
    endpoints.reverse(); // pop from the front: device 0 first
    let central_ep = endpoints.pop().expect("central endpoint");

    // ---- spawn workers ----
    let mut handles = Vec::new();
    for d in 1..n {
        let ep = endpoints.pop().expect("worker endpoint");
        let manifest = manifest.clone();
        let dev_cfg = cfg.devices[d].clone();
        let seed = cfg.seed ^ (d as u64).wrapping_mul(0x9E3779B9);
        let trace = opts.trace.clone();
        let net2 = net.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("device-{d}"))
                .spawn(move || -> Result<()> {
                    let engine = XlaEngine::cpu()?;
                    let blocks = load_all_blocks(&engine, &manifest)?;
                    let sim = SimDevice::new(dev_cfg, seed);
                    let w = StageWorker::new(d, manifest, blocks, sim, trace);
                    run_worker(w, Box::new(ep), Some(net2))
                })?,
        );
    }

    // ---- central node (device 0) ----
    let engine = XlaEngine::cpu()?;
    let blocks = load_all_blocks(&engine, &manifest)?;
    let sim = SimDevice::new(cfg.devices[0].clone(), cfg.seed ^ 0xC0FFEE);
    let worker = StageWorker::new(0, manifest.clone(), blocks, sim, opts.trace.clone());

    // ---- offline stage: profiling + initial partition (paper §III-B).
    // A resumed run warm-starts from the checkpoint instead: partition
    // and worker list come from the saved state, and the profile is
    // derived from the manifest's flop counts — no re-profiling pass
    // (relative block costs are what the cost model needs; the capacity
    // estimator re-converges from live exec reports anyway).
    let (profile, init_ranges, worker_list) = if let Some(st) = &resume {
        (
            ModelProfile::from_flops(&manifest, 1.0),
            st.checkpoint.state.ranges.clone(),
            st.checkpoint.state.worker_list.clone(),
        )
    } else {
        let reps = if opts.profile_reps == 0 { 5 } else { opts.profile_reps };
        let profile = profile_model(&manifest, &worker.blocks_rt, reps)?;
        log_info!(
            "profiled {} blocks: t0={:?}ms",
            profile.t0_ms.len(),
            profile.t0_ms.iter().map(|t| (t * 10.0).round() / 10.0).collect::<Vec<_>>()
        );
        let init_cm = CostModel {
            t0_ms: profile.t0_ms.clone(),
            out_bytes: profile.out_bytes.clone(),
            capacities: vec![1.0; n],
            bandwidth_bps: (0..n.saturating_sub(1))
                .map(|l| cfg.bandwidth(l.min(cfg.bandwidth_bps.len().saturating_sub(1))))
                .collect(),
        };
        let (init_ranges, _) = homogeneous_partition(&init_cm);
        log_info!("initial (capacity-blind) partition: {init_ranges:?}");
        (profile, init_ranges, (0..n).collect::<Vec<DeviceId>>())
    };

    // memory-cap check (single-device OOM emulation, §IV-F)
    {
        let my_range = init_ranges[0];
        // params + velocity + stash
        let my_bytes = manifest.param_bytes_range(my_range.0, my_range.1) * 3;
        let dev = SimDevice::new(cfg.devices[0].clone(), 0);
        if n == 1 && !dev.fits_memory(my_bytes) {
            let mut record = RunRecord::default();
            record.events.push(crate::metrics::Event {
                at_s: 0.0,
                kind: format!(
                    "OOM: model state {} bytes exceeds device cap {:?}",
                    my_bytes, cfg.devices[0].mem_cap_bytes
                ),
            });
            return Ok(BootResult::Oom(record));
        }
    }

    let committed =
        resume.as_ref().map(|st| st.checkpoint.state.committed_batch).unwrap_or(-1);
    let mut central = Central {
        total_batches: (cfg.epochs * cfg.batches_per_epoch) as u64,
        cfg: cfg.clone(),
        manifest: manifest.clone(),
        worker,
        endpoint: central_ep,
        net: net.clone(),
        profile,
        estimator: CapacityEstimator::default(),
        detector: FaultDetector::new(Duration::from_millis(cfg.fault_timeout_ms)),
        measured_bw: std::collections::BTreeMap::new(),
        adaptive: (cfg.compression == crate::config::Compression::Adaptive)
            .then(|| crate::net::quant::AdaptivePolicy::new(cfg.adaptive.clone())),
        record: RunRecord::default(),
        clock: RunClock::start(),
        next_inject: (committed + 1).max(0) as u64,
        inflight: 0,
        completed: committed,
        last_completion_s: 0.0,
        epoch_correct: 0.0,
        epoch_batches: 0,
        fault_armed: false,
        last_checkpoint: (committed + 1).max(0) as u64,
        store: cfg
            .checkpoint
            .as_ref()
            .map(|(dir, _)| Box::new(DiskSink::new(dir)) as Box<dyn CoordinatorStore>),
        data: opts
            .data
            .take()
            .unwrap_or_else(|| default_datasource(&manifest, cfg.seed)),
        // a resumed coordinator starts Down and rejoins through the
        // restart handshake; a fresh one walks Idle -> Profiling ->
        // Training below
        machine: if resume.is_some() {
            PhaseMachine::resuming(PhaseConfig::threaded())
        } else {
            PhaseMachine::new(PhaseConfig::threaded())
        },
        roster: match cfg.max_workers {
            Some(q) => WorkerRoster::with_capacity(q),
            None => WorkerRoster::unlimited(),
        },
        // bump the replica version epoch on every restart so a stale
        // pre-restart backup can never outrank a post-restart push
        // (DESIGN.md §9 case 2)
        replica_epoch: resume.as_ref().map(|st| st.replica_epoch + 1).unwrap_or(0),
    };
    // warm-start the link estimates from the stored leadership state so
    // the first cost model after a resume is capacity-aware, not blind;
    // only destinations on the restored worker list are taken — the
    // sidecar may predate a topology change
    if let Some(st) = &resume {
        for &(d, b) in &st.link_bw {
            if worker_list.contains(&d) {
                central.measured_bw.insert(d, b);
            }
        }
    }
    // admission: a resume restores the persisted quota and roster, then
    // (re)admits every device the readiness barrier is about to prove
    // alive; a fresh run admits the configured cluster outright
    if let Some(st) = &resume {
        // the config's quota (freshly validated) outranks the stored one
        // when both exist — the operator may have re-sized the cluster
        let quota = cfg.max_workers.map(|q| q as u64).unwrap_or(st.worker_quota);
        central.roster = WorkerRoster::restore(quota, &st.admitted);
        for d in 1..n {
            central.roster.readmit(d)?;
        }
        // each link's tier ladder resumes where it left off (clamped
        // into the possibly re-narrowed band), not at the floor
        if let Some(policy) = &mut central.adaptive {
            *policy = crate::net::quant::AdaptivePolicy::resume_at(
                cfg.adaptive.clone(),
                &st.link_tiers,
            );
        }
    } else {
        // the offline phase (profiling above) is already behind us; the
        // machine records it so both drivers share one transition log
        central.machine.step(PhaseInput::StartProfiling)?;
        for d in 1..n {
            central.roster.admit(d)?;
        }
    }

    // ---- readiness barrier: workers compile their executables at thread
    // start; probing until every worker answers prevents the fault
    // detector from firing on compile time (big models need minutes).
    {
        let mut ready: BTreeSet<DeviceId> = BTreeSet::new();
        let deadline = central.clock.raw_now() + Duration::from_secs(900);
        while ready.len() + 1 < n {
            for d in 1..n {
                if !ready.contains(&d) {
                    central.endpoint.send(d, Message::Probe)?;
                }
            }
            let wait_until = central.clock.raw_now() + Duration::from_millis(500);
            while central.clock.raw_now() < wait_until {
                if let Some((_, Message::ProbeAck { id, .. })) =
                    central.endpoint.recv_timeout(Duration::from_millis(100))
                {
                    ready.insert(id);
                }
            }
            if central.clock.raw_now() > deadline {
                bail!("workers not ready after 900s ({}/{} acked)", ready.len(), n - 1);
            }
        }
        log_info!("all {} workers ready", n - 1);
    }

    // ---- restart handshake (paper §III-E): a resumed coordinator
    // re-announces itself and reconciles every worker's uncommitted
    // progress against the checkpoint's committed batch before pushing
    // the new training state. Freshly spawned workers all report
    // `fresh`; a surviving worker (TCP deployments) would report the
    // progress it must roll back.
    if let Some(st) = &resume {
        // Down -> Rejoining: opens the machine's ack window that
        // restart_handshake's poll loop resolves
        central
            .machine
            .step(PhaseInput::CentralRestarted { now: central.clock.raw_now() })?;
        let peers: Vec<DeviceId> = (1..n).collect();
        central.restart_handshake(&peers, st.checkpoint.state.committed_batch)?;
    }
    let resumed = resume.is_some();
    if let Some(st) = resume {
        central.record.event(
            &central.clock,
            format!(
                "resumed from checkpoint at batch {} (replica epoch {}, {} link tiers)",
                st.checkpoint.state.committed_batch,
                central.replica_epoch,
                st.link_tiers.len()
            ),
        );
        // checkpoint weights take the warm-start path below — always
        // f32 (restore fidelity is a correctness requirement)
        opts.initial_weights = Some(st.checkpoint.weights);
    }

    // ---- training initialization (paper Table I) ----
    let ti = central.train_init(init_ranges.clone(), worker_list.clone(), 0);
    for d in 1..n {
        central.endpoint.send(d, Message::InitState(ti.clone()))?;
    }
    central.worker.apply_init(&ti)?;
    central.worker.measure_bandwidth(&central.endpoint)?;
    // steady state: a fresh run steps out of Profiling here; a resumed
    // one already polled Rejoining -> Training through the handshake
    if central.machine.phase() != CoordinatorPhase::Training {
        central.machine.step(PhaseInput::TrainingStarted)?;
    }
    // init just reset every stage to the policy's floor tier; a resume
    // re-announces the restored rung so wire encodings agree again
    if resumed {
        let peers: Vec<DeviceId> = (1..n).collect();
        central.rebroadcast_tier(&peers)?;
    }

    // warm start (continuous training): push pre-trained weights out —
    // shared buffers, so this stages no copies at the central node
    if let Some(init_w) = opts.initial_weights.take() {
        for (stage, &(lo, hi)) in init_ranges.iter().enumerate() {
            let blocks: Vec<crate::net::message::WireBlock> = (lo..=hi)
                .filter_map(|b| {
                    init_w.get(&b).map(|bp| (b, crate::replication::block_to_wire(bp)))
                })
                .collect();
            if blocks.is_empty() {
                continue;
            }
            let dev = worker_list[stage];
            if dev == 0 {
                central.worker.handle_weights(&central.endpoint, 0, blocks)?;
            } else {
                central.endpoint.send(dev, Message::Weights { blocks })?;
            }
        }
    }
    // give workers a moment to initialize + run bandwidth probes
    central.pump_for(Duration::from_millis(150))?;

    Ok(BootResult::Ready(Box::new(Boot {
        central,
        handles,
        net,
        collect_final_weights: opts.collect_final_weights,
    })))
}
