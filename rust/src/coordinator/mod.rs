//! The central-node coordinator, decomposed into phases that share one
//! event vocabulary ([`crate::pipeline::Event`]):
//!
//! - [`core`] — the transport-agnostic phase state machine
//!   ([`CoordinatorPhase`], `PhaseMachine`) plus worker admission
//!   (`WorkerRoster`), shared with the scenario runner (DESIGN.md §12)
//! - `offline` — §III-B bootstrap: spawn simulated devices, profile the
//!   model, initial capacity-blind partition, readiness barrier,
//!   training-init broadcast, warm-start weight push
//! - `central` — the steady-state training driver: injection up to the
//!   in-flight limit, event dispatch, stage-0 compute, evaluation,
//!   checkpointing
//! - `recovery` — §III-D dynamic re-partition and the §III-F fault
//!   handler's three cases, both funneling into the shared
//!   `Repartition -> fetch -> FetchDone -> Commit` protocol
//!
//! Both the threaded driver here and `sim::runner` execute
//! [`core::PhaseEffect`]s against their own transports; neither carries
//! phase logic of its own.
//!
//! [`run_sim_full`] chains the phases in-process: one thread per
//! simulated device (each with its own PJRT engine), the bandwidth-
//! modeled [`crate::net::sim::SimNet`], and the central node driving
//! training from the calling thread. Baseline engines (PipeDream /
//! ResPipe / single-device / sync) reuse the same driver with features
//! toggled — see [`crate::config::Engine`].

pub mod core;

mod central;
mod offline;
mod recovery;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::RunConfig;
use crate::data::DataSource;
use crate::metrics::RunRecord;
use crate::model::BlockParams;
use crate::net::message::Message;
use crate::net::Transport;
use crate::pipeline::trace::TraceSink;
use crate::{log_debug, log_warn};

pub use self::core::{
    AdmissionError, CoordinatorPhase, IllegalTransition, PhaseConfig, PhaseEffect, PhaseInput,
    PhaseMachine, RedistReason, WorkerRoster,
};
pub use crate::checkpoint::{CoordinatorStore, LeaderState};
pub use offline::default_datasource;

/// Options beyond [`RunConfig`] (custom data, tracing, warm-start weights).
#[derive(Default)]
pub struct RunOpts {
    /// Training data source (None = the config's default synthetic set).
    pub data: Option<Box<dyn DataSource>>,
    /// Pipeline event trace sink (disabled by default).
    pub trace: TraceSink,
    /// Warm-start weights (block -> tensors): the paper's continuous-
    /// training mode, where pre-trained weights are sent to the workers.
    pub initial_weights: Option<BTreeMap<usize, BlockParams>>,
    /// Gather final weights from all stages at the end of the run.
    pub collect_final_weights: bool,
    /// Profiling repetitions (paper: 10).
    pub profile_reps: usize,
}

/// A finished run: metrics plus (optionally) the final model.
pub struct RunOutput {
    /// Per-batch/per-epoch metrics, events, and the phase-transition log.
    pub record: RunRecord,
    /// Final weights per block (empty unless requested in [`RunOpts`]).
    pub final_weights: BTreeMap<usize, BlockParams>,
}

/// Convenience wrapper returning only the metrics record.
pub fn run_sim(cfg: &RunConfig) -> Result<RunRecord> {
    Ok(run_sim_full(cfg, RunOpts::default())?.record)
}

/// Run a full training job in single-process simulation: offline
/// bootstrap, steady-state training (with recovery on faults), then
/// final-weights collection and shutdown.
pub fn run_sim_full(cfg: &RunConfig, opts: RunOpts) -> Result<RunOutput> {
    let boot = match offline::bootstrap(cfg, opts)? {
        offline::BootResult::Ready(boot) => boot,
        offline::BootResult::Oom(record) => {
            return Ok(RunOutput { record, final_weights: BTreeMap::new() })
        }
    };
    let offline::Boot { mut central, handles, net, collect_final_weights } = *boot;

    central.run_training()?;

    let final_weights = if collect_final_weights {
        central.collect_final_weights()?
    } else {
        BTreeMap::new()
    };

    // ---- shutdown ----
    let n = cfg.n_devices();
    for d in 1..n {
        net.revive(d); // make sure even killed devices can hear the shutdown
        central.endpoint.send(d, Message::Shutdown)?;
    }
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => log_warn!("worker exited with error: {e:#}"),
            Err(_) => log_warn!("worker panicked"),
        }
    }

    central.record.total_s = central.clock.now_s();
    central.record.net_bytes = net.total_bytes();
    log_debug!(
        "run done in {:.1}s, {} bytes over the network",
        central.record.total_s,
        central.record.net_bytes
    );
    Ok(RunOutput { record: central.record, final_weights })
}
