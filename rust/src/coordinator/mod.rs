//! The central-node coordinator: offline-stage initialization (§III-B),
//! the online training driver, dynamic re-partition scheduling (§III-D),
//! and the fault-tolerance handler's three cases (§III-F).
//!
//! [`run_sim`] stands up the whole system in-process: one thread per
//! simulated device (each with its own PJRT engine), the bandwidth-
//! modeled [`SimNet`], and the central node driving training from the
//! calling thread. Baseline engines (PipeDream / ResPipe / single-device
//! / sync) reuse the same driver with features toggled — see
//! [`crate::config::Engine`].

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::{Engine, RunConfig};
use crate::data::{Batch, DataSource, SynthLm, SynthVision};
use crate::device::SimDevice;
use crate::fault::{renumber_worker_list, FaultDetector};
use crate::manifest::{Dtype, Manifest};
use crate::metrics::{BatchRecord, EpochRecord, RunClock, RunRecord};
use crate::model::BlockParams;
use crate::net::message::{DeviceId, Message, Payload, TrainInit};
use crate::net::sim::{SimEndpoint, SimNet};
use crate::net::Transport;
use crate::partition::{homogeneous_partition, optimal_partition, CostModel, Partition};
use crate::pipeline::trace::TraceSink;
use crate::pipeline::{run_worker, CompletedBatch, StageWorker};
use crate::profile::{profile_model, CapacityEstimator, ModelProfile};
use crate::runtime::{load_all_blocks, Engine as XlaEngine, HostTensor};
use crate::{log_debug, log_info, log_warn};

/// Options beyond [`RunConfig`] (custom data, tracing, warm-start weights).
#[derive(Default)]
pub struct RunOpts {
    pub data: Option<Box<dyn DataSource>>,
    pub trace: TraceSink,
    /// Warm-start weights (block -> tensors): the paper's continuous-
    /// training mode, where pre-trained weights are sent to the workers.
    pub initial_weights: Option<BTreeMap<usize, BlockParams>>,
    /// Gather final weights from all stages at the end of the run.
    pub collect_final_weights: bool,
    /// Profiling repetitions (paper: 10).
    pub profile_reps: usize,
}

/// A finished run: metrics plus (optionally) the final model.
pub struct RunOutput {
    pub record: RunRecord,
    pub final_weights: BTreeMap<usize, BlockParams>,
}

/// Convenience wrapper returning only the metrics record.
pub fn run_sim(cfg: &RunConfig) -> Result<RunRecord> {
    Ok(run_sim_full(cfg, RunOpts::default())?.record)
}

/// Build the default synthetic data source for a compiled model.
pub fn default_datasource(manifest: &Manifest, seed: u64) -> Box<dyn DataSource> {
    match manifest.input_dtype {
        Dtype::F32 => {
            let dim: usize = manifest.input_shape.iter().skip(1).product();
            let classes = manifest.n_classes.unwrap_or(10);
            Box::new(SynthVision::new(dim, classes, 0.6, seed, 0))
        }
        Dtype::I32 => {
            let vocab = manifest.vocab.unwrap_or(512);
            let seq = manifest.seq.unwrap_or(64);
            Box::new(SynthLm::new(vocab, seq, seed))
        }
    }
}

struct Central {
    cfg: RunConfig,
    manifest: Arc<Manifest>,
    worker: StageWorker,
    endpoint: SimEndpoint,
    net: SimNet,
    profile: ModelProfile,
    estimator: CapacityEstimator,
    detector: FaultDetector,
    measured_bw: Vec<f64>, // per link, from BwReports
    record: RunRecord,
    clock: RunClock,
    // training pointers
    next_inject: u64,
    inflight: usize,
    completed: i64,
    total_batches: u64,
    last_completion_s: f64,
    // per-epoch accumulators
    epoch_correct: f64,
    epoch_batches: u64,
    // fault plan
    fault_armed: bool,
    last_checkpoint: u64,
    data: Box<dyn DataSource>,
}

impl Central {
    fn device_of_stage(&self, stage: usize) -> DeviceId {
        self.worker.worker_list[stage]
    }

    fn n_stages(&self) -> usize {
        self.worker.n_stages()
    }

    fn last_device(&self) -> DeviceId {
        *self.worker.worker_list.last().unwrap()
    }

    fn limit(&self) -> usize {
        match self.cfg.engine {
            Engine::SyncPipeline => 1,
            _ => self.cfg.inflight_limit.unwrap_or(self.n_stages()),
        }
    }

    // ------------------------------------------------------------------
    // injection
    // ------------------------------------------------------------------

    fn batch_payload(&self, b: &Batch) -> Payload {
        match self.manifest.input_dtype {
            Dtype::F32 => Payload::F32(b.x_f32.clone()),
            Dtype::I32 => Payload::I32(b.x_i32.clone()),
        }
    }

    fn inject_one(&mut self) -> Result<()> {
        let batch = self.next_inject;
        let data = self.data.train_batch(batch, self.manifest.batch_size);
        // labels go straight to the last stage (central holds the data)
        if self.n_stages() > 1 {
            self.endpoint.send(
                self.last_device(),
                Message::Labels { batch, is_eval: false, data: data.labels.clone() },
            )?;
        } else {
            self.worker
                .handle_message(&self.endpoint, 0, Message::Labels {
                    batch,
                    is_eval: false,
                    data: data.labels.clone(),
                })?;
        }
        let x = match self.batch_payload(&data) {
            Payload::F32(v) => HostTensor::F32(v),
            Payload::I32(v) => HostTensor::I32(v),
        };
        let done = self
            .worker
            .forward_train(&self.endpoint, batch, self.worker.version, x)?;
        self.detector.arm(batch);
        self.inflight += 1;
        self.next_inject += 1;
        if let Some(cb) = done {
            // single-stage pipeline completes synchronously
            self.on_complete(cb)?;
        }
        // fault injection: kill the worker while this batch is in flight
        if let Some(f) = self.cfg.fault.clone() {
            if !self.fault_armed && batch + 1 >= f.at_batch {
                self.fault_armed = true;
                let dev = f.kill_device;
                log_info!("FAULT INJECTION: killing device {dev} at batch {batch}");
                self.record.event(&self.clock, format!("kill device {dev}"));
                self.net.kill(dev);
                if f.restarts {
                    // the device restarts (empty state) almost immediately
                    let net = self.net.clone();
                    std::thread::spawn(move || {
                        std::thread::sleep(Duration::from_millis(300));
                        net.revive(dev);
                    });
                }
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // completion
    // ------------------------------------------------------------------

    fn on_complete(&mut self, cb: CompletedBatch) -> Result<()> {
        self.detector.disarm(cb.batch);
        self.inflight = self.inflight.saturating_sub(1);
        self.completed = self.completed.max(cb.batch as i64);
        for r in &cb.reports {
            self.estimator.ingest(r);
        }
        let now = self.clock.now_s();
        let wall_ms = (now - self.last_completion_s) * 1e3;
        self.last_completion_s = now;
        let acc = cb.ncorrect / self.manifest.acc_denom as f32;
        self.epoch_correct += cb.ncorrect as f64;
        self.epoch_batches += 1;
        if self.cfg.verbose {
            log_info!(
                "batch {} loss={:.4} acc={:.3} wall={:.1}ms inflight={}",
                cb.batch,
                cb.loss,
                acc,
                wall_ms,
                self.inflight
            );
        }
        self.record.batches.push(BatchRecord {
            batch: cb.batch,
            loss: cb.loss,
            train_acc: acc,
            wall_ms,
            at_s: now,
        });
        Ok(())
    }

    // ------------------------------------------------------------------
    // message loop
    // ------------------------------------------------------------------

    /// Handle one incoming message at the central node.
    fn dispatch(&mut self, from: DeviceId, msg: Message) -> Result<()> {
        match msg {
            Message::Backward { batch, grad, loss, ncorrect, reports } => {
                if self.worker.status == 0 {
                    let done =
                        self.worker
                            .backward(&self.endpoint, batch, grad, loss, ncorrect, reports)?;
                    if let Some(cb) = done {
                        self.on_complete(cb)?;
                    }
                }
            }
            Message::BwReport { stage, bps } => {
                if stage < self.measured_bw.len() {
                    self.measured_bw[stage] = bps;
                }
            }
            Message::Weights { blocks } => {
                self.worker.handle_weights(&self.endpoint, from, blocks)?;
            }
            other => {
                // control traffic shared with workers (replica pushes into
                // the global store, fetch serving, probes, bw tests, ...)
                self.worker.handle_message(&self.endpoint, from, other)?;
            }
        }
        Ok(())
    }

    /// Drain the inbox for up to `dur`, dispatching everything.
    fn pump_for(&mut self, dur: Duration) -> Result<Vec<(u64, f32, f32)>> {
        // returns eval results observed
        let deadline = Instant::now() + dur;
        let mut evals = Vec::new();
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.endpoint.recv_timeout(left.min(Duration::from_millis(5))) {
                Some((from, Message::EvalResult { batch, loss, ncorrect })) => {
                    let _ = from;
                    evals.push((batch, loss, ncorrect));
                }
                Some((from, msg)) => self.dispatch(from, msg)?,
                None => {}
            }
            if Instant::now() >= deadline {
                return Ok(evals);
            }
        }
    }

    /// Wait until all in-flight batches complete (or a fault fires).
    fn drain(&mut self) -> Result<()> {
        let deadline = Instant::now() + Duration::from_millis(self.cfg.fault_timeout_ms * 2);
        while self.inflight > 0 {
            if let Some((from, msg)) = self.endpoint.recv_timeout(Duration::from_millis(5)) {
                self.dispatch(from, msg)?;
            }
            if let Some(b) = self.detector.overdue() {
                self.handle_fault(b)?;
            }
            if Instant::now() > deadline {
                bail!("drain timed out with {} in flight", self.inflight);
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // evaluation (forward-only through the pipeline)
    // ------------------------------------------------------------------

    fn evaluate(&mut self) -> Result<(f32, f32)> {
        let nb = self.cfg.eval_batches as u64;
        if nb == 0 {
            return Ok((f32::NAN, f32::NAN));
        }
        self.drain()?;
        let mut results: Vec<(f32, f32)> = Vec::new();
        for b in 0..nb {
            let data = self.data.val_batch(b, self.manifest.batch_size);
            if self.n_stages() > 1 {
                self.endpoint.send(
                    self.last_device(),
                    Message::Labels { batch: b, is_eval: true, data: data.labels.clone() },
                )?;
            } else {
                self.worker.handle_message(&self.endpoint, 0, Message::Labels {
                    batch: b,
                    is_eval: true,
                    data: data.labels.clone(),
                })?;
            }
            let x = match self.manifest.input_dtype {
                Dtype::F32 => HostTensor::F32(data.x_f32),
                Dtype::I32 => HostTensor::I32(data.x_i32),
            };
            if let Some((loss, nc)) = self.worker.forward_eval(&self.endpoint, b, x)? {
                results.push((loss, nc));
            }
        }
        // collect results coming back from the last stage
        let deadline = Instant::now() + Duration::from_secs(120);
        while results.len() < nb as usize {
            let evals = self.pump_for(Duration::from_millis(20))?;
            for (_, l, c) in evals {
                results.push((l, c));
            }
            if Instant::now() > deadline {
                log_warn!("eval timed out: {}/{} results", results.len(), nb);
                break;
            }
        }
        if results.is_empty() {
            return Ok((f32::NAN, f32::NAN));
        }
        let n = results.len() as f32;
        let loss = results.iter().map(|(l, _)| l).sum::<f32>() / n;
        let acc = results.iter().map(|(_, c)| c).sum::<f32>()
            / (n * self.manifest.acc_denom as f32);
        Ok((loss, acc))
    }

    // ------------------------------------------------------------------
    // dynamic re-partition (paper §III-D)
    // ------------------------------------------------------------------

    fn current_cost_model(&self, worker_list: &[DeviceId], old_ranges: &[(usize, usize)]) -> CostModel {
        // central's own online/offline ratio cancels host-contention in sim
        let central_ratio = match (self.worker.avg_exec_ms(), self.worker.my_range()) {
            (Some(avg), Some((lo, hi))) => {
                let base: f64 = self.profile.t0_ms[lo..=hi].iter().sum();
                if base > 0.0 { avg / base } else { 1.0 }
            }
            _ => 1.0,
        };
        let caps = self
            .estimator
            .capacities(worker_list, old_ranges, &self.profile.t0_ms, central_ratio);
        let n = worker_list.len();
        let mut bw = Vec::with_capacity(n.saturating_sub(1));
        for link in 0..n.saturating_sub(1) {
            let measured = self.measured_bw.get(link).copied().unwrap_or(0.0);
            bw.push(if measured > 0.0 { measured } else { self.cfg.bandwidth(link.min(self.cfg.bandwidth_bps.len().saturating_sub(1))) });
        }
        CostModel {
            t0_ms: self.profile.t0_ms.clone(),
            out_bytes: self.profile.out_bytes.clone(),
            capacities: caps,
            bandwidth_bps: bw,
        }
    }

    /// Drain, recompute the optimal cuts from live capacity estimates, and
    /// run the redistribution protocol if the partition changed.
    fn dynamic_repartition(&mut self) -> Result<()> {
        self.drain()?;
        let worker_list = self.worker.worker_list.clone();
        let old_ranges = self.worker.ranges.clone();
        let cm = self.current_cost_model(&worker_list, &old_ranges);
        let (new_ranges, cost) = optimal_partition(&cm);
        self.record
            .event(&self.clock, format!("repartition check: caps={:?}", cm.capacities));
        if new_ranges == old_ranges {
            return Ok(());
        }
        log_info!(
            "dynamic re-partition at batch {}: {:?} -> {:?} (predicted bottleneck {:.1}ms)",
            self.completed,
            old_ranges,
            new_ranges,
            cost
        );
        self.record.event(&self.clock, format!("repartition {new_ranges:?}"));
        self.run_redistribution(new_ranges.clone(), worker_list, vec![])?;
        self.record.partitions.push((self.completed.max(0) as u64, new_ranges));
        Ok(())
    }

    /// The shared Repartition -> fetch -> FetchDone -> Commit protocol.
    fn run_redistribution(
        &mut self,
        ranges: Partition,
        worker_list: Vec<DeviceId>,
        failed: Vec<usize>,
    ) -> Result<()> {
        let workers: Vec<DeviceId> =
            worker_list.iter().copied().filter(|&d| d != self.worker.device_id).collect();
        for &d in &workers {
            self.endpoint.send(
                d,
                Message::Repartition {
                    ranges: ranges.clone(),
                    worker_list: worker_list.clone(),
                    failed: failed.clone(),
                },
            )?;
        }
        self.worker.begin_repartition(
            &self.endpoint,
            ranges.clone(),
            worker_list.clone(),
            failed,
        )?;

        // await FetchDone from every worker + our own completion
        let mut done: BTreeSet<DeviceId> = BTreeSet::new();
        let deadline = Instant::now() + Duration::from_secs(60);
        while done.len() < workers.len() || !self.worker.fetch_done() {
            match self.endpoint.recv_timeout(Duration::from_millis(5)) {
                Some((_, Message::FetchDone { id })) => {
                    done.insert(id);
                }
                Some((from, Message::Weights { blocks })) => {
                    self.worker.handle_weights(&self.endpoint, from, blocks)?;
                }
                Some((from, Message::FetchWeights { blocks })) => {
                    self.worker.serve_fetch(&self.endpoint, from, &blocks)?;
                }
                Some((from, msg)) => self.dispatch(from, msg)?,
                None => {}
            }
            if Instant::now() > deadline {
                bail!(
                    "redistribution timed out ({} of {} workers done)",
                    done.len(),
                    workers.len()
                );
            }
        }

        // commit everywhere (paper's commit message)
        for &d in &workers {
            self.endpoint.send(d, Message::Commit)?;
        }
        self.worker.apply_commit()?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // fault tolerance (paper §III-F)
    // ------------------------------------------------------------------

    fn handle_fault(&mut self, overdue_batch: u64) -> Result<()> {
        let t_start = Instant::now();
        log_warn!(
            "FAULT: no gradient for batch {overdue_batch} within timeout; probing workers"
        );
        self.record.event(&self.clock, format!("fault detected at batch {overdue_batch}"));
        self.worker.status = 1;

        // probe all current workers
        let worker_list = self.worker.worker_list.clone();
        let peers: Vec<DeviceId> = worker_list
            .iter()
            .copied()
            .filter(|&d| d != self.worker.device_id)
            .collect();
        for &d in &peers {
            self.endpoint.send(d, Message::Probe)?;
        }
        let mut acks: BTreeMap<DeviceId, bool> = BTreeMap::new(); // id -> fresh
        let probe_deadline = Instant::now() + Duration::from_millis(1500);
        while acks.len() < peers.len() && Instant::now() < probe_deadline {
            match self.endpoint.recv_timeout(Duration::from_millis(10)) {
                Some((_, Message::ProbeAck { id, fresh })) => {
                    acks.insert(id, fresh);
                }
                Some((_, Message::Backward { .. })) | Some((_, Message::Forward { .. })) => {
                    // stale data traffic during recovery: discard
                }
                Some((from, msg)) => self.dispatch(from, msg)?,
                None => {}
            }
        }
        let dead: Vec<DeviceId> =
            peers.iter().copied().filter(|d| !acks.contains_key(d)).collect();
        let fresh: Vec<DeviceId> =
            acks.iter().filter(|(_, &f)| f).map(|(&d, _)| d).collect();
        let detect_s = t_start.elapsed().as_secs_f64();
        // Table III's "recover overhead" is the work AFTER the failed
        // worker is identified (renumber + re-partition + weight
        // redistribution + reset); detection/probing cost is identical
        // across systems and reported separately as an event.
        let t_redist = Instant::now();

        let committed = self.completed;
        if dead.is_empty() && fresh.is_empty() {
            // CASE 1: everyone fine — restart from the failed batch
            log_info!("fault case 1: all workers healthy; restarting from batch {}", committed + 1);
            self.record.event(&self.clock, "fault case 1: restart".to_string());
        } else if dead.is_empty() {
            // CASE 2: a worker restarted and lost its state — re-send the
            // state variables, let it re-fetch weights from its chain
            // replica holder, same partition.
            log_info!("fault case 2: restarted worker(s) {fresh:?}; restoring from replicas");
            self.record.event(&self.clock, format!("fault case 2: restore {fresh:?}"));
            let ti = self.train_init(self.worker.ranges.clone(), worker_list.clone(), 1);
            for &d in &fresh {
                self.endpoint.send(d, Message::InitState(ti.clone()))?;
            }
            // tiny pause so InitState lands before Repartition
            std::thread::sleep(Duration::from_millis(50));
            self.run_redistribution(self.worker.ranges.clone(), worker_list, vec![])?;
        } else {
            // CASE 3: dead worker(s) — renumber, re-partition, redistribute
            let failed_stages: Vec<usize> = worker_list
                .iter()
                .enumerate()
                .filter(|(_, d)| dead.contains(d))
                .map(|(s, _)| s)
                .collect();
            log_info!("fault case 3: dead stages {failed_stages:?}; re-partitioning");
            self.record
                .event(&self.clock, format!("fault case 3: dead stages {failed_stages:?}"));
            let new_list = renumber_worker_list(&worker_list, &failed_stages);
            let old_ranges = self.worker.ranges.clone();
            let new_ranges = if self.cfg.engine == Engine::ResPipe {
                // ResPipe-style recovery: the failed stage's successor
                // absorbs its whole range — no re-partitioning.
                respipe_merge(&old_ranges, &failed_stages)
            } else {
                // FTPipeHD: dynamic scheduler over the alive devices
                let alive_old_ranges: Vec<(usize, usize)> = old_ranges
                    .iter()
                    .enumerate()
                    .filter(|(s, _)| !failed_stages.contains(s))
                    .map(|(_, &r)| r)
                    .collect();
                let cm = self.current_cost_model(&new_list, &alive_old_ranges);
                optimal_partition(&cm).0
            };
            for &d in &dead {
                self.estimator.clear_device(d);
            }
            self.run_redistribution(new_ranges.clone(), new_list, failed_stages)?;
            self.record.partitions.push((committed.max(0) as u64, new_ranges));
        }

        // reset the training state everywhere (paper: discard batches
        // beyond the last committed one, status back to 0)
        let peers_now: Vec<DeviceId> = self
            .worker
            .worker_list
            .clone()
            .into_iter()
            .filter(|&d| d != self.worker.device_id)
            .collect();
        for &d in &peers_now {
            self.endpoint.send(d, Message::Reset { committed })?;
        }
        self.worker.apply_reset(committed);
        self.detector.clear();
        self.inflight = 0;
        self.next_inject = (committed + 1) as u64;

        let overhead = t_redist.elapsed().as_secs_f64();
        self.record.recovery_overhead_s = Some(overhead);
        self.record.event(
            &self.clock,
            format!("recovery complete: detect+probe {detect_s:.3}s, redistribute {overhead:.3}s"),
        );
        log_info!(
            "recovery complete (detect+probe {detect_s:.3}s, redistribute {overhead:.3}s); resuming from batch {}",
            self.next_inject
        );
        Ok(())
    }

    /// Save everything the central node can see (its own stage + the
    /// newest global/chain replicas) to disk. Completeness of the worker
    /// stages depends on the replication period — exactly the paper's
    /// §III-E tradeoff.
    fn save_checkpoint(&mut self, dir: &str, epoch: u64) -> Result<()> {
        use crate::checkpoint::{Checkpoint, CheckpointState};
        let mut weights: BTreeMap<usize, crate::model::BlockParams> = BTreeMap::new();
        for (&b, bp) in &self.worker.params.blocks {
            weights.insert(b, bp.clone());
        }
        for b in 0..self.manifest.n_blocks() {
            if weights.contains_key(&b) {
                continue;
            }
            if let Some(bp) = self.worker.backups.find_block(b) {
                weights.insert(b, bp.clone());
            }
        }
        let mut shapes: BTreeMap<usize, Vec<Vec<usize>>> = BTreeMap::new();
        for (&b, _) in &weights {
            shapes.insert(
                b,
                self.manifest.blocks[b].params.iter().map(|p| p.shape.clone()).collect(),
            );
        }
        let ck = Checkpoint {
            state: CheckpointState {
                committed_batch: self.completed,
                epoch,
                lr: self.worker.sgd.cfg.lr,
                ranges: self.worker.ranges.clone(),
                worker_list: self.worker.worker_list.clone(),
                shapes,
            },
            weights,
        };
        ck.save(dir)?;
        self.record.event(
            &self.clock,
            format!("checkpoint at batch {} ({} blocks)", self.completed, ck.weights.len()),
        );
        Ok(())
    }

    fn train_init(
        &self,
        ranges: Partition,
        worker_list: Vec<DeviceId>,
        status: u8,
    ) -> TrainInit {
        let agg = match self.cfg.engine {
            Engine::FtPipeHd => self.cfg.agg_interval_k.unwrap_or(0) as u32,
            _ => 0,
        };
        let (chain, global) = match self.cfg.engine {
            Engine::FtPipeHd => (
                self.cfg.chain_every.unwrap_or(0),
                self.cfg.global_every.unwrap_or(0),
            ),
            Engine::ResPipe => (self.cfg.chain_every.unwrap_or(0), 0),
            _ => (0, 0),
        };
        TrainInit {
            committed_forward: -1,
            committed_backward: -1,
            lr: self.cfg.lr,
            momentum: self.cfg.momentum,
            weight_decay: self.cfg.weight_decay,
            epochs: self.cfg.epochs as u64,
            batches_per_epoch: self.cfg.batches_per_epoch as u64,
            ranges,
            worker_list,
            agg_k: agg,
            chain_every: chain,
            global_every: global,
            status,
        }
    }
}

/// ResPipe recovery: the next alive worker absorbs each failed stage's
/// range (no re-partition). Returns the merged ranges for the alive stages.
fn respipe_merge(old_ranges: &[(usize, usize)], failed: &[usize]) -> Partition {
    let mut merged: Vec<(usize, usize)> = Vec::new();
    let n = old_ranges.len();
    let mut s = 0;
    while s < n {
        if failed.contains(&s) {
            s += 1;
            continue;
        }
        merged.push(old_ranges[s]);
        s += 1;
    }
    // extend each survivor backward to cover preceding failed ranges
    // (the failed stage's NEXT worker takes over its blocks)
    let mut out: Vec<(usize, usize)> = Vec::new();
    let mut expect = 0usize;
    for &(lo, hi) in &merged {
        let lo2 = expect.min(lo);
        out.push((lo2, hi));
        expect = hi + 1;
    }
    // a failed LAST stage falls to the central node (stage 0): extend the
    // final survivor forward
    if let Some(last) = out.last_mut() {
        let total_hi = old_ranges.last().unwrap().1;
        if last.1 < total_hi {
            last.1 = total_hi;
        }
    }
    out
}

/// Run a full training job in single-process simulation.
pub fn run_sim_full(cfg: &RunConfig, mut opts: RunOpts) -> Result<RunOutput> {
    cfg.validate()?;
    crate::util::logging::init_from_env();
    let manifest = Arc::new(Manifest::load(&cfg.model_dir)?);
    let n = cfg.n_devices();
    if manifest.n_blocks() < n {
        bail!("{} blocks < {} devices", manifest.n_blocks(), n);
    }

    let (net, mut endpoints) = SimNet::new(
        n,
        cfg.bandwidth_bps.clone(),
        Duration::from_secs_f64(cfg.link_latency_s),
    );
    endpoints.reverse(); // pop from the front: device 0 first
    let central_ep = endpoints.pop().expect("central endpoint");

    // ---- spawn workers ----
    let mut handles = Vec::new();
    for d in 1..n {
        let ep = endpoints.pop().expect("worker endpoint");
        let manifest = manifest.clone();
        let dev_cfg = cfg.devices[d].clone();
        let seed = cfg.seed ^ (d as u64).wrapping_mul(0x9E3779B9);
        let trace = opts.trace.clone();
        let net2 = net.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("device-{d}"))
                .spawn(move || -> Result<()> {
                    let engine = XlaEngine::cpu()?;
                    let blocks = load_all_blocks(&engine, &manifest)?;
                    let sim = SimDevice::new(dev_cfg, seed);
                    let w = StageWorker::new(d, manifest, blocks, sim, trace);
                    run_worker(w, Box::new(ep), Some(net2))
                })?,
        );
    }

    // ---- central node (device 0) ----
    let engine = XlaEngine::cpu()?;
    let blocks = load_all_blocks(&engine, &manifest)?;
    let sim = SimDevice::new(cfg.devices[0].clone(), cfg.seed ^ 0xC0FFEE);
    let worker = StageWorker::new(0, manifest.clone(), blocks, sim, opts.trace.clone());

    // ---- offline stage: profiling + initial partition (paper §III-B) ----
    let reps = if opts.profile_reps == 0 { 5 } else { opts.profile_reps };
    let profile = profile_model(&manifest, &worker.blocks_rt, reps)?;
    log_info!(
        "profiled {} blocks: t0={:?}ms",
        profile.t0_ms.len(),
        profile.t0_ms.iter().map(|t| (t * 10.0).round() / 10.0).collect::<Vec<_>>()
    );

    let worker_list: Vec<DeviceId> = (0..n).collect();
    let init_cm = CostModel {
        t0_ms: profile.t0_ms.clone(),
        out_bytes: profile.out_bytes.clone(),
        capacities: vec![1.0; n],
        bandwidth_bps: (0..n.saturating_sub(1)).map(|l| cfg.bandwidth(l.min(cfg.bandwidth_bps.len().saturating_sub(1)))).collect(),
    };
    let (init_ranges, _) = homogeneous_partition(&init_cm);
    log_info!("initial (capacity-blind) partition: {init_ranges:?}");

    // memory-cap check (single-device OOM emulation, §IV-F)
    {
        let my_range = init_ranges[0];
        let my_bytes = manifest.param_bytes_range(my_range.0, my_range.1) * 3; // params+velocity+stash
        let dev = SimDevice::new(cfg.devices[0].clone(), 0);
        if n == 1 && !dev.fits_memory(my_bytes) {
            let mut record = RunRecord::default();
            record.events.push(crate::metrics::Event {
                at_s: 0.0,
                kind: format!(
                    "OOM: model state {} bytes exceeds device cap {:?}",
                    my_bytes, cfg.devices[0].mem_cap_bytes
                ),
            });
            return Ok(RunOutput { record, final_weights: BTreeMap::new() });
        }
    }

    let mut central = Central {
        total_batches: (cfg.epochs * cfg.batches_per_epoch) as u64,
        cfg: cfg.clone(),
        manifest: manifest.clone(),
        worker,
        endpoint: central_ep,
        net: net.clone(),
        profile,
        estimator: CapacityEstimator::default(),
        detector: FaultDetector::new(Duration::from_millis(cfg.fault_timeout_ms)),
        measured_bw: vec![0.0; n.saturating_sub(1)],
        record: RunRecord::default(),
        clock: RunClock::start(),
        next_inject: 0,
        inflight: 0,
        completed: -1,
        last_completion_s: 0.0,
        epoch_correct: 0.0,
        epoch_batches: 0,
        fault_armed: false,
        last_checkpoint: 0,
        data: opts
            .data
            .take()
            .unwrap_or_else(|| default_datasource(&manifest, cfg.seed)),
    };

    // ---- readiness barrier: workers compile their executables at thread
    // start; probing until every worker answers prevents the fault
    // detector from firing on compile time (big models need minutes).
    {
        let mut ready: BTreeSet<DeviceId> = BTreeSet::new();
        let deadline = Instant::now() + Duration::from_secs(900);
        while ready.len() + 1 < n {
            for d in 1..n {
                if !ready.contains(&d) {
                    central.endpoint.send(d, Message::Probe)?;
                }
            }
            let wait_until = Instant::now() + Duration::from_millis(500);
            while Instant::now() < wait_until {
                if let Some((_, Message::ProbeAck { id, .. })) =
                    central.endpoint.recv_timeout(Duration::from_millis(100))
                {
                    ready.insert(id);
                }
            }
            if Instant::now() > deadline {
                bail!("workers not ready after 900s ({}/{} acked)", ready.len(), n - 1);
            }
        }
        log_info!("all {} workers ready", n - 1);
    }

    // ---- training initialization (paper Table I) ----
    let ti = central.train_init(init_ranges.clone(), worker_list.clone(), 0);
    for d in 1..n {
        central.endpoint.send(d, Message::InitState(ti.clone()))?;
    }
    central.worker.apply_init(&ti)?;
    central.worker.measure_bandwidth(&central.endpoint)?;

    // warm start (continuous training): push pre-trained weights out
    if let Some(init_w) = opts.initial_weights.take() {
        for (stage, &(lo, hi)) in init_ranges.iter().enumerate() {
            let blocks: Vec<(usize, Vec<Vec<f32>>)> = (lo..=hi)
                .filter_map(|b| init_w.get(&b).map(|bp| (b, bp.0.clone())))
                .collect();
            if blocks.is_empty() {
                continue;
            }
            let dev = worker_list[stage];
            if dev == 0 {
                central.worker.handle_weights(&central.endpoint, 0, blocks)?;
            } else {
                central.endpoint.send(dev, Message::Weights { blocks })?;
            }
        }
    }
    // give workers a moment to initialize + run bandwidth probes
    central.pump_for(Duration::from_millis(150))?;

    central.record.event(&central.clock, "training start".to_string());

    // ---- online stage: the training loop ----
    let repart_first = match cfg.engine {
        Engine::FtPipeHd => cfg.repartition_first,
        _ => None,
    };
    let repart_every = match cfg.engine {
        Engine::FtPipeHd => cfg.repartition_every,
        _ => None,
    };
    let mut next_repart: Option<u64> = repart_first;
    let mut epoch = 0u64;

    while central.completed + 1 < central.total_batches as i64 {
        // inject up to the in-flight limit
        while central.next_inject < central.total_batches
            && central.inflight < central.limit()
            && central.worker.status == 0
        {
            // stop at epoch boundary until eval runs
            if central.next_inject / cfg.batches_per_epoch as u64 > epoch {
                break;
            }
            central.inject_one()?;
        }

        // receive
        if let Some((from, msg)) = central.endpoint.recv_timeout(Duration::from_millis(2)) {
            central.dispatch(from, msg)?;
            while let Some((from, msg)) = central.endpoint.recv_timeout(Duration::ZERO) {
                central.dispatch(from, msg)?;
            }
        }
        // let the stage-0 worker compute queued backwards (it computes
        // inline in dispatch; pump for any queued forwards in 1-stage mode)
        central.worker.pump(&central.endpoint)?;

        // fault detection
        if let Some(b) = central.detector.overdue() {
            central.handle_fault(b)?;
        }

        // dynamic re-partition schedule
        if let Some(at) = next_repart {
            if central.completed >= at as i64 {
                central.dynamic_repartition()?;
                next_repart = repart_every.map(|e| at + e);
            }
        }

        // epoch boundary: drain + evaluate
        let done_in_epoch = (central.completed + 1) as u64;
        if done_in_epoch >= (epoch + 1) * cfg.batches_per_epoch as u64 {
            let train_acc = (central.epoch_correct
                / (central.epoch_batches.max(1) as f64 * manifest.acc_denom as f64))
                as f32;
            let (val_loss, val_acc) = central.evaluate()?;
            let at_s = central.clock.now_s();
            log_info!(
                "epoch {epoch}: train_acc={train_acc:.3} val_loss={val_loss:.4} val_acc={val_acc:.3} ({at_s:.1}s)"
            );
            central.record.epochs.push(EpochRecord {
                epoch,
                train_acc,
                val_loss,
                val_acc,
                at_s,
            });
            central.epoch_correct = 0.0;
            central.epoch_batches = 0;
            epoch += 1;
            // learning-rate schedule (paper §IV-C)
            for &(at_epoch, lr) in &cfg.lr_drops {
                if at_epoch as u64 == epoch {
                    log_info!("epoch {epoch}: setting lr to {lr}");
                    central.worker.sgd.set_lr(lr);
                    for &d in central.worker.worker_list.clone().iter().filter(|&&d| d != 0) {
                        central.endpoint.send(d, Message::SetLr { lr })?;
                    }
                }
            }
        }

        // central-node checkpoint (paper §III-E: periodic save-to-disk)
        if let Some((dir, every)) = &cfg.checkpoint {
            let done = (central.completed + 1) as u64;
            if *every > 0 && done > 0 && done % every == 0 && central.last_checkpoint != done {
                central.last_checkpoint = done;
                central.save_checkpoint(dir, epoch)?;
            }
        }
    }

    central.record.event(&central.clock, "training done".to_string());

    // ---- final weights collection ----
    let mut final_weights: BTreeMap<usize, BlockParams> = BTreeMap::new();
    if opts.collect_final_weights {
        for (b, bp) in &central.worker.params.blocks {
            final_weights.insert(*b, bp.clone());
        }
        let peers: Vec<(usize, DeviceId)> = central
            .worker
            .worker_list
            .iter()
            .enumerate()
            .filter(|(_, &d)| d != 0)
            .map(|(s, &d)| (s, d))
            .collect();
        for &(stage, dev) in &peers {
            let (lo, hi) = central.worker.ranges[stage];
            central
                .endpoint
                .send(dev, Message::FetchWeights { blocks: (lo..=hi).collect() })?;
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut expect: usize = peers
            .iter()
            .map(|&(s, _)| central.worker.ranges[s].1 - central.worker.ranges[s].0 + 1)
            .sum();
        while expect > 0 && Instant::now() < deadline {
            if let Some((_, Message::Weights { blocks })) =
                central.endpoint.recv_timeout(Duration::from_millis(10))
            {
                for (idx, tensors) in blocks {
                    if final_weights.insert(idx, BlockParams(tensors)).is_none() {
                        expect -= 1;
                    }
                }
            }
        }
    }

    // ---- shutdown ----
    for d in 1..n {
        net.revive(d); // make sure even killed devices can hear the shutdown
        central.endpoint.send(d, Message::Shutdown)?;
    }
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => log_warn!("worker exited with error: {e:#}"),
            Err(_) => log_warn!("worker panicked"),
        }
    }

    central.record.total_s = central.clock.now_s();
    central.record.net_bytes = net.total_bytes();
    log_debug!("run done in {:.1}s, {} bytes over the network", central.record.total_s, central.record.net_bytes);
    Ok(RunOutput { record: central.record, final_weights })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respipe_merge_middle_failure() {
        let old = vec![(0, 3), (4, 7), (8, 11)];
        // stage 1 dies: its successor (old stage 2) absorbs blocks 4..=7
        assert_eq!(respipe_merge(&old, &[1]), vec![(0, 3), (4, 11)]);
    }

    #[test]
    fn respipe_merge_last_failure() {
        let old = vec![(0, 3), (4, 7), (8, 11)];
        // last stage dies: trailing blocks fall to the last survivor
        assert_eq!(respipe_merge(&old, &[2]), vec![(0, 3), (4, 11)]);
    }

    #[test]
    fn respipe_merge_two_failures() {
        let old = vec![(0, 2), (3, 5), (6, 8), (9, 11)];
        assert_eq!(respipe_merge(&old, &[1, 2]), vec![(0, 2), (3, 11)]);
    }
}
