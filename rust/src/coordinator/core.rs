//! Transport-agnostic coordinator core (DESIGN.md §12).
//!
//! FTPipeHD's central node walks one lifecycle regardless of transport:
//! profile → train → (drain → repartition | probe → redistribute), with a
//! kill/rejoin detour when the coordinator itself dies (paper §III-E/F).
//! Before this module that lifecycle existed twice — ad hoc in the
//! threaded coordinator loops and as a private `Phase` enum in the
//! scenario runner — and the copies drifted (PR 5 shipped a missing tier
//! re-broadcast that only one copy had). [`PhaseMachine`] is now the
//! single copy: a pure transition function over [`PhaseInput`]s that
//! returns [`PhaseEffect`]s for a driver to execute against its own
//! transport. The threaded coordinator and the discrete-event runner are
//! thin drivers; neither owns any phase logic.
//!
//! Design rules:
//!
//! * `step` is **pure** over machine state: no clocks, no I/O, no
//!   randomness. Time enters only through input fields, which is what
//!   keeps the scenario runner's byte-identical run-twice property
//!   trivially true.
//! * Illegal transitions are **unrepresentable as state changes**: a
//!   [`PhaseInput::CentralRestarted`] outside [`CoordinatorPhase::Down`]
//!   returns [`IllegalTransition`] and leaves the machine untouched
//!   (drivers surface it as an "ignored" trace line, exactly the old
//!   validate-time behavior).
//! * Late or stray **recording inputs are absorbed**: a `ProbeAck`
//!   arriving outside `Probing` is `Ok` with no effects — matching how
//!   both drivers always treated stragglers.
//! * Every phase change (and every non-empty effect list) appends one
//!   deterministic line to an internal log, which the cross-driver
//!   conformance test compares between the threaded coordinator and the
//!   simulator.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::time::Duration;

use crate::net::message::DeviceId;

/// Public phase discriminant of the coordinator lifecycle.
///
/// `Idle → Profiling → Training` at bootstrap, then `Training` is the
/// steady state. Faults detour through `Probing → Redistributing`;
/// scheduled repartitions through `Draining → Redistributing`. A
/// coordinator kill parks the machine in `Down` until a restart walks
/// `Rejoining` back to `Training`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoordinatorPhase {
    /// Constructed, nothing started yet.
    Idle,
    /// Measuring per-device block times (paper §III-B).
    Profiling,
    /// Steady-state pipeline training (the fault detector is armed).
    Training,
    /// Injection paused; waiting for in-flight batches to land before a
    /// scheduled dynamic repartition (paper §III-D).
    Draining,
    /// A fault was detected; probing workers for liveness (paper §III-F).
    Probing,
    /// Weight redistribution in progress (paper Algorithm 1).
    Redistributing,
    /// The coordinator itself is dead (checkpoint-restart families).
    Down,
    /// Restarted coordinator collecting `WorkerState` answers before
    /// resuming from its checkpoint (paper §III-E).
    Rejoining,
    /// Cross-replica weight sync barrier: injection is paused while
    /// replica chains exchange averaged weights (DESIGN.md §14).
    Syncing,
}

impl fmt::Display for CoordinatorPhase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The one phase-name table (satellite: this replaces the string
        // tables both drivers used to carry).
        f.write_str(match self {
            CoordinatorPhase::Idle => "idle",
            CoordinatorPhase::Profiling => "profiling",
            CoordinatorPhase::Training => "training",
            CoordinatorPhase::Draining => "draining",
            CoordinatorPhase::Probing => "probing",
            CoordinatorPhase::Redistributing => "redistributing",
            CoordinatorPhase::Down => "central-down",
            CoordinatorPhase::Rejoining => "rejoining",
            CoordinatorPhase::Syncing => "syncing",
        })
    }
}

/// Why a redistribution was started — a fault (probe resolution) or a
/// scheduled dynamic repartition. Drivers use it at commit time: fault
/// commits reset the pipeline to the committed frontier, dynamic commits
/// just advance the repartition schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RedistReason {
    /// Entered from probe resolution after a detected fault.
    Fault,
    /// Entered from the scheduled dynamic-repartition drain.
    Dynamic,
}

/// Timing knobs of the machine — how long to wait for probe answers and
/// for a redistribution to finish before the escape hatches fire.
#[derive(Debug, Clone, Copy)]
pub struct PhaseConfig {
    /// Probe/rejoin answer window: a `Poll` past `entered + probe_window`
    /// resolves with whatever answered.
    pub probe_window: Duration,
    /// Redistribution deadline: a `Poll` past it aborts the
    /// redistribution (the driver decides whether to re-probe or bail).
    pub redist_window: Duration,
}

impl PhaseConfig {
    /// The threaded coordinator's historical windows: 1500 ms probe
    /// collection, 60 s redistribution deadline.
    pub fn threaded() -> PhaseConfig {
        PhaseConfig {
            probe_window: Duration::from_millis(1500),
            redist_window: Duration::from_secs(60),
        }
    }
}

/// One event fed to [`PhaseMachine::step`]. Recording inputs (`ProbeAck`,
/// `FetchDone`, `WorkerStateReport`) are absorbed when they arrive in the
/// wrong phase; lifecycle inputs (`CentralRestarted`, …) error instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PhaseInput {
    /// Bootstrap is about to profile the fleet (fresh start only).
    StartProfiling,
    /// Bootstrap finished; the pipeline is injecting batches.
    TrainingStarted,
    /// A worker answered a probe (`fresh` = it restarted stateless).
    ProbeAck {
        /// Answering device.
        id: DeviceId,
        /// True when the worker rebooted and lost its stage state.
        fresh: bool,
    },
    /// A worker finished fetching its new range during redistribution.
    FetchDone {
        /// Reporting device.
        id: DeviceId,
    },
    /// A worker answered the restarted coordinator's handshake.
    WorkerStateReport {
        /// Answering device.
        id: DeviceId,
        /// Its committed backward frontier.
        committed_bwd: i64,
        /// True when the worker holds no stage state.
        fresh: bool,
    },
    /// The gradient-timeout detector fired for `overdue`.
    FaultDetected {
        /// First overdue batch id.
        overdue: u64,
        /// Current driver time.
        now: Duration,
    },
    /// Stop injecting; a scheduled repartition is due.
    DrainForRepartition,
    /// The driver sent `Repartition` to `expect` and awaits `FetchDone`s.
    RedistributionStarted {
        /// Devices that must report `FetchDone` before commit.
        expect: BTreeSet<DeviceId>,
        /// Why this redistribution runs (decides commit behavior).
        reason: RedistReason,
        /// Current driver time.
        now: Duration,
    },
    /// Periodic driver poll; carries everything time-based decisions
    /// need so `step` itself never reads a clock.
    Poll {
        /// Current driver time.
        now: Duration,
        /// Fault detector verdict (first overdue batch, if any).
        overdue: Option<u64>,
        /// In-flight batch count (drain completion).
        inflight: usize,
        /// Live peer count (probe/rejoin completion).
        peers: usize,
        /// Whether the coordinator's own stage finished its fetches.
        local_fetch_done: bool,
    },
    /// A cross-replica sync round is due: every live chain reached its
    /// round target (hybrid parallelism, DESIGN.md §14).
    SyncDue {
        /// Sync round number (1-based; monotonically increasing).
        round: u64,
        /// Chains whose partial weights must arrive before resolution.
        expect: BTreeSet<usize>,
    },
    /// A replica chain's partial weights fully arrived at the central.
    SyncPartial {
        /// Reporting chain index.
        chain: usize,
    },
    /// The coordinator process died (scripted kill).
    KillCentral,
    /// The coordinator restarted from its checkpoint.
    CentralRestarted {
        /// Current driver time.
        now: Duration,
    },
}

impl PhaseInput {
    /// Stable kind label used in the transition log.
    pub fn kind(&self) -> &'static str {
        match self {
            PhaseInput::StartProfiling => "start-profiling",
            PhaseInput::TrainingStarted => "training-started",
            PhaseInput::ProbeAck { .. } => "probe-ack",
            PhaseInput::FetchDone { .. } => "fetch-done",
            PhaseInput::WorkerStateReport { .. } => "worker-state",
            PhaseInput::FaultDetected { .. } => "fault-detected",
            PhaseInput::DrainForRepartition => "drain",
            PhaseInput::RedistributionStarted { .. } => "redistribution-started",
            PhaseInput::SyncDue { .. } => "sync-due",
            PhaseInput::SyncPartial { .. } => "sync-partial",
            PhaseInput::Poll { .. } => "poll",
            PhaseInput::KillCentral => "kill-central",
            PhaseInput::CentralRestarted { .. } => "central-restarted",
        }
    }
}

/// What a driver must do after a transition. Effects carry the data the
/// machine accumulated (probe answers, fetch roster) so the driver never
/// reaches into machine internals.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PhaseEffect {
    /// Broadcast probes for `overdue` and wake again at `deadline`.
    SendProbes {
        /// First overdue batch id (for the driver's fault trace).
        overdue: u64,
        /// Absolute time after which the probe resolves regardless.
        deadline: Duration,
    },
    /// Probe window closed: classify `acks` into cases 1/2/3.
    ResolveProbe {
        /// Collected answers: device → fresh.
        acks: BTreeMap<DeviceId, bool>,
    },
    /// Rejoin window closed: reconcile `acks` against the checkpoint.
    ResolveRejoin {
        /// Collected answers: device → (committed backward, fresh).
        acks: BTreeMap<DeviceId, (i64, bool)>,
    },
    /// Every expected `FetchDone` arrived: send `Commit` to `expect`.
    CommitRedistribution {
        /// Devices that took part (and must receive `Commit`).
        expect: BTreeSet<DeviceId>,
        /// Why the redistribution ran (fault vs dynamic).
        reason: RedistReason,
    },
    /// The redistribution deadline passed without completion.
    AbortRedistribution,
    /// The drain finished with no fault: compute the new partition.
    RunDynamicRepartition,
    /// Ask every live replica chain to ship its weights for `round`.
    BeginSync {
        /// Sync round number.
        round: u64,
    },
    /// All expected partials arrived: average and broadcast the result.
    ResolveSync {
        /// Sync round number.
        round: u64,
        /// Chains whose partials arrived (superset of the expectation).
        chains: BTreeSet<usize>,
    },
}

impl PhaseEffect {
    /// Stable kind label used in the transition log.
    pub fn kind(&self) -> &'static str {
        match self {
            PhaseEffect::SendProbes { .. } => "send-probes",
            PhaseEffect::ResolveProbe { .. } => "resolve-probe",
            PhaseEffect::ResolveRejoin { .. } => "resolve-rejoin",
            PhaseEffect::CommitRedistribution { .. } => "commit-redistribution",
            PhaseEffect::AbortRedistribution => "abort-redistribution",
            PhaseEffect::RunDynamicRepartition => "run-dynamic-repartition",
            PhaseEffect::BeginSync { .. } => "begin-sync",
            PhaseEffect::ResolveSync { .. } => "resolve-sync",
        }
    }
}

/// A lifecycle input arrived in a phase where it is not a legal
/// transition. The machine state is untouched when this is returned.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IllegalTransition {
    /// Phase the machine was (and still is) in.
    pub from: CoordinatorPhase,
    /// Kind label of the rejected input.
    pub input: &'static str,
}

impl fmt::Display for IllegalTransition {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "illegal coordinator transition: {} in phase {}", self.input, self.from)
    }
}

impl std::error::Error for IllegalTransition {}

/// Private per-phase state: the discriminants of [`CoordinatorPhase`]
/// plus the data the in-between phases accumulate.
#[derive(Debug)]
enum State {
    Idle,
    Profiling,
    Training,
    Draining,
    Probing { acks: BTreeMap<DeviceId, bool>, deadline: Duration },
    Redistributing {
        expect: BTreeSet<DeviceId>,
        done: BTreeSet<DeviceId>,
        deadline: Duration,
        reason: RedistReason,
    },
    Down,
    Rejoining { acks: BTreeMap<DeviceId, (i64, bool)>, deadline: Duration },
    Syncing { round: u64, expect: BTreeSet<usize>, done: BTreeSet<usize> },
}

impl State {
    fn phase(&self) -> CoordinatorPhase {
        match self {
            State::Idle => CoordinatorPhase::Idle,
            State::Profiling => CoordinatorPhase::Profiling,
            State::Training => CoordinatorPhase::Training,
            State::Draining => CoordinatorPhase::Draining,
            State::Probing { .. } => CoordinatorPhase::Probing,
            State::Redistributing { .. } => CoordinatorPhase::Redistributing,
            State::Down => CoordinatorPhase::Down,
            State::Rejoining { .. } => CoordinatorPhase::Rejoining,
            State::Syncing { .. } => CoordinatorPhase::Syncing,
        }
    }
}

/// The shared coordinator phase state machine. See the module docs for
/// the contract; see [`PhaseInput`]/[`PhaseEffect`] for the API surface.
#[derive(Debug)]
pub struct PhaseMachine {
    cfg: PhaseConfig,
    state: State,
    log: Vec<String>,
}

impl PhaseMachine {
    /// A fresh coordinator: starts in [`CoordinatorPhase::Idle`].
    pub fn new(cfg: PhaseConfig) -> PhaseMachine {
        PhaseMachine { cfg, state: State::Idle, log: Vec::new() }
    }

    /// A coordinator resuming leadership from a store: starts in
    /// [`CoordinatorPhase::Down`], so the only legal way forward is
    /// [`PhaseInput::CentralRestarted`] → `Rejoining` — the restart
    /// handshake cannot be skipped by construction.
    pub fn resuming(cfg: PhaseConfig) -> PhaseMachine {
        PhaseMachine { cfg, state: State::Down, log: Vec::new() }
    }

    /// Current phase discriminant.
    pub fn phase(&self) -> CoordinatorPhase {
        self.state.phase()
    }

    /// Timing configuration the machine was built with.
    pub fn config(&self) -> PhaseConfig {
        self.cfg
    }

    /// The transition log so far: one line per phase change or non-empty
    /// effect list (`"<input>: <from>-><to> [<effects>]"`). Recording
    /// inputs that only accumulate data do not log, so the log stays
    /// bounded by the number of real transitions.
    pub fn log(&self) -> &[String] {
        &self.log
    }

    /// Drain the transition log (drivers move it into their run record).
    pub fn take_log(&mut self) -> Vec<String> {
        std::mem::take(&mut self.log)
    }

    /// Feed one input; returns the phase after the transition and the
    /// effects the driver must execute, or [`IllegalTransition`] with the
    /// machine untouched. Deterministic: the same input sequence always
    /// yields the same phase trace and effect sequence.
    pub fn step(
        &mut self,
        input: PhaseInput,
    ) -> Result<(CoordinatorPhase, Vec<PhaseEffect>), IllegalTransition> {
        let before = self.phase();
        let kind = input.kind();
        let illegal = || IllegalTransition { from: before, input: kind };
        let mut effects: Vec<PhaseEffect> = Vec::new();
        match input {
            PhaseInput::StartProfiling => match self.state {
                State::Idle => self.state = State::Profiling,
                _ => return Err(illegal()),
            },
            PhaseInput::TrainingStarted => match self.state {
                State::Idle | State::Profiling => self.state = State::Training,
                _ => return Err(illegal()),
            },
            PhaseInput::ProbeAck { id, fresh } => {
                if let State::Probing { acks, .. } = &mut self.state {
                    acks.insert(id, fresh);
                } // absorbed elsewhere: late acks after resolution
            }
            PhaseInput::FetchDone { id } => {
                if let State::Redistributing { done, .. } = &mut self.state {
                    done.insert(id);
                } // absorbed elsewhere: late FetchDone after commit/abort
            }
            PhaseInput::WorkerStateReport { id, committed_bwd, fresh } => {
                if let State::Rejoining { acks, .. } = &mut self.state {
                    acks.insert(id, (committed_bwd, fresh));
                } // absorbed elsewhere: late answers after rejoin resolved
            }
            PhaseInput::FaultDetected { overdue, now } => match self.state {
                State::Training | State::Draining => {
                    let deadline = now + self.cfg.probe_window;
                    self.state = State::Probing { acks: BTreeMap::new(), deadline };
                    effects.push(PhaseEffect::SendProbes { overdue, deadline });
                }
                _ => return Err(illegal()),
            },
            PhaseInput::DrainForRepartition => match self.state {
                State::Training => self.state = State::Draining,
                _ => return Err(illegal()),
            },
            PhaseInput::RedistributionStarted { expect, reason, now } => match self.state {
                State::Training => {
                    self.state = State::Redistributing {
                        expect,
                        done: BTreeSet::new(),
                        deadline: now + self.cfg.redist_window,
                        reason,
                    };
                }
                _ => return Err(illegal()),
            },
            PhaseInput::SyncDue { round, expect } => match self.state {
                State::Training => {
                    self.state = State::Syncing { round, expect, done: BTreeSet::new() };
                    effects.push(PhaseEffect::BeginSync { round });
                }
                _ => return Err(illegal()),
            },
            PhaseInput::SyncPartial { chain } => match &mut self.state {
                State::Syncing { done, .. } => {
                    done.insert(chain);
                }
                // A partial reaching a dead or rejoining coordinator is a
                // driver bug, not a straggler: the sync barrier cannot be
                // open while the coordinator is down.
                State::Down | State::Rejoining { .. } => return Err(illegal()),
                _ => {} // absorbed elsewhere: late partials after resolution
            },
            PhaseInput::KillCentral => match self.state {
                State::Down => return Err(illegal()),
                _ => self.state = State::Down,
            },
            PhaseInput::CentralRestarted { now } => match self.state {
                State::Down => {
                    self.state = State::Rejoining {
                        acks: BTreeMap::new(),
                        deadline: now + self.cfg.probe_window,
                    };
                }
                _ => return Err(illegal()),
            },
            PhaseInput::Poll { now, overdue, inflight, peers, local_fetch_done } => {
                let cur = std::mem::replace(&mut self.state, State::Down);
                let (next, eff) =
                    Self::poll(cur, &self.cfg, now, overdue, inflight, peers, local_fetch_done);
                self.state = next;
                effects.extend(eff);
            }
        }
        let after = self.phase();
        if after != before || !effects.is_empty() {
            let mut line = format!("{kind}: {before}->{after}");
            if !effects.is_empty() {
                line.push_str(" [");
                line.push_str(
                    &effects.iter().map(PhaseEffect::kind).collect::<Vec<_>>().join(" "),
                );
                line.push(']');
            }
            self.log.push(line);
        }
        Ok((after, effects))
    }

    /// The `Poll` decision table, pure over the owned state. Decision
    /// order matches the historical drivers exactly: an overdue batch
    /// outranks drain completion; completion outranks deadlines.
    fn poll(
        state: State,
        cfg: &PhaseConfig,
        now: Duration,
        overdue: Option<u64>,
        inflight: usize,
        peers: usize,
        local_fetch_done: bool,
    ) -> (State, Vec<PhaseEffect>) {
        let probe = |b: u64| {
            let deadline = now + cfg.probe_window;
            (
                State::Probing { acks: BTreeMap::new(), deadline },
                vec![PhaseEffect::SendProbes { overdue: b, deadline }],
            )
        };
        match state {
            State::Idle | State::Profiling | State::Down => (state, vec![]),
            State::Training => match overdue {
                Some(b) => probe(b),
                None => (State::Training, vec![]),
            },
            State::Draining => match overdue {
                Some(b) => probe(b),
                None if inflight == 0 => {
                    (State::Training, vec![PhaseEffect::RunDynamicRepartition])
                }
                None => (State::Draining, vec![]),
            },
            State::Probing { acks, deadline } => {
                if acks.len() >= peers || now >= deadline {
                    (State::Training, vec![PhaseEffect::ResolveProbe { acks }])
                } else {
                    (State::Probing { acks, deadline }, vec![])
                }
            }
            State::Rejoining { acks, deadline } => {
                if acks.len() >= peers || now >= deadline {
                    (State::Training, vec![PhaseEffect::ResolveRejoin { acks }])
                } else {
                    (State::Rejoining { acks, deadline }, vec![])
                }
            }
            State::Syncing { round, expect, done } => {
                // No deadline: the sync barrier is driven by the replica
                // runner, which already bounds the round by its event
                // ceiling. Resolution is purely "every expected chain
                // answered".
                if done.is_superset(&expect) {
                    (State::Training, vec![PhaseEffect::ResolveSync { round, chains: done }])
                } else {
                    (State::Syncing { round, expect, done }, vec![])
                }
            }
            State::Redistributing { expect, done, deadline, reason } => {
                if done.is_superset(&expect) && local_fetch_done {
                    (
                        State::Training,
                        vec![PhaseEffect::CommitRedistribution { expect, reason }],
                    )
                } else if now >= deadline {
                    (State::Training, vec![PhaseEffect::AbortRedistribution])
                } else {
                    (State::Redistributing { expect, done, deadline, reason }, vec![])
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Worker admission
// ---------------------------------------------------------------------

/// Why an admission request was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionError {
    /// The roster is at its capacity quota.
    Full {
        /// The configured quota.
        capacity: usize,
    },
    /// The device was explicitly evicted; it needs
    /// [`WorkerRoster::readmit`], not a plain admit.
    Evicted,
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::Full { capacity } => {
                write!(f, "roster full (capacity {capacity})")
            }
            AdmissionError::Evicted => f.write_str("device was evicted; readmit required"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Explicit worker membership with a capacity quota — replacing the
/// implicit "whoever answered the probe" membership. Admission is
/// explicit ([`admit`](WorkerRoster::admit)), removal is explicit
/// ([`evict`](WorkerRoster::evict)), and an evicted device can only come
/// back through [`readmit`](WorkerRoster::readmit). The default quota is
/// unlimited, so existing deployments see no behavior change; the quota
/// travels in `TrainInit::worker_quota` (0 = unlimited) without touching
/// the Off-mode wire-byte pricing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerRoster {
    capacity: Option<usize>,
    admitted: BTreeSet<DeviceId>,
    evicted: BTreeSet<DeviceId>,
}

impl Default for WorkerRoster {
    fn default() -> Self {
        WorkerRoster::unlimited()
    }
}

impl WorkerRoster {
    /// A roster with no capacity quota.
    pub fn unlimited() -> WorkerRoster {
        WorkerRoster { capacity: None, admitted: BTreeSet::new(), evicted: BTreeSet::new() }
    }

    /// A roster admitting at most `cap` workers at a time.
    pub fn with_capacity(cap: usize) -> WorkerRoster {
        WorkerRoster {
            capacity: Some(cap),
            admitted: BTreeSet::new(),
            evicted: BTreeSet::new(),
        }
    }

    /// The quota, if any.
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Wire encoding of the quota for `TrainInit` (0 = unlimited).
    pub fn quota_wire(&self) -> u64 {
        self.capacity.map(|c| c as u64).unwrap_or(0)
    }

    /// Admit a device. Idempotent for already-admitted devices; rejects
    /// evicted devices and quota overflows.
    pub fn admit(&mut self, id: DeviceId) -> Result<(), AdmissionError> {
        if self.admitted.contains(&id) {
            return Ok(());
        }
        if self.evicted.contains(&id) {
            return Err(AdmissionError::Evicted);
        }
        if let Some(cap) = self.capacity {
            if self.admitted.len() >= cap {
                return Err(AdmissionError::Full { capacity: cap });
            }
        }
        self.admitted.insert(id);
        Ok(())
    }

    /// Remove a device from the roster (dead or misbehaving). Returns
    /// whether it was admitted.
    pub fn evict(&mut self, id: DeviceId) -> bool {
        let was = self.admitted.remove(&id);
        self.evicted.insert(id);
        was
    }

    /// Clear an eviction and admit the device again (a restarted worker
    /// answering a probe fresh). Subject to the same quota.
    pub fn readmit(&mut self, id: DeviceId) -> Result<(), AdmissionError> {
        self.evicted.remove(&id);
        self.admit(id)
    }

    /// Whether `id` is currently admitted.
    pub fn is_admitted(&self, id: DeviceId) -> bool {
        self.admitted.contains(&id)
    }

    /// Currently admitted devices, ascending.
    pub fn admitted(&self) -> impl Iterator<Item = DeviceId> + '_ {
        self.admitted.iter().copied()
    }

    /// Number of admitted devices.
    pub fn len(&self) -> usize {
        self.admitted.len()
    }

    /// True when no device is admitted.
    pub fn is_empty(&self) -> bool {
        self.admitted.is_empty()
    }

    /// Persistence snapshot: `(quota_wire, admitted devices)`.
    pub fn snapshot(&self) -> (u64, Vec<DeviceId>) {
        (self.quota_wire(), self.admitted.iter().copied().collect())
    }

    /// Rebuild from a [`snapshot`](WorkerRoster::snapshot) (evictions are
    /// not persisted: a restart is a clean slate, matching the replica
    /// epoch bump that already invalidates pre-restart state).
    pub fn restore(quota_wire: u64, admitted: &[DeviceId]) -> WorkerRoster {
        WorkerRoster {
            capacity: (quota_wire > 0).then_some(quota_wire as usize),
            admitted: admitted.iter().copied().collect(),
            evicted: BTreeSet::new(),
        }
    }
}

/// Invalidate per-link adaptive-compression state on a `worker_list`
/// change (repartition commit, rejoin, admission — DESIGN.md §10).
///
/// Bandwidth measurements and tier ladders are keyed by destination
/// device; after a topology change, entries for departed devices
/// describe links that no longer exist, and a stale measurement would
/// otherwise pin the fleet at an escalated tier forever. Both drivers
/// call this at every commit point so the two stay in lockstep. Valid
/// destinations are `worker_list[1..]` — the central device (stage 0)
/// is never a probe destination.
///
/// Returns the destinations whose measurement or ladder was dropped,
/// ascending (deterministic, for tracing). An unchanged topology returns
/// an empty vec and mutates nothing.
pub fn prune_link_state(
    measured_bw: &mut BTreeMap<DeviceId, f64>,
    policy: Option<&mut crate::net::quant::AdaptivePolicy>,
    worker_list: &[DeviceId],
) -> Vec<DeviceId> {
    let live: BTreeSet<DeviceId> = worker_list.iter().skip(1).copied().collect();
    let mut dropped: BTreeSet<DeviceId> = BTreeSet::new();
    measured_bw.retain(|&d, _| {
        let keep = live.contains(&d);
        if !keep {
            dropped.insert(d);
        }
        keep
    });
    if let Some(p) = policy {
        p.retain(|d| {
            let keep = live.contains(&d);
            if !keep {
                dropped.insert(d);
            }
            keep
        });
    }
    dropped.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PhaseConfig {
        PhaseConfig {
            probe_window: Duration::from_millis(100),
            redist_window: Duration::from_millis(500),
        }
    }

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    fn poll(now: Duration, overdue: Option<u64>, inflight: usize, peers: usize) -> PhaseInput {
        PhaseInput::Poll { now, overdue, inflight, peers, local_fetch_done: true }
    }

    #[test]
    fn case3_fault_walks_probe_then_redistribution() {
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::TrainingStarted).unwrap();
        let (p, eff) = m.step(poll(ms(10), Some(7), 2, 2)).unwrap();
        assert_eq!(p, CoordinatorPhase::Probing);
        assert!(matches!(eff[0], PhaseEffect::SendProbes { overdue: 7, .. }));
        // one of two peers answers; the probe stays open
        m.step(PhaseInput::ProbeAck { id: 1, fresh: false }).unwrap();
        let (p, eff) = m.step(poll(ms(20), None, 2, 2)).unwrap();
        assert_eq!((p, eff.len()), (CoordinatorPhase::Probing, 0));
        // the deadline closes it with the partial answer set
        let (p, eff) = m.step(poll(ms(200), None, 2, 2)).unwrap();
        assert_eq!(p, CoordinatorPhase::Training);
        let PhaseEffect::ResolveProbe { acks } = &eff[0] else { panic!("{eff:?}") };
        assert_eq!(acks.get(&1), Some(&false));
        assert_eq!(acks.len(), 1);
        // the driver classifies case 3 and starts a redistribution
        let expect: BTreeSet<DeviceId> = [1].into();
        m.step(PhaseInput::RedistributionStarted {
            expect: expect.clone(),
            reason: RedistReason::Fault,
            now: ms(200),
        })
        .unwrap();
        m.step(PhaseInput::FetchDone { id: 1 }).unwrap();
        let (p, eff) = m.step(poll(ms(210), None, 0, 2)).unwrap();
        assert_eq!(p, CoordinatorPhase::Training);
        assert_eq!(
            eff[0],
            PhaseEffect::CommitRedistribution { expect, reason: RedistReason::Fault }
        );
    }

    #[test]
    fn drain_completes_into_dynamic_repartition() {
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::TrainingStarted).unwrap();
        m.step(PhaseInput::DrainForRepartition).unwrap();
        // still draining while batches are in flight
        let (p, eff) = m.step(poll(ms(1), None, 3, 2)).unwrap();
        assert_eq!((p, eff.len()), (CoordinatorPhase::Draining, 0));
        let (p, eff) = m.step(poll(ms(2), None, 0, 2)).unwrap();
        assert_eq!(p, CoordinatorPhase::Training);
        assert_eq!(eff, vec![PhaseEffect::RunDynamicRepartition]);
    }

    #[test]
    fn fault_during_drain_outranks_drain_completion() {
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::TrainingStarted).unwrap();
        m.step(PhaseInput::DrainForRepartition).unwrap();
        let (p, eff) = m.step(poll(ms(5), Some(3), 0, 2)).unwrap();
        assert_eq!(p, CoordinatorPhase::Probing);
        assert!(matches!(eff[0], PhaseEffect::SendProbes { overdue: 3, .. }));
    }

    #[test]
    fn redistribution_deadline_aborts() {
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::TrainingStarted).unwrap();
        m.step(PhaseInput::RedistributionStarted {
            expect: [1, 2].into(),
            reason: RedistReason::Dynamic,
            now: ms(0),
        })
        .unwrap();
        m.step(PhaseInput::FetchDone { id: 1 }).unwrap();
        // past the 500 ms window with worker 2 silent
        let (p, eff) = m.step(poll(ms(600), None, 0, 2)).unwrap();
        assert_eq!(p, CoordinatorPhase::Training);
        assert_eq!(eff, vec![PhaseEffect::AbortRedistribution]);
    }

    #[test]
    fn illegal_transitions_leave_the_machine_untouched() {
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::TrainingStarted).unwrap();
        let err = m.step(PhaseInput::CentralRestarted { now: ms(0) }).unwrap_err();
        assert_eq!(err.from, CoordinatorPhase::Training);
        assert_eq!(err.input, "central-restarted");
        assert_eq!(m.phase(), CoordinatorPhase::Training);
        // kill is legal from any live phase, but not twice
        m.step(PhaseInput::KillCentral).unwrap();
        assert_eq!(m.phase(), CoordinatorPhase::Down);
        assert!(m.step(PhaseInput::KillCentral).is_err());
        // and the only way out of Down is a restart
        assert!(m.step(PhaseInput::TrainingStarted).is_err());
        let (p, _) = m.step(PhaseInput::CentralRestarted { now: ms(0) }).unwrap();
        assert_eq!(p, CoordinatorPhase::Rejoining);
    }

    #[test]
    fn stray_recording_inputs_are_absorbed_silently() {
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::TrainingStarted).unwrap();
        let logged = m.log().len();
        m.step(PhaseInput::ProbeAck { id: 1, fresh: true }).unwrap();
        m.step(PhaseInput::FetchDone { id: 1 }).unwrap();
        m.step(PhaseInput::WorkerStateReport { id: 1, committed_bwd: 3, fresh: false })
            .unwrap();
        assert_eq!(m.phase(), CoordinatorPhase::Training);
        assert_eq!(m.log().len(), logged, "absorbed inputs must not log");
    }

    #[test]
    fn rejoin_collects_worker_state_and_resolves() {
        let mut m = PhaseMachine::resuming(cfg());
        assert_eq!(m.phase(), CoordinatorPhase::Down);
        m.step(PhaseInput::CentralRestarted { now: ms(0) }).unwrap();
        m.step(PhaseInput::WorkerStateReport { id: 1, committed_bwd: 9, fresh: false })
            .unwrap();
        m.step(PhaseInput::WorkerStateReport { id: 2, committed_bwd: -1, fresh: true })
            .unwrap();
        let (p, eff) = m.step(poll(ms(10), None, 0, 2)).unwrap();
        assert_eq!(p, CoordinatorPhase::Training);
        let PhaseEffect::ResolveRejoin { acks } = &eff[0] else { panic!("{eff:?}") };
        assert_eq!(acks.get(&1), Some(&(9, false)));
        assert_eq!(acks.get(&2), Some(&(-1, true)));
    }

    #[test]
    fn log_lines_are_deterministic_kind_only_entries() {
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::StartProfiling).unwrap();
        m.step(PhaseInput::TrainingStarted).unwrap();
        m.step(poll(ms(1), Some(4), 1, 1)).unwrap();
        m.step(PhaseInput::ProbeAck { id: 1, fresh: false }).unwrap();
        m.step(poll(ms(2), None, 1, 1)).unwrap();
        assert_eq!(
            m.log(),
            &[
                "start-profiling: idle->profiling",
                "training-started: profiling->training",
                "poll: training->probing [send-probes]",
                "poll: probing->training [resolve-probe]",
            ]
        );
    }

    #[test]
    fn sync_round_walks_barrier_and_resolves() {
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::TrainingStarted).unwrap();
        let (p, eff) =
            m.step(PhaseInput::SyncDue { round: 1, expect: [1, 2].into() }).unwrap();
        assert_eq!(p, CoordinatorPhase::Syncing);
        assert_eq!(eff, vec![PhaseEffect::BeginSync { round: 1 }]);
        // one of two chains answers; the barrier stays open
        m.step(PhaseInput::SyncPartial { chain: 1 }).unwrap();
        let (p, eff) = m.step(poll(ms(10), None, 0, 2)).unwrap();
        assert_eq!((p, eff.len()), (CoordinatorPhase::Syncing, 0));
        // the second answer resolves it on the next poll
        m.step(PhaseInput::SyncPartial { chain: 2 }).unwrap();
        let (p, eff) = m.step(poll(ms(20), None, 0, 2)).unwrap();
        assert_eq!(p, CoordinatorPhase::Training);
        assert_eq!(
            eff,
            vec![PhaseEffect::ResolveSync { round: 1, chains: [1, 2].into() }]
        );
        assert_eq!(
            m.log(),
            &[
                "training-started: idle->training",
                "sync-due: training->syncing [begin-sync]",
                "poll: syncing->training [resolve-sync]",
            ]
        );
    }

    #[test]
    fn sync_due_is_illegal_outside_training() {
        let mut m = PhaseMachine::new(cfg());
        let err = m.step(PhaseInput::SyncDue { round: 1, expect: [1].into() }).unwrap_err();
        assert_eq!((err.from, err.input), (CoordinatorPhase::Idle, "sync-due"));
        assert_eq!(m.phase(), CoordinatorPhase::Idle);
        // and a second SyncDue inside Syncing is also illegal
        m.step(PhaseInput::TrainingStarted).unwrap();
        m.step(PhaseInput::SyncDue { round: 1, expect: [1].into() }).unwrap();
        assert!(m.step(PhaseInput::SyncDue { round: 2, expect: [1].into() }).is_err());
        assert_eq!(m.phase(), CoordinatorPhase::Syncing);
    }

    #[test]
    fn sync_partial_is_rejected_from_down_and_rejoining() {
        // absorbed in Training (a straggler after resolution)...
        let mut m = PhaseMachine::new(cfg());
        m.step(PhaseInput::TrainingStarted).unwrap();
        let logged = m.log().len();
        m.step(PhaseInput::SyncPartial { chain: 1 }).unwrap();
        assert_eq!(m.log().len(), logged);
        // ...but an error from Down and Rejoining, machine untouched
        m.step(PhaseInput::KillCentral).unwrap();
        let err = m.step(PhaseInput::SyncPartial { chain: 1 }).unwrap_err();
        assert_eq!((err.from, err.input), (CoordinatorPhase::Down, "sync-partial"));
        assert_eq!(m.phase(), CoordinatorPhase::Down);
        m.step(PhaseInput::CentralRestarted { now: ms(0) }).unwrap();
        let err = m.step(PhaseInput::SyncPartial { chain: 1 }).unwrap_err();
        assert_eq!((err.from, err.input), (CoordinatorPhase::Rejoining, "sync-partial"));
        assert_eq!(m.phase(), CoordinatorPhase::Rejoining);
    }

    #[test]
    fn roster_enforces_quota_and_eviction() {
        let mut r = WorkerRoster::with_capacity(2);
        r.admit(1).unwrap();
        r.admit(2).unwrap();
        assert_eq!(r.admit(2), Ok(()), "admit is idempotent");
        assert_eq!(r.admit(3), Err(AdmissionError::Full { capacity: 2 }));
        assert!(r.evict(1));
        assert_eq!(r.admit(1), Err(AdmissionError::Evicted));
        r.readmit(1).unwrap();
        assert!(r.is_admitted(1));
        assert_eq!(r.len(), 2);
        // unlimited roster never fills
        let mut u = WorkerRoster::unlimited();
        for d in 0..100 {
            u.admit(d).unwrap();
        }
        assert_eq!(u.quota_wire(), 0);
    }

    #[test]
    fn roster_snapshot_roundtrips() {
        let mut r = WorkerRoster::with_capacity(8);
        r.admit(1).unwrap();
        r.admit(5).unwrap();
        r.evict(5);
        let (quota, admitted) = r.snapshot();
        assert_eq!((quota, admitted.clone()), (8, vec![1]));
        let back = WorkerRoster::restore(quota, &admitted);
        assert_eq!(back.capacity(), Some(8));
        assert!(back.is_admitted(1));
        // evictions are not persisted: the restored roster can admit 5
        let mut back = back;
        back.admit(5).unwrap();
    }

    #[test]
    fn prune_link_state_drops_departed_destinations() {
        use crate::net::quant::{AdaptivePolicy, AdaptiveThresholds, Tier};
        // regression for the stale-measurement bug: after a case-3
        // repartition evicts device 3, its old measurement and ladder
        // must not survive to pin the fleet at an escalated tier
        let mut bw: BTreeMap<DeviceId, f64> =
            [(1, 5e7), (2, 4e7), (3, 9e4)].into_iter().collect();
        let mut p = AdaptivePolicy::new(AdaptiveThresholds::default());
        assert_eq!(p.observe(3, 9e4), Some(Tier::FullQ4));
        assert_eq!(p.observe(2, 3e6), Some(Tier::Activations));
        // device 3 evicted; device 4 admitted in its place
        let dropped = prune_link_state(&mut bw, Some(&mut p), &[0, 1, 2, 4]);
        assert_eq!(dropped, vec![3]);
        assert!(!bw.contains_key(&3), "stale measurement gone");
        assert_eq!(p.tier_for(3), Tier::Off, "stale ladder gone");
        assert_eq!(p.tier_for(2), Tier::Activations, "live ladder untouched");
        assert_eq!(bw.get(&2), Some(&4e7));
        // unchanged topology: a no-op, nothing reported
        assert!(prune_link_state(&mut bw, Some(&mut p), &[0, 1, 2, 4]).is_empty());
        // the central device's slot is never a valid destination
        let mut bw: BTreeMap<DeviceId, f64> = [(0, 1e6), (1, 2e6)].into_iter().collect();
        let dropped = prune_link_state(&mut bw, None, &[0, 1]);
        assert_eq!(dropped, vec![0], "a measurement keyed to central is bogus: dropped");
    }

    #[test]
    fn prune_link_state_reports_ladder_only_drops() {
        use crate::net::quant::{AdaptivePolicy, AdaptiveThresholds, Tier};
        // a ladder can outlive its measurement (e.g. the measurement map
        // was rebuilt on coordinator restart): pruning must still report
        // and drop it
        let mut bw: BTreeMap<DeviceId, f64> = BTreeMap::new();
        let mut p = AdaptivePolicy::new(AdaptiveThresholds::default());
        assert_eq!(p.observe(5, 1e4), Some(Tier::FullQ4));
        let dropped = prune_link_state(&mut bw, Some(&mut p), &[0, 1, 2]);
        assert_eq!(dropped, vec![5]);
        assert!(p.overrides().is_empty());
    }
}
