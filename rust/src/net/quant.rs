//! INT8 tensor quantization — the compressed wire currency (AccEPT-style
//! bit-level compressed transfer, arXiv:2311.05827).
//!
//! A [`QTensor`] is an affine-quantized f32 tensor: one `u8` per element
//! plus a per-tensor `(scale, zero)` pair, so a quantized activation or
//! gradient costs ~1/4 of its f32 bytes on a link the paper prices at
//! `latency + bytes/bandwidth`. The codec moves the `u8` payload without
//! ever materializing intermediate f32s; dequantization happens exactly
//! once, at the receiving stage's boundary, straight into a
//! [`TensorBuf`].
//!
//! Determinism contract: `quantize` and `dequantize` are pure element-wise
//! IEEE-754 single-precision pipelines with a fixed evaluation order, so
//! two runs of one scenario produce bit-identical quantized bytes and
//! bit-identical dequantized tensors (the scenario suite asserts this
//! end to end). Which messages are quantized is selected by
//! [`Compression`] (see `config::Compression`); `Off` keeps every
//! tensor f32, so numerics, event order, and the bandwidth model's
//! `Message::byte_len` accounting are exactly the pre-compression
//! behavior. (The codec *framing* carries a version byte — tensors carry
//! a dtype tag since v2, the restart handshake joined in v3 — so frames
//! are not byte-compatible with older peers even under `Off`; all
//! transports in one cluster speak one version.)
//!
//! Gradients additionally carry an error-feedback [`Residual`] on the
//! sender: the quantization error of step `t` is added to the gradient of
//! step `t+1` before quantizing, so quantization noise stays bounded
//! instead of accumulating across SGD steps (DESIGN.md §8).

use std::fmt;
use std::sync::Arc;

use super::buf::TensorBuf;

/// Which message classes travel quantized (policy knob; lives here so the
/// wire layer owns it, re-exported as `config::Compression`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Compression {
    /// Everything f32 — the wire format is byte-for-byte the v1 format.
    #[default]
    Off,
    /// Data plane only: forward activations + backward gradients.
    Activations,
    /// Data plane + weight transfers (`ReplicaPush` / `Weights` replies).
    Full,
}

impl Compression {
    /// Quantize forward activations and backward gradients?
    pub fn data_plane(self) -> bool {
        !matches!(self, Compression::Off)
    }

    /// Quantize weight transfers (replica pushes, fetch replies)?
    pub fn weights(self) -> bool {
        matches!(self, Compression::Full)
    }

    pub fn to_u8(self) -> u8 {
        match self {
            Compression::Off => 0,
            Compression::Activations => 1,
            Compression::Full => 2,
        }
    }

    pub fn from_u8(x: u8) -> Option<Compression> {
        match x {
            0 => Some(Compression::Off),
            1 => Some(Compression::Activations),
            2 => Some(Compression::Full),
            _ => None,
        }
    }

    /// Parse the JSON/CLI spelling ("off" / "activations" / "full").
    pub fn parse(s: &str) -> Option<Compression> {
        match s {
            "off" => Some(Compression::Off),
            "activations" => Some(Compression::Activations),
            "full" => Some(Compression::Full),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Compression::Off => "off",
            Compression::Activations => "activations",
            Compression::Full => "full",
        }
    }
}

/// An affine-quantized tensor: `x ≈ zero + q * scale`, `q ∈ [0, 255]`.
///
/// The byte payload is `Arc`-backed like [`TensorBuf`], so cloning a
/// quantized message (queueing, replica fan-out) is a refcount bump.
#[derive(Clone)]
pub struct QTensor {
    data: Arc<Vec<u8>>,
    scale: f32,
    zero: f32,
}

impl QTensor {
    /// Quantize with a per-tensor dynamic range (min/max over finite
    /// elements). Deterministic: a fixed element order and fixed f32
    /// operations, so equal inputs always produce equal bytes.
    ///
    /// Degenerate ranges encode exactly: a constant tensor gets
    /// `scale = 0`, so every element dequantizes to precisely `zero`.
    pub fn quantize(xs: &[f32]) -> QTensor {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in xs {
            if x.is_finite() {
                lo = lo.min(x);
                hi = hi.max(x);
            }
        }
        if !(lo <= hi) {
            // empty tensor, or nothing finite to anchor a range on
            return QTensor { data: Arc::new(vec![0u8; xs.len()]), scale: 0.0, zero: 0.0 };
        }
        let scale = (hi - lo) / 255.0;
        if scale == 0.0 {
            return QTensor { data: Arc::new(vec![0u8; xs.len()]), scale: 0.0, zero: lo };
        }
        let inv = 1.0f32 / scale;
        // `as u8` saturates (and maps NaN to 0), so out-of-range values
        // clamp deterministically without a branch
        let data: Vec<u8> = xs.iter().map(|&x| ((x - lo) * inv).round() as u8).collect();
        QTensor { data: Arc::new(data), scale, zero: lo }
    }

    /// Rebuild from wire parts (codec decode path — no f32 intermediate).
    pub fn from_parts(data: Vec<u8>, scale: f32, zero: f32) -> QTensor {
        QTensor { data: Arc::new(data), scale, zero }
    }

    /// Dequantize into a fresh shared buffer — the single materializing
    /// f32 write a quantized tensor pays, at the receiver's boundary.
    pub fn dequantize(&self) -> TensorBuf {
        let zero = self.zero;
        let scale = self.scale;
        TensorBuf::new(self.data.iter().map(|&q| zero + q as f32 * scale).collect())
    }

    /// Dequantize one element (used by the error-feedback residual).
    #[inline]
    pub fn dequantize_at(&self, i: usize) -> f32 {
        self.zero + self.data[i] as f32 * self.scale
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Wire payload bytes: one per element plus the (scale, zero) pair.
    pub fn byte_len(&self) -> usize {
        self.data.len() + 8
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    pub fn zero(&self) -> f32 {
        self.zero
    }

    /// Same allocation? (zero-copy assertions, mirroring `TensorBuf`.)
    pub fn ptr_eq(&self, other: &QTensor) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Worst-case absolute dequantization error of any finite in-range
    /// element: half a quantization step (plus fp rounding slack).
    pub fn tolerance(&self) -> f32 {
        0.5 * self.scale + 1e-6
    }
}

/// Bit-exact equality: scale/zero compare by representation, so a
/// re-encoded tensor is equal iff it is byte-identical on the wire.
impl PartialEq for QTensor {
    fn eq(&self, other: &QTensor) -> bool {
        self.scale.to_bits() == other.scale.to_bits()
            && self.zero.to_bits() == other.zero.to_bits()
            && (Arc::ptr_eq(&self.data, &other.data) || self.data == other.data)
    }
}

impl fmt::Debug for QTensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "QTensor(len={}, scale={}, zero={}, head={:?})",
            self.len(),
            self.scale,
            self.zero,
            &self.data[..self.len().min(4)]
        )
    }
}

/// Error-feedback state for one outgoing gradient edge (sender side).
///
/// `fold` quantizes `g + r` and retains the new quantization error as
/// `r`, so the error injected at step `t` is corrected at step `t+1`
/// instead of compounding. The residual is deliberately cleared whenever
/// the edge's meaning changes (init, commit of a new partition, reset,
/// crash-restart) — it is per-run deterministic state, never persisted.
#[derive(Debug, Default)]
pub struct Residual {
    r: Vec<f32>,
}

impl Residual {
    /// Quantize `g` with error feedback; updates the stored residual.
    pub fn fold(&mut self, g: &[f32]) -> QTensor {
        if self.r.len() != g.len() {
            // shape changed (new partition): stale error is meaningless
            self.r = vec![0.0; g.len()];
        }
        let v: Vec<f32> = g.iter().zip(self.r.iter()).map(|(&a, &b)| a + b).collect();
        let q = QTensor::quantize(&v);
        for i in 0..v.len() {
            let e = v[i] - q.dequantize_at(i);
            // a transient NaN/Inf gradient element must not poison the
            // carried error forever (quantize itself already saturates
            // nonfinite values); drop that element's residual instead
            self.r[i] = if e.is_finite() { e } else { 0.0 };
        }
        q
    }

    pub fn clear(&mut self) {
        self.r.clear();
    }

    /// Largest carried error magnitude (introspection/tests).
    pub fn max_abs(&self) -> f32 {
        self.r.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_dequantize_within_half_step() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let q = QTensor::quantize(&xs);
        let back = q.dequantize();
        let tol = q.tolerance();
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() <= tol, "{a} vs {b} (tol {tol})");
        }
    }

    #[test]
    fn quantize_is_deterministic() {
        let xs: Vec<f32> = (0..257).map(|i| (i as f32).cos()).collect();
        let a = QTensor::quantize(&xs);
        let b = QTensor::quantize(&xs);
        assert_eq!(a, b);
        assert_eq!(a.bytes(), b.bytes());
        assert_eq!(a.scale().to_bits(), b.scale().to_bits());
        let da = a.dequantize();
        let db = b.dequantize();
        let bits = |t: &TensorBuf| t.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&da), bits(&db), "dequantize must be bit-reproducible");
    }

    #[test]
    fn constant_and_empty_tensors_are_exact() {
        let q = QTensor::quantize(&[2.5; 17]);
        assert_eq!(q.scale(), 0.0);
        assert_eq!(q.dequantize().as_slice(), &[2.5; 17]);
        let q = QTensor::quantize(&[]);
        assert!(q.is_empty());
        assert!(q.dequantize().is_empty());
    }

    #[test]
    fn range_endpoints_roundtrip_exactly() {
        let q = QTensor::quantize(&[-1.0, 0.25, 1.0]);
        let back = q.dequantize();
        assert_eq!(back[0], -1.0, "range minimum is exact (q=0)");
        // maximum lands on q=255: zero + 255*scale == hi up to fp rounding
        assert!((back[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn nonfinite_elements_do_not_poison_the_range() {
        let q = QTensor::quantize(&[f32::NAN, -2.0, f32::INFINITY, 2.0]);
        let back = q.dequantize();
        assert_eq!(back[1], -2.0);
        assert!((back[3] - 2.0).abs() < 1e-5);
        assert!(back[0].is_finite() && back[2].is_finite());
    }

    #[test]
    fn clone_shares_bytes() {
        let q = QTensor::quantize(&[0.0, 1.0, 2.0]);
        let c = q.clone();
        assert!(q.ptr_eq(&c));
        assert_eq!(q.byte_len(), 3 + 8);
    }

    #[test]
    fn residual_bounds_accumulated_error() {
        // same gradient applied repeatedly: WITH error feedback, the sum
        // of dequantized sends tracks the true sum to within one step
        let g = vec![0.013f32, -0.027, 0.5, -0.4999, 0.25];
        let mut res = Residual::default();
        let mut sent = vec![0.0f64; g.len()];
        let steps = 200;
        for _ in 0..steps {
            let q = res.fold(&g);
            let d = q.dequantize();
            for (s, v) in sent.iter_mut().zip(d.iter()) {
                *s += *v as f64;
            }
        }
        for (i, s) in sent.iter().enumerate() {
            let truth = g[i] as f64 * steps as f64;
            let step = ((1.0 - -0.4999) / 255.0) as f64; // range of g+r, approx
            assert!(
                (s - truth).abs() <= 2.0 * step + 1e-3,
                "element {i}: sent {s} vs true {truth}"
            );
        }
        assert!(res.max_abs() <= 0.01, "residual itself stays within one step");
    }

    #[test]
    fn residual_survives_a_transient_nonfinite_gradient() {
        let mut res = Residual::default();
        res.fold(&[0.1, 0.2, 0.3]);
        // one poisoned step: the nonfinite element saturates on the wire
        // but must not leave NaN/Inf in the carried error
        res.fold(&[0.1, f32::NAN, f32::INFINITY]);
        assert!(res.max_abs().is_finite(), "residual stays finite");
        let q = res.fold(&[0.1, 0.2, 0.3]);
        let back = q.dequantize();
        for (a, b) in [0.1f32, 0.2, 0.3].iter().zip(back.iter()) {
            assert!((a - b).abs() <= 2.0 * q.tolerance() + 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn residual_resets_on_shape_change() {
        let mut res = Residual::default();
        res.fold(&[1.0, 2.0, 3.0]);
        let q = res.fold(&[5.0; 7]);
        assert_eq!(q.len(), 7);
        assert_eq!(q.dequantize().as_slice(), &[5.0; 7], "no stale residual leaked in");
    }

    #[test]
    fn compression_policy_knobs() {
        assert!(!Compression::Off.data_plane() && !Compression::Off.weights());
        assert!(Compression::Activations.data_plane() && !Compression::Activations.weights());
        assert!(Compression::Full.data_plane() && Compression::Full.weights());
        for c in [Compression::Off, Compression::Activations, Compression::Full] {
            assert_eq!(Compression::from_u8(c.to_u8()), Some(c));
            assert_eq!(Compression::parse(c.name()), Some(c));
        }
        assert_eq!(Compression::from_u8(9), None);
        assert_eq!(Compression::parse("gzip"), None);
    }
}
