//! Quantized tensors — the compressed wire currency (AccEPT-style
//! bit-level compressed transfer, arXiv:2311.05827).
//!
//! A [`QTensor`] is an affine-quantized f32 tensor: `x ≈ zero + q·scale`
//! with codes packed at one of two widths ([`Bits`] — one `u8` per
//! element, or two 4-bit codes per byte) and scales at one of two
//! granularities ([`Scheme`] — one `(scale, zero)` pair per tensor, or
//! one pair per channel of a 2-D weight). The codec moves the packed
//! payload without ever materializing intermediate f32s; dequantization
//! happens exactly once, at the receiving stage's boundary, straight
//! into a [`TensorBuf`].
//!
//! Which encoding each message class uses is a [`Tier`], selected by the
//! cluster [`Compression`] policy (re-exported as `config::Compression`):
//! static tiers pin the encoding for the whole run, while
//! [`Compression::Adaptive`] lets the coordinator walk a tier ladder
//! *per link* ([`AdaptivePolicy`]) as each destination's measured
//! bandwidth degrades, broadcasting the per-link tier table in
//! `SetCompression` control messages (DESIGN.md §10). `Off`
//! keeps every tensor f32, so numerics, event order, and the bandwidth
//! model's `Message::byte_len` accounting are exactly the
//! pre-compression behavior. (The codec *framing* carries a version byte
//! — tensors carry a dtype tag since v2, per-channel and 4-bit arms
//! joined in v4 — so frames are not byte-compatible with older peers
//! even under `Off`; all transports in one cluster speak one version.)
//!
//! Determinism contract: `quantize*` and `dequantize` are pure
//! element-wise IEEE-754 single-precision pipelines with a fixed
//! evaluation order, so two runs of one scenario produce bit-identical
//! quantized bytes and bit-identical dequantized tensors (the scenario
//! suite asserts this end to end).
//!
//! Gradients (and 4-bit replica pushes) additionally carry an
//! error-feedback [`Residual`] on the sender: the quantization error of
//! step `t` is added to the payload of step `t+1` before quantizing, so
//! quantization noise stays bounded instead of accumulating across
//! sends (DESIGN.md §8, §10).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use super::buf::TensorBuf;
use super::message::DeviceId;

// ---------------------------------------------------------------------
// policy: tiers, the cluster knob, and the adaptive controller
// ---------------------------------------------------------------------

/// One rung of the compression ladder — the *effective* wire encoding a
/// stage applies right now. Ordered: a "greater" tier compresses more.
/// Static [`Compression`] policies pin one tier for the whole run;
/// `Compression::Adaptive` moves along the ladder at run time via
/// `SetCompression` messages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Tier {
    /// Everything f32 — byte-for-byte the uncompressed wire format.
    #[default]
    Off,
    /// Data plane only: forward activations + backward gradients (Q8).
    Activations,
    /// Data plane + weight transfers (replica pushes and fetch/warm-start
    /// replies travel Q8, per-channel for 2-D blocks).
    Full,
    /// [`Tier::Full`] with replica pushes packed to 4 bits (two codes per
    /// byte, per-channel scales, sender-side error feedback). Restore
    /// traffic (fetch replies / warm-starts) stays Q8 — replicas are a
    /// best-effort background stream, restores are a correctness path.
    FullQ4,
}

impl Tier {
    /// Quantize forward activations and backward gradients?
    pub fn data_plane(self) -> bool {
        !matches!(self, Tier::Off)
    }

    /// Quantize weight transfers at all?
    pub fn weights(self) -> bool {
        matches!(self, Tier::Full | Tier::FullQ4)
    }

    /// Coding of periodic replica pushes under this tier.
    pub fn replica_coding(self) -> WeightCoding {
        match self {
            Tier::Off | Tier::Activations => WeightCoding::F32,
            Tier::Full => WeightCoding::Q8,
            Tier::FullQ4 => WeightCoding::Q4,
        }
    }

    /// Coding of restore traffic (fetch replies / warm-start pushes):
    /// never coarser than Q8 — a restored stage trains on these bytes.
    pub fn restore_coding(self) -> WeightCoding {
        match self {
            Tier::Off | Tier::Activations => WeightCoding::F32,
            Tier::Full | Tier::FullQ4 => WeightCoding::Q8,
        }
    }

    pub fn to_u8(self) -> u8 {
        match self {
            Tier::Off => 0,
            Tier::Activations => 1,
            Tier::Full => 2,
            Tier::FullQ4 => 3,
        }
    }

    pub fn from_u8(x: u8) -> Option<Tier> {
        match x {
            0 => Some(Tier::Off),
            1 => Some(Tier::Activations),
            2 => Some(Tier::Full),
            3 => Some(Tier::FullQ4),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Tier::Off => "off",
            Tier::Activations => "activations",
            Tier::Full => "full",
            Tier::FullQ4 => "full+q4",
        }
    }

    /// Parse the JSON/CLI spelling (the `tier_floor` / `tier_ceiling`
    /// knobs of `RunConfig`).
    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "off" => Some(Tier::Off),
            "activations" => Some(Tier::Activations),
            "full" => Some(Tier::Full),
            "full+q4" => Some(Tier::FullQ4),
            _ => None,
        }
    }
}

/// The cluster-wide policy knob (distributed via `TrainInit`; lives here
/// so the wire layer owns it, re-exported as `config::Compression`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Compression {
    /// Everything f32 — the wire format is byte-for-byte the v1 format.
    #[default]
    Off,
    /// Data plane only: forward activations + backward gradients.
    Activations,
    /// Data plane + weight transfers (`ReplicaPush` / `Weights` replies).
    Full,
    /// [`Compression::Full`] with 4-bit replica pushes ([`Tier::FullQ4`]).
    FullQ4,
    /// Coordinator-driven: every stage starts at [`Tier::Off`] and the
    /// central node escalates/relaxes the tier per measured link
    /// bandwidth ([`AdaptivePolicy`]) via `SetCompression` messages.
    Adaptive,
}

impl Compression {
    /// The tier a stage applies at init time, before any
    /// `SetCompression` arrives (identity for the static policies).
    pub fn initial_tier(self) -> Tier {
        match self {
            Compression::Off | Compression::Adaptive => Tier::Off,
            Compression::Activations => Tier::Activations,
            Compression::Full => Tier::Full,
            Compression::FullQ4 => Tier::FullQ4,
        }
    }

    /// Quantize forward activations and backward gradients (initially)?
    pub fn data_plane(self) -> bool {
        self.initial_tier().data_plane()
    }

    /// Quantize weight transfers (initially)?
    pub fn weights(self) -> bool {
        self.initial_tier().weights()
    }

    pub fn to_u8(self) -> u8 {
        match self {
            Compression::Off => 0,
            Compression::Activations => 1,
            Compression::Full => 2,
            Compression::FullQ4 => 3,
            Compression::Adaptive => 4,
        }
    }

    pub fn from_u8(x: u8) -> Option<Compression> {
        match x {
            0 => Some(Compression::Off),
            1 => Some(Compression::Activations),
            2 => Some(Compression::Full),
            3 => Some(Compression::FullQ4),
            4 => Some(Compression::Adaptive),
            _ => None,
        }
    }

    /// Parse the JSON/CLI spelling.
    pub fn parse(s: &str) -> Option<Compression> {
        match s {
            "off" => Some(Compression::Off),
            "activations" => Some(Compression::Activations),
            "full" => Some(Compression::Full),
            "full+q4" => Some(Compression::FullQ4),
            "adaptive" => Some(Compression::Adaptive),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Compression::Off => "off",
            Compression::Activations => "activations",
            Compression::Full => "full",
            Compression::FullQ4 => "full+q4",
            Compression::Adaptive => "adaptive",
        }
    }
}

/// How a weight tensor is coded on the wire (per [`Tier`] and traffic
/// class — see [`Tier::replica_coding`] / [`Tier::restore_coding`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightCoding {
    F32,
    Q8,
    Q4,
}

/// Bandwidth thresholds (bytes/sec) of the adaptive ladder: measured
/// link bandwidth below `*_below` enters that tier. Relaxing back down
/// the ladder additionally requires the bandwidth to clear the current
/// tier's entry threshold by `relax_factor` (hysteresis), so jitter
/// around a boundary can never flip the tier back and forth.
#[derive(Debug, Clone)]
pub struct AdaptiveThresholds {
    pub activations_below: f64,
    pub full_below: f64,
    pub q4_below: f64,
    pub relax_factor: f64,
    /// The ladder band the controller may move in: the tier never drops
    /// below `tier_floor` or rises above `tier_ceiling`, no matter what
    /// the links measure. In a wide fleet one bad link would otherwise
    /// down-tier *every* stage to [`Tier::FullQ4`]; a ceiling caps that
    /// blast radius, and a floor pins a known-constrained deployment at
    /// its tier without waiting for measurements. Defaults (`Off` /
    /// `FullQ4`) leave the full ladder open — the pre-band behavior.
    pub tier_floor: Tier,
    /// See [`AdaptiveThresholds::tier_floor`].
    pub tier_ceiling: Tier,
}

impl Default for AdaptiveThresholds {
    fn default() -> AdaptiveThresholds {
        AdaptiveThresholds {
            activations_below: 4e6,
            full_below: 1e6,
            q4_below: 2.5e5,
            relax_factor: 1.5,
            tier_floor: Tier::Off,
            tier_ceiling: Tier::FullQ4,
        }
    }
}

impl AdaptiveThresholds {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.q4_below > 0.0
                && self.q4_below < self.full_below
                && self.full_below < self.activations_below,
            "adaptive thresholds must be ordered 0 < q4 ({}) < full ({}) < activations ({})",
            self.q4_below,
            self.full_below,
            self.activations_below
        );
        anyhow::ensure!(
            self.relax_factor >= 1.0 && self.relax_factor.is_finite(),
            "relax_factor must be >= 1.0 (got {})",
            self.relax_factor
        );
        anyhow::ensure!(
            self.tier_floor <= self.tier_ceiling,
            "tier_floor ({}) must not exceed tier_ceiling ({})",
            self.tier_floor.name(),
            self.tier_ceiling.name()
        );
        Ok(())
    }
}

/// The coordinator-side tier controller for [`Compression::Adaptive`]:
/// one independent escalate/relax ladder **per destination device**, each
/// a pure, deterministic function of that link's observed bandwidth
/// sequence. Escalation is immediate (the link just got worse — compress
/// now); relaxation is hysteretic (see [`AdaptiveThresholds`]). Keying by
/// destination device — not boot-time stage index — means one degraded
/// link escalates only the traffic *into* that device while every other
/// link keeps its own tier, and the key survives renumbering.
///
/// A destination with no entry sits at `tier_floor`; ladders that relax
/// back to the floor are removed, so [`AdaptivePolicy::overrides`] stays
/// the minimal set of links that differ from the floor (and an empty
/// override list means "whole fleet at the floor").
#[derive(Debug, Clone)]
pub struct AdaptivePolicy {
    th: AdaptiveThresholds,
    /// Per-destination tier, keyed by destination [`DeviceId`]. BTreeMap
    /// so iteration (and thus broadcast/trace order) is deterministic.
    links: BTreeMap<DeviceId, Tier>,
}

impl AdaptivePolicy {
    /// A fresh controller: every link at `tier_floor`, no overrides.
    pub fn new(th: AdaptiveThresholds) -> AdaptivePolicy {
        AdaptivePolicy { th, links: BTreeMap::new() }
    }

    /// Rebuild a controller from persisted per-link tiers (coordinator
    /// resume, DESIGN.md §12): each stored tier is clamped into the
    /// configured band in case the operator re-narrowed it across the
    /// restart; entries that clamp onto the floor are dropped.
    pub fn resume_at(th: AdaptiveThresholds, links: &[(DeviceId, Tier)]) -> AdaptivePolicy {
        let mut p = AdaptivePolicy::new(th);
        for &(dest, tier) in links {
            let tier = tier.clamp(p.th.tier_floor, p.th.tier_ceiling);
            if tier != p.th.tier_floor {
                p.links.insert(dest, tier);
            }
        }
        p
    }

    pub fn thresholds(&self) -> &AdaptiveThresholds {
        &self.th
    }

    /// The tier currently applied to traffic toward `dest`.
    pub fn tier_for(&self, dest: DeviceId) -> Tier {
        self.links.get(&dest).copied().unwrap_or(self.th.tier_floor)
    }

    /// The most-escalated tier across all links (the floor when no link
    /// is escalated) — for logs and the legacy single-tier summary.
    pub fn max_tier(&self) -> Tier {
        self.links.values().copied().max().unwrap_or(self.th.tier_floor)
    }

    /// Every link whose tier differs from `tier_floor`, in ascending
    /// destination order (deterministic — suitable for the wire and for
    /// persistence).
    pub fn overrides(&self) -> Vec<(DeviceId, Tier)> {
        self.links.iter().map(|(&d, &t)| (d, t)).collect()
    }

    /// Drop the ladder for `dest` (its measurements no longer describe a
    /// live link). Returns true if an escalated ladder was removed.
    pub fn forget(&mut self, dest: DeviceId) -> bool {
        self.links.remove(&dest).is_some()
    }

    /// Keep only ladders whose destination satisfies `keep` (topology
    /// change: the rest describe links that no longer exist).
    pub fn retain<F: FnMut(DeviceId) -> bool>(&mut self, mut keep: F) {
        self.links.retain(|&d, _| keep(d));
    }

    /// The tier `bps` maps to, ignoring hysteresis.
    pub fn target(&self, bps: f64) -> Tier {
        if bps < self.th.q4_below {
            Tier::FullQ4
        } else if bps < self.th.full_below {
            Tier::Full
        } else if bps < self.th.activations_below {
            Tier::Activations
        } else {
            Tier::Off
        }
    }

    /// The bandwidth below which `tier` is entered (`Off` has no entry).
    fn entry_threshold(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Off => f64::INFINITY,
            Tier::Activations => self.th.activations_below,
            Tier::Full => self.th.full_below,
            Tier::FullQ4 => self.th.q4_below,
        }
    }

    /// Feed one bandwidth observation for the link into `dest`. Returns
    /// `Some(new_tier)` iff that link's tier changed; every other link's
    /// ladder is untouched.
    pub fn observe(&mut self, dest: DeviceId, bps: f64) -> Option<Tier> {
        if !bps.is_finite() || bps <= 0.0 {
            return None; // unmeasured / nonsense observation: hold
        }
        let current = self.tier_for(dest);
        // the band clamp comes before the change test: a target outside
        // [floor, ceiling] that clamps back onto the current rung is a
        // hold, not a change
        let target = self.target(bps).clamp(self.th.tier_floor, self.th.tier_ceiling);
        let relax_floor = self.entry_threshold(current) * self.th.relax_factor;
        let next = match target.cmp(&current) {
            std::cmp::Ordering::Greater => target, // worse link: escalate now
            std::cmp::Ordering::Less if bps > relax_floor => target,
            _ => return None, // same rung, or inside the hysteresis band
        };
        if next == self.th.tier_floor {
            self.links.remove(&dest); // back at the floor: no override
        } else {
            self.links.insert(dest, next);
        }
        Some(next)
    }
}

// ---------------------------------------------------------------------
// the quantized tensor
// ---------------------------------------------------------------------

/// Code width: 8-bit (`q ∈ [0, 255]`, one code per byte) or 4-bit
/// (`q ∈ [0, 15]`, two codes per byte — even element in the low nibble).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bits {
    B8,
    B4,
}

impl Bits {
    /// Packed payload bytes for `len` elements.
    pub fn packed_len(self, len: usize) -> usize {
        match self {
            Bits::B8 => len,
            Bits::B4 => len.div_ceil(2),
        }
    }

    fn qmax(self) -> f32 {
        match self {
            Bits::B8 => 255.0,
            Bits::B4 => 15.0,
        }
    }
}

/// Scale granularity. `PerTensor` is the original (v2) layout —
/// wire-compatible within the dtype-tag framing. `PerChannel` carries
/// one `(scale, zero)` pair per channel of a 2-D weight:
/// `interleaved = false` means contiguous rows (element `i` belongs to
/// channel `i / (len / pairs.len())` — per-row of a row-major matrix);
/// `interleaved = true` means channel `i % pairs.len()` (per-column,
/// the natural axis for a `[in, out]` linear weight whose column count
/// is small). Pair lists are `Arc`-backed like the code payload.
#[derive(Debug, Clone)]
pub enum Scheme {
    PerTensor { scale: f32, zero: f32 },
    PerChannel { pairs: Arc<Vec<(f32, f32)>>, interleaved: bool },
}

/// Which per-channel axis (if any) a weight tensor of `shape` should
/// use. Channels only pay when each one amortizes its 8-byte pair over
/// enough elements: per-row needs wide rows, per-column (interleaved)
/// needs tall columns; everything else stays per-tensor.
pub fn weight_channel_hint(shape: &[usize], len: usize) -> ChannelHint {
    if shape.len() == 2 && shape[0].saturating_mul(shape[1]) == len && len > 0 {
        let (r, c) = (shape[0], shape[1]);
        if r > 1 && c >= 16 {
            return ChannelHint::Rows(r);
        }
        if c > 1 && r >= 16 {
            return ChannelHint::Cols(c);
        }
    }
    ChannelHint::PerTensor
}

/// Advice from [`weight_channel_hint`] consumed by
/// [`QTensor::quantize_weights`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelHint {
    PerTensor,
    /// Contiguous per-row channels of a row-major `[rows, cols]` tensor.
    Rows(usize),
    /// Interleaved per-column channels (`channel = i % cols`).
    Cols(usize),
}

/// An affine-quantized tensor (see module docs). The packed byte payload
/// and the per-channel pair list are `Arc`-backed like [`TensorBuf`], so
/// cloning a quantized message (queueing, replica fan-out) is a
/// refcount bump.
#[derive(Clone)]
pub struct QTensor {
    data: Arc<Vec<u8>>,
    len: usize,
    bits: Bits,
    scheme: Scheme,
}

/// Min/max over the finite elements at the yielded indices (fixed
/// order — the range scan of one quantization channel).
fn channel_range(xs: &[f32], idx: impl Iterator<Item = usize>) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for i in idx {
        let x = xs[i];
        if x.is_finite() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    (lo, hi)
}

impl QTensor {
    /// Quantize with a per-tensor dynamic range (min/max over finite
    /// elements) at 8 bits — the original wire arm, byte-identical to
    /// the pre-`Scheme` encoder. Deterministic: a fixed element order
    /// and fixed f32 operations, so equal inputs always produce equal
    /// bytes.
    ///
    /// Degenerate ranges encode exactly: a constant tensor gets
    /// `scale = 0`, so every element dequantizes to precisely `zero`.
    pub fn quantize(xs: &[f32]) -> QTensor {
        Self::quantize_bits(xs, Bits::B8)
    }

    /// Per-tensor quantization at either code width.
    pub fn quantize_bits(xs: &[f32], bits: Bits) -> QTensor {
        let (lo, hi) = channel_range(xs, 0..xs.len());
        let len = xs.len();
        if !(lo <= hi) {
            // empty tensor, or nothing finite to anchor a range on
            return QTensor {
                data: Arc::new(vec![0u8; bits.packed_len(len)]),
                len,
                bits,
                scheme: Scheme::PerTensor { scale: 0.0, zero: 0.0 },
            };
        }
        let scale = (hi - lo) / bits.qmax();
        if scale == 0.0 {
            return QTensor {
                data: Arc::new(vec![0u8; bits.packed_len(len)]),
                len,
                bits,
                scheme: Scheme::PerTensor { scale: 0.0, zero: lo },
            };
        }
        let inv = 1.0f32 / scale;
        let data = match bits {
            // `as u8` saturates (and maps NaN to 0), so out-of-range
            // values clamp deterministically without a branch
            Bits::B8 => xs.iter().map(|&x| ((x - lo) * inv).round() as u8).collect(),
            Bits::B4 => {
                let mut packed = vec![0u8; bits.packed_len(len)];
                for (i, &x) in xs.iter().enumerate() {
                    let c = q4_code(x, lo, inv);
                    packed[i / 2] |= c << ((i & 1) * 4);
                }
                packed
            }
        };
        QTensor { data: Arc::new(data), len, bits, scheme: Scheme::PerTensor { scale, zero: lo } }
    }

    /// Quantize a weight tensor with per-channel scales where the hint
    /// says they pay (one `(scale, zero)` pair per row or column of a
    /// 2-D block), falling back to the per-tensor path otherwise. The
    /// fixed per-channel evaluation order (ranges channel by channel,
    /// codes element by element) keeps the determinism contract.
    pub fn quantize_weights(xs: &[f32], hint: ChannelHint, bits: Bits) -> QTensor {
        let len = xs.len();
        let (nch, interleaved) = match hint {
            ChannelHint::PerTensor => return Self::quantize_bits(xs, bits),
            ChannelHint::Rows(r) => (r, false),
            ChannelHint::Cols(c) => (c, true),
        };
        if nch == 0 || len == 0 || len % nch != 0 {
            return Self::quantize_bits(xs, bits); // malformed hint: fall back
        }
        let cols = len / nch;
        let mut pairs = Vec::with_capacity(nch);
        for ch in 0..nch {
            let (lo, hi) = if interleaved {
                // strided visit (ch, ch+nch, ...) — same element order as
                // a filter over 0..len, in O(len/nch) per channel
                channel_range(xs, (ch..len).step_by(nch))
            } else {
                channel_range(xs, ch * cols..(ch + 1) * cols)
            };
            if !(lo <= hi) {
                pairs.push((0.0f32, 0.0f32));
            } else {
                let scale = (hi - lo) / bits.qmax();
                pairs.push((scale, lo));
            }
        }
        let mut data = vec![0u8; bits.packed_len(len)];
        for (i, &x) in xs.iter().enumerate() {
            let ch = if interleaved { i % nch } else { i / cols };
            let (scale, zero) = pairs[ch];
            let c = if scale == 0.0 {
                0u8
            } else {
                let inv = 1.0f32 / scale;
                match bits {
                    Bits::B8 => ((x - zero) * inv).round() as u8,
                    Bits::B4 => q4_code(x, zero, inv),
                }
            };
            match bits {
                Bits::B8 => data[i] = c,
                Bits::B4 => data[i / 2] |= c << ((i & 1) * 4),
            }
        }
        QTensor {
            data: Arc::new(data),
            len,
            bits,
            scheme: Scheme::PerChannel { pairs: Arc::new(pairs), interleaved },
        }
    }

    /// Rebuild the legacy 8-bit per-tensor arm from wire parts (codec
    /// decode path — no f32 intermediate).
    pub fn from_parts(data: Vec<u8>, scale: f32, zero: f32) -> QTensor {
        let len = data.len();
        QTensor {
            data: Arc::new(data),
            len,
            bits: Bits::B8,
            scheme: Scheme::PerTensor { scale, zero },
        }
    }

    /// Rebuild any arm from wire parts, validating internal consistency
    /// (the codec calls this on untrusted bytes).
    pub fn from_wire(
        data: Vec<u8>,
        len: usize,
        bits: Bits,
        scheme: Scheme,
    ) -> anyhow::Result<QTensor> {
        anyhow::ensure!(
            data.len() == bits.packed_len(len),
            "quantized payload {} bytes, expected {} for {len} elements",
            data.len(),
            bits.packed_len(len)
        );
        if let Scheme::PerChannel { pairs, .. } = &scheme {
            anyhow::ensure!(
                !pairs.is_empty() && len % pairs.len() == 0,
                "{len} elements do not divide into {} channels",
                pairs.len()
            );
        }
        Ok(QTensor { data: Arc::new(data), len, bits, scheme })
    }

    #[inline]
    fn code_at(&self, i: usize) -> u8 {
        match self.bits {
            Bits::B8 => self.data[i],
            Bits::B4 => (self.data[i / 2] >> ((i & 1) * 4)) & 0x0F,
        }
    }

    #[inline]
    fn pair_at(&self, i: usize) -> (f32, f32) {
        match &self.scheme {
            Scheme::PerTensor { scale, zero } => (*scale, *zero),
            Scheme::PerChannel { pairs, interleaved } => {
                let nch = pairs.len();
                let ch = if *interleaved { i % nch } else { i / (self.len / nch) };
                pairs[ch]
            }
        }
    }

    /// Dequantize into a fresh shared buffer — the single materializing
    /// f32 write a quantized tensor pays, at the receiver's boundary.
    pub fn dequantize(&self) -> TensorBuf {
        TensorBuf::new((0..self.len).map(|i| self.dequantize_at(i)).collect())
    }

    /// Dequantize one element (used by the error-feedback residual).
    #[inline]
    pub fn dequantize_at(&self, i: usize) -> f32 {
        let (scale, zero) = self.pair_at(i);
        zero + self.code_at(i) as f32 * scale
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bits(&self) -> Bits {
        self.bits
    }

    pub fn scheme(&self) -> &Scheme {
        &self.scheme
    }

    /// Wire payload bytes: the packed codes plus every `(scale, zero)`
    /// pair (the legacy 8-bit per-tensor arm keeps its original
    /// accounting; the newer arms also count an 8-byte length/flags
    /// header). This is the bandwidth model's currency.
    pub fn byte_len(&self) -> usize {
        let (pairs, hdr) = match (&self.scheme, self.bits) {
            (Scheme::PerTensor { .. }, Bits::B8) => (1, 0),
            (Scheme::PerTensor { .. }, Bits::B4) => (1, 8),
            (Scheme::PerChannel { pairs, .. }, _) => (pairs.len(), 8),
        };
        self.data.len() + 8 * pairs + hdr
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    /// Per-tensor scale (panics on a per-channel tensor — the codec and
    /// tests only call this on the per-tensor arm).
    pub fn scale(&self) -> f32 {
        match &self.scheme {
            Scheme::PerTensor { scale, .. } => *scale,
            Scheme::PerChannel { .. } => panic!("per-channel QTensor has no single scale"),
        }
    }

    /// Per-tensor zero point (see [`QTensor::scale`]).
    pub fn zero(&self) -> f32 {
        match &self.scheme {
            Scheme::PerTensor { zero, .. } => *zero,
            Scheme::PerChannel { .. } => panic!("per-channel QTensor has no single zero"),
        }
    }

    /// Same allocation? (zero-copy assertions, mirroring `TensorBuf`.)
    pub fn ptr_eq(&self, other: &QTensor) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Worst-case absolute dequantization error of any finite in-range
    /// element: half a quantization step of the widest channel (plus fp
    /// rounding slack).
    pub fn tolerance(&self) -> f32 {
        let max_scale = match &self.scheme {
            Scheme::PerTensor { scale, .. } => *scale,
            Scheme::PerChannel { pairs, .. } => {
                pairs.iter().fold(0.0f32, |m, &(s, _)| m.max(s))
            }
        };
        0.5 * max_scale + 1e-6
    }
}

/// 4-bit code with the same nonfinite contract as the 8-bit `as u8`
/// cast: NaN → 0, +Inf saturates high, −Inf saturates low.
#[inline]
fn q4_code(x: f32, zero: f32, inv: f32) -> u8 {
    let r = ((x - zero) * inv).round();
    if r >= 15.0 {
        15
    } else if r >= 0.0 {
        r as u8
    } else {
        0 // negative overflow and NaN (fails both comparisons)
    }
}

/// Bit-exact equality: scales/zeros compare by representation, so a
/// re-encoded tensor is equal iff it is byte-identical on the wire.
impl PartialEq for QTensor {
    fn eq(&self, other: &QTensor) -> bool {
        let scheme_eq = match (&self.scheme, &other.scheme) {
            (
                Scheme::PerTensor { scale: s1, zero: z1 },
                Scheme::PerTensor { scale: s2, zero: z2 },
            ) => s1.to_bits() == s2.to_bits() && z1.to_bits() == z2.to_bits(),
            (
                Scheme::PerChannel { pairs: p1, interleaved: i1 },
                Scheme::PerChannel { pairs: p2, interleaved: i2 },
            ) => {
                i1 == i2
                    && p1.len() == p2.len()
                    && p1.iter().zip(p2.iter()).all(|(a, b)| {
                        a.0.to_bits() == b.0.to_bits() && a.1.to_bits() == b.1.to_bits()
                    })
            }
            _ => false,
        };
        scheme_eq
            && self.bits == other.bits
            && self.len == other.len
            && (Arc::ptr_eq(&self.data, &other.data) || self.data == other.data)
    }
}

impl fmt::Debug for QTensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "QTensor(len={}, bits={:?}, scheme={:?}, head={:?})",
            self.len,
            self.bits,
            self.scheme,
            &self.data[..self.data.len().min(4)]
        )
    }
}

// ---------------------------------------------------------------------
// error feedback
// ---------------------------------------------------------------------

/// Error-feedback state for one outgoing quantized edge (sender side) —
/// a gradient edge, or one tensor of a 4-bit replica-push stream.
///
/// `fold` quantizes `g + r` and retains the new quantization error as
/// `r`, so the error injected at send `t` is corrected at send `t+1`
/// instead of compounding. The residual is deliberately cleared whenever
/// the edge's meaning changes (init, commit of a new partition, reset,
/// crash-restart, a `SetCompression` tier switch) — it is per-run
/// deterministic state, never persisted.
#[derive(Debug, Default)]
pub struct Residual {
    r: Vec<f32>,
}

impl Residual {
    /// Quantize `g` with error feedback through the default per-tensor
    /// 8-bit arm; updates the stored residual.
    pub fn fold(&mut self, g: &[f32]) -> QTensor {
        self.fold_with(g, QTensor::quantize)
    }

    /// [`Residual::fold`] with a caller-chosen quantizer (the Q4
    /// replica path passes a per-channel 4-bit encoder).
    pub fn fold_with(
        &mut self,
        g: &[f32],
        quantize: impl FnOnce(&[f32]) -> QTensor,
    ) -> QTensor {
        if self.r.len() != g.len() {
            // shape changed (new partition): stale error is meaningless
            self.r = vec![0.0; g.len()];
        }
        let v: Vec<f32> = g.iter().zip(self.r.iter()).map(|(&a, &b)| a + b).collect();
        let q = quantize(&v);
        for i in 0..v.len() {
            let e = v[i] - q.dequantize_at(i);
            // a transient NaN/Inf element must not poison the carried
            // error forever (quantize itself already saturates nonfinite
            // values); drop that element's residual instead
            self.r[i] = if e.is_finite() { e } else { 0.0 };
        }
        q
    }

    pub fn clear(&mut self) {
        self.r.clear();
    }

    /// Largest carried error magnitude (introspection/tests).
    pub fn max_abs(&self) -> f32 {
        self.r.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_dequantize_within_half_step() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let q = QTensor::quantize(&xs);
        let back = q.dequantize();
        let tol = q.tolerance();
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() <= tol, "{a} vs {b} (tol {tol})");
        }
    }

    #[test]
    fn quantize_is_deterministic() {
        let xs: Vec<f32> = (0..257).map(|i| (i as f32).cos()).collect();
        let a = QTensor::quantize(&xs);
        let b = QTensor::quantize(&xs);
        assert_eq!(a, b);
        assert_eq!(a.bytes(), b.bytes());
        assert_eq!(a.scale().to_bits(), b.scale().to_bits());
        let da = a.dequantize();
        let db = b.dequantize();
        let bits = |t: &TensorBuf| t.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&da), bits(&db), "dequantize must be bit-reproducible");
    }

    #[test]
    fn constant_and_empty_tensors_are_exact() {
        let q = QTensor::quantize(&[2.5; 17]);
        assert_eq!(q.scale(), 0.0);
        assert_eq!(q.dequantize().as_slice(), &[2.5; 17]);
        let q = QTensor::quantize(&[]);
        assert!(q.is_empty());
        assert!(q.dequantize().is_empty());
    }

    #[test]
    fn range_endpoints_roundtrip_exactly() {
        let q = QTensor::quantize(&[-1.0, 0.25, 1.0]);
        let back = q.dequantize();
        assert_eq!(back[0], -1.0, "range minimum is exact (q=0)");
        // maximum lands on q=255: zero + 255*scale == hi up to fp rounding
        assert!((back[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn nonfinite_elements_do_not_poison_the_range() {
        let q = QTensor::quantize(&[f32::NAN, -2.0, f32::INFINITY, 2.0]);
        let back = q.dequantize();
        assert_eq!(back[1], -2.0);
        assert!((back[3] - 2.0).abs() < 1e-5);
        assert!(back[0].is_finite() && back[2].is_finite());
    }

    #[test]
    fn clone_shares_bytes() {
        let q = QTensor::quantize(&[0.0, 1.0, 2.0]);
        let c = q.clone();
        assert!(q.ptr_eq(&c));
        assert_eq!(q.byte_len(), 3 + 8);
    }

    // ---------------- per-channel + Q4 arms ----------------

    #[test]
    fn per_channel_rows_roundtrip_within_per_row_tolerance() {
        // two rows with wildly different ranges: per-channel scales keep
        // the small row precise where a per-tensor scale would flatten it
        let rows = 2usize;
        let cols = 32usize;
        let mut xs = Vec::new();
        for i in 0..cols {
            xs.push(1000.0 + i as f32); // row 0: big range
        }
        for i in 0..cols {
            xs.push(0.001 * i as f32); // row 1: tiny range
        }
        let q = QTensor::quantize_weights(&xs, ChannelHint::Rows(rows), Bits::B8);
        assert!(matches!(q.scheme(), Scheme::PerChannel { interleaved: false, .. }));
        let back = q.dequantize();
        // row 1 must be quantized against its own ~0.031 range, so the
        // error stays below a per-row half step (~6e-5), far below the
        // per-tensor step (~4) that a shared scale would impose
        for i in 0..cols {
            let a = xs[cols + i];
            let b = back[cols + i];
            assert!((a - b).abs() <= 1e-4, "row-1 elem {i}: {a} vs {b}");
        }
        let pt = QTensor::quantize(&xs);
        assert!(pt.tolerance() > 1.0, "sanity: per-tensor step is huge here");
    }

    #[test]
    fn per_channel_cols_interleave_correctly() {
        // [16, 4] row-major: column j holds values around j * 100
        let (r, c) = (16usize, 4usize);
        let xs: Vec<f32> =
            (0..r * c).map(|i| (i % c) as f32 * 100.0 + (i / c) as f32 * 0.01).collect();
        let hint = weight_channel_hint(&[r, c], r * c);
        assert_eq!(hint, ChannelHint::Cols(c), "small-col 2-D weights go per-column");
        let q = QTensor::quantize_weights(&xs, hint, Bits::B8);
        assert!(matches!(q.scheme(), Scheme::PerChannel { interleaved: true, .. }));
        let back = q.dequantize();
        for (i, (&a, &b)) in xs.iter().zip(back.iter()).enumerate() {
            assert!((a - b).abs() <= 1e-3, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn q4_roundtrip_within_tolerance_and_odd_lengths_pack() {
        for len in [1usize, 2, 7, 16, 33] {
            let xs: Vec<f32> = (0..len).map(|i| (i as f32 * 0.7).sin()).collect();
            let q = QTensor::quantize_bits(&xs, Bits::B4);
            assert_eq!(q.bytes().len(), len.div_ceil(2), "len {len}: packed size");
            let back = q.dequantize();
            assert_eq!(back.len(), len);
            let tol = q.tolerance();
            for (i, (&a, &b)) in xs.iter().zip(back.iter()).enumerate() {
                assert!((a - b).abs() <= tol, "len {len} elem {i}: {a} vs {b} (tol {tol})");
            }
        }
    }

    #[test]
    fn q4_nonfinite_contract_matches_q8() {
        let xs = [f32::NAN, -2.0, f32::INFINITY, 2.0, f32::NEG_INFINITY];
        let q = QTensor::quantize_bits(&xs, Bits::B4);
        let back = q.dequantize();
        // finite elements anchor the range and roundtrip within tolerance
        assert!((back[1] + 2.0).abs() <= q.tolerance());
        assert!((back[3] - 2.0).abs() <= q.tolerance());
        // nonfinite elements saturate into the finite range, like Q8
        // (up to fp rounding of zero + 15 * scale at the top end)
        let tol = q.tolerance();
        for (i, b) in back.iter().enumerate() {
            assert!(b.is_finite(), "elem {i} must dequantize finite, got {b}");
            assert!(
                *b >= -2.0 - tol && *b <= 2.0 + tol,
                "elem {i} saturates into range, got {b}"
            );
        }
        // NaN maps to code 0 (the range minimum), matching `as u8`
        assert_eq!(back[0], -2.0);
    }

    #[test]
    fn q4_is_deterministic_and_cuts_bytes_8x() {
        let xs: Vec<f32> = (0..4096).map(|i| ((i * 37) % 101) as f32 * 0.3 - 15.0).collect();
        let a = QTensor::quantize_weights(&xs, ChannelHint::Rows(64), Bits::B4);
        let b = QTensor::quantize_weights(&xs, ChannelHint::Rows(64), Bits::B4);
        assert_eq!(a, b);
        let bits = |t: &TensorBuf| t.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.dequantize()), bits(&b.dequantize()));
        // per-channel Q4: 2048 code bytes + 64 pairs; >= 6x under f32
        let f32_bytes = xs.len() * 4;
        assert!(
            f32_bytes >= 6 * a.byte_len(),
            "per-channel q4 {} vs f32 {}",
            a.byte_len(),
            f32_bytes
        );
        // per-tensor Q4 on a long 1-D tensor approaches the full 8x
        let pt = QTensor::quantize_bits(&xs, Bits::B4);
        assert!(
            (f32_bytes as f64) / (pt.byte_len() as f64) > 7.5,
            "per-tensor q4 {} vs f32 {}",
            pt.byte_len(),
            f32_bytes
        );
    }

    #[test]
    fn weight_channel_hint_picks_paying_axes_only() {
        assert_eq!(weight_channel_hint(&[64, 64], 4096), ChannelHint::Rows(64));
        assert_eq!(weight_channel_hint(&[64, 4], 256), ChannelHint::Cols(4));
        assert_eq!(weight_channel_hint(&[4, 4], 16), ChannelHint::PerTensor);
        assert_eq!(weight_channel_hint(&[128], 128), ChannelHint::PerTensor);
        assert_eq!(weight_channel_hint(&[64, 64], 999), ChannelHint::PerTensor, "shape/len lie");
        assert_eq!(weight_channel_hint(&[], 0), ChannelHint::PerTensor);
    }

    #[test]
    fn malformed_wire_parts_are_rejected() {
        assert!(QTensor::from_wire(vec![0; 3], 7, Bits::B8, Scheme::PerTensor {
            scale: 1.0,
            zero: 0.0
        })
        .is_err());
        assert!(QTensor::from_wire(vec![0; 4], 7, Bits::B4, Scheme::PerChannel {
            pairs: Arc::new(vec![(1.0, 0.0); 3]),
            interleaved: false,
        })
        .is_err());
        assert!(QTensor::from_wire(vec![0; 4], 8, Bits::B4, Scheme::PerChannel {
            pairs: Arc::new(vec![(1.0, 0.0); 4]),
            interleaved: true,
        })
        .is_ok());
    }

    // ---------------- error feedback ----------------

    #[test]
    fn residual_bounds_accumulated_error() {
        // same gradient applied repeatedly: WITH error feedback, the sum
        // of dequantized sends tracks the true sum to within one step
        let g = vec![0.013f32, -0.027, 0.5, -0.4999, 0.25];
        let mut res = Residual::default();
        let mut sent = vec![0.0f64; g.len()];
        let steps = 200;
        for _ in 0..steps {
            let q = res.fold(&g);
            let d = q.dequantize();
            for (s, v) in sent.iter_mut().zip(d.iter()) {
                *s += *v as f64;
            }
        }
        for (i, s) in sent.iter().enumerate() {
            let truth = g[i] as f64 * steps as f64;
            let step = ((1.0 - -0.4999) / 255.0) as f64; // range of g+r, approx
            assert!(
                (s - truth).abs() <= 2.0 * step + 1e-3,
                "element {i}: sent {s} vs true {truth}"
            );
        }
        assert!(res.max_abs() <= 0.01, "residual itself stays within one step");
    }

    #[test]
    fn residual_bounds_accumulated_error_under_q4() {
        // the Q4 replica path reuses the same feedback loop at 4 bits:
        // the accumulated error of repeated pushes stays within a few
        // (coarser) steps instead of growing linearly
        let g = vec![0.4f32, -0.3, 0.11, -0.09];
        let mut res = Residual::default();
        let mut sent = vec![0.0f64; g.len()];
        let steps = 100;
        for _ in 0..steps {
            let q = res.fold_with(&g, |v| QTensor::quantize_bits(v, Bits::B4));
            let d = q.dequantize();
            for (s, v) in sent.iter_mut().zip(d.iter()) {
                *s += *v as f64;
            }
        }
        let step = (0.8f64 + 0.1) / 15.0; // rough range / 15
        for (i, s) in sent.iter().enumerate() {
            let truth = g[i] as f64 * steps as f64;
            assert!(
                (s - truth).abs() <= 4.0 * step + 1e-2,
                "element {i}: sent {s} vs true {truth}"
            );
        }
    }

    #[test]
    fn residual_survives_a_transient_nonfinite_gradient() {
        let mut res = Residual::default();
        res.fold(&[0.1, 0.2, 0.3]);
        // one poisoned step: the nonfinite element saturates on the wire
        // but must not leave NaN/Inf in the carried error
        res.fold(&[0.1, f32::NAN, f32::INFINITY]);
        assert!(res.max_abs().is_finite(), "residual stays finite");
        let q = res.fold(&[0.1, 0.2, 0.3]);
        let back = q.dequantize();
        for (a, b) in [0.1f32, 0.2, 0.3].iter().zip(back.iter()) {
            assert!((a - b).abs() <= 2.0 * q.tolerance() + 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn residual_resets_on_shape_change() {
        let mut res = Residual::default();
        res.fold(&[1.0, 2.0, 3.0]);
        let q = res.fold(&[5.0; 7]);
        assert_eq!(q.len(), 7);
        assert_eq!(q.dequantize().as_slice(), &[5.0; 7], "no stale residual leaked in");
    }

    // ---------------- policy ----------------

    #[test]
    fn compression_policy_knobs() {
        assert!(!Compression::Off.data_plane() && !Compression::Off.weights());
        assert!(Compression::Activations.data_plane() && !Compression::Activations.weights());
        assert!(Compression::Full.data_plane() && Compression::Full.weights());
        assert!(Compression::FullQ4.data_plane() && Compression::FullQ4.weights());
        assert!(!Compression::Adaptive.data_plane(), "adaptive starts at Off");
        for c in [
            Compression::Off,
            Compression::Activations,
            Compression::Full,
            Compression::FullQ4,
            Compression::Adaptive,
        ] {
            assert_eq!(Compression::from_u8(c.to_u8()), Some(c));
            assert_eq!(Compression::parse(c.name()), Some(c));
        }
        assert_eq!(Compression::from_u8(9), None);
        assert_eq!(Compression::parse("gzip"), None);
    }

    #[test]
    fn tier_ladder_orders_and_codings() {
        assert!(Tier::Off < Tier::Activations);
        assert!(Tier::Activations < Tier::Full);
        assert!(Tier::Full < Tier::FullQ4);
        assert_eq!(Tier::FullQ4.replica_coding(), WeightCoding::Q4);
        assert_eq!(Tier::FullQ4.restore_coding(), WeightCoding::Q8, "restores never Q4");
        assert_eq!(Tier::Full.replica_coding(), WeightCoding::Q8);
        assert_eq!(Tier::Activations.replica_coding(), WeightCoding::F32);
        for t in [Tier::Off, Tier::Activations, Tier::Full, Tier::FullQ4] {
            assert_eq!(Tier::from_u8(t.to_u8()), Some(t));
        }
        assert_eq!(Tier::from_u8(4), None);
    }

    #[test]
    fn adaptive_policy_escalates_immediately_and_relaxes_with_hysteresis() {
        let th = AdaptiveThresholds {
            activations_below: 3e6,
            full_below: 4e5,
            q4_below: 1.5e5,
            relax_factor: 1.5,
            ..AdaptiveThresholds::default()
        };
        th.validate().unwrap();
        let mut p = AdaptivePolicy::new(th);
        assert_eq!(p.tier_for(1), Tier::Off);
        assert_eq!(p.observe(1, 5e7), None, "fast link: stay Off");
        // multi-step escalation in one observation
        assert_eq!(p.observe(1, 2.0e5), Some(Tier::Full));
        // jitter just above the entry threshold must NOT relax
        assert_eq!(p.observe(1, 5.0e5), None, "4e5 * 1.5 = 6e5 not cleared");
        assert_eq!(p.tier_for(1), Tier::Full);
        // clearing the band relaxes to the target tier directly
        assert_eq!(p.observe(1, 7.0e5), Some(Tier::Activations));
        // degrade to the bottom rung
        assert_eq!(p.observe(1, 1.0e5), Some(Tier::FullQ4));
        // and a fully recovered link walks straight back to Off
        assert_eq!(p.observe(1, 5e7), Some(Tier::Off));
        assert!(p.overrides().is_empty(), "back at the floor: no override kept");
        // nonsense observations hold the tier
        assert_eq!(p.observe(1, 0.0), None);
        assert_eq!(p.observe(1, f64::NAN), None);
        assert_eq!(p.observe(1, f64::INFINITY), None);
    }

    #[test]
    fn adaptive_policy_runs_each_link_ladder_independently() {
        let th = AdaptiveThresholds {
            activations_below: 3e6,
            full_below: 4e5,
            q4_below: 1.5e5,
            relax_factor: 1.5,
            ..AdaptiveThresholds::default()
        };
        let mut p = AdaptivePolicy::new(th);
        // link ->2 collapses; link ->3 merely degrades; link ->1 is fine
        assert_eq!(p.observe(2, 1.0e5), Some(Tier::FullQ4));
        assert_eq!(p.observe(3, 2.0e5), Some(Tier::Full));
        assert_eq!(p.observe(1, 5e7), None);
        assert_eq!(p.tier_for(1), Tier::Off, "healthy link untouched by the bad ones");
        assert_eq!(p.tier_for(2), Tier::FullQ4);
        assert_eq!(p.tier_for(3), Tier::Full);
        assert_eq!(p.max_tier(), Tier::FullQ4);
        // hysteresis is evaluated against each link's own rung
        assert_eq!(p.observe(3, 5.0e5), None, "5e5 < 4e5*1.5: inside ->3's band");
        assert_eq!(p.observe(2, 2.0e5), None, "2e5 < 1.5e5*1.5: inside ->2's band");
        // recovery of one link does not move the other
        assert_eq!(p.observe(3, 5e7), Some(Tier::Off));
        assert_eq!(p.tier_for(2), Tier::FullQ4, "->2 still escalated after ->3 relaxed");
        assert_eq!(p.overrides(), vec![(2, Tier::FullQ4)]);
    }

    #[test]
    fn adaptive_policy_overrides_iterate_in_destination_order() {
        let mut p = AdaptivePolicy::new(AdaptiveThresholds::default());
        // insert in scrambled order; overrides() must come back sorted
        for dest in [9, 2, 7, 4] {
            assert!(p.observe(dest, 1.0e4).is_some());
        }
        let devs: Vec<usize> = p.overrides().iter().map(|&(d, _)| d).collect();
        assert_eq!(devs, vec![2, 4, 7, 9], "deterministic ascending iteration");
        // forget/retain prune ladders without touching the others
        assert!(p.forget(7));
        assert!(!p.forget(7), "second forget is a no-op");
        p.retain(|d| d != 9);
        let devs: Vec<usize> = p.overrides().iter().map(|&(d, _)| d).collect();
        assert_eq!(devs, vec![2, 4]);
        assert_eq!(p.tier_for(7), Tier::Off, "forgotten link reads as the floor");
    }

    #[test]
    fn adaptive_policy_resume_clamps_each_link_into_the_band() {
        let th = AdaptiveThresholds {
            tier_floor: Tier::Activations,
            tier_ceiling: Tier::Full,
            ..AdaptiveThresholds::default()
        };
        let p = AdaptivePolicy::resume_at(
            th,
            &[(1, Tier::Off), (2, Tier::FullQ4), (3, Tier::Full)],
        );
        assert_eq!(p.tier_for(1), Tier::Activations, "below-floor entry clamps to floor");
        assert_eq!(p.tier_for(2), Tier::Full, "above-ceiling entry clamps to ceiling");
        assert_eq!(p.tier_for(3), Tier::Full);
        assert_eq!(
            p.overrides(),
            vec![(2, Tier::Full), (3, Tier::Full)],
            "floor-clamped entries are dropped, not stored"
        );
    }

    #[test]
    fn adaptive_thresholds_validate_ordering() {
        assert!(AdaptiveThresholds::default().validate().is_ok());
        let bad = AdaptiveThresholds { q4_below: 5e6, ..AdaptiveThresholds::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptiveThresholds { relax_factor: 0.5, ..AdaptiveThresholds::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptiveThresholds {
            tier_floor: Tier::Full,
            tier_ceiling: Tier::Activations,
            ..AdaptiveThresholds::default()
        };
        assert!(bad.validate().is_err(), "inverted band must not validate");
    }

    #[test]
    fn adaptive_policy_respects_the_tier_band() {
        // ceiling: a catastrophic link cannot push past Full
        let th = AdaptiveThresholds {
            tier_ceiling: Tier::Full,
            ..AdaptiveThresholds::default()
        };
        th.validate().unwrap();
        let mut p = AdaptivePolicy::new(th);
        assert_eq!(p.tier_for(1), Tier::Off);
        assert_eq!(p.observe(1, 1e3), Some(Tier::Full), "capped at the ceiling, not FullQ4");
        assert_eq!(p.observe(1, 1e2), None, "already at the ceiling: hold, not re-announce");
        // floor: every link starts there and a perfect link cannot
        // relax below it
        let th = AdaptiveThresholds {
            tier_floor: Tier::Activations,
            ..AdaptiveThresholds::default()
        };
        let mut p = AdaptivePolicy::new(th);
        assert_eq!(p.tier_for(1), Tier::Activations, "every link boots at the floor");
        assert_eq!(p.observe(1, 1e12), None, "a fast link clamps back onto the floor: hold");
        assert_eq!(p.observe(1, 1e5), Some(Tier::FullQ4), "escalation above the floor still works");
        assert_eq!(p.observe(1, 1e12), Some(Tier::Activations), "relaxation stops at the floor");
        assert!(p.overrides().is_empty(), "floor tier is implicit, never an override");
        // parse round-trip for the config spelling
        for t in [Tier::Off, Tier::Activations, Tier::Full, Tier::FullQ4] {
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
        assert_eq!(Tier::parse("bogus"), None);
    }
}
