//! The minimal event reactor under the TCP transport (DESIGN.md §13).
//!
//! Three pieces, none of which is a runtime:
//!
//! * a hand-rolled, Linux-gated [`poll(2)`] shim ([`PollSet`]) — no libc
//!   crate is available offline, so the one syscall the event loop needs
//!   is declared by hand. Platforms without the shim fall back to a
//!   short-sleep polling loop that reports every socket ready (correct
//!   but degraded: every registered socket is nonblocking, so a spurious
//!   "ready" costs one `WouldBlock`).
//! * a self-pipe wakeup ([`WakePipe`]) so `Transport::send` — a pure
//!   enqueue on the caller thread — can nudge the I/O driver out of
//!   `poll`. An atomic `pending` flag coalesces wakes: on a busy
//!   endpoint only the first enqueue between two driver iterations pays
//!   a syscall, the rest are a single uncontended atomic swap.
//! * the socket-free framing state machines: [`WriteQueue`] (per-peer
//!   outbound frames, scatter-gather coalescing via `write_vectored`,
//!   partial-write resume) and [`FrameAssembler`] (bulk reads into a
//!   cursor buffer, in-place `[u32 length][codec frame]` parsing,
//!   oversized-frame rejection). Both are pure over `Write`/`Read`, so
//!   the readiness edge cases are unit-tested here without sockets.
//!
//! [`poll(2)`]: https://man7.org/linux/man-pages/man2/poll.2.html

use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use anyhow::Result;

use super::codec::{frame_header, frame_payload_len};

/// Raw file descriptor (our own alias so the non-Linux fallback compiles
/// without `std::os::unix`).
pub type Fd = i32;

/// Reusable buffers shrink back to this capacity after an oversized
/// frame, so one multi-MB weight push doesn't pin that much memory per
/// connection forever (these are memory-capped edge devices).
pub const MAX_RETAINED_BUF: usize = 1 << 20;

// ---------- the poll(2) shim ----------

#[cfg(target_os = "linux")]
mod sys {
    /// `struct pollfd` from `<poll.h>` (identical layout on every Linux
    /// target rustc supports).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    pub const F_GETFL: i32 = 3;
    pub const F_SETFL: i32 = 4;
    pub const O_NONBLOCK: i32 = 0o4000;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: u64, timeout_ms: i32) -> i32;
        pub fn pipe(fds: *mut i32) -> i32;
        pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        pub fn close(fd: i32) -> i32;
        pub fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
    }
}

/// What a polled descriptor reported.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Readiness {
    pub readable: bool,
    pub writable: bool,
    /// POLLERR / POLLHUP / POLLNVAL — the connection needs attention
    /// regardless of the interest it was registered with.
    pub error: bool,
}

/// A rebuild-per-iteration poll set: `register` descriptors with their
/// interests, `wait`, then ask each slot (the index `register` returned)
/// for its [`Readiness`].
pub struct PollSet {
    #[cfg(target_os = "linux")]
    fds: Vec<sys::PollFd>,
    #[cfg(not(target_os = "linux"))]
    n: usize,
}

impl PollSet {
    pub fn new() -> PollSet {
        #[cfg(target_os = "linux")]
        {
            PollSet { fds: Vec::new() }
        }
        #[cfg(not(target_os = "linux"))]
        {
            PollSet { n: 0 }
        }
    }

    /// Drop every registration (capacity is kept).
    pub fn clear(&mut self) {
        #[cfg(target_os = "linux")]
        self.fds.clear();
        #[cfg(not(target_os = "linux"))]
        {
            self.n = 0;
        }
    }

    /// Register `fd` with read/write interest; returns the slot index.
    pub fn register(&mut self, fd: Fd, read: bool, write: bool) -> usize {
        #[cfg(target_os = "linux")]
        {
            let mut events = 0i16;
            if read {
                events |= sys::POLLIN;
            }
            if write {
                events |= sys::POLLOUT;
            }
            self.fds.push(sys::PollFd { fd, events, revents: 0 });
            self.fds.len() - 1
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = (fd, read, write);
            self.n += 1;
            self.n - 1
        }
    }

    /// Block until something is ready or `timeout` passes. Returns the
    /// number of ready descriptors (0 on timeout / EINTR). The fallback
    /// sleeps a short slice and reports everything ready — every socket
    /// behind this set is nonblocking, so spurious readiness is safe.
    pub fn wait(&mut self, timeout: Duration) -> usize {
        #[cfg(target_os = "linux")]
        {
            let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            let rc = unsafe { sys::poll(self.fds.as_mut_ptr(), self.fds.len() as u64, ms) };
            if rc < 0 {
                // EINTR (or any other failure): report nothing ready and
                // let the driver rebuild + retry on the next iteration
                for f in &mut self.fds {
                    f.revents = 0;
                }
                return 0;
            }
            rc as usize
        }
        #[cfg(not(target_os = "linux"))]
        {
            std::thread::sleep(timeout.min(Duration::from_millis(2)));
            self.n
        }
    }

    /// Readiness of the descriptor `register` put at `slot`.
    pub fn readiness(&self, slot: usize) -> Readiness {
        #[cfg(target_os = "linux")]
        {
            let r = self.fds[slot].revents;
            Readiness {
                readable: r & sys::POLLIN != 0,
                writable: r & sys::POLLOUT != 0,
                error: r & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0,
            }
        }
        #[cfg(not(target_os = "linux"))]
        {
            debug_assert!(slot < self.n);
            Readiness { readable: true, writable: true, error: false }
        }
    }
}

impl Default for PollSet {
    fn default() -> PollSet {
        PollSet::new()
    }
}

/// The descriptor of a pollable socket-like object, for [`PollSet::register`].
#[cfg(target_os = "linux")]
pub fn socket_fd<T: std::os::unix::io::AsRawFd>(s: &T) -> Fd {
    s.as_raw_fd()
}

/// Fallback: no real descriptors — the degraded [`PollSet`] ignores them.
#[cfg(not(target_os = "linux"))]
pub fn socket_fd<T>(_s: &T) -> Fd {
    -1
}

// ---------- self-pipe wakeup ----------

/// Wakes a [`PollSet::wait`] from another thread. `wake` is called on
/// every `Transport::send`, so it is built to be almost free on a busy
/// endpoint: a relaxed-path atomic swap skips the pipe write whenever a
/// wake is already pending (the driver clears the flag *before* it
/// drains, so a send landing mid-drain still produces a fresh wake).
pub struct WakePipe {
    #[cfg(target_os = "linux")]
    read_fd: Fd,
    #[cfg(target_os = "linux")]
    write_fd: Fd,
    pending: AtomicBool,
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        #[cfg(target_os = "linux")]
        {
            let mut fds = [0i32; 2];
            if unsafe { sys::pipe(fds.as_mut_ptr()) } != 0 {
                return Err(io::Error::last_os_error());
            }
            for fd in fds {
                unsafe {
                    let fl = sys::fcntl(fd, sys::F_GETFL, 0);
                    sys::fcntl(fd, sys::F_SETFL, fl | sys::O_NONBLOCK);
                }
            }
            Ok(WakePipe { read_fd: fds[0], write_fd: fds[1], pending: AtomicBool::new(false) })
        }
        #[cfg(not(target_os = "linux"))]
        {
            Ok(WakePipe { pending: AtomicBool::new(false) })
        }
    }

    /// The end to register (read interest) in the driver's [`PollSet`].
    pub fn read_fd(&self) -> Fd {
        #[cfg(target_os = "linux")]
        {
            self.read_fd
        }
        #[cfg(not(target_os = "linux"))]
        {
            -1
        }
    }

    /// Nudge the driver. Coalesced: only the first call after a `drain`
    /// writes to the pipe.
    pub fn wake(&self) {
        if self.pending.swap(true, Ordering::SeqCst) {
            return; // a wake is already in flight
        }
        #[cfg(target_os = "linux")]
        {
            // a full pipe means wakes are pending anyway — EAGAIN is fine
            let byte = 1u8;
            unsafe { sys::write(self.write_fd, &byte, 1) };
        }
    }

    /// Driver side: clear the flag, then empty the pipe. Clearing first
    /// means a concurrent `wake` after this point writes a fresh byte
    /// and the driver cannot sleep through it.
    pub fn drain(&self) {
        self.pending.store(false, Ordering::SeqCst);
        #[cfg(target_os = "linux")]
        {
            let mut buf = [0u8; 64];
            loop {
                let n = unsafe { sys::read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
                if n <= 0 {
                    break; // EAGAIN / EOF / error: pipe is empty enough
                }
            }
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for WakePipe {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.read_fd);
            sys::close(self.write_fd);
        }
    }
}

// ---------- outbound: per-peer frame queue with write coalescing ----------

/// One length-framed message awaiting the wire. `off` is the write
/// cursor over the virtual `[header][payload]` concatenation.
struct Frame {
    header: [u8; 4],
    payload: Vec<u8>,
    off: usize,
}

impl Frame {
    fn remaining(&self) -> usize {
        4 + self.payload.len() - self.off
    }
}

/// What one [`WriteQueue::write_to`] pass achieved.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WriteProgress {
    /// Frames fully handed to the OS in this pass.
    pub completed: usize,
    /// The sink said `WouldBlock`: re-arm write interest and come back.
    pub blocked: bool,
}

/// A peer's outbound queue. `Transport::send` pushes encoded frames; the
/// I/O driver drains it with vectored writes that gather many
/// `[header][payload]` pairs into one syscall and survive partial writes
/// at any byte boundary (including mid-header).
#[derive(Default)]
pub struct WriteQueue {
    frames: VecDeque<Frame>,
    queued_bytes: usize,
}

impl WriteQueue {
    pub fn new() -> WriteQueue {
        WriteQueue::default()
    }

    /// Enqueue one encoded codec frame (the 4-byte length header is
    /// derived here — callers hand over payload bytes only).
    pub fn push(&mut self, payload: Vec<u8>) {
        self.queued_bytes += 4 + payload.len();
        self.frames.push_back(Frame { header: frame_header(payload.len()), payload, off: 0 });
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Frames waiting (a partially written head frame still counts).
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    /// Unwritten bytes across all queued frames.
    pub fn queued_bytes(&self) -> usize {
        self.queued_bytes
    }

    /// Forget partial-write progress on the head frame. Called when a
    /// connection is replaced: the new peer socket must see the frame
    /// from byte 0, not from wherever the dead one stalled.
    pub fn rewind(&mut self) {
        if let Some(f) = self.frames.front_mut() {
            self.queued_bytes += f.off;
            f.off = 0;
        }
    }

    /// Drop everything (peer is unreachable), recycling payload buffers
    /// into `pool`. Returns the number of frames dropped.
    pub fn clear_into(&mut self, pool: &mut Vec<Vec<u8>>) -> usize {
        let n = self.frames.len();
        for f in self.frames.drain(..) {
            pool.push(f.payload);
        }
        self.queued_bytes = 0;
        n
    }

    /// Write as much as the sink accepts, coalescing up to `coalesce`
    /// frames per vectored write. Completed payload buffers are recycled
    /// into `pool`. `Err` means the connection is dead (including a
    /// zero-byte write); the queue keeps its frames so the caller can
    /// [`Self::rewind`] and retry on a fresh connection.
    pub fn write_to<W: Write>(
        &mut self,
        w: &mut W,
        coalesce: usize,
        pool: &mut Vec<Vec<u8>>,
    ) -> io::Result<WriteProgress> {
        let mut progress = WriteProgress::default();
        let coalesce = coalesce.max(1);
        while !self.frames.is_empty() {
            let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(coalesce * 2);
            for (i, f) in self.frames.iter().take(coalesce).enumerate() {
                if i == 0 && f.off > 0 {
                    if f.off < 4 {
                        slices.push(IoSlice::new(&f.header[f.off..]));
                        slices.push(IoSlice::new(&f.payload));
                    } else {
                        slices.push(IoSlice::new(&f.payload[f.off - 4..]));
                    }
                } else {
                    slices.push(IoSlice::new(&f.header));
                    slices.push(IoSlice::new(&f.payload));
                }
            }
            match w.write_vectored(&slices) {
                Ok(0) => {
                    return Err(io::Error::new(io::ErrorKind::WriteZero, "peer accepted 0 bytes"))
                }
                Ok(n) => self.advance(n, pool, &mut progress.completed),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    progress.blocked = true;
                    return Ok(progress);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(progress)
    }

    /// Consume `n` written bytes off the front of the queue.
    fn advance(&mut self, mut n: usize, pool: &mut Vec<Vec<u8>>, completed: &mut usize) {
        self.queued_bytes -= n;
        while n > 0 {
            let rem = self.frames.front().expect("wrote more than was queued").remaining();
            if n >= rem {
                n -= rem;
                let f = self.frames.pop_front().unwrap();
                pool.push(f.payload);
                *completed += 1;
            } else {
                self.frames.front_mut().unwrap().off += n;
                n = 0;
            }
        }
    }
}

// ---------- inbound: bulk reads + in-place frame parsing ----------

/// What one [`FrameAssembler::read_from`] pass observed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReadProgress {
    pub bytes: usize,
    /// Clean end-of-stream (peer closed). Parse what is buffered, then
    /// drop the connection.
    pub eof: bool,
}

/// Reassembles `[u32 length][codec frame]` out of a nonblocking byte
/// stream: bulk reads land in one growable buffer with start/end
/// cursors, frames are parsed in place (the returned slice borrows the
/// buffer — zero copies before `codec::decode`), and `compact` reclaims
/// consumed space between read bursts.
pub struct FrameAssembler {
    buf: Vec<u8>,
    start: usize,
    end: usize,
}

/// Read chunk size — one syscall ingests many small frames.
const READ_CHUNK: usize = 64 * 1024;

/// Per-pass ingest cap so one firehose connection cannot starve the rest
/// of the poll loop.
const MAX_READ_PER_PASS: usize = 1 << 20;

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler { buf: Vec::new(), start: 0, end: 0 }
    }

    /// Bytes buffered but not yet returned as frames.
    pub fn buffered(&self) -> usize {
        self.end - self.start
    }

    /// Current buffer footprint (tests assert the post-burst shrink).
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Pull whatever the stream has (until `WouldBlock`, EOF, or the
    /// per-pass cap). `Err` means the connection died mid-read.
    pub fn read_from<R: Read>(&mut self, r: &mut R) -> io::Result<ReadProgress> {
        let mut progress = ReadProgress::default();
        loop {
            if self.buf.len() - self.end < READ_CHUNK {
                self.buf.resize(self.end + READ_CHUNK, 0);
            }
            match r.read(&mut self.buf[self.end..]) {
                Ok(0) => {
                    progress.eof = true;
                    return Ok(progress);
                }
                Ok(n) => {
                    self.end += n;
                    progress.bytes += n;
                    if progress.bytes >= MAX_READ_PER_PASS {
                        return Ok(progress);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(progress),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    /// Parse the next complete frame, if any. The slice borrows this
    /// assembler's buffer and is valid until the next mutating call.
    /// `Err` = oversized (corrupt) length prefix: kill the connection.
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>> {
        if self.buffered() < 4 {
            return Ok(None);
        }
        let header: [u8; 4] = self.buf[self.start..self.start + 4].try_into().unwrap();
        let len = frame_payload_len(header)?;
        if self.buffered() < 4 + len {
            return Ok(None);
        }
        let a = self.start + 4;
        let b = a + len;
        self.start = b;
        Ok(Some(&self.buf[a..b]))
    }

    /// Reclaim consumed space (called between read bursts, when no
    /// parsed slice is outstanding) and shed oversized capacity.
    pub fn compact(&mut self) {
        if self.start > 0 {
            self.buf.copy_within(self.start..self.end, 0);
            self.end -= self.start;
            self.start = 0;
        }
        if self.end < MAX_RETAINED_BUF && self.buf.len() > MAX_RETAINED_BUF {
            self.buf.truncate(MAX_RETAINED_BUF);
            self.buf.shrink_to(MAX_RETAINED_BUF);
        }
    }
}

impl Default for FrameAssembler {
    fn default() -> FrameAssembler {
        FrameAssembler::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A sink that accepts at most `per_call` bytes per write and starts
    /// answering `WouldBlock` once `accept_total` bytes have landed.
    struct Throttle {
        out: Vec<u8>,
        per_call: usize,
        accept_total: usize,
    }

    impl Write for Throttle {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.out.len() >= self.accept_total {
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "full"));
            }
            let n = buf.len().min(self.per_call).min(self.accept_total - self.out.len());
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    fn framed(payload: &[u8]) -> Vec<u8> {
        let mut v = (payload.len() as u32).to_le_bytes().to_vec();
        v.extend_from_slice(payload);
        v
    }

    #[test]
    fn write_queue_coalesces_and_completes() {
        let mut q = WriteQueue::new();
        q.push(vec![1, 2, 3, 4, 5]);
        q.push(vec![9, 9]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.queued_bytes(), 4 + 5 + 4 + 2);
        let mut pool = Vec::new();
        let mut w = Throttle { out: Vec::new(), per_call: usize::MAX, accept_total: usize::MAX };
        let p = q.write_to(&mut w, 16, &mut pool).unwrap();
        assert_eq!(p, WriteProgress { completed: 2, blocked: false });
        assert!(q.is_empty());
        assert_eq!(q.queued_bytes(), 0);
        assert_eq!(pool.len(), 2, "payload buffers recycled");
        let mut expect = framed(&[1, 2, 3, 4, 5]);
        expect.extend(framed(&[9, 9]));
        assert_eq!(w.out, expect);
    }

    #[test]
    fn write_queue_survives_partial_writes_at_any_boundary() {
        // 3 bytes per call splits the 4-byte header across writes; the
        // queue must resume exactly where the socket stalled
        for per_call in 1..=7 {
            let mut q = WriteQueue::new();
            q.push(vec![10, 20, 30]);
            q.push((0..40u8).collect());
            let mut pool = Vec::new();
            let mut w = Throttle { out: Vec::new(), per_call, accept_total: usize::MAX };
            let p = q.write_to(&mut w, 4, &mut pool).unwrap();
            assert_eq!(p.completed, 2, "per_call={per_call}");
            let mut expect = framed(&[10, 20, 30]);
            expect.extend(framed(&(0..40u8).collect::<Vec<_>>()));
            assert_eq!(w.out, expect, "per_call={per_call}");
        }
    }

    #[test]
    fn write_queue_blocks_and_resumes() {
        let mut q = WriteQueue::new();
        q.push(vec![7; 32]);
        let mut pool = Vec::new();
        // socket takes 10 bytes (header + 6 payload) then blocks
        let mut w = Throttle { out: Vec::new(), per_call: 64, accept_total: 10 };
        let p = q.write_to(&mut w, 4, &mut pool).unwrap();
        assert!(p.blocked);
        assert_eq!(p.completed, 0);
        assert_eq!(q.len(), 1);
        assert_eq!(q.queued_bytes(), 36 - 10);
        // readiness returns: the rest goes out and the frame completes
        w.accept_total = usize::MAX;
        let p = q.write_to(&mut w, 4, &mut pool).unwrap();
        assert_eq!(p, WriteProgress { completed: 1, blocked: false });
        assert_eq!(w.out, framed(&[7; 32]));
    }

    #[test]
    fn write_queue_rewind_restarts_the_head_frame() {
        let mut q = WriteQueue::new();
        q.push(vec![1, 2, 3, 4]);
        let mut pool = Vec::new();
        let mut w = Throttle { out: Vec::new(), per_call: 64, accept_total: 6 };
        assert!(q.write_to(&mut w, 4, &mut pool).unwrap().blocked);
        // connection died mid-frame; a fresh one must see byte 0 again
        q.rewind();
        assert_eq!(q.queued_bytes(), 8);
        let mut w2 = Throttle { out: Vec::new(), per_call: 64, accept_total: usize::MAX };
        assert_eq!(q.write_to(&mut w2, 4, &mut pool).unwrap().completed, 1);
        assert_eq!(w2.out, framed(&[1, 2, 3, 4]));
    }

    #[test]
    fn write_queue_zero_byte_write_is_an_error() {
        struct Dead;
        impl Write for Dead {
            fn write(&mut self, _buf: &[u8]) -> io::Result<usize> {
                Ok(0)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut q = WriteQueue::new();
        q.push(vec![1]);
        assert!(q.write_to(&mut Dead, 4, &mut Vec::new()).is_err());
        assert_eq!(q.len(), 1, "the frame is kept for a retry on a fresh connection");
    }

    /// A stream that serves scripted chunks, then `WouldBlock`.
    struct Chunks {
        data: Vec<u8>,
        pos: usize,
        per_call: usize,
    }

    impl Read for Chunks {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.pos >= self.data.len() {
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "drained"));
            }
            let n = (self.data.len() - self.pos).min(self.per_call).min(buf.len());
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn assembler_reassembles_across_arbitrary_chunking() {
        let mut wire = framed(b"hello");
        wire.extend(framed(&[]));
        wire.extend(framed(&[0xAB; 300]));
        for per_call in [1, 2, 3, 5, 64, 1024] {
            let mut r = Chunks { data: wire.clone(), pos: 0, per_call };
            let mut asm = FrameAssembler::new();
            let mut got: Vec<Vec<u8>> = Vec::new();
            loop {
                let p = asm.read_from(&mut r).unwrap();
                while let Some(f) = asm.next_frame().unwrap() {
                    got.push(f.to_vec());
                }
                asm.compact();
                if p.bytes == 0 {
                    break;
                }
            }
            assert_eq!(got.len(), 3, "per_call={per_call}");
            assert_eq!(got[0], b"hello");
            assert_eq!(got[1], Vec::<u8>::new());
            assert_eq!(got[2], vec![0xAB; 300]);
            assert_eq!(asm.buffered(), 0);
        }
    }

    #[test]
    fn assembler_rejects_oversized_frames() {
        let mut r = Chunks { data: vec![0xFF, 0xFF, 0xFF, 0xFF, 1, 2, 3], pos: 0, per_call: 64 };
        let mut asm = FrameAssembler::new();
        asm.read_from(&mut r).unwrap();
        assert!(asm.next_frame().is_err(), "a ~4GiB length prefix is a corrupt stream");
    }

    #[test]
    fn assembler_reports_eof() {
        struct Eof;
        impl Read for Eof {
            fn read(&mut self, _buf: &mut [u8]) -> io::Result<usize> {
                Ok(0)
            }
        }
        let p = FrameAssembler::new().read_from(&mut Eof).unwrap();
        assert!(p.eof);
    }

    #[test]
    fn assembler_sheds_capacity_after_a_burst() {
        let big = vec![7u8; 3 * MAX_RETAINED_BUF];
        let mut r = Chunks { data: framed(&big), pos: 0, per_call: usize::MAX };
        let mut asm = FrameAssembler::new();
        loop {
            let p = asm.read_from(&mut r).unwrap();
            if p.bytes == 0 {
                break;
            }
        }
        assert_eq!(asm.next_frame().unwrap().unwrap().len(), big.len());
        assert!(asm.capacity() > MAX_RETAINED_BUF);
        asm.compact();
        assert!(asm.capacity() <= MAX_RETAINED_BUF, "multi-MB burst must not pin memory");
    }

    #[test]
    fn wake_pipe_is_poll_visible_and_coalesced() {
        let wp = WakePipe::new().unwrap();
        for _ in 0..100 {
            wp.wake(); // coalesced: at most one byte in the pipe
        }
        let mut ps = PollSet::new();
        let slot = ps.register(wp.read_fd(), true, false);
        ps.wait(Duration::from_millis(200));
        assert!(ps.readiness(slot).readable);
        wp.drain();
        // wake-after-drain is visible again (the flag was cleared)
        wp.wake();
        ps.clear();
        let slot = ps.register(wp.read_fd(), true, false);
        ps.wait(Duration::from_millis(200));
        assert!(ps.readiness(slot).readable);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn drained_wake_pipe_times_out() {
        let wp = WakePipe::new().unwrap();
        wp.wake();
        wp.drain();
        let mut ps = PollSet::new();
        let slot = ps.register(wp.read_fd(), true, false);
        let t0 = std::time::Instant::now();
        let n = ps.wait(Duration::from_millis(30));
        assert_eq!(n, 0);
        assert!(!ps.readiness(slot).readable);
        assert!(t0.elapsed() >= Duration::from_millis(20), "poll must actually block");
    }
}
