//! Real TCP transport for multi-process deployment (the analogue of the
//! paper's Flask/HTTP stack, with the binary codec instead of JSON),
//! rebuilt around the [`reactor`](super::reactor) event loop
//! (DESIGN.md §13).
//!
//! Frames are `[u32 little-endian length][codec frame]`. Each endpoint
//! runs ONE I/O driver thread: a nonblocking listener, every accepted
//! and dialed socket, and a self-pipe wakeup all sit in a single
//! [`PollSet`]. [`Transport::send`] is a pure enqueue — encode into a
//! pooled buffer, push onto the peer's [`WriteQueue`], nudge the driver
//! — zero syscalls on the caller thread (at most one coalesced wake
//! write). The driver drains queues with vectored writes that gather
//! many header+payload pairs per syscall.
//!
//! Dialing stays on short-lived helper threads (blocking
//! `connect_timeout` with the historical bounded exponential backoff on
//! the [`crate::sim::Clock`] seam) — a worker that binds slightly later
//! than its peers is still bridged, and the driver never blocks in
//! `connect`. Known-down peers fail fast: sends inside the `down_ttl`
//! window are silently dropped (except `Probe`, the fault handler's
//! "is it back up?" signal), exactly the old semantics.
//!
//! The driver also keeps per-peer health books — last-seen time,
//! consecutive failures, an RTT EWMA fed by the existing `Probe`/
//! `BwTest` ack traffic — surfaced through
//! [`Transport::peer_health`](super::Transport::peer_health) and the
//! [`super::latency_ordered`] fan-out helper.
//!
//! Delivery semantics vs. the old blocking transport: `send` no longer
//! implies "written before return", so [`TcpEndpoint::flush`] is the
//! explicit local barrier ("handed to the OS or dropped"), and `Drop`
//! performs a bounded best-effort flush so a worker's final messages
//! still reach the wire before the endpoint dies.

use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::codec;
use super::message::{DeviceId, Message};
use super::reactor::{socket_fd, FrameAssembler, PollSet, WakePipe, WriteQueue, MAX_RETAINED_BUF};
use super::{PeerHealth, Transport};
use crate::sim::clock::{real_clock, SharedClock};

/// Retry/backoff/queue tuning of a [`TcpEndpoint`]. Construct via
/// [`TcpConfig::builder`] (fields are private so knobs can grow without
/// breaking callers); the defaults reproduce the historical hardcoded
/// constants. All backoff waiting runs on the [`crate::sim::Clock`] seam.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TcpConfig {
    connect_attempts: u32,
    connect_backoff: Duration,
    connect_timeout: Duration,
    down_ttl: Duration,
    coalesce_frames: usize,
    flush_on_drop: Duration,
}

impl Default for TcpConfig {
    fn default() -> TcpConfig {
        TcpConfig {
            connect_attempts: 5,
            connect_backoff: Duration::from_millis(10),
            connect_timeout: Duration::from_millis(500),
            down_ttl: Duration::from_secs(1),
            coalesce_frames: 16,
            flush_on_drop: Duration::from_secs(2),
        }
    }
}

impl TcpConfig {
    pub fn builder() -> TcpConfigBuilder {
        TcpConfigBuilder { cfg: TcpConfig::default() }
    }

    /// A builder seeded with this config, for per-flag overrides on top
    /// of a loaded/preset base.
    pub fn to_builder(&self) -> TcpConfigBuilder {
        TcpConfigBuilder { cfg: self.clone() }
    }

    /// A patient schedule for CI/loopback tests: the same doubling
    /// backoff but with more attempts (~2.5 s total), so a worker thread
    /// descheduled on an oversubscribed runner still gets bridged.
    pub fn patient() -> TcpConfig {
        TcpConfig::builder().connect_attempts(9).build()
    }

    /// First-contact dial schedule: up to this many tries with doubling
    /// sleeps starting at [`Self::connect_backoff`] (defaults: 5 tries
    /// sleeping 10+20+40+80 ms ≈ 150 ms, bridging workers that bind a
    /// beat late at cluster start). Once a peer has been reached, later
    /// redials use a single attempt (fast fail, like a dead sim device).
    pub fn connect_attempts(&self) -> u32 {
        self.connect_attempts
    }

    pub fn connect_backoff(&self) -> Duration {
        self.connect_backoff
    }

    /// Per-attempt bound on TCP connect (a SYN-blackholed host must not
    /// stall the dialer for the OS default of minutes).
    pub fn connect_timeout(&self) -> Duration {
        self.connect_timeout
    }

    /// After a failed dial the peer is considered down for this long:
    /// sends fail fast (silent drop) instead of re-dialing per message
    /// while the fault handler converges. `Probe` messages bypass this.
    pub fn down_ttl(&self) -> Duration {
        self.down_ttl
    }

    /// Max frames gathered into one vectored write.
    pub fn coalesce_frames(&self) -> usize {
        self.coalesce_frames
    }

    /// Bound on the best-effort [`TcpEndpoint::flush`] that `Drop`
    /// performs so queued final messages reach the wire.
    pub fn flush_on_drop(&self) -> Duration {
        self.flush_on_drop
    }
}

/// Builder for [`TcpConfig`] — `TcpConfig::builder().connect_attempts(9).build()`.
/// Out-of-range values are clamped to the nearest sane one (at least one
/// connect attempt, at least one frame per write).
#[derive(Debug, Clone)]
pub struct TcpConfigBuilder {
    cfg: TcpConfig,
}

impl TcpConfigBuilder {
    pub fn connect_attempts(mut self, n: u32) -> Self {
        self.cfg.connect_attempts = n.max(1);
        self
    }

    pub fn connect_backoff(mut self, d: Duration) -> Self {
        self.cfg.connect_backoff = d;
        self
    }

    pub fn connect_timeout(mut self, d: Duration) -> Self {
        self.cfg.connect_timeout = d;
        self
    }

    pub fn down_ttl(mut self, d: Duration) -> Self {
        self.cfg.down_ttl = d;
        self
    }

    pub fn coalesce_frames(mut self, n: usize) -> Self {
        self.cfg.coalesce_frames = n.max(1);
        self
    }

    pub fn flush_on_drop(mut self, d: Duration) -> Self {
        self.cfg.flush_on_drop = d;
        self
    }

    pub fn build(self) -> TcpConfig {
        self.cfg
    }
}

/// Encoded-frame buffers recycled between senders and the driver.
const POOL_CAP: usize = 32;

/// Driver tick when nothing is ready (sends interrupt it via the wake
/// pipe, so this only bounds shutdown/redial latency, not send latency).
const POLL_TICK: Duration = Duration::from_millis(200);

/// One peer's outbound connection, queue, and health books.
#[derive(Default)]
struct Peer {
    conn: Option<TcpStream>,
    queue: WriteQueue,
    /// a dial thread for this peer is in flight
    dialing: bool,
    /// reached at least once (first contact gets the full backoff)
    ever_connected: bool,
    /// the last write error already triggered a one-shot redial; a
    /// second consecutive failure drops the queue (the old transport's
    /// two-attempt rewrite semantics)
    redialed: bool,
    /// don't redial before this clock time (fast-fail window)
    down_until: Option<Duration>,
    last_seen: Option<Duration>,
    rtt: Option<Duration>,
    failures: u32,
    /// enqueue time of the newest unanswered `Probe`/`BwTest`, matched
    /// with its ack to feed the RTT estimate
    probe_sent: Option<Duration>,
}

struct State {
    peers: HashMap<DeviceId, Peer>,
    /// frames accepted by `send` but not yet written-to-OS or dropped —
    /// the quantity `flush` waits on
    pending: usize,
}

/// Everything shared between caller threads, dial threads, and the driver.
struct Shared {
    id: DeviceId,
    addrs: Vec<String>,
    cfg: TcpConfig,
    clock: SharedClock,
    state: Mutex<State>,
    /// signaled whenever `pending` drops to zero
    flushed: Condvar,
    wake: WakePipe,
    stop: AtomicBool,
    /// recycled encode buffers (send pops, driver/dial push back)
    pool: Mutex<Vec<Vec<u8>>>,
}

impl Shared {
    fn recycle_all(&self, scratch: &mut Vec<Vec<u8>>) {
        let mut pool = self.pool.lock().unwrap();
        for mut b in scratch.drain(..) {
            if pool.len() < POOL_CAP && b.capacity() <= MAX_RETAINED_BUF {
                b.clear();
                pool.push(b);
            }
        }
    }
}

/// TCP endpoint: `addrs[i]` is the listen address of device `i`.
pub struct TcpEndpoint {
    sh: Arc<Shared>,
    inbox_rx: Receiver<(DeviceId, Message)>,
    driver: Mutex<Option<JoinHandle<()>>>,
}

impl TcpEndpoint {
    /// Bind `addrs[id]` and start the I/O driver. All devices must use
    /// the same `addrs` vector (the worker list of the deployment).
    pub fn bind(id: DeviceId, addrs: Vec<String>) -> Result<TcpEndpoint> {
        TcpEndpoint::bind_with(id, addrs, TcpConfig::default(), real_clock())
    }

    /// [`Self::bind`] with explicit tuning and time source.
    pub fn bind_with(
        id: DeviceId,
        addrs: Vec<String>,
        cfg: TcpConfig,
        clock: SharedClock,
    ) -> Result<TcpEndpoint> {
        let listener =
            TcpListener::bind(&addrs[id]).with_context(|| format!("binding {}", addrs[id]))?;
        TcpEndpoint::with_listener(id, addrs, cfg, clock, listener)
    }

    /// Re-attach a restarted central (or any restarted device) to its
    /// old address: retry the bind over the backoff schedule, riding on
    /// SO_REUSEADDR (which std sets on Unix listeners) so the dead
    /// process's lingering socket doesn't block the restart. Workers'
    /// existing `CentralRestart`/`WorkerState` handshake then completes
    /// over the fresh listener.
    pub fn rebind(
        id: DeviceId,
        addrs: Vec<String>,
        cfg: TcpConfig,
        clock: SharedClock,
    ) -> Result<TcpEndpoint> {
        let attempts = cfg.connect_attempts().max(3);
        let mut delay = cfg.connect_backoff();
        let mut last_err = None;
        for attempt in 0..attempts {
            match TcpListener::bind(&addrs[id]) {
                Ok(l) => return TcpEndpoint::with_listener(id, addrs, cfg, clock, l),
                Err(e) => {
                    last_err = Some(e);
                    if attempt + 1 < attempts {
                        clock.sleep(delay);
                        delay *= 2;
                    }
                }
            }
        }
        Err(last_err.unwrap())
            .with_context(|| format!("rebinding {} ({attempts} attempts)", addrs[id]))
    }

    fn with_listener(
        id: DeviceId,
        addrs: Vec<String>,
        cfg: TcpConfig,
        clock: SharedClock,
        listener: TcpListener,
    ) -> Result<TcpEndpoint> {
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let sh = Arc::new(Shared {
            id,
            addrs,
            cfg,
            clock,
            state: Mutex::new(State { peers: HashMap::new(), pending: 0 }),
            flushed: Condvar::new(),
            wake: WakePipe::new().context("wake pipe")?,
            stop: AtomicBool::new(false),
            pool: Mutex::new(Vec::new()),
        });
        let (tx, rx) = channel();
        let sh2 = Arc::clone(&sh);
        let driver = std::thread::Builder::new()
            .name(format!("tcp-driver-{id}"))
            .spawn(move || driver_loop(&sh2, &listener, &tx))?;
        Ok(TcpEndpoint { sh, inbox_rx: rx, driver: Mutex::new(Some(driver)) })
    }

    /// The enqueue behind [`Transport::send`]: encode into a pooled
    /// buffer (outside any lock), push onto the peer's queue, wake the
    /// driver. Known-down peers drop silently (except `Probe`) — same
    /// timeout-at-the-coordinator semantics as a dead sim device.
    fn enqueue(&self, to: DeviceId, msg: Message) -> Result<()> {
        let sh = &self.sh;
        if sh.stop.load(Ordering::SeqCst) {
            return Ok(()); // after shutdown sends are silently dropped
        }
        let mut buf = sh.pool.lock().unwrap().pop().unwrap_or_default();
        codec::encode_into(&mut buf, sh.id, &msg);
        let now = sh.clock.now();
        let mut dial = None;
        {
            let mut st = sh.state.lock().unwrap();
            let p = st.peers.entry(to).or_default();
            if !matches!(msg, Message::Probe) {
                if let Some(until) = p.down_until {
                    if now < until {
                        drop(st);
                        buf.clear();
                        let mut scratch = vec![buf];
                        sh.recycle_all(&mut scratch);
                        return Ok(());
                    }
                    p.down_until = None;
                }
            }
            if matches!(msg, Message::Probe | Message::BwTest { .. }) {
                p.probe_sent = Some(now);
            }
            p.queue.push(buf);
            st.pending += 1;
            if p.conn.is_none() && !p.dialing {
                p.dialing = true;
                let attempts = if p.ever_connected { 1 } else { sh.cfg.connect_attempts };
                dial = Some((to, attempts));
            }
        }
        if let Some((to, attempts)) = dial {
            spawn_dial(sh, to, attempts);
        }
        sh.wake.wake();
        Ok(())
    }

    /// Block until every accepted send has left this endpoint — written
    /// to the OS or dropped as undeliverable — or `timeout` passes
    /// (then `Err` with the outstanding count). A local barrier, not a
    /// delivery guarantee. The deadline is wall-clock: flushing waits on
    /// real kernel I/O regardless of the configured [`crate::sim::Clock`].
    pub fn flush(&self, timeout: Duration) -> Result<()> {
        self.sh.wake.wake();
        let deadline = Instant::now() + timeout;
        let mut st = self.sh.state.lock().unwrap();
        while st.pending > 0 {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                bail!("flush timed out with {} frame(s) still queued", st.pending);
            }
            let (g, _) = self.sh.flushed.wait_timeout(st, left).unwrap();
            st = g;
        }
        Ok(())
    }

    /// Stop the driver and drop all queues. Idempotent; subsequent
    /// sends are silently dropped, buffered receives still drain.
    pub fn shutdown(&self) {
        self.sh.stop.store(true, Ordering::SeqCst);
        self.sh.wake.wake();
        if let Some(h) = self.driver.lock().unwrap().take() {
            h.join().ok();
        }
    }

    /// This endpoint's health books about `peer`
    /// ([`PeerHealth::default`] for a peer never contacted).
    pub fn peer_health(&self, peer: DeviceId) -> PeerHealth {
        let st = self.sh.state.lock().unwrap();
        match st.peers.get(&peer) {
            Some(p) => PeerHealth {
                last_seen: p.last_seen,
                rtt: p.rtt,
                consecutive_failures: p.failures,
            },
            None => PeerHealth::default(),
        }
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        // preserve the old blocking-send guarantee at the boundary: a
        // worker's final messages (last Backward, Shutdown acks) get a
        // bounded window to reach the wire before the driver dies
        let _ = self.flush(self.sh.cfg.flush_on_drop);
        self.shutdown();
    }
}

impl Transport for TcpEndpoint {
    fn my_id(&self) -> DeviceId {
        self.sh.id
    }

    fn send(&self, to: DeviceId, msg: Message) -> Result<()> {
        self.enqueue(to, msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<(DeviceId, Message)> {
        // Disconnected (driver exited after `shutdown`) reads as None
        // once buffered messages drain — same surface as a quiet net
        self.inbox_rx.recv_timeout(timeout).ok()
    }

    fn n_devices(&self) -> usize {
        self.sh.addrs.len()
    }

    fn peer_health(&self, peer: DeviceId) -> PeerHealth {
        TcpEndpoint::peer_health(self, peer)
    }

    fn flush(&self, timeout: Duration) -> Result<()> {
        TcpEndpoint::flush(self, timeout)
    }

    fn shutdown(&self) {
        TcpEndpoint::shutdown(self)
    }
}

// ---------- dialing (blocking, on short-lived helper threads) ----------

fn connect_once(sh: &Shared, to: DeviceId) -> Result<TcpStream> {
    let addr = sh.addrs[to]
        .to_socket_addrs()
        .with_context(|| format!("resolving {}", sh.addrs[to]))?
        .next()
        .with_context(|| format!("no address for {}", sh.addrs[to]))?;
    let stream = TcpStream::connect_timeout(&addr, sh.cfg.connect_timeout)?;
    stream.set_nodelay(true).ok();
    stream.set_nonblocking(true).context("nonblocking peer socket")?;
    Ok(stream)
}

/// Connect with bounded exponential backoff. A peer that binds a beat
/// late (worker startup order is unordered) is retried; a peer that
/// stays unreachable returns Err after the schedule is exhausted.
fn connect_with_backoff(sh: &Shared, to: DeviceId, attempts: u32) -> Result<TcpStream> {
    let mut delay = sh.cfg.connect_backoff;
    let mut last_err = None;
    for attempt in 0..attempts {
        match connect_once(sh, to) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                last_err = Some(e);
                if attempt + 1 < attempts {
                    sh.clock.sleep(delay);
                    delay *= 2;
                }
            }
        }
    }
    Err(last_err.unwrap()).with_context(|| {
        format!("connecting to device {to} at {} ({attempts} attempts)", sh.addrs[to])
    })
}

/// Dial `to` off-thread; on success hand the nonblocking stream to the
/// driver, on failure drop the peer's queue and open its fast-fail
/// window. Either way the driver is woken to react.
fn spawn_dial(sh: &Arc<Shared>, to: DeviceId, attempts: u32) {
    let sh = Arc::clone(sh);
    std::thread::Builder::new()
        .name(format!("tcp-dial-{}-{to}", sh.id))
        .spawn(move || {
            let result = connect_with_backoff(&sh, to, attempts);
            let mut scratch: Vec<Vec<u8>> = Vec::new();
            {
                let mut st = sh.state.lock().unwrap();
                let p = st.peers.entry(to).or_default();
                p.dialing = false;
                match result {
                    Ok(stream) => {
                        p.conn = Some(stream);
                        p.ever_connected = true;
                        p.redialed = false;
                        p.down_until = None;
                        p.failures = 0;
                    }
                    Err(e) => {
                        p.failures += 1;
                        p.down_until = Some(sh.clock.now() + sh.cfg.down_ttl);
                        let dropped = p.queue.clear_into(&mut scratch);
                        if dropped > 0 {
                            st.pending -= dropped;
                            crate::log_warn!(
                                "tcp dial: dropping {dropped} frame(s) to device {to}: {e:#}"
                            );
                            if st.pending == 0 {
                                sh.flushed.notify_all();
                            }
                        }
                    }
                }
            }
            sh.recycle_all(&mut scratch);
            sh.wake.wake();
        })
        .ok();
}

// ---------- the I/O driver ----------

/// One accepted (inbound) connection and its frame reassembly state.
struct InConn {
    stream: TcpStream,
    asm: FrameAssembler,
}

fn accept_all(listener: &TcpListener, inbound: &mut Vec<InConn>) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nodelay(true).ok();
                if stream.set_nonblocking(true).is_ok() {
                    inbound.push(InConn { stream, asm: FrameAssembler::new() });
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

/// Drain one inbound connection: bulk-read, parse frames, decode, push
/// to the inbox, and record `(from, was_probe_ack)` health events for
/// batched application. Returns false when the connection should close.
fn service_inbound(
    c: &mut InConn,
    inbox: &Sender<(DeviceId, Message)>,
    events: &mut Vec<(DeviceId, bool)>,
) -> bool {
    let progress = match c.asm.read_from(&mut c.stream) {
        Ok(p) => p,
        Err(e) => {
            crate::log_warn!("tcp reader: {e:#}; closing connection");
            return false;
        }
    };
    loop {
        match c.asm.next_frame() {
            Ok(Some(frame)) => match codec::decode(frame) {
                Ok((from, msg)) => {
                    let is_ack = matches!(msg, Message::ProbeAck { .. } | Message::BwAck { .. });
                    events.push((from, is_ack));
                    if inbox.send((from, msg)).is_err() {
                        return false; // endpoint receiver dropped
                    }
                }
                Err(e) => {
                    crate::log_warn!("tcp reader: undecodable frame ({e}); closing connection");
                    return false;
                }
            },
            Ok(None) => break,
            Err(e) => {
                crate::log_warn!("tcp reader: {e:#}; closing connection");
                return false;
            }
        }
    }
    c.asm.compact();
    // EOF after parsing: the peer closed; a partial trailing frame can
    // never complete, so the connection goes either way
    !progress.eof
}

/// The per-endpoint event loop: one poll set over the wake pipe, the
/// listener, every inbound connection, and every outbound connection
/// (read interest for stale detection, write interest while its queue
/// is nonempty).
fn driver_loop(sh: &Arc<Shared>, listener: &TcpListener, inbox: &Sender<(DeviceId, Message)>) {
    let mut poll = PollSet::new();
    let mut inbound: Vec<InConn> = Vec::new();
    let mut events: Vec<(DeviceId, bool)> = Vec::new();
    let mut scratch: Vec<Vec<u8>> = Vec::new();
    while !sh.stop.load(Ordering::SeqCst) {
        poll.clear();
        let wake_slot = poll.register(sh.wake.read_fd(), true, false);
        let listen_slot = poll.register(socket_fd(listener), true, false);
        let in_slots: Vec<usize> =
            inbound.iter().map(|c| poll.register(socket_fd(&c.stream), true, false)).collect();
        let out_slots: Vec<(DeviceId, usize)> = {
            let st = sh.state.lock().unwrap();
            st.peers
                .iter()
                .filter_map(|(&d, p)| {
                    let c = p.conn.as_ref()?;
                    Some((d, poll.register(socket_fd(c), true, !p.queue.is_empty())))
                })
                .collect()
        };
        poll.wait(POLL_TICK);
        if sh.stop.load(Ordering::SeqCst) {
            break;
        }
        if poll.readiness(wake_slot).readable {
            sh.wake.drain();
        }
        if poll.readiness(listen_slot).readable {
            accept_all(listener, &mut inbound);
        }

        // inbound traffic → inbox + health events
        let mut keep = vec![true; inbound.len()];
        for (i, c) in inbound.iter_mut().enumerate() {
            let r = poll.readiness(in_slots[i]);
            if r.readable || r.error {
                keep[i] = service_inbound(c, inbox, &mut events);
            }
        }
        if keep.contains(&false) {
            let mut it = keep.into_iter();
            inbound.retain(|_| it.next().unwrap());
        }
        if !events.is_empty() {
            apply_health_events(sh, &mut events);
        }

        // outbound: stale detection, then optimistic coalesced writes
        service_outbound(sh, &poll, &out_slots, &mut scratch);
        sh.recycle_all(&mut scratch);
    }

    // drain on exit: everything still queued is dropped, flush waiters
    // are released, and dropping `inbox` disconnects `recv_timeout`
    let mut st = sh.state.lock().unwrap();
    for p in st.peers.values_mut() {
        p.queue.clear_into(&mut scratch);
        p.conn = None;
    }
    st.pending = 0;
    sh.flushed.notify_all();
}

/// Batched inbound health bookkeeping: any frame from a peer proves it
/// alive (refresh last-seen, zero failures, clear the down window); a
/// `ProbeAck`/`BwAck` additionally closes the RTT measurement opened
/// when the probe was enqueued (EWMA, 3:1 old:new).
fn apply_health_events(sh: &Shared, events: &mut Vec<(DeviceId, bool)>) {
    let now = sh.clock.now();
    let mut st = sh.state.lock().unwrap();
    for (from, is_ack) in events.drain(..) {
        let p = st.peers.entry(from).or_default();
        p.last_seen = Some(now);
        p.failures = 0;
        p.down_until = None;
        if is_ack {
            if let Some(t0) = p.probe_sent.take() {
                let sample = now.saturating_sub(t0);
                p.rtt = Some(match p.rtt {
                    Some(old) => (old * 3 + sample) / 4,
                    None => sample,
                });
            }
        }
    }
}

/// One outbound pass under the state lock: drop connections the peer
/// closed (our links are strictly one-way, so readable/EOF on an
/// outbound socket means FIN or RST), then drain every nonempty queue
/// with vectored writes. A write error redials once; a second
/// consecutive failure drops the queue and opens the fast-fail window
/// (the old transport's two-attempt semantics).
fn service_outbound(
    sh: &Arc<Shared>,
    poll: &PollSet,
    out_slots: &[(DeviceId, usize)],
    scratch: &mut Vec<Vec<u8>>,
) {
    let now = sh.clock.now();
    let mut dials: Vec<(DeviceId, u32)> = Vec::new();
    let mut done = 0usize;
    let mut st = sh.state.lock().unwrap();

    for &(d, slot) in out_slots {
        let r = poll.readiness(slot);
        if !(r.readable || r.error) {
            continue;
        }
        let Some(p) = st.peers.get_mut(&d) else { continue };
        let stale = match &mut p.conn {
            Some(_) if r.error => true, // POLLERR/POLLHUP: no read needed
            Some(c) => {
                let mut probe = [0u8; 256];
                match c.read(&mut probe) {
                    // EOF, unexpected data, or a real error all mean the
                    // peer is gone (it restarted or reset); WouldBlock is
                    // the only healthy answer on a one-way link
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => false,
                    _ => true,
                }
            }
            None => false,
        };
        if stale {
            p.conn = None;
            p.queue.rewind();
        }
    }

    for (&d, p) in st.peers.iter_mut() {
        if p.queue.is_empty() {
            continue;
        }
        match &mut p.conn {
            Some(c) => match p.queue.write_to(c, sh.cfg.coalesce_frames, scratch) {
                Ok(pr) => {
                    done += pr.completed;
                    if pr.completed > 0 {
                        p.redialed = false;
                    }
                }
                Err(e) => {
                    p.conn = None;
                    p.queue.rewind();
                    if p.redialed {
                        p.failures += 1;
                        p.down_until = Some(now + sh.cfg.down_ttl);
                        let n = p.queue.clear_into(scratch);
                        done += n;
                        p.redialed = false;
                        crate::log_warn!(
                            "tcp send: dropping {n} frame(s) to device {d} after rewrite failed: {e:#}"
                        );
                    } else if !p.dialing {
                        p.redialed = true;
                        p.dialing = true;
                        dials.push((d, 1));
                    }
                }
            },
            None => {
                let held_down = matches!(p.down_until, Some(u) if now < u);
                if !p.dialing && !held_down {
                    p.dialing = true;
                    let attempts = if p.ever_connected { 1 } else { sh.cfg.connect_attempts };
                    dials.push((d, attempts));
                }
            }
        }
    }

    if done > 0 {
        st.pending -= done;
        if st.pending == 0 {
            sh.flushed.notify_all();
        }
    }
    drop(st);
    for (d, attempts) in dials {
        spawn_dial(sh, d, attempts);
    }
}

/// Helper for tests/benches/examples: build `n` endpoints on loopback ports.
pub fn loopback_cluster(n: usize, base_port: u16) -> Result<Vec<TcpEndpoint>> {
    let addrs: Vec<String> =
        (0..n).map(|i| format!("127.0.0.1:{}", base_port + i as u16)).collect();
    (0..n).map(|i| TcpEndpoint::bind(i, addrs.clone())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_roundtrip_two_devices() {
        let eps = loopback_cluster(2, 46100).unwrap();
        eps[0]
            .send(1, Message::Labels { batch: 7, is_eval: true, data: vec![1, 2, 3] })
            .unwrap();
        let (from, msg) = eps[1].recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(from, 0);
        assert_eq!(msg, Message::Labels { batch: 7, is_eval: true, data: vec![1, 2, 3] });
    }

    #[test]
    fn tcp_large_payload() {
        let eps = loopback_cluster(2, 46110).unwrap();
        let data: crate::net::TensorBuf = vec![1.5f32; 200_000].into();
        eps[1].send(0, Message::Weights { blocks: vec![(3, vec![data.clone().into()])] }).unwrap();
        match eps[0].recv_timeout(Duration::from_secs(5)) {
            Some((1, Message::Weights { blocks })) => {
                assert_eq!(blocks[0].0, 3);
                assert_eq!(blocks[0].1[0].as_f32().unwrap(), &data);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn send_to_unreachable_peer_is_silent() {
        // device 1 never binds; send must not error (timeout semantics),
        // and flush must complete once the dial schedule gives up
        let addrs = vec!["127.0.0.1:46120".into(), "127.0.0.1:46121".into()];
        let ep = TcpEndpoint::bind(0, addrs).unwrap();
        ep.send(1, Message::Probe).unwrap();
        ep.flush(Duration::from_secs(10)).unwrap();
        assert!(ep.peer_health(1).consecutive_failures >= 1);
    }

    #[test]
    fn late_binding_peer_is_reached_by_backoff() {
        // device 1 binds ~40ms after device 0 starts sending: the
        // reconnect loop must bridge the gap instead of dropping. The
        // patient schedule keeps this stable on slow CI runners.
        let addrs = vec!["127.0.0.1:46130".to_string(), "127.0.0.1:46131".to_string()];
        let a0 = addrs.clone();
        let ep0 =
            TcpEndpoint::bind_with(0, a0, TcpConfig::patient(), crate::sim::real_clock()).unwrap();
        let addrs1 = addrs.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            TcpEndpoint::bind(1, addrs1).unwrap()
        });
        ep0.send(1, Message::FetchDone { id: 0 }).unwrap();
        let ep1 = h.join().unwrap();
        match ep1.recv_timeout(Duration::from_secs(5)) {
            Some((0, Message::FetchDone { id: 0 })) => {}
            other => panic!("late-bound peer missed the message: {other:?}"),
        }
    }

    #[test]
    fn config_defaults_match_historical_constants() {
        let c = TcpConfig::default();
        assert_eq!(c.connect_attempts(), 5);
        assert_eq!(c.connect_backoff(), Duration::from_millis(10));
        assert_eq!(c.connect_timeout(), Duration::from_millis(500));
        assert_eq!(c.down_ttl(), Duration::from_secs(1));
        assert_eq!(c.coalesce_frames(), 16);
        assert!(TcpConfig::patient().connect_attempts() > c.connect_attempts());
    }

    #[test]
    fn builder_overrides_clamps_and_roundtrips() {
        let c = TcpConfig::builder()
            .connect_attempts(0) // clamped to 1
            .connect_backoff(Duration::from_millis(1))
            .connect_timeout(Duration::from_millis(99))
            .down_ttl(Duration::from_millis(7))
            .coalesce_frames(0) // clamped to 1
            .flush_on_drop(Duration::from_millis(3))
            .build();
        assert_eq!(c.connect_attempts(), 1);
        assert_eq!(c.coalesce_frames(), 1);
        assert_eq!(c.connect_backoff(), Duration::from_millis(1));
        assert_eq!(c.connect_timeout(), Duration::from_millis(99));
        assert_eq!(c.down_ttl(), Duration::from_millis(7));
        assert_eq!(c.flush_on_drop(), Duration::from_millis(3));
        assert_eq!(c.to_builder().build(), c, "to_builder round-trips every knob");
        assert_eq!(TcpConfig::patient().connect_attempts(), 9);
    }

    #[test]
    fn down_ttl_is_configurable_and_expires() {
        // a tiny TTL re-dials almost immediately instead of holding the
        // peer down for a second (the old hardcoded window)
        let cfg = TcpConfig::builder()
            .connect_attempts(1)
            .down_ttl(Duration::from_millis(1))
            .build();
        let addrs = vec!["127.0.0.1:46140".to_string(), "127.0.0.1:46141".to_string()];
        let ep0 = TcpEndpoint::bind_with(0, addrs.clone(), cfg, crate::sim::real_clock()).unwrap();
        ep0.send(1, Message::FetchDone { id: 0 }).unwrap(); // peer down
        ep0.flush(Duration::from_secs(10)).unwrap(); // dial failed, frame dropped
        assert!(ep0.peer_health(1).consecutive_failures >= 1);
        std::thread::sleep(Duration::from_millis(5)); // TTL expired
        let ep1 = TcpEndpoint::bind(1, addrs).unwrap();
        ep0.send(1, Message::FetchDone { id: 7 }).unwrap(); // re-dials now
        match ep1.recv_timeout(Duration::from_secs(2)) {
            Some((0, Message::FetchDone { id: 7 })) => {}
            other => panic!("expired down-cache still blocking sends: {other:?}"),
        }
    }

    #[test]
    fn flush_times_out_while_a_dial_backs_off() {
        let cfg = TcpConfig::builder()
            .connect_attempts(4)
            .connect_backoff(Duration::from_millis(200))
            .flush_on_drop(Duration::ZERO) // keep Drop fast in this test
            .build();
        let addrs = vec!["127.0.0.1:46150".into(), "127.0.0.1:46151".into()];
        let ep = TcpEndpoint::bind_with(0, addrs, cfg, crate::sim::real_clock()).unwrap();
        ep.send(1, Message::FetchDone { id: 0 }).unwrap();
        let err = ep.flush(Duration::from_millis(50));
        assert!(err.is_err(), "the frame is still queued behind a backing-off dial");
    }

    #[test]
    fn peer_health_is_default_for_unknown_peers() {
        let addrs = vec!["127.0.0.1:46160".into(), "127.0.0.1:46161".into()];
        let ep = TcpEndpoint::bind(0, addrs).unwrap();
        assert_eq!(ep.peer_health(1), PeerHealth::default());
    }

    #[test]
    fn shutdown_is_idempotent_and_silences_sends() {
        let addrs = vec!["127.0.0.1:46170".into(), "127.0.0.1:46171".into()];
        let ep = TcpEndpoint::bind(0, addrs).unwrap();
        ep.shutdown();
        ep.shutdown();
        ep.send(1, Message::Probe).unwrap(); // silently dropped
        assert!(ep.recv_timeout(Duration::from_millis(10)).is_none());
    }
}
