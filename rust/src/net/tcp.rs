//! Real TCP transport for multi-process deployment (the analogue of the
//! paper's Flask/HTTP stack, with the binary codec instead of JSON).
//!
//! Frames are `[u32 little-endian length][codec frame]`. Each device runs
//! one listener; outgoing connections are opened lazily and cached. A
//! reader thread per accepted connection pushes decoded messages into the
//! endpoint's inbox, so `recv_timeout` has identical semantics to the sim
//! transport and the whole pipeline runs unchanged over real sockets
//! (exercised by `rust/tests/tcp_transport.rs`).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::codec;
use super::message::{DeviceId, Message};
use super::Transport;

/// TCP endpoint: `addrs[i]` is the listen address of device `i`.
pub struct TcpEndpoint {
    id: DeviceId,
    addrs: Vec<String>,
    conns: Mutex<HashMap<DeviceId, TcpStream>>,
    inbox_rx: Receiver<(DeviceId, Message)>,
    _inbox_tx: Sender<(DeviceId, Message)>, // keeps channel alive
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    anyhow::ensure!(len < 1 << 30, "frame too large: {len}");
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

fn write_frame(stream: &mut TcpStream, frame: &[u8]) -> Result<()> {
    stream.write_all(&(frame.len() as u32).to_le_bytes())?;
    stream.write_all(frame)?;
    stream.flush()?;
    Ok(())
}

impl TcpEndpoint {
    /// Bind `addrs[id]` and start the acceptor. All devices must use the
    /// same `addrs` vector (the worker list of the deployment).
    pub fn bind(id: DeviceId, addrs: Vec<String>) -> Result<TcpEndpoint> {
        let listener = TcpListener::bind(&addrs[id])
            .with_context(|| format!("binding {}", addrs[id]))?;
        let (tx, rx) = channel();
        let tx_acceptor = tx.clone();
        std::thread::Builder::new()
            .name(format!("tcp-accept-{id}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(mut stream) = stream else { continue };
                    let tx = tx_acceptor.clone();
                    std::thread::Builder::new()
                        .name("tcp-read".into())
                        .spawn(move || {
                            loop {
                                match read_frame(&mut stream) {
                                    Ok(frame) => match codec::decode(&frame) {
                                        Ok((from, msg)) => {
                                            if tx.send((from, msg)).is_err() {
                                                break;
                                            }
                                        }
                                        Err(_) => break,
                                    },
                                    Err(_) => break, // peer closed
                                }
                            }
                        })
                        .ok();
                }
            })?;
        Ok(TcpEndpoint {
            id,
            addrs,
            conns: Mutex::new(HashMap::new()),
            inbox_rx: rx,
            _inbox_tx: tx,
        })
    }

    fn connect(&self, to: DeviceId) -> Result<TcpStream> {
        let stream = TcpStream::connect(&self.addrs[to])
            .with_context(|| format!("connecting to {}", self.addrs[to]))?;
        stream.set_nodelay(true).ok();
        Ok(stream)
    }
}

impl Transport for TcpEndpoint {
    fn my_id(&self) -> DeviceId {
        self.id
    }

    fn send(&self, to: DeviceId, msg: Message) -> Result<()> {
        let frame = codec::encode(self.id, &msg);
        let mut conns = self.conns.lock().unwrap();
        // lazily (re)connect; one retry on a stale cached connection
        for attempt in 0..2 {
            if !conns.contains_key(&to) {
                match self.connect(to) {
                    Ok(s) => {
                        conns.insert(to, s);
                    }
                    Err(e) => {
                        if attempt == 1 {
                            // unreachable peer: drop silently (same
                            // semantics as the sim transport / a dead
                            // Flask worker — the failure surfaces as a
                            // timeout at the coordinator).
                            let _ = e;
                            return Ok(());
                        }
                        continue;
                    }
                }
            }
            let stream = conns.get_mut(&to).unwrap();
            match write_frame(stream, &frame) {
                Ok(()) => return Ok(()),
                Err(_) => {
                    conns.remove(&to); // stale; retry once with a new conn
                }
            }
        }
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<(DeviceId, Message)> {
        self.inbox_rx.recv_timeout(timeout).ok()
    }

    fn n_devices(&self) -> usize {
        self.addrs.len()
    }
}

/// Helper for tests/examples: build `n` endpoints on loopback ports.
pub fn loopback_cluster(n: usize, base_port: u16) -> Result<Vec<Arc<TcpEndpoint>>> {
    let addrs: Vec<String> = (0..n)
        .map(|i| format!("127.0.0.1:{}", base_port + i as u16))
        .collect();
    (0..n)
        .map(|i| Ok(Arc::new(TcpEndpoint::bind(i, addrs.clone())?)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_roundtrip_two_devices() {
        let eps = loopback_cluster(2, 46100).unwrap();
        eps[0]
            .send(
                1,
                Message::Labels { batch: 7, is_eval: true, data: vec![1, 2, 3] },
            )
            .unwrap();
        let (from, msg) = eps[1].recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(from, 0);
        assert_eq!(
            msg,
            Message::Labels { batch: 7, is_eval: true, data: vec![1, 2, 3] }
        );
    }

    #[test]
    fn tcp_large_payload() {
        let eps = loopback_cluster(2, 46110).unwrap();
        let data = vec![1.5f32; 200_000];
        eps[1]
            .send(0, Message::Weights { blocks: vec![(3, vec![data.clone()])] })
            .unwrap();
        match eps[0].recv_timeout(Duration::from_secs(5)) {
            Some((1, Message::Weights { blocks })) => {
                assert_eq!(blocks[0].0, 3);
                assert_eq!(blocks[0].1[0], data);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn send_to_unreachable_peer_is_silent() {
        // device 1 never binds; send must not error (timeout semantics)
        let addrs = vec!["127.0.0.1:46120".into(), "127.0.0.1:46121".into()];
        let ep = TcpEndpoint::bind(0, addrs).unwrap();
        ep.send(1, Message::Probe).unwrap();
    }
}
