//! Real TCP transport for multi-process deployment (the analogue of the
//! paper's Flask/HTTP stack, with the binary codec instead of JSON).
//!
//! Frames are `[u32 little-endian length][codec frame]`. Each device runs
//! one listener; outgoing connections are opened lazily, cached, and
//! re-established with a bounded exponential backoff — a worker that
//! binds slightly later than its peers (normal at cluster start) no
//! longer kills the run. A reader thread per accepted connection pushes
//! decoded messages into the endpoint's inbox, so `recv_timeout` has
//! identical semantics to the sim transport and the whole pipeline runs
//! unchanged over real sockets.
//!
//! Buffer discipline: each sender thread serializes outgoing messages
//! into one thread-local reusable frame buffer (outside the connection
//! lock, so concurrent senders encode in parallel) and each reader
//! thread reads frames into one reusable buffer — steady-state traffic
//! performs no per-message allocations beyond the decoded tensors
//! themselves.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::codec;
use super::message::{DeviceId, Message};
use super::Transport;
use crate::sim::clock::{real_clock, SharedClock};

/// Retry/backoff tuning of a [`TcpEndpoint`]. The defaults reproduce the
/// historical hardcoded constants; tests on slow runners (and deployments
/// with slower cluster start) widen them instead of racing fixed sleeps.
/// All waiting runs on the [`crate::sim::Clock`] seam.
#[derive(Debug, Clone)]
pub struct TcpConfig {
    /// First-contact reconnect schedule: up to `connect_attempts` tries
    /// with doubling sleeps starting at `connect_backoff` (defaults:
    /// 5 tries sleeping 10+20+40+80 ms ≈ 150 ms of backoff, bridging
    /// workers that bind a beat late at cluster start). Once a peer has
    /// been reached, later reconnects use a single attempt (fast fail,
    /// like a dead sim device).
    pub connect_attempts: u32,
    pub connect_backoff: Duration,
    /// Per-attempt bound on TCP connect (a SYN-blackholed host must not
    /// stall the sender for the OS default of minutes).
    pub connect_timeout: Duration,
    /// After a connect failure the peer is considered down for this
    /// long: sends fail fast (silent drop) instead of re-dialing per
    /// message while the fault handler converges. `Probe` messages
    /// bypass this — they are exactly the "is it back up?" signal.
    pub down_ttl: Duration,
}

impl Default for TcpConfig {
    fn default() -> TcpConfig {
        TcpConfig {
            connect_attempts: 5,
            connect_backoff: Duration::from_millis(10),
            connect_timeout: Duration::from_millis(500),
            down_ttl: Duration::from_secs(1),
        }
    }
}

impl TcpConfig {
    /// A patient schedule for CI/loopback tests: the same doubling
    /// backoff but with more attempts (~2.5 s total), so a worker thread
    /// descheduled on an oversubscribed runner still gets bridged.
    pub fn patient() -> TcpConfig {
        TcpConfig { connect_attempts: 9, ..TcpConfig::default() }
    }
}

/// Hard cap on a frame's size; larger reads indicate a corrupt stream.
const MAX_FRAME: usize = 1 << 30;

/// Reusable frame buffers shrink back to this capacity after an
/// oversized frame, so one multi-MB weight push doesn't pin that much
/// memory per thread forever (these are memory-capped edge devices).
const MAX_RETAINED_BUF: usize = 1 << 20;

/// TCP endpoint: `addrs[i]` is the listen address of device `i`.
pub struct TcpEndpoint {
    id: DeviceId,
    addrs: Vec<String>,
    cfg: TcpConfig,
    clock: SharedClock,
    io: Mutex<IoState>,
    inbox_rx: Receiver<(DeviceId, Message)>,
    _inbox_tx: Sender<(DeviceId, Message)>, // keeps channel alive
}

/// Outgoing side: cached connections + peer liveness bookkeeping.
struct IoState {
    conns: HashMap<DeviceId, TcpStream>,
    /// peers reached at least once (first contact gets the full backoff)
    ever_connected: HashSet<DeviceId>,
    /// peer -> don't redial before this clock time
    down_until: HashMap<DeviceId, Duration>,
}

fn peer_of(stream: &TcpStream) -> String {
    stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".into())
}

/// Read one frame into `buf` (reused across frames). Returns Ok(false) on
/// a clean peer close before a frame starts.
fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<bool> {
    let mut len4 = [0u8; 4];
    match stream.read_exact(&mut len4) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(false),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len4) as usize;
    anyhow::ensure!(
        len < MAX_FRAME,
        "frame too large from peer {}: {len} bytes (cap {MAX_FRAME}) — corrupt stream?",
        peer_of(stream)
    );
    buf.clear();
    if buf.capacity() > MAX_RETAINED_BUF && len < MAX_RETAINED_BUF {
        buf.shrink_to(MAX_RETAINED_BUF);
    }
    // append via Take: reuses capacity without zero-filling first
    let n = (&mut *stream)
        .take(len as u64)
        .read_to_end(buf)
        .with_context(|| format!("reading a {len}-byte frame"))?;
    anyhow::ensure!(n == len, "peer {} closed mid-frame ({n}/{len} bytes)", peer_of(stream));
    Ok(true)
}

fn write_frame(stream: &mut TcpStream, frame: &[u8]) -> Result<()> {
    stream.write_all(&(frame.len() as u32).to_le_bytes())?;
    stream.write_all(frame)?;
    stream.flush()?;
    Ok(())
}

impl TcpEndpoint {
    /// Bind `addrs[id]` and start the acceptor. All devices must use the
    /// same `addrs` vector (the worker list of the deployment).
    pub fn bind(id: DeviceId, addrs: Vec<String>) -> Result<TcpEndpoint> {
        TcpEndpoint::bind_with(id, addrs, TcpConfig::default(), real_clock())
    }

    /// [`Self::bind`] with explicit retry tuning and time source.
    pub fn bind_with(
        id: DeviceId,
        addrs: Vec<String>,
        cfg: TcpConfig,
        clock: SharedClock,
    ) -> Result<TcpEndpoint> {
        let listener = TcpListener::bind(&addrs[id])
            .with_context(|| format!("binding {}", addrs[id]))?;
        let (tx, rx) = channel();
        let tx_acceptor = tx.clone();
        std::thread::Builder::new()
            .name(format!("tcp-accept-{id}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(mut stream) = stream else { continue };
                    let tx = tx_acceptor.clone();
                    std::thread::Builder::new()
                        .name("tcp-read".into())
                        .spawn(move || {
                            let mut buf: Vec<u8> = Vec::new();
                            loop {
                                match read_frame(&mut stream, &mut buf) {
                                    Ok(true) => match codec::decode(&buf) {
                                        Ok((from, msg)) => {
                                            if tx.send((from, msg)).is_err() {
                                                break; // endpoint dropped
                                            }
                                        }
                                        Err(e) => {
                                            crate::log_warn!(
                                                "tcp reader: undecodable frame ({e}); \
                                                 closing connection"
                                            );
                                            break;
                                        }
                                    },
                                    Ok(false) => break, // peer closed cleanly
                                    Err(e) => {
                                        crate::log_warn!("tcp reader: {e:#}; closing connection");
                                        break;
                                    }
                                }
                            }
                        })
                        .ok();
                }
            })?;
        Ok(TcpEndpoint {
            id,
            addrs,
            cfg,
            clock,
            io: Mutex::new(IoState {
                conns: HashMap::new(),
                ever_connected: HashSet::new(),
                down_until: HashMap::new(),
            }),
            inbox_rx: rx,
            _inbox_tx: tx,
        })
    }

    /// One bounded connect attempt.
    fn connect_once(&self, to: DeviceId) -> Result<TcpStream> {
        let addr = self.addrs[to]
            .to_socket_addrs()
            .with_context(|| format!("resolving {}", self.addrs[to]))?
            .next()
            .with_context(|| format!("no address for {}", self.addrs[to]))?;
        let stream = TcpStream::connect_timeout(&addr, self.cfg.connect_timeout)?;
        stream.set_nodelay(true).ok();
        Ok(stream)
    }

    /// Connect with bounded exponential backoff. A peer that binds a beat
    /// late (worker startup order is unordered) is retried; a peer that
    /// stays unreachable returns Err after the schedule is exhausted.
    fn connect_with_backoff(&self, to: DeviceId, attempts: u32) -> Result<TcpStream> {
        let mut delay = self.cfg.connect_backoff;
        let mut last_err = None;
        for attempt in 0..attempts {
            match self.connect_once(to) {
                Ok(stream) => return Ok(stream),
                Err(e) => {
                    last_err = Some(e);
                    if attempt + 1 < attempts {
                        self.clock.sleep(delay);
                        delay *= 2;
                    }
                }
            }
        }
        Err(last_err.unwrap()).with_context(|| {
            format!("connecting to device {to} at {} ({attempts} attempts)", self.addrs[to])
        })
    }

    /// Ship one encoded frame: lazily (re)connect, write, one rewrite
    /// attempt on a stale cached connection (the peer may have restarted
    /// between sends). Unreachable peers are dropped silently — same
    /// semantics as the sim transport / a dead Flask worker; the failure
    /// surfaces as a timeout at the coordinator.
    fn send_frame(&self, to: DeviceId, frame: &[u8], msg: &Message) -> Result<()> {
        let mut io = self.io.lock().unwrap();
        let io = &mut *io;
        // fail fast to a known-down peer — except probes, which are the
        // fault handler's one-shot "is it back up?" signal and must
        // always attempt a real dial
        if !matches!(msg, Message::Probe) {
            if let Some(until) = io.down_until.get(&to) {
                if self.clock.now() < *until {
                    return Ok(());
                }
                io.down_until.remove(&to);
            }
        }
        for attempt in 0..2 {
            if !io.conns.contains_key(&to) {
                let attempts = if io.ever_connected.contains(&to) {
                    1
                } else {
                    self.cfg.connect_attempts
                };
                match self.connect_with_backoff(to, attempts) {
                    Ok(s) => {
                        io.ever_connected.insert(to);
                        io.down_until.remove(&to);
                        io.conns.insert(to, s);
                    }
                    Err(e) => {
                        io.down_until.insert(to, self.clock.now() + self.cfg.down_ttl);
                        crate::log_warn!("tcp send: dropping {} to device {to}: {e:#}", msg.tag());
                        return Ok(());
                    }
                }
            }
            let stream = io.conns.get_mut(&to).unwrap();
            match write_frame(stream, frame) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    io.conns.remove(&to); // stale; retry once with a new conn
                    if attempt == 1 {
                        crate::log_warn!(
                            "tcp send: dropping {} to device {to} after rewrite failed: {e:#}",
                            msg.tag()
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

impl Transport for TcpEndpoint {
    fn my_id(&self) -> DeviceId {
        self.id
    }

    fn send(&self, to: DeviceId, msg: Message) -> Result<()> {
        thread_local! {
            /// Per-sender-thread reusable frame buffer; encoding happens
            /// OUTSIDE the connection lock so concurrent senders (worker
            /// loop + replication pushes) serialize frames in parallel.
            static WBUF: RefCell<Vec<u8>> = RefCell::new(Vec::new());
        }
        WBUF.with(|wbuf| {
            let mut wbuf = wbuf.borrow_mut();
            codec::encode_into(&mut wbuf, self.id, &msg);
            let result = self.send_frame(to, &wbuf, &msg);
            if wbuf.capacity() > MAX_RETAINED_BUF && wbuf.len() < MAX_RETAINED_BUF {
                wbuf.shrink_to(MAX_RETAINED_BUF);
            }
            result
        })
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<(DeviceId, Message)> {
        self.inbox_rx.recv_timeout(timeout).ok()
    }

    fn n_devices(&self) -> usize {
        self.addrs.len()
    }
}

/// Helper for tests/examples: build `n` endpoints on loopback ports.
pub fn loopback_cluster(n: usize, base_port: u16) -> Result<Vec<Arc<TcpEndpoint>>> {
    let addrs: Vec<String> = (0..n)
        .map(|i| format!("127.0.0.1:{}", base_port + i as u16))
        .collect();
    (0..n)
        .map(|i| Ok(Arc::new(TcpEndpoint::bind(i, addrs.clone())?)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_roundtrip_two_devices() {
        let eps = loopback_cluster(2, 46100).unwrap();
        eps[0]
            .send(
                1,
                Message::Labels { batch: 7, is_eval: true, data: vec![1, 2, 3] },
            )
            .unwrap();
        let (from, msg) = eps[1].recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(from, 0);
        assert_eq!(
            msg,
            Message::Labels { batch: 7, is_eval: true, data: vec![1, 2, 3] }
        );
    }

    #[test]
    fn tcp_large_payload() {
        let eps = loopback_cluster(2, 46110).unwrap();
        let data: crate::net::TensorBuf = vec![1.5f32; 200_000].into();
        eps[1]
            .send(0, Message::Weights { blocks: vec![(3, vec![data.clone().into()])] })
            .unwrap();
        match eps[0].recv_timeout(Duration::from_secs(5)) {
            Some((1, Message::Weights { blocks })) => {
                assert_eq!(blocks[0].0, 3);
                assert_eq!(blocks[0].1[0].as_f32().unwrap(), &data);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn send_to_unreachable_peer_is_silent() {
        // device 1 never binds; send must not error (timeout semantics),
        // even after the full reconnect/backoff schedule runs out
        let addrs = vec!["127.0.0.1:46120".into(), "127.0.0.1:46121".into()];
        let ep = TcpEndpoint::bind(0, addrs).unwrap();
        ep.send(1, Message::Probe).unwrap();
    }

    #[test]
    fn late_binding_peer_is_reached_by_backoff() {
        // device 1 binds ~40ms after device 0 starts sending: the
        // reconnect loop must bridge the gap instead of dropping. The
        // patient schedule keeps this stable on slow CI runners (the
        // default ~150ms window used to race the spawned thread).
        let addrs = vec!["127.0.0.1:46130".to_string(), "127.0.0.1:46131".to_string()];
        let a0 = addrs.clone();
        let ep0 =
            TcpEndpoint::bind_with(0, a0, TcpConfig::patient(), crate::sim::real_clock())
                .unwrap();
        let addrs1 = addrs.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            TcpEndpoint::bind(1, addrs1).unwrap()
        });
        ep0.send(1, Message::FetchDone { id: 0 }).unwrap();
        let ep1 = h.join().unwrap();
        match ep1.recv_timeout(Duration::from_secs(5)) {
            Some((0, Message::FetchDone { id: 0 })) => {}
            other => panic!("late-bound peer missed the message: {other:?}"),
        }
    }

    #[test]
    fn config_defaults_match_historical_constants() {
        let c = TcpConfig::default();
        assert_eq!(c.connect_attempts, 5);
        assert_eq!(c.connect_backoff, Duration::from_millis(10));
        assert_eq!(c.connect_timeout, Duration::from_millis(500));
        assert_eq!(c.down_ttl, Duration::from_secs(1));
        assert!(TcpConfig::patient().connect_attempts > c.connect_attempts);
    }

    #[test]
    fn down_ttl_is_configurable_and_expires() {
        // a tiny TTL re-dials almost immediately instead of holding the
        // peer down for a second (the old hardcoded window)
        let cfg = TcpConfig {
            connect_attempts: 1,
            down_ttl: Duration::from_millis(1),
            ..TcpConfig::default()
        };
        let addrs = vec!["127.0.0.1:46140".to_string(), "127.0.0.1:46141".to_string()];
        let ep0 = TcpEndpoint::bind_with(0, addrs.clone(), cfg, crate::sim::real_clock())
            .unwrap();
        ep0.send(1, Message::FetchDone { id: 0 }).unwrap(); // peer down: cached
        std::thread::sleep(Duration::from_millis(5)); // TTL expired
        let ep1 = TcpEndpoint::bind(1, addrs).unwrap();
        ep0.send(1, Message::FetchDone { id: 7 }).unwrap(); // re-dials now
        match ep1.recv_timeout(Duration::from_secs(2)) {
            Some((0, Message::FetchDone { id: 7 })) => {}
            other => panic!("expired down-cache still blocking sends: {other:?}"),
        }
    }
}
