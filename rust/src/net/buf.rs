//! Shared tensor buffers — the zero-copy currency of the whole engine.
//!
//! A [`TensorBuf`] is an immutable-by-default, reference-counted f32
//! buffer. Activations, gradients, and replicated weights travel as
//! `TensorBuf`s end to end: a `clone()` bumps a refcount instead of
//! copying megabytes, so queuing a message, stashing an activation for
//! backward, snapshotting a weight version, and pushing a replica all
//! share one allocation. Mutation goes through [`TensorBuf::make_mut`]
//! (copy-on-write): the optimizer updates weights in place while any
//! outstanding snapshot/replica keeps the old bytes alive unchanged.
//!
//! The in-process [`super::sim::SimNet`] moves messages by value, so a
//! send carries the buffer through to the receiver without any f32 copy
//! at all (asserted by `rust/tests/zero_copy.rs`); the TCP transport pays
//! exactly one serialization write per hop, into a reused frame buffer.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// Cheaply-cloneable, `Arc`-backed f32 buffer.
#[derive(Clone, Default)]
pub struct TensorBuf(Arc<Vec<f32>>);

impl TensorBuf {
    pub fn new(data: Vec<f32>) -> TensorBuf {
        TensorBuf(Arc::new(data))
    }

    pub fn zeros(n: usize) -> TensorBuf {
        TensorBuf(Arc::new(vec![0.0; n]))
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn byte_len(&self) -> usize {
        self.0.len() * 4
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    /// Copy out into an owned vector (explicit — the only copying exit).
    pub fn to_vec(&self) -> Vec<f32> {
        self.0.as_ref().clone()
    }

    /// Copy-on-write mutable access: in-place when this is the only
    /// holder, one copy when a snapshot/replica still shares the buffer.
    pub fn make_mut(&mut self) -> &mut Vec<f32> {
        Arc::make_mut(&mut self.0)
    }

    /// Do `self` and `other` share the same allocation? (Used by the
    /// zero-copy tests to prove no f32s were duplicated.)
    pub fn ptr_eq(&self, other: &TensorBuf) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }

    /// Number of live references to the underlying allocation.
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.0)
    }
}

impl Deref for TensorBuf {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.0
    }
}

impl AsRef<[f32]> for TensorBuf {
    fn as_ref(&self) -> &[f32] {
        &self.0
    }
}

impl From<Vec<f32>> for TensorBuf {
    fn from(v: Vec<f32>) -> TensorBuf {
        TensorBuf::new(v)
    }
}

impl From<&[f32]> for TensorBuf {
    fn from(v: &[f32]) -> TensorBuf {
        TensorBuf::new(v.to_vec())
    }
}

impl FromIterator<f32> for TensorBuf {
    fn from_iter<I: IntoIterator<Item = f32>>(iter: I) -> TensorBuf {
        TensorBuf::new(iter.into_iter().collect())
    }
}

/// Content equality (with a same-allocation fast path).
impl PartialEq for TensorBuf {
    fn eq(&self, other: &TensorBuf) -> bool {
        self.ptr_eq(other) || self.0 == other.0
    }
}

impl PartialEq<Vec<f32>> for TensorBuf {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[f32]> for TensorBuf {
    fn eq(&self, other: &[f32]) -> bool {
        self.as_slice() == other
    }
}

impl fmt::Debug for TensorBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.len() <= 8 {
            write!(f, "TensorBuf({:?})", self.as_slice())
        } else {
            write!(
                f,
                "TensorBuf(len={}, head={:?}, rc={})",
                self.len(),
                &self.as_slice()[..4],
                self.ref_count()
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_shares_allocation() {
        let a = TensorBuf::from(vec![1.0, 2.0, 3.0]);
        let b = a.clone();
        assert!(a.ptr_eq(&b));
        assert_eq!(a.ref_count(), 2);
        assert_eq!(a, b);
    }

    #[test]
    fn make_mut_is_in_place_when_unique() {
        let mut a = TensorBuf::from(vec![1.0; 4]);
        let before = a.as_slice().as_ptr();
        a.make_mut()[0] = 9.0;
        assert_eq!(a.as_slice().as_ptr(), before, "unique buffer must mutate in place");
        assert_eq!(a[0], 9.0);
    }

    #[test]
    fn make_mut_copies_when_shared_and_preserves_snapshot() {
        let mut a = TensorBuf::from(vec![1.0; 4]);
        let snap = a.clone();
        a.make_mut()[0] = 9.0;
        assert!(!a.ptr_eq(&snap), "copy-on-write must fork");
        assert_eq!(snap[0], 1.0, "snapshot unchanged");
        assert_eq!(a[0], 9.0);
    }

    #[test]
    fn deref_and_eq_by_content() {
        let a = TensorBuf::from(vec![1.0, 2.0]);
        let b = TensorBuf::from(vec![1.0, 2.0]);
        assert_eq!(a, b);
        assert_eq!(&a[..], &[1.0, 2.0]);
        assert_eq!(a.byte_len(), 8);
    }
}
