//! Length-prefixed binary codec for [`Message`] (serde/bincode are not
//! available offline). Little-endian, tag byte per variant, `u32` lengths.
//! Frame layout used by the TCP transport:
//!
//! ```text
//! [u32 frame_len][u8 version][u8 tag][payload...]
//! ```
//!
//! Encoding targets a caller-provided, reusable frame buffer
//! ([`encode_into`]) so a long-lived connection serializes every message
//! into the same allocation; [`encode`] is the convenience wrapper that
//! allocates a fresh one. Decoding materializes f32 tensors directly into
//! [`TensorBuf`]s — that single write is the only f32 copy a message pays
//! on the TCP path (the sim transport skips the codec entirely).
//!
//! Round-trip safety is property-tested below over every variant.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::buf::TensorBuf;
use super::message::{
    DeviceId, ExecReport, Message, Payload, ReplicaKind, TrainInit, WireBlock, WireTensor,
};
use super::quant::{Bits, Compression, QTensor, Scheme, Tier};

/// v2: tensors inside `Backward`/`Weights`/`ReplicaPush` carry a dtype
/// tag (f32 | q8), `Forward` payloads gained a q8 arm, and `InitState`
/// carries the cluster's [`Compression`] policy.
///
/// v3: the central checkpoint-restart handshake — `CentralRestart`
/// (tag 19) and `WorkerState` (tag 20).
///
/// v4: the adaptive-compression wire — quantized tensors carry a scheme
/// subtag (per-tensor q8 keeps its v2 layout under subtag 1; per-channel
/// q8 and packed q4 arms are subtags 2–4), `Forward` quant payloads use
/// the same subtag space, `InitState` gained `bw_probe_every`, and
/// `SetCompression` is message tag 21. The bump exists so a v4 peer
/// never talks past a v3 one that would reject the new arms mid-stream.
///
/// v5: `InitState` carries the adaptive tier band — `tier_floor` and
/// `tier_ceiling`, one byte each after `bw_probe_bytes`.
///
/// v6: `InitState` carries the coordinator's `replica_epoch` and the
/// admission `worker_quota`, two u64s after `tier_ceiling` (DESIGN.md
/// §12). Neither changes `Message::byte_len`'s pricing formula, so v5
/// traffic traces stay byte-identical.
///
/// v7: per-link adaptive compression (DESIGN.md §10) — `BwReport` gains
/// the probed destination device (a trailing usize), and `SetCompression`
/// gains the per-destination override list (a trailing count + `(usize
/// device, u8 tier)` pairs, written only when non-empty). Both are
/// optional-trailing fields: an empty override list elides even its
/// count, and the decoder reads the extras only when bytes remain in the
/// frame (`decode` checks exact frame consumption, which makes trailing
/// optionals unambiguous). Pricing (`Message::byte_len`) is unchanged.
///
/// v8: the replica axis (DESIGN.md §14) — `ReplicaSync` is message
/// tag 22 (cross-replica weight partials/averages on the quantized
/// wire), and `InitState` gains `replicas` + `sync_every` as a trailing
/// optional *pair* (written together only when either is non-default,
/// i.e. `replicas != 1 || sync_every != 0`), so every default-valued
/// frame keeps its v7 byte pattern. Pricing is unchanged for old
/// variants; `ReplicaSync` gets its own frozen formula.
pub const CODEC_VERSION: u8 = 8;

// ---------- primitive writers ----------

struct W<'a>(&'a mut Vec<u8>);

impl W<'_> {
    fn u8(&mut self, x: u8) {
        self.0.push(x);
    }
    fn u32(&mut self, x: u32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn i64(&mut self, x: i64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn f32(&mut self, x: f32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn f64(&mut self, x: f64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }
    fn bool(&mut self, x: bool) {
        self.u8(u8::from(x));
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        self.0.reserve(xs.len() * 4);
        for &x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn i32s(&mut self, xs: &[i32]) {
        self.u32(xs.len() as u32);
        self.0.reserve(xs.len() * 4);
        for &x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn bytes(&mut self, xs: &[u8]) {
        self.u32(xs.len() as u32);
        self.0.extend_from_slice(xs);
    }
    /// Quantized tensor, scheme-subtagged (1 = q8 per-tensor in the v2
    /// layout; 2 = q8 per-channel; 3 = q4 per-tensor; 4 = q4
    /// per-channel). The packed payload is written as-is — no f32
    /// materialization anywhere on the encode path.
    fn qtensor(&mut self, q: &QTensor) {
        match (q.bits(), q.scheme()) {
            (Bits::B8, Scheme::PerTensor { scale, zero }) => {
                self.u8(1);
                self.bytes(q.bytes());
                self.f32(*scale);
                self.f32(*zero);
            }
            (Bits::B4, Scheme::PerTensor { scale, zero }) => {
                self.u8(3);
                self.u32(q.len() as u32);
                self.f32(*scale);
                self.f32(*zero);
                self.bytes(q.bytes());
            }
            (bits, Scheme::PerChannel { pairs, interleaved }) => {
                self.u8(if matches!(bits, Bits::B8) { 2 } else { 4 });
                self.u32(q.len() as u32);
                self.bool(*interleaved);
                self.u32(pairs.len() as u32);
                for &(s, z) in pairs.iter() {
                    self.f32(s);
                    self.f32(z);
                }
                self.bytes(q.bytes());
            }
        }
    }
    /// Dtype-tagged tensor (0 = f32; 1–4 = the quantized subtags).
    fn wire_tensor(&mut self, t: &WireTensor) {
        match t {
            WireTensor::F32(v) => {
                self.u8(0);
                self.f32s(v);
            }
            WireTensor::Quant(q) => self.qtensor(q),
        }
    }
    fn blocks(&mut self, blocks: &[WireBlock]) {
        self.u32(blocks.len() as u32);
        for (idx, tensors) in blocks {
            self.usize(*idx);
            self.u32(tensors.len() as u32);
            for t in tensors {
                self.wire_tensor(t);
            }
        }
    }
}

// ---------- primitive readers ----------

struct R<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> R<'a> {
    fn need(&self, n: usize) -> Result<()> {
        if self.i + n > self.b.len() {
            bail!("codec underrun at {} (+{n} > {})", self.i, self.b.len());
        }
        Ok(())
    }
    fn u8(&mut self) -> Result<u8> {
        self.need(1)?;
        self.i += 1;
        Ok(self.b[self.i - 1])
    }
    fn u32(&mut self) -> Result<u32> {
        self.need(4)?;
        let x = u32::from_le_bytes(self.b[self.i..self.i + 4].try_into().unwrap());
        self.i += 4;
        Ok(x)
    }
    fn u64(&mut self) -> Result<u64> {
        self.need(8)?;
        let x = u64::from_le_bytes(self.b[self.i..self.i + 8].try_into().unwrap());
        self.i += 8;
        Ok(x)
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }
    fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        self.need(n * 4)?;
        let v = self.b[self.i..self.i + n * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.i += n * 4;
        Ok(v)
    }
    /// The single materializing f32 write of the decode path.
    fn tensor(&mut self) -> Result<TensorBuf> {
        Ok(TensorBuf::new(self.f32s()?))
    }
    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        self.need(n * 4)?;
        let v = self.b[self.i..self.i + n * 4]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.i += n * 4;
        Ok(v)
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        self.need(n)?;
        let v = self.b[self.i..self.i + n].to_vec();
        self.i += n;
        Ok(v)
    }
    /// The packed payload lands directly in the `QTensor`'s shared
    /// buffer — decode never expands a quantized tensor to f32. `tag` is
    /// the scheme subtag already consumed by the caller.
    fn qtensor_body(&mut self, tag: u8) -> Result<QTensor> {
        match tag {
            1 => {
                let data = self.bytes()?;
                let scale = self.f32()?;
                let zero = self.f32()?;
                Ok(QTensor::from_parts(data, scale, zero))
            }
            3 => {
                let len = self.u32()? as usize;
                let scale = self.f32()?;
                let zero = self.f32()?;
                let data = self.bytes()?;
                QTensor::from_wire(data, len, Bits::B4, Scheme::PerTensor { scale, zero })
            }
            2 | 4 => {
                let len = self.u32()? as usize;
                let interleaved = self.bool()?;
                let n = self.u32()? as usize;
                self.need(n * 8)?;
                let mut pairs = Vec::with_capacity(n);
                for _ in 0..n {
                    pairs.push((self.f32()?, self.f32()?));
                }
                let data = self.bytes()?;
                let bits = if tag == 2 { Bits::B8 } else { Bits::B4 };
                QTensor::from_wire(data, len, bits, Scheme::PerChannel {
                    pairs: Arc::new(pairs),
                    interleaved,
                })
            }
            t => bail!("bad quantized-tensor subtag {t}"),
        }
    }
    /// A quantized tensor with its leading subtag (Forward payloads).
    fn qtensor(&mut self) -> Result<QTensor> {
        let tag = self.u8()?;
        self.qtensor_body(tag)
    }
    fn wire_tensor(&mut self) -> Result<WireTensor> {
        match self.u8()? {
            0 => Ok(WireTensor::F32(self.tensor()?)),
            t @ 1..=4 => Ok(WireTensor::Quant(self.qtensor_body(t)?)),
            t => bail!("bad tensor dtype tag {t}"),
        }
    }
    fn blocks(&mut self) -> Result<Vec<WireBlock>> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let idx = self.usize()?;
            let nt = self.u32()? as usize;
            let mut tensors = Vec::with_capacity(nt);
            for _ in 0..nt {
                tensors.push(self.wire_tensor()?);
            }
            out.push((idx, tensors));
        }
        Ok(out)
    }
}

// ---------- message encode/decode ----------

/// Encode `(from, msg)` into `buf` (cleared first), without the outer u32
/// length prefix — the TCP transport adds that. `buf` is reusable across
/// calls: a steady-state connection serializes every frame into the same
/// allocation.
pub fn encode_into(buf: &mut Vec<u8>, from: DeviceId, msg: &Message) {
    buf.clear();
    buf.reserve(64 + msg.byte_len());
    let mut w = W(buf);
    w.u8(CODEC_VERSION);
    w.usize(from);
    match msg {
        Message::Forward { batch, version0, is_eval, data } => {
            w.u8(0);
            w.u64(*batch);
            w.u64(*version0);
            w.bool(*is_eval);
            match data {
                Payload::F32(v) => {
                    w.u8(0);
                    w.f32s(v);
                }
                Payload::I32(v) => {
                    w.u8(1);
                    w.i32s(v);
                }
                Payload::Quant(q) => {
                    w.u8(2);
                    w.qtensor(q);
                }
            }
        }
        Message::Labels { batch, is_eval, data } => {
            w.u8(1);
            w.u64(*batch);
            w.bool(*is_eval);
            w.i32s(data);
        }
        Message::Backward { batch, grad, loss, ncorrect, reports } => {
            w.u8(2);
            w.u64(*batch);
            w.wire_tensor(grad);
            w.f32(*loss);
            w.f32(*ncorrect);
            w.u32(reports.len() as u32);
            for r in reports {
                w.usize(r.device);
                w.f64(r.avg_ms);
                w.u32(r.batches);
            }
        }
        Message::EvalResult { batch, loss, ncorrect } => {
            w.u8(3);
            w.u64(*batch);
            w.f32(*loss);
            w.f32(*ncorrect);
        }
        Message::Probe => w.u8(4),
        Message::ProbeAck { id, fresh } => {
            w.u8(5);
            w.usize(*id);
            w.bool(*fresh);
        }
        Message::InitState(t) => {
            w.u8(6);
            w.i64(t.committed_forward);
            w.i64(t.committed_backward);
            w.f32(t.lr);
            w.f32(t.momentum);
            w.f32(t.weight_decay);
            w.u64(t.epochs);
            w.u64(t.batches_per_epoch);
            w.u32(t.ranges.len() as u32);
            for (a, b) in &t.ranges {
                w.usize(*a);
                w.usize(*b);
            }
            w.u32(t.worker_list.len() as u32);
            for d in &t.worker_list {
                w.usize(*d);
            }
            w.u32(t.agg_k);
            w.u64(t.chain_every);
            w.u64(t.global_every);
            w.u8(t.status);
            w.u8(t.compression.to_u8());
            w.u64(t.bw_probe_every);
            w.u64(t.bw_probe_bytes);
            w.u8(t.tier_floor.to_u8());
            w.u8(t.tier_ceiling.to_u8());
            w.u64(t.replica_epoch);
            w.u64(t.worker_quota);
            // v8 trailing pair: elided when both hold their defaults so
            // a single-chain frame keeps its v7 byte pattern. Written
            // together (never one alone) to keep decoding unambiguous.
            if t.replicas != 1 || t.sync_every != 0 {
                w.u64(t.replicas);
                w.u64(t.sync_every);
            }
        }
        Message::Repartition { ranges, worker_list, failed } => {
            w.u8(7);
            w.u32(ranges.len() as u32);
            for (a, b) in ranges {
                w.usize(*a);
                w.usize(*b);
            }
            w.u32(worker_list.len() as u32);
            for d in worker_list {
                w.usize(*d);
            }
            w.u32(failed.len() as u32);
            for f in failed {
                w.usize(*f);
            }
        }
        Message::FetchWeights { blocks } => {
            w.u8(8);
            w.u32(blocks.len() as u32);
            for b in blocks {
                w.usize(*b);
            }
        }
        Message::Weights { blocks } => {
            w.u8(9);
            w.blocks(blocks);
        }
        Message::ReplicaPush { kind, owner_stage, owner_device, version, blocks } => {
            w.u8(10);
            w.u8(match kind {
                ReplicaKind::Chain => 0,
                ReplicaKind::Global => 1,
            });
            w.usize(*owner_stage);
            w.usize(*owner_device);
            w.u64(*version);
            w.blocks(blocks);
        }
        Message::FetchDone { id } => {
            w.u8(11);
            w.usize(*id);
        }
        Message::Commit => w.u8(12),
        Message::Reset { committed } => {
            w.u8(13);
            w.i64(*committed);
        }
        Message::BwTest { payload_bytes, data } => {
            w.u8(14);
            w.u32(*payload_bytes);
            w.bytes(data);
        }
        Message::BwAck { payload_bytes } => {
            w.u8(15);
            w.u32(*payload_bytes);
        }
        Message::BwReport { stage, bps, to } => {
            w.u8(17);
            w.usize(*stage);
            w.f64(*bps);
            // v7 trailing field: elided for the `to == 0` sentinel so the
            // default frame keeps its v6 byte pattern
            if *to != 0 {
                w.usize(*to);
            }
        }
        Message::SetLr { lr } => {
            w.u8(18);
            w.f32(*lr);
        }
        Message::CentralRestart { committed } => {
            w.u8(19);
            w.i64(*committed);
        }
        Message::WorkerState { id, committed_fwd, committed_bwd, fresh } => {
            w.u8(20);
            w.usize(*id);
            w.i64(*committed_fwd);
            w.i64(*committed_bwd);
            w.bool(*fresh);
        }
        Message::SetCompression { tier, links } => {
            w.u8(21);
            w.u8(tier.to_u8());
            // v7 trailing field: an empty override table elides even its
            // count, keeping the single-byte v6 pattern for defaults
            if !links.is_empty() {
                w.usize(links.len());
                for &(dev, t) in links {
                    w.usize(dev);
                    w.u8(t.to_u8());
                }
            }
        }
        Message::ReplicaSync { round, block_id, tensors } => {
            w.u8(22);
            w.u64(*round);
            w.usize(*block_id);
            w.u32(tensors.len() as u32);
            for t in tensors {
                w.wire_tensor(t);
            }
        }
        Message::Shutdown => w.u8(16),
    }
}

/// Encode into a fresh frame (see [`encode_into`] for the reusable form).
pub fn encode(from: DeviceId, msg: &Message) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_into(&mut buf, from, msg);
    buf
}

// ---------- wire framing ----------

/// Hard cap on one framed message. A length prefix at or above this is a
/// corrupt/hostile stream, not a legitimate payload — the transport drops
/// the connection instead of allocating gigabytes.
pub const MAX_FRAME: usize = 1 << 30;

/// The outer length prefix the TCP transport puts in front of a codec
/// frame: `[u32 LE payload_len][codec frame]`.
pub fn frame_header(payload_len: usize) -> [u8; 4] {
    debug_assert!(payload_len < MAX_FRAME);
    (payload_len as u32).to_le_bytes()
}

/// Parse a [`frame_header`], rejecting oversized (corrupt) lengths.
pub fn frame_payload_len(header: [u8; 4]) -> Result<usize> {
    let len = u32::from_le_bytes(header) as usize;
    if len >= MAX_FRAME {
        bail!("framed message of {len} bytes exceeds the {MAX_FRAME}-byte cap — corrupt stream?");
    }
    Ok(len)
}

/// Decode a frame produced by [`encode`]/[`encode_into`]. Returns
/// `(from, message)`.
pub fn decode(frame: &[u8]) -> Result<(DeviceId, Message)> {
    let mut r = R { b: frame, i: 0 };
    let ver = r.u8()?;
    if ver != CODEC_VERSION {
        bail!("codec version {ver} != {CODEC_VERSION}");
    }
    let from = r.usize()?;
    let tag = r.u8()?;
    let msg = match tag {
        0 => {
            let batch = r.u64()?;
            let version0 = r.u64()?;
            let is_eval = r.bool()?;
            let data = match r.u8()? {
                0 => Payload::F32(r.tensor()?),
                1 => Payload::I32(r.i32s()?),
                2 => Payload::Quant(r.qtensor()?),
                t => bail!("bad payload tag {t}"),
            };
            Message::Forward { batch, version0, is_eval, data }
        }
        1 => Message::Labels { batch: r.u64()?, is_eval: r.bool()?, data: r.i32s()? },
        2 => {
            let batch = r.u64()?;
            let grad = r.wire_tensor()?;
            let loss = r.f32()?;
            let ncorrect = r.f32()?;
            let n = r.u32()? as usize;
            let mut reports = Vec::with_capacity(n);
            for _ in 0..n {
                reports.push(ExecReport {
                    device: r.usize()?,
                    avg_ms: r.f64()?,
                    batches: r.u32()?,
                });
            }
            Message::Backward { batch, grad, loss, ncorrect, reports }
        }
        3 => Message::EvalResult { batch: r.u64()?, loss: r.f32()?, ncorrect: r.f32()? },
        4 => Message::Probe,
        5 => Message::ProbeAck { id: r.usize()?, fresh: r.bool()? },
        6 => {
            let committed_forward = r.i64()?;
            let committed_backward = r.i64()?;
            let lr = r.f32()?;
            let momentum = r.f32()?;
            let weight_decay = r.f32()?;
            let epochs = r.u64()?;
            let batches_per_epoch = r.u64()?;
            let nr = r.u32()? as usize;
            let mut ranges = Vec::with_capacity(nr);
            for _ in 0..nr {
                ranges.push((r.usize()?, r.usize()?));
            }
            let nw = r.u32()? as usize;
            let mut worker_list = Vec::with_capacity(nw);
            for _ in 0..nw {
                worker_list.push(r.usize()?);
            }
            Message::InitState(TrainInit {
                committed_forward,
                committed_backward,
                lr,
                momentum,
                weight_decay,
                epochs,
                batches_per_epoch,
                ranges,
                worker_list,
                agg_k: r.u32()?,
                chain_every: r.u64()?,
                global_every: r.u64()?,
                status: r.u8()?,
                compression: {
                    let c = r.u8()?;
                    Compression::from_u8(c)
                        .ok_or_else(|| anyhow!("bad compression policy {c}"))?
                },
                bw_probe_every: r.u64()?,
                bw_probe_bytes: r.u64()?,
                tier_floor: {
                    let t = r.u8()?;
                    Tier::from_u8(t).ok_or_else(|| anyhow!("bad tier_floor {t}"))?
                },
                tier_ceiling: {
                    let t = r.u8()?;
                    Tier::from_u8(t).ok_or_else(|| anyhow!("bad tier_ceiling {t}"))?
                },
                replica_epoch: r.u64()?,
                worker_quota: r.u64()?,
                // v8 trailing pair; absent in v7-shaped frames means the
                // single-chain defaults
                replicas: if r.i < frame.len() { r.u64()? } else { 1 },
                sync_every: if r.i < frame.len() { r.u64()? } else { 0 },
            })
        }
        7 => {
            let nr = r.u32()? as usize;
            let mut ranges = Vec::with_capacity(nr);
            for _ in 0..nr {
                ranges.push((r.usize()?, r.usize()?));
            }
            let nw = r.u32()? as usize;
            let mut worker_list = Vec::with_capacity(nw);
            for _ in 0..nw {
                worker_list.push(r.usize()?);
            }
            let nf = r.u32()? as usize;
            let mut failed = Vec::with_capacity(nf);
            for _ in 0..nf {
                failed.push(r.usize()?);
            }
            Message::Repartition { ranges, worker_list, failed }
        }
        8 => {
            let n = r.u32()? as usize;
            let mut blocks = Vec::with_capacity(n);
            for _ in 0..n {
                blocks.push(r.usize()?);
            }
            Message::FetchWeights { blocks }
        }
        9 => Message::Weights { blocks: r.blocks()? },
        10 => Message::ReplicaPush {
            kind: match r.u8()? {
                0 => ReplicaKind::Chain,
                1 => ReplicaKind::Global,
                t => bail!("bad replica kind {t}"),
            },
            owner_stage: r.usize()?,
            owner_device: r.usize()?,
            version: r.u64()?,
            blocks: r.blocks()?,
        },
        11 => Message::FetchDone { id: r.usize()? },
        12 => Message::Commit,
        13 => Message::Reset { committed: r.i64()? },
        14 => Message::BwTest { payload_bytes: r.u32()?, data: r.bytes()? },
        15 => Message::BwAck { payload_bytes: r.u32()? },
        16 => Message::Shutdown,
        17 => {
            let stage = r.usize()?;
            let bps = r.f64()?;
            // v7 trailing destination; absent in v6-shaped frames (0 is
            // the "unknown" sentinel — never a real probe destination)
            let to = if r.i < frame.len() { r.usize()? } else { 0 };
            Message::BwReport { stage, bps, to }
        }
        18 => Message::SetLr { lr: r.f32()? },
        19 => Message::CentralRestart { committed: r.i64()? },
        20 => Message::WorkerState {
            id: r.usize()?,
            committed_fwd: r.i64()?,
            committed_bwd: r.i64()?,
            fresh: r.bool()?,
        },
        21 => {
            let t = r.u8()?;
            let tier = Tier::from_u8(t).ok_or_else(|| anyhow!("bad compression tier {t}"))?;
            // v7 trailing override table; absent means "no overrides"
            let mut links = Vec::new();
            if r.i < frame.len() {
                let n = r.usize()?;
                links.reserve(n.min(1 << 16));
                for _ in 0..n {
                    let dev = r.usize()?;
                    let t = r.u8()?;
                    let tier =
                        Tier::from_u8(t).ok_or_else(|| anyhow!("bad compression tier {t}"))?;
                    links.push((dev, tier));
                }
            }
            Message::SetCompression { tier, links }
        }
        22 => {
            let round = r.u64()?;
            let block_id = r.usize()?;
            let nt = r.u32()? as usize;
            let mut tensors = Vec::with_capacity(nt);
            for _ in 0..nt {
                tensors.push(r.wire_tensor()?);
            }
            Message::ReplicaSync { round, block_id, tensors }
        }
        t => return Err(anyhow!("unknown message tag {t}")),
    };
    if r.i != frame.len() {
        bail!("codec: {} trailing bytes", frame.len() - r.i);
    }
    Ok((from, msg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::quant::ChannelHint;
    use crate::util::prop::{check, G};

    fn roundtrip(from: DeviceId, msg: &Message) {
        let frame = encode(from, msg);
        let (f2, m2) = decode(&frame).expect("decode");
        assert_eq!(f2, from);
        assert_eq!(&m2, msg);
    }

    #[test]
    fn frame_header_roundtrips_and_rejects_oversize() {
        for len in [0usize, 1, 255, 65_536, MAX_FRAME - 1] {
            assert_eq!(frame_payload_len(frame_header(len)).unwrap(), len);
        }
        assert!(frame_payload_len((MAX_FRAME as u32).to_le_bytes()).is_err());
        assert!(frame_payload_len([0xFF; 4]).is_err());
    }

    #[test]
    fn roundtrip_all_simple_variants() {
        roundtrip(0, &Message::Probe);
        roundtrip(1, &Message::ProbeAck { id: 1, fresh: true });
        roundtrip(2, &Message::Commit);
        roundtrip(3, &Message::Shutdown);
        roundtrip(0, &Message::Reset { committed: -1 });
        roundtrip(0, &Message::FetchDone { id: 2 });
        roundtrip(0, &Message::EvalResult { batch: 9, loss: 1.5, ncorrect: 3.0 });
        roundtrip(0, &Message::BwAck { payload_bytes: 1024 });
        roundtrip(2, &Message::BwReport { stage: 1, bps: 12.5e6, to: 0 });
        roundtrip(2, &Message::BwReport { stage: 1, bps: 12.5e6, to: 4 });
        roundtrip(0, &Message::SetLr { lr: 0.00625 });
        roundtrip(0, &Message::CentralRestart { committed: -1 });
        roundtrip(0, &Message::CentralRestart { committed: 1999 });
        roundtrip(2, &Message::WorkerState {
            id: 2,
            committed_fwd: 41,
            committed_bwd: 40,
            fresh: false,
        });
        roundtrip(3, &Message::WorkerState {
            id: 3,
            committed_fwd: -1,
            committed_bwd: -1,
            fresh: true,
        });
        for tier in [Tier::Off, Tier::Activations, Tier::Full, Tier::FullQ4] {
            roundtrip(0, &Message::SetCompression { tier, links: vec![] });
        }
        roundtrip(
            0,
            &Message::SetCompression {
                tier: Tier::Off,
                links: vec![(2, Tier::Full), (5, Tier::FullQ4)],
            },
        );
    }

    #[test]
    fn v6_default_byte_patterns_are_preserved() {
        // the v7 trailing fields must be elided for default values, so a
        // default-valued frame is byte-identical to its v6 layout
        let frame = encode(0, &Message::SetCompression { tier: Tier::Full, links: vec![] });
        let bare = &frame[frame.len() - 2..];
        assert_eq!(bare, &[21, Tier::Full.to_u8()], "tag + tier byte, nothing trailing");
        let with = encode(0, &Message::SetCompression {
            tier: Tier::Full,
            links: vec![(3, Tier::FullQ4)],
        });
        assert!(with.len() > frame.len(), "overrides extend the frame");
        let plain = encode(2, &Message::BwReport { stage: 1, bps: 1e6, to: 0 });
        let keyed = encode(2, &Message::BwReport { stage: 1, bps: 1e6, to: 4 });
        assert_eq!(keyed.len(), plain.len() + 8, "destination is one trailing usize");
        assert_eq!(&keyed[..plain.len()], &plain[..], "prefix unchanged");
    }

    #[test]
    fn roundtrip_forward_both_payloads() {
        roundtrip(
            0,
            &Message::Forward {
                batch: 42,
                version0: 7,
                is_eval: false,
                data: Payload::F32(vec![1.0, -2.5, 3.25].into()),
            },
        );
        roundtrip(
            1,
            &Message::Forward {
                batch: 43,
                version0: 0,
                is_eval: true,
                data: Payload::I32(vec![-1, 0, 5_000_000]),
            },
        );
    }

    #[test]
    fn roundtrip_init_state() {
        roundtrip(
            0,
            &Message::InitState(TrainInit {
                committed_forward: -1,
                committed_backward: -1,
                lr: 0.05,
                momentum: 0.9,
                weight_decay: 4e-5,
                epochs: 3,
                batches_per_epoch: 100,
                ranges: vec![(0, 3), (4, 7), (8, 11)],
                worker_list: vec![0, 1, 2],
                agg_k: 4,
                chain_every: 50,
                global_every: 100,
                status: 0,
                compression: Compression::Activations,
                bw_probe_every: 5,
                bw_probe_bytes: 2048,
                tier_floor: Tier::Activations,
                tier_ceiling: Tier::Full,
                replica_epoch: 3,
                worker_quota: 8,
                replicas: 2,
                sync_every: 10,
            }),
        );
        roundtrip(
            0,
            &Message::ReplicaSync {
                round: 4,
                block_id: 7,
                tensors: vec![vec![1.0f32, -2.0, 0.5].into()],
            },
        );
    }

    /// Satellite: the v8 trailing pair must be elided for default values,
    /// so a single-chain `InitState` frame is byte-identical to its v7
    /// layout — and a replica-axis frame extends it by exactly the pair.
    #[test]
    fn v7_default_byte_patterns_are_preserved() {
        let ti = |replicas: u64, sync_every: u64| {
            Message::InitState(TrainInit {
                committed_forward: -1,
                committed_backward: -1,
                lr: 0.05,
                momentum: 0.9,
                weight_decay: 4e-5,
                epochs: 1,
                batches_per_epoch: 10,
                ranges: vec![(0, 4)],
                worker_list: vec![0, 1],
                agg_k: 0,
                chain_every: 0,
                global_every: 0,
                status: 0,
                compression: Compression::Off,
                bw_probe_every: 0,
                bw_probe_bytes: 0,
                tier_floor: Tier::Off,
                tier_ceiling: Tier::FullQ4,
                replica_epoch: 0,
                worker_quota: 0,
                replicas,
                sync_every,
            })
        };
        let plain = encode(0, &ti(1, 0));
        let keyed = encode(0, &ti(2, 10));
        assert_eq!(keyed.len(), plain.len() + 16, "the pair is two trailing u64s");
        assert_eq!(&keyed[..plain.len()], &plain[..], "prefix unchanged");
        // either field non-default forces the whole pair onto the wire
        assert_eq!(encode(0, &ti(1, 5)).len(), plain.len() + 16);
        // and the v7-shaped frame decodes to the single-chain defaults
        let (_, m) = decode(&plain).unwrap();
        let Message::InitState(t) = m else { panic!("wrong variant") };
        assert_eq!((t.replicas, t.sync_every), (1, 0));
    }

    #[test]
    fn decode_rejects_bad_version_and_truncation() {
        let mut frame = encode(0, &Message::Probe);
        frame[0] = 99;
        assert!(decode(&frame).is_err());
        let frame = encode(0, &Message::Labels { batch: 1, is_eval: false, data: vec![1, 2, 3] });
        assert!(decode(&frame[..frame.len() - 2]).is_err());
    }

    #[test]
    fn encode_into_reuses_the_buffer() {
        let big = Message::Forward {
            batch: 1,
            version0: 1,
            is_eval: false,
            data: Payload::F32(vec![0.5; 1024].into()),
        };
        let mut buf = Vec::new();
        encode_into(&mut buf, 0, &big);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        // a second, smaller message must reuse the same allocation
        encode_into(&mut buf, 0, &Message::Probe);
        assert_eq!(buf.capacity(), cap);
        assert_eq!(buf.as_ptr(), ptr);
        assert_eq!(decode(&buf).unwrap().1, Message::Probe);
        // and re-encoding the big one still round-trips
        encode_into(&mut buf, 3, &big);
        assert_eq!(decode(&buf).unwrap(), (3, big));
    }

    #[test]
    fn prop_roundtrip_random_messages_all_variants() {
        check("codec-roundtrip", 400, |g: &mut G<'_>| {
            let from = g.usize_in(0, 7);
            let msg = random_message(g);
            let frame = encode(from, &msg);
            match decode(&frame) {
                Ok((f2, m2)) if f2 == from && m2 == msg => Ok(()),
                Ok(_) => Err(format!("mismatch after roundtrip of {}", msg.tag())),
                Err(e) => Err(format!("decode of {} failed: {e}", msg.tag())),
            }
        });
    }

    /// Satellite: exact re-encode stability for quantized payloads
    /// across EVERY quant arm (per-tensor/per-channel × q8/q4, odd
    /// lengths included). For every tensor-carrying variant,
    /// decode(encode(m)) re-encodes to the byte-identical frame, and
    /// quantized tensors compare bit-exactly (QTensor equality is
    /// representation equality, so `m2 == msg` on a quant arm asserts
    /// identical packed bytes + identical scale/zero bit patterns).
    #[test]
    fn prop_quant_reencode_is_byte_identical() {
        check("codec-quant-reencode", 200, |g: &mut G<'_>| {
            let len = g.sized_usize(0, 64);
            let xs = g.vec_f32(len);
            // a second tensor with per-channel-friendly geometry
            let wide: Vec<f32> = g.vec_f32(64);
            let arms: Vec<QTensor> = vec![
                QTensor::quantize(&xs),
                QTensor::quantize_bits(&xs, Bits::B4), // odd lens pack here
                QTensor::quantize_weights(&wide, ChannelHint::Rows(2), Bits::B8),
                QTensor::quantize_weights(&wide, ChannelHint::Cols(4), Bits::B4),
            ];
            for q in arms {
                let msgs = vec![
                    Message::Forward {
                        batch: 1,
                        version0: 2,
                        is_eval: false,
                        data: Payload::Quant(q.clone()),
                    },
                    Message::Backward {
                        batch: 3,
                        grad: WireTensor::Quant(q.clone()),
                        loss: 0.5,
                        ncorrect: 1.0,
                        reports: vec![],
                    },
                    Message::Weights { blocks: vec![(4, vec![WireTensor::Quant(q.clone())])] },
                    Message::ReplicaPush {
                        kind: ReplicaKind::Global,
                        owner_stage: 1,
                        owner_device: 2,
                        version: 9,
                        blocks: vec![(0, vec![WireTensor::Quant(q.clone()), xs.clone().into()])],
                    },
                    Message::ReplicaSync {
                        round: 2,
                        block_id: 1,
                        tensors: vec![WireTensor::Quant(q.clone()), xs.clone().into()],
                    },
                ];
                for msg in msgs {
                    let frame = encode(5, &msg);
                    let (_, m2) = decode(&frame).map_err(|e| format!("{}: {e}", msg.tag()))?;
                    if m2 != msg {
                        return Err(format!("{}: value drift after roundtrip", msg.tag()));
                    }
                    let frame2 = encode(5, &m2);
                    if frame2 != frame {
                        return Err(format!("{}: re-encoded frame differs", msg.tag()));
                    }
                }
            }
            Ok(())
        });
    }

    /// Satellite: lossy-path accuracy. f32 → quantize → wire → dequantize
    /// stays within the tensor's scale-derived tolerance for every quant
    /// arm (per-tensor q8 and packed per-channel q4 alike).
    #[test]
    fn prop_f32_quant_f32_within_scale_tolerance() {
        check("codec-quant-tolerance", 200, |g: &mut G<'_>| {
            let len = g.sized_usize(1, 64);
            let xs = g.vec_f32(len);
            let wide = g.vec_f32(32);
            let arms = vec![
                QTensor::quantize(&xs),
                QTensor::quantize_weights(&wide, ChannelHint::Rows(2), Bits::B4),
            ];
            for (src, q) in [(&xs, &arms[0]), (&wide, &arms[1])] {
                let tol = q.tolerance();
                let msg = Message::Forward {
                    batch: 0,
                    version0: 0,
                    is_eval: false,
                    data: Payload::Quant(q.clone()),
                };
                let (_, m2) = decode(&encode(1, &msg)).map_err(|e| e.to_string())?;
                let Message::Forward { data: Payload::Quant(q2), .. } = m2 else {
                    return Err("payload changed class".into());
                };
                let back = q2.dequantize();
                for (i, (&a, &b)) in src.iter().zip(back.iter()).enumerate() {
                    if (a - b).abs() > tol {
                        return Err(format!("elem {i}: {a} -> {b} exceeds tol {tol}"));
                    }
                }
            }
            Ok(())
        });
    }

    /// A random wire tensor across every encoding arm, so every
    /// tensor-carrying variant is property-tested in all of them.
    fn random_wire_tensor(g: &mut G<'_>, len: usize) -> WireTensor {
        let xs = g.vec_f32(len);
        match g.usize_in(0, 3) {
            0 => WireTensor::F32(xs.into()),
            1 => WireTensor::Quant(QTensor::quantize(&xs)),
            2 => WireTensor::Quant(QTensor::quantize_bits(&xs, Bits::B4)),
            _ => {
                // pick a channel count that divides len (falls back to
                // per-tensor inside quantize_weights when it can't pay)
                let nch = if len % 4 == 0 && len >= 4 { 4 } else { 1 };
                let hint =
                    if g.bool() { ChannelHint::Rows(nch.max(1)) } else { ChannelHint::Cols(nch) };
                let bits = if g.bool() { Bits::B8 } else { Bits::B4 };
                WireTensor::Quant(QTensor::quantize_weights(&xs, hint, bits))
            }
        }
    }

    /// Uniformly draws from EVERY `Message` variant (23 as of codec v8).
    fn random_message(g: &mut G<'_>) -> Message {
        let blocks = |g: &mut G<'_>| -> Vec<WireBlock> {
            (0..g.usize_in(0, 3))
                .map(|i| {
                    let nt = g.usize_in(1, 3);
                    let len = g.size.min(16);
                    (i, (0..nt).map(|_| random_wire_tensor(g, len)).collect())
                })
                .collect()
        };
        let reports = |g: &mut G<'_>| -> Vec<ExecReport> {
            (0..g.usize_in(0, 4))
                .map(|d| ExecReport {
                    device: d,
                    avg_ms: g.f64_in(0.1, 50.0),
                    batches: g.usize_in(1, 64) as u32,
                })
                .collect()
        };
        match g.usize_in(0, 22) {
            0 => Message::Forward {
                batch: g.usize_in(0, 1000) as u64,
                version0: g.usize_in(0, 50) as u64,
                is_eval: g.bool(),
                data: match g.usize_in(0, 3) {
                    0 => Payload::F32(g.vec_f32(g.size).into()),
                    1 => Payload::I32((0..g.size).map(|i| i as i32 - 3).collect()),
                    2 => Payload::Quant(QTensor::quantize(&g.vec_f32(g.size))),
                    _ => Payload::Quant(QTensor::quantize_bits(&g.vec_f32(g.size), Bits::B4)),
                },
            },
            1 => Message::Labels {
                batch: g.usize_in(0, 99) as u64,
                is_eval: g.bool(),
                data: (0..g.usize_in(0, 20)).map(|i| i as i32).collect(),
            },
            2 => Message::Backward {
                batch: g.usize_in(0, 99) as u64,
                grad: {
                    let len = g.size;
                    random_wire_tensor(g, len)
                },
                loss: g.f64_in(0.0, 10.0) as f32,
                ncorrect: g.usize_in(0, 32) as f32,
                reports: reports(g),
            },
            3 => Message::EvalResult {
                batch: g.usize_in(0, 99) as u64,
                loss: g.f64_in(0.0, 5.0) as f32,
                ncorrect: g.usize_in(0, 32) as f32,
            },
            4 => Message::Probe,
            5 => Message::ProbeAck { id: g.usize_in(0, 9), fresh: g.bool() },
            6 => Message::InitState(TrainInit {
                committed_forward: g.usize_in(0, 100) as i64 - 1,
                committed_backward: g.usize_in(0, 100) as i64 - 1,
                lr: g.f64_in(1e-4, 0.5) as f32,
                momentum: g.f64_in(0.0, 0.99) as f32,
                weight_decay: g.f64_in(0.0, 1e-3) as f32,
                epochs: g.usize_in(1, 10) as u64,
                batches_per_epoch: g.usize_in(1, 500) as u64,
                ranges: (0..g.usize_in(1, 4)).map(|i| (i * 2, i * 2 + 1)).collect(),
                worker_list: (0..g.usize_in(1, 4)).collect(),
                agg_k: g.usize_in(0, 8) as u32,
                chain_every: g.usize_in(0, 100) as u64,
                global_every: g.usize_in(0, 200) as u64,
                status: u8::from(g.bool()),
                compression: *g.pick(&[
                    Compression::Off,
                    Compression::Activations,
                    Compression::Full,
                    Compression::FullQ4,
                    Compression::Adaptive,
                ]),
                bw_probe_every: g.usize_in(0, 16) as u64,
                bw_probe_bytes: g.usize_in(0, 1 << 16) as u64,
                tier_floor: Tier::Off,
                tier_ceiling: *g.pick(&[Tier::Activations, Tier::Full, Tier::FullQ4]),
                replica_epoch: g.usize_in(0, 4) as u64,
                worker_quota: g.usize_in(0, 64) as u64,
                // 1/0 (the elided single-chain defaults) stay in the mix
                replicas: g.usize_in(1, 4) as u64,
                sync_every: g.usize_in(0, 20) as u64,
            }),
            7 => Message::Repartition {
                ranges: (0..g.usize_in(1, 4)).map(|i| (i * 2, i * 2 + 1)).collect(),
                worker_list: (0..g.usize_in(1, 4)).collect(),
                failed: (0..g.usize_in(0, 2)).collect(),
            },
            8 => Message::FetchWeights { blocks: (0..g.usize_in(0, 8)).collect() },
            9 => Message::Weights { blocks: blocks(g) },
            10 => Message::ReplicaPush {
                kind: if g.bool() { ReplicaKind::Chain } else { ReplicaKind::Global },
                owner_stage: g.usize_in(0, 4),
                owner_device: g.usize_in(0, 4),
                version: g.usize_in(0, 100) as u64,
                blocks: blocks(g),
            },
            11 => Message::FetchDone { id: g.usize_in(0, 9) },
            12 => Message::Commit,
            13 => Message::Reset { committed: g.usize_in(0, 100) as i64 - 1 },
            14 => Message::BwTest {
                payload_bytes: g.usize_in(0, 100) as u32,
                data: (0..g.usize_in(0, 64)).map(|i| i as u8).collect(),
            },
            15 => Message::BwAck { payload_bytes: g.usize_in(0, 1 << 20) as u32 },
            16 => Message::BwReport {
                stage: g.usize_in(0, 5),
                bps: g.f64_in(1e3, 1e9),
                // 0 (the elided "unknown" sentinel) must stay in the mix
                to: g.usize_in(0, 6),
            },
            17 => Message::SetLr { lr: g.f64_in(1e-5, 0.5) as f32 },
            18 => Message::CentralRestart { committed: g.usize_in(0, 500) as i64 - 1 },
            19 => Message::WorkerState {
                id: g.usize_in(0, 9),
                committed_fwd: g.usize_in(0, 500) as i64 - 1,
                committed_bwd: g.usize_in(0, 500) as i64 - 1,
                fresh: g.bool(),
            },
            20 => Message::SetCompression {
                tier: *g.pick(&[Tier::Off, Tier::Activations, Tier::Full, Tier::FullQ4]),
                links: (0..g.usize_in(0, 4))
                    .map(|i| {
                        (
                            i + g.usize_in(1, 3),
                            *g.pick(&[Tier::Off, Tier::Activations, Tier::Full, Tier::FullQ4]),
                        )
                    })
                    .collect(),
            },
            21 => Message::ReplicaSync {
                round: g.usize_in(0, 50) as u64,
                block_id: g.usize_in(0, 15),
                tensors: {
                    let nt = g.usize_in(0, 3);
                    let len = g.size.min(16);
                    (0..nt).map(|_| random_wire_tensor(g, len)).collect()
                },
            },
            _ => Message::Shutdown,
        }
    }
}
