//! The message set exchanged between devices.
//!
//! Data plane: `Forward` activations, `Labels` (central -> last stage),
//! `Backward` gradients (carrying loss + per-device execution reports back
//! to the central node, as the paper piggybacks profiling data on
//! gradients, §III-D). Control plane: everything the init, dynamic
//! re-partition, replication, and fault-tolerance protocols need (§III-B/E/F).

use super::buf::TensorBuf;
use super::quant::{Bits, ChannelHint, Compression, QTensor, Tier, WeightCoding};

/// Physical device id (stable across re-partitions; stage indices map to
/// device ids through the worker list).
pub type DeviceId = usize;

/// Activation payload entering a stage (shared f32 acts, i32 tokens, or
/// a quantized activation). The f32/quant arms are `Arc`-backed:
/// cloning the payload (or the whole message) shares the buffer instead
/// of copying it.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(TensorBuf),
    I32(Vec<i32>),
    /// Affine-quantized activation (see [`crate::net::quant`]) — in
    /// practice always the per-tensor INT8 arm; the wire self-describes
    /// the scheme either way.
    Quant(QTensor),
}

impl Payload {
    pub fn byte_len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len() * 4,
            Payload::I32(v) => v.len() * 4,
            Payload::Quant(q) => q.byte_len(),
        }
    }
}

/// A tensor on the wire: full-precision (shared buffer, zero-copy) or
/// quantized (INT8 or packed INT4, per-tensor or per-channel scales —
/// the [`QTensor`] self-describes its arm). Gradients and the tensors
/// inside [`WireBlock`]s travel as `WireTensor`s;
/// [`WireTensor::into_f32`] is the receiver-boundary dequantization
/// step (a move for the f32 arm).
#[derive(Debug, Clone, PartialEq)]
pub enum WireTensor {
    F32(TensorBuf),
    Quant(QTensor),
}

impl WireTensor {
    pub fn len(&self) -> usize {
        match self {
            WireTensor::F32(t) => t.len(),
            WireTensor::Quant(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload bytes on the wire (the bandwidth model's currency).
    pub fn byte_len(&self) -> usize {
        match self {
            WireTensor::F32(t) => t.len() * 4,
            WireTensor::Quant(q) => q.byte_len(),
        }
    }

    pub fn as_f32(&self) -> Option<&TensorBuf> {
        match self {
            WireTensor::F32(t) => Some(t),
            WireTensor::Quant(_) => None,
        }
    }

    pub fn as_quant(&self) -> Option<&QTensor> {
        match self {
            WireTensor::Quant(q) => Some(q),
            WireTensor::F32(_) => None,
        }
    }

    /// Materialize as f32: a move (no copy) for the f32 arm, the single
    /// dequantization write for the quantized arm.
    pub fn into_f32(self) -> TensorBuf {
        match self {
            WireTensor::F32(t) => t,
            WireTensor::Quant(q) => q.dequantize(),
        }
    }

    /// Wrap one weight tensor for the wire under `coding`, applying
    /// per-channel scales where the shape-derived `hint` says they pay
    /// (see [`crate::net::quant::weight_channel_hint`]).
    pub fn from_weights(t: &TensorBuf, coding: WeightCoding, hint: ChannelHint) -> WireTensor {
        match coding {
            WeightCoding::F32 => WireTensor::F32(t.clone()),
            WeightCoding::Q8 => WireTensor::Quant(QTensor::quantize_weights(t, hint, Bits::B8)),
            WeightCoding::Q4 => WireTensor::Quant(QTensor::quantize_weights(t, hint, Bits::B4)),
        }
    }
}

impl From<TensorBuf> for WireTensor {
    fn from(t: TensorBuf) -> WireTensor {
        WireTensor::F32(t)
    }
}

impl From<Vec<f32>> for WireTensor {
    fn from(v: Vec<f32>) -> WireTensor {
        WireTensor::F32(TensorBuf::new(v))
    }
}

impl From<QTensor> for WireTensor {
    fn from(q: QTensor) -> WireTensor {
        WireTensor::Quant(q)
    }
}

/// Which replication schedule produced a backup (paper §III-E).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaKind {
    /// every worker -> its next worker (last -> central)
    Chain,
    /// every worker -> central
    Global,
}

/// Execution-time report piggybacked on backward messages: average
/// fwd+bwd wall time per batch on that device since the last report.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecReport {
    pub device: DeviceId,
    pub avg_ms: f64,
    pub batches: u32,
}

/// State variables sent at training initialization (paper Table I).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainInit {
    pub committed_forward: i64,
    pub committed_backward: i64,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub epochs: u64,
    pub batches_per_epoch: u64,
    /// stage cut points: block range (start, end) inclusive per stage.
    pub ranges: Vec<(usize, usize)>,
    pub worker_list: Vec<DeviceId>,
    /// aggregation interval factor k (0 = disabled)
    pub agg_k: u32,
    pub chain_every: u64,
    pub global_every: u64,
    /// 0 = normal, 1 = fault recovery in progress (paper `status`)
    pub status: u8,
    /// Wire-compression policy, distributed cluster-wide at init. The
    /// wire is self-describing, so a sender/receiver tier mismatch is
    /// never a decode error — the policy only selects what each sender
    /// *produces* (initially; `Adaptive` retunes via `SetCompression`).
    pub compression: Compression,
    /// Re-measure the link to the next worker every this many batches
    /// (paper §III-B's measurement, made periodic so the adaptive
    /// policy sees degradation). 0 = only the one-shot init probe.
    pub bw_probe_every: u64,
    /// Fixed payload for those periodic probes. 0 = auto-size from the
    /// last measured rate (a fixed small echo is latency-capped at
    /// `payload / rtt` and would mis-measure fast links).
    pub bw_probe_bytes: u64,
    /// Band the effective tier may move in (`tier_floor` ..=
    /// `tier_ceiling`): every stage clamps its tier into it at init and
    /// on every `SetCompression`, so a floor takes effect without any
    /// broadcast and one bad link can never down-tier the fleet past
    /// the ceiling. The full-ladder defaults (`Off`/`FullQ4`) are
    /// byte-for-byte the pre-band behavior.
    pub tier_floor: Tier,
    /// See [`TrainInit::tier_floor`].
    pub tier_ceiling: Tier,
    /// Coordinator restart epoch folded into every replica version
    /// (high bits — see [`crate::replication::epoch_version`]). Bumped
    /// once per coordinator restart so pre-restart backups can never
    /// shadow post-restart pushes (DESIGN.md §9's case-2 wart). 0 until
    /// the first restart, which keeps historical runs byte-identical.
    pub replica_epoch: u64,
    /// Admission quota the coordinator is enforcing (0 = unlimited) —
    /// informational for workers; the roster itself lives coordinator-
    /// side ([`crate::coordinator::WorkerRoster`], DESIGN.md §12).
    pub worker_quota: u64,
    /// Pipeline replicas in the run (hybrid pipeline + data parallelism,
    /// DESIGN.md §14). 1 = the historical single-chain world; encoded as
    /// a v8 optional-trailing field so default-valued frames keep their
    /// v7 byte pattern.
    pub replicas: u64,
    /// Cross-replica weight-sync period in committed batches per chain
    /// (0 = never). Same v8 optional-trailing encoding as `replicas`.
    pub sync_every: u64,
}

/// A block's tensors on the wire — shared buffers (or quantized bytes),
/// so building a `Weights`/`ReplicaPush` message from a parameter store
/// is refcount bumps (plus an optional INT8 pass), never a deep f32 copy
/// of the stage's weights.
pub type WireBlock = (usize, Vec<WireTensor>);

#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    // ---------------- data plane ----------------
    Forward {
        batch: u64,
        /// weight version at stage 0 when injected (vertical-sync tag).
        version0: u64,
        is_eval: bool,
        data: Payload,
    },
    Labels {
        batch: u64,
        is_eval: bool,
        data: Vec<i32>,
    },
    Backward {
        batch: u64,
        /// f32 or INT8-quantized per the sender's [`Compression`] policy
        /// (quantized gradients carry error feedback on the sender side).
        grad: WireTensor,
        /// loss/ncorrect measured at the last stage, carried to central.
        loss: f32,
        ncorrect: f32,
        /// exec reports appended by each stage as the gradient flows back.
        reports: Vec<ExecReport>,
    },
    EvalResult {
        batch: u64,
        loss: f32,
        ncorrect: f32,
    },

    // ---------------- control plane ----------------
    Probe,
    ProbeAck {
        id: DeviceId,
        /// true when the device restarted and lost its state (paper case 2)
        fresh: bool,
    },
    InitState(TrainInit),
    /// New partition after dynamic re-partition or fault recovery.
    Repartition {
        ranges: Vec<(usize, usize)>,
        worker_list: Vec<DeviceId>,
        /// stage indices (in the OLD list) that failed; empty for dynamic.
        failed: Vec<usize>,
    },
    /// Request blocks from a peer (redistribution / restore).
    FetchWeights {
        blocks: Vec<usize>,
    },
    /// Reply to FetchWeights — blocks the peer could serve.
    Weights {
        blocks: Vec<WireBlock>,
    },
    /// Periodic weight backup (paper §III-E).
    ReplicaPush {
        kind: ReplicaKind,
        owner_stage: usize,
        owner_device: DeviceId,
        version: u64,
        blocks: Vec<WireBlock>,
    },
    /// Worker -> central: finished fetching all needed weights.
    FetchDone {
        id: DeviceId,
    },
    /// Central -> workers: everyone fetched; swap to the new sub-model.
    Commit,
    /// Reset committed ids; discard in-flight batches beyond `committed`.
    Reset {
        committed: i64,
    },
    /// Bandwidth measurement: central asks `Probe`-style echo with payload.
    BwTest {
        payload_bytes: u32,
        data: Vec<u8>,
    },
    BwAck {
        payload_bytes: u32,
    },
    /// Central -> workers: learning-rate change (paper §IV-C drops the
    /// lr at epoch 130; the schedule lives in RunConfig::lr_drops).
    SetLr {
        lr: f32,
    },
    /// Worker -> central: measured bandwidth of its link to the next
    /// worker (paper §III-B: "the i-th worker measures the bandwidth
    /// between itself and its next worker, B_{i,i+1}"). `to` names the
    /// probed destination *device* so the coordinator can key its
    /// per-link ladder by something that survives renumbering; `to == 0`
    /// means "unknown" (a pre-v7 peer — probe destinations are never the
    /// central device), and the coordinator falls back to resolving
    /// `stage` against the live worker list.
    BwReport {
        stage: usize,
        bps: f64,
        to: DeviceId,
    },
    /// Central -> workers after a coordinator reboot (paper §III-E): the
    /// central node recovered from its periodic checkpoint, whose newest
    /// committed batch is `committed`. Receivers pause, drop protocol
    /// state the dead coordinator can no longer complete (an in-flight
    /// redistribution, replica version numbering) plus any work beyond
    /// `committed`, and answer with [`Message::WorkerState`].
    CentralRestart {
        committed: i64,
    },
    /// Worker -> central: progress report for restart reconciliation —
    /// what this worker had committed when the coordinator came back,
    /// and whether it lost its own state too (`fresh`, like ProbeAck).
    WorkerState {
        id: DeviceId,
        committed_fwd: i64,
        committed_bwd: i64,
        fresh: bool,
    },
    /// Central -> workers under [`Compression::Adaptive`]: install the
    /// per-link tier table (DESIGN.md §10). `tier` is the default for
    /// every destination not listed; `links` are the per-destination
    /// overrides, sorted ascending by device id. Receivers *replace*
    /// their whole outgoing tier map (so stale overrides cannot linger)
    /// and clear error-feedback residuals on any effective change;
    /// decoding never depends on it (tensors self-describe their arm),
    /// so the handshake needs no barrier and cannot corrupt in-flight
    /// traffic.
    SetCompression {
        tier: Tier,
        links: Vec<(DeviceId, Tier)>,
    },
    /// Cross-replica weight sync (hybrid pipeline + data parallelism,
    /// DESIGN.md §14). Chain heads send their per-replica partials for
    /// one block to the central node every `sync_every` committed
    /// batches; the central node averages the live chains' partials and
    /// broadcasts the result back in the same message shape. The tensors
    /// ride the same [`WireTensor`] arms as replica pushes, so sync
    /// traffic inherits the per-link compression ladders.
    ReplicaSync {
        round: u64,
        block_id: usize,
        tensors: Vec<WireTensor>,
    },
    Shutdown,
}

impl Message {
    /// Human-readable tag (logging/tracing).
    pub fn tag(&self) -> &'static str {
        match self {
            Message::Forward { .. } => "Forward",
            Message::Labels { .. } => "Labels",
            Message::Backward { .. } => "Backward",
            Message::EvalResult { .. } => "EvalResult",
            Message::Probe => "Probe",
            Message::ProbeAck { .. } => "ProbeAck",
            Message::InitState(_) => "InitState",
            Message::Repartition { .. } => "Repartition",
            Message::FetchWeights { .. } => "FetchWeights",
            Message::Weights { .. } => "Weights",
            Message::ReplicaPush { .. } => "ReplicaPush",
            Message::FetchDone { .. } => "FetchDone",
            Message::Commit => "Commit",
            Message::Reset { .. } => "Reset",
            Message::BwTest { .. } => "BwTest",
            Message::BwAck { .. } => "BwAck",
            Message::BwReport { .. } => "BwReport",
            Message::SetLr { .. } => "SetLr",
            Message::CentralRestart { .. } => "CentralRestart",
            Message::WorkerState { .. } => "WorkerState",
            Message::SetCompression { .. } => "SetCompression",
            Message::ReplicaSync { .. } => "ReplicaSync",
            Message::Shutdown => "Shutdown",
        }
    }

    /// Approximate wire size (drives the bandwidth model; the codec's
    /// exact framing differs by a few header bytes). Quantized tensors
    /// report their compressed size, so the virtual network prices the
    /// compression win; with [`Compression::Off`] every value here is
    /// byte-identical to the pre-quantization format.
    pub fn byte_len(&self) -> usize {
        let blocks_len = |blocks: &[WireBlock]| {
            blocks
                .iter()
                .map(|(_, ts)| 8 + ts.iter().map(|t| 4 + t.byte_len()).sum::<usize>())
                .sum::<usize>()
        };
        16 + match self {
            Message::Forward { data, .. } => data.byte_len(),
            Message::Labels { data, .. } => data.len() * 4,
            Message::Backward { grad, reports, .. } => grad.byte_len() + reports.len() * 20,
            Message::EvalResult { .. } => 16,
            Message::Probe | Message::ProbeAck { .. } => 8,
            // Pricing formula, not serialization: deliberately does NOT
            // grow with newer TrainInit fields (replica_epoch,
            // worker_quota, the tier band...) so the bandwidth model —
            // and every recorded Off-mode scenario trace — stays
            // byte-identical as the init handshake evolves.
            Message::InitState(ti) => 64 + ti.ranges.len() * 16 + ti.worker_list.len() * 8,
            Message::Repartition { ranges, worker_list, failed } => {
                ranges.len() * 16 + worker_list.len() * 8 + failed.len() * 8
            }
            Message::FetchWeights { blocks } => blocks.len() * 8,
            Message::Weights { blocks } => blocks_len(blocks),
            Message::ReplicaPush { blocks, .. } => 24 + blocks_len(blocks),
            Message::FetchDone { .. } => 8,
            Message::Commit | Message::Shutdown => 0,
            Message::Reset { .. } => 8,
            Message::BwTest { data, .. } => 4 + data.len(),
            Message::BwAck { .. } => 4,
            // Pricing stays fixed (same rationale as InitState above):
            // the BwReport `to` field and the SetCompression override
            // list are control-plane metadata a few bytes long, and
            // pricing them would shift every adaptive-mode trace.
            Message::BwReport { .. } => 16,
            Message::SetLr { .. } => 4,
            Message::CentralRestart { .. } => 8,
            Message::WorkerState { .. } => 25,
            Message::SetCompression { .. } => 1,
            // Frozen pricing formula (same contract as the arms above):
            // 16 header bytes (round + block_id) plus 4 + payload per
            // tensor, mirroring the per-tensor term of `blocks_len`.
            Message::ReplicaSync { tensors, .. } => {
                16 + tensors.iter().map(|t| 4 + t.byte_len()).sum::<usize>()
            }
        }
    }
}
