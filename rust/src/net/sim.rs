//! In-process simulated network: per-link bandwidth + latency modeling,
//! fault injection, and byte accounting.
//!
//! Substitution rationale (DESIGN.md §3): the paper's devices talk over
//! WiFi links of a few MB/s. Every message here traverses a per-link
//! "wire" thread that sleeps `latency + bytes/bandwidth` before delivery,
//! so transfer costs appear in wall-clock exactly where the paper's do —
//! serialized per link, overlapped with compute on other devices. Killing
//! a device silently drops its traffic, which is precisely what a crashed
//! Flask worker looks like to the others (timeouts, not errors).
//!
//! Zero-copy: messages move by value through the wire threads — no codec
//! pass, no frame buffer. With `TensorBuf`-backed payloads the receiver
//! gets the sender's exact allocation (asserted below and in
//! `rust/tests/zero_copy.rs`); only the *modeled* byte count is charged.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::message::{DeviceId, Message};
use super::{PeerHealth, Transport};
use crate::sim::clock::{real_clock, SharedClock};

struct WireItem {
    to: DeviceId,
    from: DeviceId,
    msg: Message,
    transfer: Duration,
}

struct Inner {
    n: usize,
    latency: Duration,
    /// bandwidth (bytes/s) of adjacent link i<->i+1; single entry = global.
    bw: Vec<f64>,
    dead: Vec<AtomicBool>,
    inbox_tx: Vec<Sender<(DeviceId, Message)>>,
    links: Mutex<HashMap<(DeviceId, DeviceId), Sender<WireItem>>>,
    pub total_bytes: AtomicU64,
    pub bytes_out: Vec<AtomicU64>,
    /// messages delivered (for tests)
    pub delivered: AtomicU64,
    /// messages accepted but not yet through their wire thread — what
    /// `Transport::flush` waits on (net-wide: the wire is shared)
    in_flight: AtomicU64,
}

impl Inner {
    /// Effective bandwidth between two (possibly non-adjacent) devices:
    /// the min over the chain of links between them (conservative).
    fn bandwidth(&self, a: DeviceId, b: DeviceId) -> f64 {
        if self.bw.len() == 1 {
            return self.bw[0];
        }
        let (lo, hi) = (a.min(b), a.max(b));
        if lo == hi {
            return f64::INFINITY;
        }
        self.bw[lo..hi].iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

/// Shared handle: fault injection + accounting (held by the test driver).
#[derive(Clone)]
pub struct SimNet {
    inner: Arc<Inner>,
}

/// A device's endpoint (owns the unique inbox receiver).
pub struct SimEndpoint {
    id: DeviceId,
    inner: Arc<Inner>,
    inbox_rx: Receiver<(DeviceId, Message)>,
    /// peer -> when this endpoint last received from it (real clock;
    /// feeds `Transport::peer_health`, does not touch the cost model)
    last_seen: Mutex<HashMap<DeviceId, Duration>>,
    clock: SharedClock,
}

impl SimNet {
    /// Build an `n`-device network. `bw` has 1 (global) or n-1 (per-link)
    /// entries in bytes/sec.
    pub fn new(n: usize, bw: Vec<f64>, latency: Duration) -> (SimNet, Vec<SimEndpoint>) {
        assert!(n >= 1);
        assert!(bw.len() == 1 || bw.len() == n - 1, "bw entries");
        let mut inbox_tx = Vec::with_capacity(n);
        let mut inbox_rx = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            inbox_tx.push(tx);
            inbox_rx.push(rx);
        }
        let inner = Arc::new(Inner {
            n,
            latency,
            bw,
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
            inbox_tx,
            links: Mutex::new(HashMap::new()),
            total_bytes: AtomicU64::new(0),
            bytes_out: (0..n).map(|_| AtomicU64::new(0)).collect(),
            delivered: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
        });
        let endpoints = inbox_rx
            .into_iter()
            .enumerate()
            .map(|(id, rx)| SimEndpoint {
                id,
                inner: inner.clone(),
                inbox_rx: rx,
                last_seen: Mutex::new(HashMap::new()),
                clock: real_clock(),
            })
            .collect();
        (SimNet { inner }, endpoints)
    }

    /// Kill a device: its traffic (both directions) is dropped from now on.
    pub fn kill(&self, d: DeviceId) {
        self.inner.dead[d].store(true, Ordering::SeqCst);
    }

    /// Revive a device (paper case 2: "restarts as soon as it failed").
    pub fn revive(&self, d: DeviceId) {
        self.inner.dead[d].store(false, Ordering::SeqCst);
    }

    pub fn is_dead(&self, d: DeviceId) -> bool {
        self.inner.dead[d].load(Ordering::SeqCst)
    }

    pub fn total_bytes(&self) -> u64 {
        self.inner.total_bytes.load(Ordering::Relaxed)
    }

    pub fn bytes_out(&self, d: DeviceId) -> u64 {
        self.inner.bytes_out[d].load(Ordering::Relaxed)
    }

    pub fn delivered(&self) -> u64 {
        self.inner.delivered.load(Ordering::Relaxed)
    }

    pub fn n_devices(&self) -> usize {
        self.inner.n
    }
}

fn send_impl(inner: &Arc<Inner>, from: DeviceId, to: DeviceId, msg: Message) -> Result<()> {
    if inner.dead[from].load(Ordering::SeqCst) || inner.dead[to].load(Ordering::SeqCst) {
        return Ok(()); // dropped silently — the receiver just never hears it
    }
    let bytes = msg.byte_len();
    inner.total_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    inner.bytes_out[from].fetch_add(bytes as u64, Ordering::Relaxed);
    let bwv = inner.bandwidth(from, to);
    let transfer = if bwv.is_finite() {
        Duration::from_secs_f64(bytes as f64 / bwv)
    } else {
        Duration::ZERO
    };
    // One wire thread per directed pair, created lazily; it serializes
    // transfers on that link and delivers after the modeled delay.
    let tx = {
        let mut links = inner.links.lock().unwrap();
        links
            .entry((from, to))
            .or_insert_with(|| {
                let (tx, rx) = channel::<WireItem>();
                let inner2 = inner.clone();
                std::thread::Builder::new()
                    .name(format!("wire-{from}-{to}"))
                    .spawn(move || {
                        while let Ok(item) = rx.recv() {
                            std::thread::sleep(inner2.latency + item.transfer);
                            if !inner2.dead[item.to].load(Ordering::SeqCst)
                                && !inner2.dead[item.from].load(Ordering::SeqCst)
                            {
                                if inner2.inbox_tx[item.to]
                                    .send((item.from, item.msg))
                                    .is_ok()
                                {
                                    inner2.delivered.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            // off the wire (delivered or dropped): flush
                            // barriers stop waiting on this message
                            inner2.in_flight.fetch_sub(1, Ordering::SeqCst);
                        }
                    })
                    .expect("spawn wire thread");
                tx
            })
            .clone()
    };
    inner.in_flight.fetch_add(1, Ordering::SeqCst);
    if tx.send(WireItem { to, from, msg, transfer }).is_err() {
        inner.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
    Ok(())
}

impl Transport for SimEndpoint {
    fn my_id(&self) -> DeviceId {
        self.id
    }

    fn send(&self, to: DeviceId, msg: Message) -> Result<()> {
        send_impl(&self.inner, self.id, to, msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<(DeviceId, Message)> {
        if self.inner.dead[self.id].load(Ordering::SeqCst) {
            // a dead device hears nothing
            std::thread::sleep(timeout.min(Duration::from_millis(20)));
            return None;
        }
        let got = self.inbox_rx.recv_timeout(timeout).ok();
        if let Some((from, _)) = &got {
            self.last_seen.lock().unwrap().insert(*from, self.clock.now());
        }
        got
    }

    fn n_devices(&self) -> usize {
        self.inner.n
    }

    fn peer_health(&self, peer: DeviceId) -> PeerHealth {
        SimEndpoint::peer_health(self, peer)
    }

    fn flush(&self, timeout: Duration) -> Result<()> {
        SimEndpoint::flush(self, timeout)
    }

    fn shutdown(&self) {
        SimEndpoint::shutdown(self)
    }
}

impl SimEndpoint {
    /// Drain anything already queued without waiting.
    pub fn try_drain(&self) -> Vec<(DeviceId, Message)> {
        let mut out = Vec::new();
        while let Ok(m) = self.inbox_rx.try_recv() {
            out.push(m);
        }
        for (from, _) in &out {
            self.last_seen.lock().unwrap().insert(*from, self.clock.now());
        }
        out
    }

    /// Health books about `peer`. The sim has perfect knowledge: RTT is
    /// the modeled round trip (2× link latency), failures report whether
    /// the peer is currently dead, last-seen tracks real receipts.
    pub fn peer_health(&self, peer: DeviceId) -> PeerHealth {
        PeerHealth {
            last_seen: self.last_seen.lock().unwrap().get(&peer).copied(),
            rtt: Some(self.inner.latency * 2),
            consecutive_failures: u32::from(self.inner.dead[peer].load(Ordering::SeqCst)),
        }
    }

    /// Wait for the modeled wire to quiesce (net-wide: the wire threads
    /// are shared, so this is a superset of "this endpoint's sends").
    /// Messages to/from dead devices are dropped at accept time and
    /// never occupy the wire.
    pub fn flush(&self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            let n = self.inner.in_flight.load(Ordering::SeqCst);
            if n == 0 {
                return Ok(());
            }
            if Instant::now() >= deadline {
                bail!("flush timed out with {n} message(s) on the wire");
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Teardown = this device leaves the net: subsequent sends and
    /// receives drop, exactly like [`SimNet::kill`] on itself.
    pub fn shutdown(&self) {
        self.inner.dead[self.id].store(true, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn probe() -> Message {
        Message::Probe
    }

    #[test]
    fn basic_delivery() {
        let (_net, mut eps) = SimNet::new(2, vec![1e9], Duration::ZERO);
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        a.send(1, probe()).unwrap();
        let (from, msg) = b.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(from, 0);
        assert_eq!(msg, Message::Probe);
    }

    #[test]
    fn bandwidth_delays_large_messages() {
        // 400 KB at 4 MB/s => ~100 ms
        let (_net, eps) = SimNet::new(2, vec![4e6], Duration::ZERO);
        let data = vec![0f32; 100_000];
        let t0 = Instant::now();
        eps[0]
            .send(1, Message::Weights { blocks: vec![(0, vec![data.into()])] })
            .unwrap();
        let got = eps[1].recv_timeout(Duration::from_secs(2));
        let dt = t0.elapsed();
        assert!(got.is_some());
        assert!(dt >= Duration::from_millis(80), "dt={dt:?}");
        assert!(dt < Duration::from_millis(500), "dt={dt:?}");
    }

    #[test]
    fn latency_applies_to_small_messages() {
        let (_net, eps) = SimNet::new(2, vec![1e9], Duration::from_millis(30));
        let t0 = Instant::now();
        eps[0].send(1, probe()).unwrap();
        assert!(eps[1].recv_timeout(Duration::from_secs(1)).is_some());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn killed_device_drops_traffic_both_ways() {
        let (net, eps) = SimNet::new(3, vec![1e9], Duration::ZERO);
        net.kill(1);
        eps[0].send(1, probe()).unwrap(); // to dead: dropped
        eps[1].send(2, probe()).unwrap(); // from dead: dropped
        assert!(eps[1].recv_timeout(Duration::from_millis(50)).is_none());
        assert!(eps[2].recv_timeout(Duration::from_millis(50)).is_none());
        // but 0 -> 2 still works
        eps[0].send(2, probe()).unwrap();
        assert!(eps[2].recv_timeout(Duration::from_secs(1)).is_some());
    }

    #[test]
    fn revive_restores_delivery() {
        let (net, eps) = SimNet::new(2, vec![1e9], Duration::ZERO);
        net.kill(1);
        eps[0].send(1, probe()).unwrap();
        assert!(eps[1].recv_timeout(Duration::from_millis(50)).is_none());
        net.revive(1);
        eps[0].send(1, probe()).unwrap();
        assert!(eps[1].recv_timeout(Duration::from_secs(1)).is_some());
    }

    #[test]
    fn non_adjacent_bandwidth_is_min_of_chain() {
        let (net, _eps) = SimNet::new(3, vec![8e6, 2e6], Duration::ZERO);
        assert_eq!(net.inner.bandwidth(0, 2), 2e6);
        assert_eq!(net.inner.bandwidth(0, 1), 8e6);
        assert_eq!(net.inner.bandwidth(2, 1), 2e6);
    }

    #[test]
    fn byte_accounting() {
        let (net, eps) = SimNet::new(2, vec![1e9], Duration::ZERO);
        let msg = Message::Labels { batch: 0, is_eval: false, data: vec![0; 100] };
        let expect = msg.byte_len() as u64;
        eps[0].send(1, msg).unwrap();
        let _ = eps[1].recv_timeout(Duration::from_secs(1));
        assert_eq!(net.total_bytes(), expect);
        assert_eq!(net.bytes_out(0), expect);
        assert_eq!(net.bytes_out(1), 0);
    }

    #[test]
    fn delivery_is_zero_copy_for_tensor_payloads() {
        use crate::net::TensorBuf;
        let (_net, eps) = SimNet::new(2, vec![1e9], Duration::ZERO);
        let t = TensorBuf::from(vec![0.25f32; 4096]);
        eps[0]
            .send(
                1,
                Message::Forward {
                    batch: 0,
                    version0: 0,
                    is_eval: false,
                    data: crate::net::Payload::F32(t.clone()),
                },
            )
            .unwrap();
        match eps[1].recv_timeout(Duration::from_secs(1)) {
            Some((0, Message::Forward { data: crate::net::Payload::F32(got), .. })) => {
                assert!(got.ptr_eq(&t), "sim delivery must share the sender's allocation");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn flush_waits_for_the_modeled_wire() {
        // 400 KB at 4 MB/s => ~100 ms on the wire; flush must block
        // until the transfer clears, then the receipt is immediate
        let (_net, eps) = SimNet::new(2, vec![4e6], Duration::ZERO);
        let data = vec![0f32; 100_000];
        let t0 = Instant::now();
        eps[0].send(1, Message::Weights { blocks: vec![(0, vec![data.into()])] }).unwrap();
        eps[0].flush(Duration::from_secs(5)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(80), "flush returned mid-transfer");
        assert!(eps[1].recv_timeout(Duration::from_millis(50)).is_some());
    }

    #[test]
    fn peer_health_reflects_the_model() {
        let (net, eps) = SimNet::new(2, vec![1e9], Duration::from_millis(15));
        assert_eq!(eps[0].peer_health(1).rtt, Some(Duration::from_millis(30)));
        assert_eq!(eps[0].peer_health(1).consecutive_failures, 0);
        assert_eq!(eps[0].peer_health(1).last_seen, None);
        eps[1].send(0, probe()).unwrap();
        assert!(eps[0].recv_timeout(Duration::from_secs(1)).is_some());
        assert!(eps[0].peer_health(1).last_seen.is_some());
        net.kill(1);
        assert_eq!(eps[0].peer_health(1).consecutive_failures, 1);
    }

    #[test]
    fn shutdown_removes_the_device_from_the_net() {
        let (net, eps) = SimNet::new(2, vec![1e9], Duration::ZERO);
        eps[0].shutdown();
        assert!(net.is_dead(0));
        eps[0].send(1, probe()).unwrap(); // silently dropped
        assert!(eps[1].recv_timeout(Duration::from_millis(50)).is_none());
    }

    #[test]
    fn per_link_fifo_order() {
        let (_net, eps) = SimNet::new(2, vec![1e9], Duration::ZERO);
        for b in 0..20u64 {
            eps[0]
                .send(1, Message::Labels { batch: b, is_eval: false, data: vec![] })
                .unwrap();
        }
        for b in 0..20u64 {
            match eps[1].recv_timeout(Duration::from_secs(1)) {
                Some((_, Message::Labels { batch, .. })) => assert_eq!(batch, b),
                other => panic!("unexpected {other:?}"),
            }
        }
    }
}
