//! Networking: shared tensor buffers, message types, binary codec, and
//! the [`Transport`] abstraction with two implementations — [`sim::SimNet`]
//! (bandwidth/latency-modeled in-process links with fault injection; the
//! default testbed, DESIGN.md §3) and [`tcp`] (real sockets for
//! multi-process deployment, the analogue of the paper's Flask HTTP
//! transport). Hot-path payloads are [`TensorBuf`]-backed: cloning and
//! queueing a message never copies tensor data (see `net/buf.rs`).

pub mod buf;
pub mod codec;
pub mod message;
pub mod quant;
pub mod sim;
pub mod tcp;

pub use buf::TensorBuf;
pub use message::{DeviceId, Message, Payload, ReplicaKind, WireTensor};
pub use quant::{Compression, QTensor, Residual};

use std::time::Duration;

use anyhow::Result;

/// A device's endpoint into the network.
pub trait Transport: Send {
    fn my_id(&self) -> DeviceId;
    /// Fire-and-forget send (delivery is asynchronous; lost if target dead).
    fn send(&self, to: DeviceId, msg: Message) -> Result<()>;
    /// Receive the next message, waiting up to `timeout`.
    fn recv_timeout(&self, timeout: Duration) -> Option<(DeviceId, Message)>;
    /// Number of devices in the network.
    fn n_devices(&self) -> usize;
}
