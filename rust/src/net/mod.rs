//! Networking: shared tensor buffers, message types, binary codec, and
//! the [`Transport`] abstraction with two implementations — [`SimNet`]
//! (bandwidth/latency-modeled in-process links with fault injection; the
//! default testbed, DESIGN.md §3) and [`TcpEndpoint`] (real nonblocking
//! sockets behind the [`reactor`] event loop for multi-process
//! deployment, the analogue of the paper's Flask HTTP transport;
//! DESIGN.md §13). Hot-path payloads are [`TensorBuf`]-backed: cloning
//! and queueing a message never copies tensor data (see `net/buf.rs`).
//!
//! This module is the consolidated public surface: callers use
//! `net::{Transport, TcpEndpoint, TcpConfig, SimNet, encode, decode}`
//! rather than reaching through submodule paths.

pub mod buf;
pub mod codec;
pub mod message;
pub mod quant;
pub mod reactor;
pub mod sim;
pub mod tcp;

pub use buf::TensorBuf;
pub use codec::{decode, encode, encode_into, CODEC_VERSION, MAX_FRAME};
pub use message::{DeviceId, Message, Payload, ReplicaKind, WireTensor};
pub use quant::{Compression, QTensor, Residual};
pub use sim::{SimEndpoint, SimNet};
pub use tcp::{loopback_cluster, TcpConfig, TcpConfigBuilder, TcpEndpoint};

use std::time::Duration;

use anyhow::Result;

/// A peer-health snapshot, as observed by one endpoint about another
/// (see [`Transport::peer_health`]). Every field is "unknown" until the
/// transport has evidence — [`PeerHealth::default`] is the honest answer
/// for a peer never heard from.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeerHealth {
    /// When this endpoint last received anything from the peer, on the
    /// transport's clock.
    pub last_seen: Option<Duration>,
    /// Round-trip estimate, fed by the existing `Probe`/`BwTest` ack
    /// traffic (EWMA on TCP; the modeled 2×latency on the sim net).
    pub rtt: Option<Duration>,
    /// Consecutive failed delivery/connect attempts since the peer was
    /// last heard from. `0` for a healthy (or never-contacted) peer.
    pub consecutive_failures: u32,
}

/// A device's endpoint into the network.
pub trait Transport: Send {
    fn my_id(&self) -> DeviceId;
    /// Fire-and-forget send (delivery is asynchronous; lost if target dead).
    fn send(&self, to: DeviceId, msg: Message) -> Result<()>;
    /// Receive the next message, waiting up to `timeout`.
    fn recv_timeout(&self, timeout: Duration) -> Option<(DeviceId, Message)>;
    /// Number of devices in the network.
    fn n_devices(&self) -> usize;

    /// Health bookkeeping for `peer`. Transports that keep no books
    /// return [`PeerHealth::default`] (everything unknown).
    fn peer_health(&self, _peer: DeviceId) -> PeerHealth {
        PeerHealth::default()
    }

    /// Block until every send already accepted by this endpoint has left
    /// it — handed to the OS or dropped as undeliverable — or `timeout`
    /// passes (then `Err` with the outstanding count). This is a local
    /// barrier, not a delivery guarantee. Queue-less transports return
    /// `Ok` immediately.
    fn flush(&self, _timeout: Duration) -> Result<()> {
        Ok(())
    }

    /// Graceful teardown: stop I/O and release transport resources.
    /// Subsequent sends are silently dropped, pending receives drain.
    /// Idempotent; also invoked by endpoint `Drop` impls.
    fn shutdown(&self) {}
}

/// Order fan-out peers by observed health: fewest consecutive failures
/// first, then lowest RTT estimate (unknown RTT sorts last), then id for
/// determinism. Purely advisory — the deterministic sim-driven
/// coordinator paths do *not* use it (reordering sends would perturb the
/// byte-identical scenario traces); it serves latency-sensitive
/// replication fan-out over real sockets.
pub fn latency_ordered(t: &dyn Transport, peers: &[DeviceId]) -> Vec<DeviceId> {
    let mut out = peers.to_vec();
    out.sort_by_key(|&d| {
        let h = t.peer_health(d);
        (h.consecutive_failures, h.rtt.unwrap_or(Duration::MAX), d)
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A transport that only answers health questions.
    struct Healths(Vec<PeerHealth>);

    impl Transport for Healths {
        fn my_id(&self) -> DeviceId {
            0
        }
        fn send(&self, _to: DeviceId, _msg: Message) -> Result<()> {
            Ok(())
        }
        fn recv_timeout(&self, _timeout: Duration) -> Option<(DeviceId, Message)> {
            None
        }
        fn n_devices(&self) -> usize {
            self.0.len()
        }
        fn peer_health(&self, peer: DeviceId) -> PeerHealth {
            self.0[peer]
        }
    }

    #[test]
    fn default_surface_is_inert() {
        struct Bare;
        impl Transport for Bare {
            fn my_id(&self) -> DeviceId {
                0
            }
            fn send(&self, _to: DeviceId, _msg: Message) -> Result<()> {
                Ok(())
            }
            fn recv_timeout(&self, _timeout: Duration) -> Option<(DeviceId, Message)> {
                None
            }
            fn n_devices(&self) -> usize {
                1
            }
        }
        let b = Bare;
        assert_eq!(b.peer_health(0), PeerHealth::default());
        assert!(b.flush(Duration::from_secs(1)).is_ok());
        b.shutdown();
    }

    #[test]
    fn latency_ordered_prefers_healthy_then_fast_then_id() {
        let ms = Duration::from_millis;
        let t = Healths(vec![
            PeerHealth { rtt: Some(ms(9)), ..Default::default() },      // 0: healthy, slow
            PeerHealth { rtt: None, ..Default::default() },             // 1: healthy, unknown rtt
            PeerHealth { rtt: Some(ms(2)), ..Default::default() },      // 2: healthy, fast
            PeerHealth { consecutive_failures: 3, ..Default::default() }, // 3: failing
            PeerHealth { rtt: None, ..Default::default() },             // 4: ties with 1 → id order
        ]);
        assert_eq!(latency_ordered(&t, &[0, 1, 2, 3, 4]), vec![2, 0, 1, 4, 3]);
        // input subset + order independence
        assert_eq!(latency_ordered(&t, &[4, 3, 2]), vec![2, 4, 3]);
    }
}
